"""L1: Pallas kernels for the compute hot-spots (flash attention, fused
linear-cross-entropy) plus their pure-jnp oracles in ``ref``.

Everything here lowers with ``interpret=True`` so the emitted HLO runs on
any PJRT backend, including the Rust CPU client (see DESIGN.md §4 for the
TPU hardware-adaptation story).
"""

from . import attention, fused_ce, ref  # noqa: F401
