//! FLOPs model for transformer fine-tuning (feeds GPU compute times in the
//! iteration simulator).
//!
//! Standard accounting: a matmul of `[m,k]×[k,n]` is `2·m·k·n` FLOPs; the
//! backward pass costs 2× forward; activation checkpointing adds one extra
//! forward ("recompute") during backward. Attention adds the quadratic
//! `QKᵀ` and `PV` terms (causal → ×0.5).

use super::ModelConfig;

/// FLOPs for ONE transformer block's forward over a `[batch, context]`
/// micro-batch.
pub fn block_fwd_flops(m: &ModelConfig, batch: usize, context: usize) -> f64 {
    let tokens = (batch * context) as f64;
    let h = m.hidden as f64;
    let qo = (m.heads * m.head_dim) as f64;
    let kv = (m.kv_heads * m.head_dim) as f64;
    // projections: q, k, v, o
    let proj = 2.0 * tokens * h * (qo + 2.0 * kv + qo);
    // attention scores + weighted values, causal half
    let attn = 2.0 * 2.0 * (batch as f64) * (context as f64).powi(2) * qo * 0.5;
    // gated mlp: gate, up, down
    let mlp = 2.0 * tokens * 3.0 * h * m.ffn_hidden as f64;
    proj + attn + mlp
}

/// FLOPs for the embedding + LM head + loss over the micro-batch.
pub fn head_fwd_flops(m: &ModelConfig, batch: usize, context: usize) -> f64 {
    let tokens = (batch * context) as f64;
    2.0 * tokens * m.hidden as f64 * m.vocab as f64
}

/// Forward FLOPs for the whole model.
pub fn model_fwd_flops(m: &ModelConfig, batch: usize, context: usize) -> f64 {
    m.layers as f64 * block_fwd_flops(m, batch, context) + head_fwd_flops(m, batch, context)
}

/// Total training FLOPs per iteration per GPU, with activation
/// checkpointing (fwd + recompute-fwd + 2×fwd backward = 4× fwd).
pub fn iteration_flops(m: &ModelConfig, batch: usize, context: usize, recompute: bool) -> f64 {
    let fwd = model_fwd_flops(m, batch, context);
    if recompute {
        4.0 * fwd
    } else {
        3.0 * fwd
    }
}

/// Per-block compute work during each phase (drives the streaming
/// scheduler): forward is 1× block-fwd; backward with recompute is 3×.
pub fn block_bwd_flops(m: &ModelConfig, batch: usize, context: usize, recompute: bool) -> f64 {
    let f = block_fwd_flops(m, batch, context);
    if recompute {
        3.0 * f
    } else {
        2.0 * f
    }
}

/// Sanity approximation `6·P·tokens` (no attention term) — used in tests
/// to keep the detailed model honest.
pub fn six_p_tokens(m: &ModelConfig, batch: usize, context: usize) -> f64 {
    6.0 * m.params() as f64 * (batch * context) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets::{mistral_nemo_12b, qwen25_7b};

    #[test]
    fn close_to_six_p_tokens_at_short_context() {
        // At short context the attention term is small: 4×fwd ≈ (8/6)·6PT.
        // (4×fwd ≈ 8·P·T with recompute; compare against the 6PT baseline.)
        let m = qwen25_7b();
        let detailed = iteration_flops(&m, 1, 512, false); // 3×fwd ≈ 6PT
        let approx = six_p_tokens(&m, 1, 512);
        let ratio = detailed / approx;
        assert!((0.85..1.15).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn attention_term_grows_quadratically() {
        let m = mistral_nemo_12b();
        let f4k = block_fwd_flops(&m, 1, 4096);
        let f32k = block_fwd_flops(&m, 1, 32768);
        // linear part ×8; quadratic attention pushes beyond 8×
        assert!(f32k / f4k > 8.0);
        assert!(f32k / f4k < 30.0);
    }

    #[test]
    fn recompute_adds_one_forward() {
        let m = qwen25_7b();
        let with = iteration_flops(&m, 2, 1024, true);
        let without = iteration_flops(&m, 2, 1024, false);
        let fwd = model_fwd_flops(&m, 2, 1024);
        assert!((with - without - fwd).abs() / fwd < 1e-12);
    }

    #[test]
    fn bwd_block_is_3x_fwd_with_recompute() {
        let m = qwen25_7b();
        let f = block_fwd_flops(&m, 1, 2048);
        assert!((block_bwd_flops(&m, 1, 2048, true) - 3.0 * f).abs() < 1e-3);
        assert!((block_bwd_flops(&m, 1, 2048, false) - 2.0 * f).abs() < 1e-3);
    }

    #[test]
    fn flops_scale_linearly_with_batch() {
        let m = qwen25_7b();
        let f1 = iteration_flops(&m, 1, 4096, true);
        let f4 = iteration_flops(&m, 4, 4096, true);
        assert!((f4 / f1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn blocks_sum_close_to_model_total() {
        let m = mistral_nemo_12b();
        let blocks = m.layers as f64 * block_fwd_flops(&m, 2, 4096);
        let total = model_fwd_flops(&m, 2, 4096);
        assert!(blocks < total);
        assert!(blocks / total > 0.8, "head shouldn't dominate at 4k");
    }
}
