//! Fault-injection acceptance pins and invariants (ISSUE 7).
//!
//! * The pinned 100-job mixed-context trace × the derived pinned fault
//!   trace (link degrade + CXL AIC hot-remove + restore inside the
//!   busiest AIC window): `evacuate` strictly beats `fail-stop` on both
//!   completed jobs and goodput; `fail-stop` demonstrably kills work.
//! * Zero-fault bitwise no-op: with an empty fault trace every recovery
//!   policy reproduces the fault-free simulator digest exactly.
//! * Fault-trace JSON round-trips with a verified digest; replays are
//!   digest-identical across reruns and `--threads`.
//! * proptest_lite invariants under random generated fault traces:
//!   conservation (completed + rejected + failed == arrived, nothing
//!   unfinished), occupancy ≤ the *degraded* capacity in every sample,
//!   zero residual occupancy after the drain, and bit-stable reruns
//!   across seeds × recovery policies × thread counts.

use cxlfine::fleet::{
    faults, mixed_trace_with_xl, pinned_faults_from_baseline, scheduler, simulate_fleet,
    simulate_fleet_faulted, Degradation, FaultGen, FaultKind, FaultTrace, FleetResult, FleetTrace,
    JobStatus, TraceGen,
};
use cxlfine::topology::presets::{config_a, dev_tiny, with_dram_capacity};
use cxlfine::topology::SystemTopology;
use cxlfine::util::json::Json;
use cxlfine::util::units::{GIB, MIB};

/// The acceptance pin: on the pinned 100-job trace with the derived
/// pinned fault trace (≥ 1 AIC hot-remove mid-run, ≥ 1 link degrade),
/// `evacuate` strictly beats `fail-stop` on completed jobs AND goodput,
/// and every replay is digest-identical across thread counts.
#[test]
fn pinned_faults_evacuate_strictly_beats_fail_stop() {
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let trace = mixed_trace_with_xl(&topo, 1007, 92, 8);
    assert_eq!(trace.jobs.len(), 100, "the XL cell must exist at 128 GiB DRAM");
    let policy = scheduler::by_name("placement-aware").unwrap();
    let baseline = simulate_fleet(&topo, &trace, &policy, 4);
    assert_eq!(baseline.completed(), 100);

    let fault_trace = pinned_faults_from_baseline(&topo, &baseline);
    fault_trace.validate(&topo).unwrap();
    assert!(
        fault_trace.events.iter().any(|e| matches!(e.kind, FaultKind::NodeOffline { .. })),
        "the pinned trace must hot-remove an AIC"
    );
    assert!(
        fault_trace.events.iter().any(|e| matches!(e.kind, FaultKind::LinkDegrade { .. })),
        "the pinned trace must degrade a link"
    );
    // The derived trace survives a JSON round trip with verified digest.
    let text = fault_trace.to_json().to_string_pretty();
    let back = FaultTrace::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, fault_trace);
    assert_eq!(back.digest(), fault_trace.digest());

    let run = |name: &str, threads: usize| {
        let recovery = faults::by_name(name).unwrap();
        simulate_fleet_faulted(&topo, &trace, &policy, &fault_trace, &recovery, threads)
    };
    let fs = run("fail-stop", 4);
    let cr = run("checkpoint-restart", 4);
    let ev = run("evacuate", 4);

    // The hot-remove landed on resident regions: fail-stop kills work.
    assert!(fs.failed() >= 1, "the AIC hot-remove must hit at least one job");
    assert!(fs.lost_tokens() > 0);
    for r in fs.records.iter().filter(|r| r.status == JobStatus::Failed) {
        let reason = r.reason.as_deref().unwrap_or_default();
        assert!(!reason.is_empty(), "job {}: a kill must carry its reason", r.id);
    }

    // The strict acceptance beats.
    assert!(
        ev.completed() > fs.completed(),
        "evacuate must complete strictly more jobs than fail-stop: {} vs {}",
        ev.completed(),
        fs.completed()
    );
    assert!(
        ev.goodput_tokens_per_sec() > fs.goodput_tokens_per_sec(),
        "evacuate must strictly beat fail-stop on goodput: {:.1} vs {:.1} tok/s",
        ev.goodput_tokens_per_sec(),
        fs.goodput_tokens_per_sec()
    );
    // The graded ladder the bench gates on.
    assert!(ev.completed() >= cr.completed(), "evacuate ≥ checkpoint-restart");
    assert!(cr.completed() >= fs.completed(), "checkpoint-restart ≥ fail-stop");
    assert!(ev.interruptions() >= 1, "the fault must interrupt someone");

    // Conservation under faults: every job reaches a terminal state.
    for res in [&fs, &cr, &ev] {
        assert_eq!(
            res.completed() + res.rejected() + res.failed(),
            100,
            "{}: conservation",
            res.recovery
        );
        assert_eq!(res.unfinished(), 0, "{}", res.recovery);
        assert_eq!(res.n_faults, 3, "{}", res.recovery);
    }

    // Deterministic replay: digest-identical across reruns and threads.
    assert_eq!(run("evacuate", 1).digest(), ev.digest());
    assert_eq!(run("fail-stop", 1).digest(), fs.digest());
    assert_eq!(run("checkpoint-restart", 1).digest(), cr.digest());
}

/// Zero-fault runs are a bitwise no-op: every recovery policy and thread
/// count reproduces the fault-free digest exactly.
#[test]
fn empty_fault_trace_is_a_bitwise_noop() {
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let trace = mixed_trace_with_xl(&topo, 1007, 10, 0);
    let empty = FaultTrace::empty();
    for policy in scheduler::registry() {
        let base = simulate_fleet(&topo, &trace, &policy, 4);
        for recovery in faults::registry() {
            for threads in [1, 4] {
                let res =
                    simulate_fleet_faulted(&topo, &trace, &policy, &empty, &recovery, threads);
                assert_eq!(
                    res.digest(),
                    base.digest(),
                    "{} × {} × {threads} threads",
                    policy.name(),
                    recovery.name()
                );
            }
        }
    }
}

/// dev-tiny shrunk so tiny-2m jobs contend for both memory and GPU slots.
fn tight_topo() -> SystemTopology {
    let mut t = dev_tiny();
    t.mem_nodes[0].capacity = 48 * MIB;
    t.mem_nodes[1].capacity = 16 * MIB;
    t.mem_nodes[2].capacity = 16 * MIB;
    t.validate();
    t
}

fn tiny_trace(seed: u64, n_jobs: usize) -> FleetTrace {
    let mut g = TraceGen::mixed(seed, n_jobs);
    g.models = vec!["tiny-2m".into()];
    g.contexts = vec![256, 1024, 16384];
    g.batches = vec![1, 2, 8];
    g.schedules = vec!["zero-offload".into(), "lora:4".into()];
    g.engines = vec!["cxl-aware+striping".into(), "baseline-dram".into()];
    g.mean_interarrival_s = 0.001;
    g.min_iterations = 1;
    g.max_iterations = 3;
    g.generate()
}

/// Replay the fault prefix against the pristine topology to get the
/// effective capacity vector at sample time `t_s` (events at exactly
/// `t_s` are applied: the simulator samples after applying the fault).
fn caps_at(topo: &SystemTopology, fault_trace: &FaultTrace, t_s: f64) -> Vec<u64> {
    let mut deg = Degradation::pristine(topo);
    for e in &fault_trace.events {
        if e.t_s <= t_s {
            deg.apply(&e.kind);
        }
    }
    deg.effective_caps(topo)
}

fn check_faulted_invariants(
    res: &FleetResult,
    topo: &SystemTopology,
    fault_trace: &FaultTrace,
    arrived: usize,
) -> Result<(), String> {
    if res.arrived() != arrived {
        return Err(format!("arrived {} != {arrived}", res.arrived()));
    }
    // Conservation: every arrived job is terminal after the drain.
    if res.completed() + res.rejected() + res.failed() != arrived || res.unfinished() != 0 {
        return Err(format!(
            "conservation broken: {} completed + {} rejected + {} failed != {arrived} \
             ({} unfinished)",
            res.completed(),
            res.rejected(),
            res.failed(),
            res.unfinished()
        ));
    }
    // Occupancy never exceeds the *degraded* capacity of any node, in any
    // sample; GPU and queue bounds as in the fault-free suite.
    for s in &res.samples {
        let caps = caps_at(topo, fault_trace, s.t_s);
        for (n, &u) in s.used.iter().enumerate() {
            if u > caps[n] {
                return Err(format!(
                    "node {n} over degraded capacity at t={}: {u} > {}",
                    s.t_s, caps[n]
                ));
            }
        }
        if s.running > topo.gpus.len() {
            return Err(format!("{} running on {} GPUs", s.running, topo.gpus.len()));
        }
        if s.queue_len > arrived {
            return Err("queue longer than the population".into());
        }
    }
    // Everything was released: zero residual occupancy after the drain.
    if let Some(last) = res.samples.last() {
        if last.used.iter().any(|&u| u > 0) {
            return Err(format!("residual occupancy after drain at t={}", last.t_s));
        }
    }
    // Work accounting: nothing useful exceeds what was processed.
    for r in &res.records {
        if r.status == JobStatus::Completed && r.processed_tokens < r.total_tokens {
            return Err(format!(
                "job {}: completed with {} processed < {} total tokens",
                r.id, r.processed_tokens, r.total_tokens
            ));
        }
        if r.status == JobStatus::Failed && r.reason.is_none() {
            return Err(format!("job {}: failed without a reason", r.id));
        }
    }
    Ok(())
}

#[test]
fn faulted_fleet_invariants_hold_over_random_traces() {
    use cxlfine::util::proptest_lite::*;
    let topo = tight_topo();
    let cases = PairOf(U64Range { lo: 1, hi: 1 << 40 }, UsizeRange { lo: 4, hi: 16 });
    forall("faulted-fleet-invariants", 113, 4, &cases, |(seed, n_jobs)| {
        let trace = tiny_trace(*seed, *n_jobs);
        // Tiny-model jobs drain in (sub)seconds: a short horizon lands
        // most faults inside the busy window.
        let fault_trace = FaultGen::new(seed ^ 0x9e3779b97f4a7c15, 5, 0.5).generate(&topo);
        let policy = scheduler::by_name("placement-aware").unwrap();
        for recovery in faults::registry() {
            let res = simulate_fleet_faulted(&topo, &trace, &policy, &fault_trace, &recovery, 2);
            check_faulted_invariants(&res, &topo, &fault_trace, *n_jobs)
                .map_err(|e| format!("{} seed {seed}: {e}", recovery.name()))?;
        }
        Ok(())
    });
}

#[test]
fn faulted_reruns_are_bit_stable_across_seeds_policies_and_threads() {
    let topo = tight_topo();
    for seed in [3u64, 19] {
        let trace = tiny_trace(seed, 12);
        let fault_trace = FaultGen::new(seed + 1, 5, 0.5).generate(&topo);
        let policy = scheduler::by_name("placement-aware").unwrap();
        for recovery in faults::registry() {
            let a = simulate_fleet_faulted(&topo, &trace, &policy, &fault_trace, &recovery, 1);
            let b = simulate_fleet_faulted(&topo, &trace, &policy, &fault_trace, &recovery, 4);
            assert_eq!(
                a.digest(),
                b.digest(),
                "{} seed {seed}: digests must survive rerun + thread change",
                recovery.name()
            );
            assert_eq!(a.n_events, b.n_events);
            assert_eq!(a.recovery, recovery.name());
        }
    }
}
