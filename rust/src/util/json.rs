//! Minimal JSON value type, serializer and parser.
//!
//! `serde`/`serde_json` are not in the offline vendor set, so the crate
//! carries its own implementation. It supports the full JSON grammar
//! (objects, arrays, strings with escapes incl. `\uXXXX`, numbers, bools,
//! null) — enough for artifact manifests, bench-result files and configs.
//! Object key order is preserved (insertion order) so emitted files diff
//! cleanly.

use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object: (key, value) pairs plus an index for O(log n) lookup.
    Obj(JsonObj),
}

/// Insertion-ordered JSON object.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JsonObj {
    pairs: Vec<(String, Json)>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&mut self, key: impl Into<String>, val: impl Into<Json>) -> &mut Self {
        let key = key.into();
        let val = val.into();
        if let Some(slot) = self.pairs.iter_mut().find(|(k, _)| *k == key) {
            slot.1 = val;
        } else {
            self.pairs.push((key, val));
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Json)> {
        self.pairs.iter().map(|(k, v)| (k.as_str(), v))
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}
impl From<JsonObj> for Json {
    fn from(o: JsonObj) -> Self {
        Json::Obj(o)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(v: &[T]) -> Self {
        Json::Arr(v.iter().cloned().map(Into::into).collect())
    }
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as u64)
            } else {
                None
            }
        })
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["a"]["b"]`-style path lookup.
    pub fn path(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur.as_obj()?.get(key)?;
        }
        Some(cur)
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns an error with byte offset on failure.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null like most tolerant writers.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.set(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(obj));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Handle UTF-16 surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                        .ok_or_else(|| self.err("bad surrogate pair"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(ch);
                            continue; // pos already advanced past hex digits
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 encoded char.
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| self.err(format!("bad number {text:?}: {e}")))
    }
}

/// Convenience: build a `Json::Obj` inline.
#[macro_export]
macro_rules! jobj {
    ($($key:expr => $val:expr),* $(,)?) => {{
        let mut o = $crate::util::json::JsonObj::new();
        $( o.set($key, $val); )*
        $crate::util::json::Json::Obj(o)
    }};
}

/// Convenience: build a `Json::Arr` inline.
#[macro_export]
macro_rules! jarr {
    ($($val:expr),* $(,)?) => {
        $crate::util::json::Json::Arr(vec![ $( $val.into() ),* ])
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let doc = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_u64(), Some(1));
        assert_eq!(v.path(&["c", "d"]).unwrap().as_f64(), Some(-2500.0));
        let arr = v.path(&["b"]).unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_bool(), Some(true));
        assert_eq!(arr[1], Json::Null);
        assert_eq!(arr[2].as_str(), Some("x\ny"));
        // Re-parse the compact serialization and compare.
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""éA😀""#).unwrap();
        assert_eq!(v.as_str(), Some("éA😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn macros() {
        let v = jobj! {
            "name" => "fig5",
            "points" => jarr![1u64, 2u64, 3u64],
        };
        assert_eq!(v.path(&["name"]).unwrap().as_str(), Some("fig5"));
        assert_eq!(v.path(&["points"]).unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn pretty_parses_back() {
        let v = jobj! {"a" => jarr![Json::Null, Json::Bool(false)], "b" => 1.25};
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn set_overwrites() {
        let mut o = JsonObj::new();
        o.set("k", 1u64);
        o.set("k", 2u64);
        assert_eq!(o.len(), 1);
        assert_eq!(o.get("k").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn nested_deep() {
        let mut doc = String::new();
        for _ in 0..50 {
            doc.push('[');
        }
        doc.push('1');
        for _ in 0..50 {
            doc.push(']');
        }
        assert!(Json::parse(&doc).is_ok());
    }

    use std::collections::BTreeMap;
    #[test]
    fn to_sorted_map() {
        let v = Json::parse(r#"{"b": 1, "a": 2}"#).unwrap();
        let m: BTreeMap<&str, f64> = v
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, v)| (k, v.as_f64().unwrap()))
            .collect();
        assert_eq!(m.keys().copied().collect::<Vec<_>>(), vec!["a", "b"]);
    }
}
