//! End-to-end integration: load the AOT artifacts through PJRT, run the
//! functional Figure-1 training loop, and check real learning happens.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use cxlfine::runtime::{Arg, HostTensor, HostTensorI32, Runtime};
use cxlfine::train::{batch_shape, Trainer, TrainerCfg};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::env::var("CXLFINE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let p = std::path::PathBuf::from(dir);
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts at {} — run `make artifacts`", p.display());
        None
    }
}

fn load_runtime() -> Option<Runtime> {
    let dir = artifacts_dir()?;
    Some(Runtime::load(dir).expect("loading artifacts"))
}

#[test]
fn runtime_loads_all_entries() {
    let Some(rt) = load_runtime() else { return };
    for name in ["embed_fwd", "block_fwd", "block_bwd", "head_loss", "embed_bwd"] {
        assert!(rt.manifest().entry(name).is_ok(), "missing entry {name}");
    }
    assert_eq!(rt.platform(), "cpu");
}

#[test]
fn embed_fwd_gathers_rows() {
    let Some(rt) = load_runtime() else { return };
    let e = rt.manifest().entry("embed_fwd").unwrap();
    let (b, c) = (e.inputs[0].shape[0], e.inputs[0].shape[1]);
    let (v, h) = (e.inputs[1].shape[0], e.inputs[1].shape[1]);
    // emb[i][j] = i so output[b][c][j] == ids[b][c]
    let emb: Vec<f32> = (0..v).flat_map(|i| std::iter::repeat(i as f32).take(h)).collect();
    let ids: Vec<i32> = (0..(b * c) as i32).map(|i| i % v as i32).collect();
    let out = rt
        .exec(
            "embed_fwd",
            &[
                Arg::I32(HostTensorI32::new(ids.clone(), vec![b, c])),
                Arg::F32(HostTensor::new(emb, vec![v, h])),
            ],
        )
        .unwrap()
        .remove(0);
    assert_eq!(out.shape, vec![b, c, h]);
    for (t, &id) in ids.iter().enumerate() {
        assert_eq!(out.data[t * h], id as f32, "row {t}");
    }
}

#[test]
fn block_fwd_shape_checks_are_enforced() {
    let Some(rt) = load_runtime() else { return };
    // wrong arity
    let err = rt.exec("block_fwd", &[]).unwrap_err();
    assert!(err.to_string().contains("expected"));
}

#[test]
fn head_loss_of_uniform_logits_is_log_vocab() {
    let Some(rt) = load_runtime() else { return };
    let e = rt.manifest().entry("head_loss").unwrap();
    let xs = &e.inputs[0].shape; // [B, C, H]
    let (v, h) = (e.inputs[2].shape[0], e.inputs[2].shape[1]);
    // zero hidden states → all logits 0 → uniform softmax → loss = ln V
    let x = HostTensor::zeros(xs);
    let lnf = HostTensor::new(vec![1.0; h], vec![h]);
    let emb = HostTensor::new(vec![0.01; v * h], vec![v, h]);
    let labels = HostTensorI32::new(vec![0; xs[0] * xs[1]], vec![xs[0], xs[1]]);
    let out = rt
        .exec(
            "head_loss",
            &[Arg::F32(x), Arg::F32(lnf), Arg::F32(emb), Arg::I32(labels)],
        )
        .unwrap();
    let loss = out[0].data[0];
    let want = (v as f32).ln();
    assert!(
        (loss - want).abs() < 1e-3,
        "uniform loss {loss} != ln({v}) = {want}"
    );
}

#[test]
fn training_reduces_loss() {
    let Some(rt) = load_runtime() else { return };
    let (b, c) = batch_shape(&rt).unwrap();
    let cfg = TrainerCfg {
        batch: b,
        context: c,
        steps: 30,
        log_every: 10,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&rt, cfg).expect("trainer");
    let logs = trainer.train().expect("training");
    let first: f64 = logs[..5].iter().map(|l| l.loss).sum::<f64>() / 5.0;
    let last: f64 = logs[logs.len() - 5..].iter().map(|l| l.loss).sum::<f64>() / 5.0;
    // 30 steps on the synthetic bigram task must cut loss substantially
    assert!(
        last < first * 0.8,
        "no learning: first≈{first:.3} last≈{last:.3}"
    );
    // checkpoint arena holds L blocks of [B, C, H] f32
    let layers = rt.manifest().meta_usize("layers").unwrap();
    let hidden = rt.manifest().meta_usize("hidden").unwrap();
    let expect = (layers * b * c * hidden * 4) as u64;
    assert_eq!(logs[0].checkpoint_bytes, expect);
}

#[test]
fn streamed_blocks_match_monolithic_loss() {
    // The per-block streamed fwd (what the trainer does) must equal the
    // whole-model loss computed in one shot — validating that block
    // streaming + checkpointing changes nothing numerically.
    let Some(rt) = load_runtime() else { return };
    let (b, c) = batch_shape(&rt).unwrap();
    let layers = rt.manifest().meta_usize("layers").unwrap();
    let cfg = TrainerCfg {
        batch: b,
        context: c,
        steps: 1,
        ..Default::default()
    };
    let mut t1 = Trainer::new(&rt, cfg.clone()).unwrap();
    let mut t2 = Trainer::new(&rt, cfg).unwrap();
    // same seed → same data and init → identical first-step loss
    let (l1, _) = t1.step().unwrap();
    let (l2, _) = t2.step().unwrap();
    assert_eq!(l1.to_bits(), l2.to_bits(), "trainer must be deterministic");
    assert!(l1 > 0.0 && l1 < 2.0 * (layers as f64 + (2048f64).ln()));
}
