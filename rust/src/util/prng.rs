//! Deterministic pseudo-random number generation.
//!
//! No `rand` crate is available in the offline vendor set, so this module
//! provides the two generators the rest of the crate needs:
//!
//! * [`SplitMix64`] — stateless-ish stream used for seeding,
//! * [`Xoshiro256pp`] — the general-purpose generator (xoshiro256++ 1.0,
//!   Blackman & Vigna, public domain reference implementation).
//!
//! Everything in the crate that consumes randomness takes an explicit
//! generator so simulations, tests and property checks are reproducible
//! from a single `u64` seed.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` using Lemire's rejection method.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        // Lemire multiply-shift with rejection to remove modulo bias.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Exponentially distributed sample with the given `mean`, via the
    /// inverse-CDF transform `-mean · ln(1 − U)` — the inter-arrival time
    /// of a Poisson process with rate `1/mean`. One uniform draw per call,
    /// so traces built from this are reproducible from the seed alone.
    pub fn exp_mean(&mut self, mean: f64) -> f64 {
        assert!(mean > 0.0 && mean.is_finite(), "exp_mean needs mean > 0");
        // 1 − U ∈ (0, 1], so ln never sees 0 and the sample is finite.
        -mean * (1.0 - self.next_f64()).ln()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// `true` with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Zipf-distributed rank in `[1, n]` with exponent `s ≥ 0`
    /// (`P(k) ∝ k^{-s}`; `s = 0` degenerates to uniform). Inverse-CDF by
    /// linear scan over the normalized weights — O(n) per call, which is
    /// fine for the trace generators' rank spaces (≤ a few thousand) and
    /// buys the property the crate's determinism contract needs: exactly
    /// **one** uniform draw per call, so the stream position after a call
    /// is seed-determined and the same seed yields a byte-identical
    /// sample sequence.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        assert!(n >= 1, "zipf needs a non-empty rank space");
        assert!(s >= 0.0 && s.is_finite(), "zipf exponent must be finite and >= 0");
        let z: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let u = self.next_f64() * z;
        let mut acc = 0.0;
        for k in 1..=n {
            acc += (k as f64).powf(-s);
            if u < acc {
                return k;
            }
        }
        n
    }

    /// Bounded-Pareto sample in `[lo, hi]` with tail index `alpha > 0` —
    /// the heavy-tailed length distribution serving traces are drawn from
    /// (most requests short, a fat tail of very long ones). Inverse-CDF
    /// transform, one uniform draw per call:
    /// `x = lo / (1 − U·(1 − (lo/hi)^α))^(1/α)`.
    pub fn bounded_pareto(&mut self, lo: f64, hi: f64, alpha: f64) -> f64 {
        assert!(
            lo > 0.0 && hi >= lo && hi.is_finite(),
            "bounded_pareto needs 0 < lo <= hi < inf"
        );
        assert!(alpha > 0.0 && alpha.is_finite(), "bounded_pareto needs alpha > 0");
        let u = self.next_f64();
        let r = (lo / hi).powf(alpha);
        let x = lo / (1.0 - u * (1.0 - r)).powf(1.0 / alpha);
        // Float roundoff can land a hair past hi; the support is closed.
        x.min(hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public splitmix64.c.
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn deterministic_across_clones() {
        let mut a = Xoshiro256pp::seeded(42);
        let mut b = a.clone();
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn unit_interval_bounds() {
        let mut rng = Xoshiro256pp::seeded(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Xoshiro256pp::seeded(9);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            // expectation 10_000, allow ±6%
            assert!((9_400..=10_600).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn below_never_exceeds_bound() {
        let mut rng = Xoshiro256pp::seeded(11);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(rng.below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut rng = Xoshiro256pp::seeded(13);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..1000 {
            match rng.range_u64(3, 5) {
                3 => saw_lo = true,
                5 => saw_hi = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn exp_mean_moments_and_support() {
        let mut rng = Xoshiro256pp::seeded(29);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.exp_mean(3.0);
            assert!(x >= 0.0 && x.is_finite(), "sample {x}");
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn exp_mean_is_deterministic() {
        let mut a = Xoshiro256pp::seeded(31);
        let mut b = Xoshiro256pp::seeded(31);
        for _ in 0..100 {
            assert_eq!(a.exp_mean(7.0).to_bits(), b.exp_mean(7.0).to_bits());
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256pp::seeded(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn zipf_pinned_values_and_determinism() {
        // Pinned reference stream: any change to the sampler's arithmetic
        // or draw count shows up here before it silently reshapes every
        // generated serving trace.
        let mut rng = Xoshiro256pp::seeded(4242);
        let v: Vec<u64> = (0..8).map(|_| rng.zipf(64, 1.1)).collect();
        assert_eq!(v, vec![2, 1, 12, 1, 1, 2, 11, 16]);
        let mut a = Xoshiro256pp::seeded(97);
        let mut b = Xoshiro256pp::seeded(97);
        for _ in 0..200 {
            assert_eq!(a.zipf(1000, 0.9), b.zipf(1000, 0.9));
        }
    }

    #[test]
    fn zipf_support_and_skew() {
        let mut rng = Xoshiro256pp::seeded(51);
        let n = 50_000;
        let mut ones = 0usize;
        for _ in 0..n {
            let k = rng.zipf(100, 1.0);
            assert!((1..=100).contains(&k));
            if k == 1 {
                ones += 1;
            }
        }
        // P(1) = 1/H_100 ≈ 0.193 — rank 1 must dominate visibly.
        let frac = ones as f64 / n as f64;
        assert!((0.17..=0.22).contains(&frac), "P(rank 1) = {frac}");
        // s = 0 degenerates to uniform: rank 1 near 1%.
        let mut uni = 0usize;
        for _ in 0..n {
            if rng.zipf(100, 0.0) == 1 {
                uni += 1;
            }
        }
        let frac = uni as f64 / n as f64;
        assert!((0.005..=0.016).contains(&frac), "uniform P(rank 1) = {frac}");
    }

    #[test]
    fn bounded_pareto_pinned_values_and_determinism() {
        let mut rng = Xoshiro256pp::seeded(4242);
        let v: Vec<u64> = (0..6).map(|_| rng.bounded_pareto(64.0, 8192.0, 1.2) as u64).collect();
        assert_eq!(v, vec![92, 73, 174, 75, 66, 85]);
        let mut a = Xoshiro256pp::seeded(11);
        let mut b = Xoshiro256pp::seeded(11);
        for _ in 0..200 {
            assert_eq!(
                a.bounded_pareto(16.0, 1024.0, 1.5).to_bits(),
                b.bounded_pareto(16.0, 1024.0, 1.5).to_bits()
            );
        }
    }

    #[test]
    fn bounded_pareto_support_and_tail() {
        let mut rng = Xoshiro256pp::seeded(73);
        let n = 50_000;
        let (mut below_2lo, mut above_half) = (0usize, 0usize);
        for _ in 0..n {
            let x = rng.bounded_pareto(100.0, 10_000.0, 1.1);
            assert!((100.0..=10_000.0).contains(&x), "sample {x}");
            if x < 200.0 {
                below_2lo += 1;
            }
            if x > 5_000.0 {
                above_half += 1;
            }
        }
        // Mass concentrates near lo (analytic P(x < 2·lo) ≈ 0.54 for
        // α = 1.1) but the bounded tail is fat enough to matter.
        assert!(below_2lo as f64 / n as f64 > 0.45, "head mass {below_2lo}");
        assert!(above_half as f64 / n as f64 > 0.005, "tail mass {above_half}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256pp::seeded(23);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
