//! Execution-trace recording for simulated iterations, exported as Chrome
//! trace JSON (`chrome://tracing` / Perfetto). Invaluable for *seeing* the
//! overlap structure: parameter prefetch lanes, checkpoint offloads,
//! per-GPU compute, the STEP tail — and how contention stretches them.

use crate::jobj;
use crate::util::digest::Fnv64;
use crate::util::json::{Json, JsonObj};

/// One completed span.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    /// Human label, e.g. "fwd-param-load b=3".
    pub name: String,
    /// Track (Chrome trace "tid"), e.g. "gpu0/h2d".
    pub lane: String,
    pub start_s: f64,
    pub end_s: f64,
}

impl Span {
    pub fn duration(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// Collects spans during a simulation run.
#[derive(Clone, Debug, Default)]
pub struct TraceRecorder {
    spans: Vec<Span>,
}

impl TraceRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, name: impl Into<String>, lane: impl Into<String>, start_s: f64, end_s: f64) {
        let (name, lane) = (name.into(), lane.into());
        debug_assert!(end_s >= start_s, "span {name} ends before it starts");
        self.spans.push(Span {
            name,
            lane,
            start_s,
            end_s,
        });
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Bit-exact FNV-1a digest of the full span sequence (names, lanes,
    /// and `to_bits` timestamps, in recording order). This is the
    /// golden-trace lock of DESIGN.md §7: two simulator builds emit the
    /// same digest iff their event sequences are byte-identical —
    /// `rust/tests/golden_trace.rs` uses it to pin Fig. 6/7/9 cells across
    /// the slab/heap DES refactor and across debug/release profiles.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_u64(self.spans.len() as u64);
        for s in &self.spans {
            h.write_str(&s.name);
            h.write_str(&s.lane);
            h.write_f64(s.start_s);
            h.write_f64(s.end_s);
        }
        h.finish()
    }

    /// Total span time per lane (utilization summary).
    pub fn lane_busy(&self) -> Vec<(String, f64)> {
        let mut acc: std::collections::BTreeMap<String, f64> = Default::default();
        for s in &self.spans {
            *acc.entry(s.lane.clone()).or_insert(0.0) += s.duration();
        }
        acc.into_iter().collect()
    }

    /// Chrome trace event format (JSON array of "X" complete events;
    /// timestamps in microseconds as the format requires).
    pub fn to_chrome_trace(&self) -> Json {
        let mut events = Vec::with_capacity(self.spans.len());
        // stable lane ordering → stable tids
        let mut lanes: Vec<&str> = self.spans.iter().map(|s| s.lane.as_str()).collect();
        lanes.sort_unstable();
        lanes.dedup();
        let tid_of = |lane: &str| lanes.binary_search(&lane).unwrap() as u64;
        for s in &self.spans {
            let mut o = JsonObj::new();
            o.set("name", s.name.as_str());
            o.set("ph", "X");
            o.set("ts", s.start_s * 1e6);
            o.set("dur", s.duration() * 1e6);
            o.set("pid", 0u64);
            o.set("tid", tid_of(&s.lane));
            events.push(Json::Obj(o));
        }
        // thread-name metadata so lanes are labeled in the viewer
        for lane in &lanes {
            let mut o = JsonObj::new();
            o.set("name", "thread_name");
            o.set("ph", "M");
            o.set("pid", 0u64);
            o.set("tid", tid_of(lane));
            o.set("args", jobj! {"name" => *lane});
            events.push(Json::Obj(o));
        }
        Json::Arr(events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut tr = TraceRecorder::new();
        tr.record("load b=0", "gpu0/h2d", 0.0, 1.0);
        tr.record("load b=1", "gpu0/h2d", 1.0, 2.5);
        tr.record("fwd b=0", "gpu0/compute", 1.0, 2.0);
        assert_eq!(tr.spans().len(), 3);
        let busy = tr.lane_busy();
        assert_eq!(busy.len(), 2);
        assert_eq!(busy[0].0, "gpu0/compute");
        assert!((busy[0].1 - 1.0).abs() < 1e-12);
        assert!((busy[1].1 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn chrome_trace_shape() {
        let mut tr = TraceRecorder::new();
        tr.record("a", "lane0", 0.5, 1.5);
        tr.record("b", "lane1", 0.0, 0.25);
        let j = tr.to_chrome_trace();
        let arr = j.as_arr().unwrap();
        // 2 spans + 2 thread_name metadata
        assert_eq!(arr.len(), 4);
        let first = &arr[0];
        assert_eq!(first.path(&["ph"]).unwrap().as_str(), Some("X"));
        assert_eq!(first.path(&["ts"]).unwrap().as_f64(), Some(0.5e6));
        assert_eq!(first.path(&["dur"]).unwrap().as_f64(), Some(1e6));
        // parses back
        let text = j.to_string_pretty();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn digest_is_stable_and_order_sensitive() {
        let mut a = TraceRecorder::new();
        a.record("x", "lane", 0.0, 1.0);
        a.record("y", "lane", 1.0, 2.0);
        let mut b = TraceRecorder::new();
        b.record("x", "lane", 0.0, 1.0);
        b.record("y", "lane", 1.0, 2.0);
        assert_eq!(a.digest(), b.digest(), "same spans → same digest");
        let mut c = TraceRecorder::new();
        c.record("y", "lane", 1.0, 2.0);
        c.record("x", "lane", 0.0, 1.0);
        assert_ne!(a.digest(), c.digest(), "recording order is part of the lock");
    }

    #[test]
    fn digest_sees_last_ulp_timestamp_changes() {
        let t = 1.0f64;
        let t_next = f64::from_bits(t.to_bits() + 1);
        let mut a = TraceRecorder::new();
        a.record("x", "lane", 0.0, t);
        let mut b = TraceRecorder::new();
        b.record("x", "lane", 0.0, t_next);
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn digest_separates_name_and_lane() {
        // length-prefixing must keep ("ab","c") distinct from ("a","bc")
        let mut a = TraceRecorder::new();
        a.record("ab", "c", 0.0, 1.0);
        let mut b = TraceRecorder::new();
        b.record("a", "bc", 0.0, 1.0);
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn empty_recorder_behaves() {
        let tr = TraceRecorder::new();
        assert!(tr.is_empty());
        assert_eq!(tr.spans().len(), 0);
        assert!(tr.lane_busy().is_empty());
        let j = tr.to_chrome_trace();
        assert_eq!(j.as_arr().unwrap().len(), 0);
        // digest of the empty trace is the length-0 prefix, reproducibly
        assert_eq!(tr.digest(), TraceRecorder::new().digest());
    }

    #[test]
    fn zero_width_spans_are_legal() {
        let mut tr = TraceRecorder::new();
        tr.record("instant", "lane", 1.5, 1.5);
        assert_eq!(tr.spans()[0].duration(), 0.0);
        let busy = tr.lane_busy();
        assert_eq!(busy.len(), 1);
        assert_eq!(busy[0].1, 0.0);
    }

    #[test]
    fn lanes_get_distinct_tids() {
        let mut tr = TraceRecorder::new();
        tr.record("a", "z-lane", 0.0, 1.0);
        tr.record("b", "a-lane", 0.0, 1.0);
        let j = tr.to_chrome_trace();
        let arr = j.as_arr().unwrap();
        let tids: std::collections::HashSet<u64> = arr[..2]
            .iter()
            .map(|e| e.path(&["tid"]).unwrap().as_u64().unwrap())
            .collect();
        assert_eq!(tids.len(), 2);
    }
}
