//! Table I: breakdown of system-memory components during long-context CPU
//! offloading.
//!
//! | Component                 | Precision | Bytes                  |
//! |---------------------------|-----------|------------------------|
//! | Model parameters          | bf16      | 2·P                    |
//! | Gradients                 | bf16      | 2·P                    |
//! | Checkpointed activations  | bf16      | 2·(N_g·B·C·L·H)        |
//! | Model parameters (master) | fp32      | 4·P                    |
//! | Gradients (accum)         | fp32      | 4·P                    |
//! | Optimizer states (Adam)   | fp32      | 8·P                    |

use super::ModelConfig;
use crate::mem::TensorClass;

/// A fine-tuning workload shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Workload {
    /// Number of GPUs (`N_g`).
    pub n_gpus: usize,
    /// Per-GPU micro-batch (`B`).
    pub batch: usize,
    /// Context length in tokens (`C`).
    pub context: usize,
}

impl Workload {
    pub fn new(n_gpus: usize, batch: usize, context: usize) -> Self {
        assert!(n_gpus > 0 && batch > 0 && context > 0);
        Self {
            n_gpus,
            batch,
            context,
        }
    }

    /// Tokens processed per iteration across all GPUs.
    pub fn tokens_per_iter(&self) -> u64 {
        (self.n_gpus * self.batch * self.context) as u64
    }
}

/// Byte sizes of each Table-I component.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Footprint {
    pub params_bf16: u64,
    pub grads_bf16: u64,
    pub activations_bf16: u64,
    pub params_fp32: u64,
    pub grads_fp32: u64,
    pub optimizer_fp32: u64,
}

impl Footprint {
    /// Apply the Table-I formulas.
    pub fn compute(model: &ModelConfig, w: &Workload) -> Self {
        let p = model.params();
        let act = 2
            * (w.n_gpus as u64)
            * (w.batch as u64)
            * (w.context as u64)
            * (model.layers as u64)
            * (model.hidden as u64);
        Self {
            params_bf16: 2 * p,
            grads_bf16: 2 * p,
            activations_bf16: act,
            params_fp32: 4 * p,
            grads_fp32: 4 * p,
            optimizer_fp32: 8 * p,
        }
    }

    /// Total system-memory demand.
    pub fn total(&self) -> u64 {
        self.params_bf16
            + self.grads_bf16
            + self.activations_bf16
            + self.params_fp32
            + self.grads_fp32
            + self.optimizer_fp32
    }

    /// Latency-critical subtotal (fp32 P, G, O — the DRAM side of Fig. 8a).
    pub fn latency_critical(&self) -> u64 {
        self.params_fp32 + self.grads_fp32 + self.optimizer_fp32
    }

    /// Latency-tolerant subtotal (bf16 P, G, A — the CXL side of Fig. 8a).
    pub fn gpu_transfer(&self) -> u64 {
        self.params_bf16 + self.grads_bf16 + self.activations_bf16
    }

    /// Per-class view, aligned with `mem::TensorClass`.
    pub fn by_class(&self) -> [(TensorClass, u64); 6] {
        [
            (TensorClass::MasterParams, self.params_fp32),
            (TensorClass::Gradients32, self.grads_fp32),
            (TensorClass::OptimizerStates, self.optimizer_fp32),
            (TensorClass::Params16, self.params_bf16),
            (TensorClass::Grads16, self.grads_bf16),
            (TensorClass::Activations, self.activations_bf16),
        ]
    }

    /// Activations bytes for ONE GPU (per-GPU regions are allocated
    /// separately so striping can give them per-card affinity).
    pub fn activations_per_gpu(&self, w: &Workload) -> u64 {
        self.activations_bf16 / w.n_gpus as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets::{mistral_nemo_12b, qwen25_7b, tiny_2m};
    use crate::util::units::GIB;

    #[test]
    fn table_i_formulas() {
        let m = tiny_2m();
        let w = Workload::new(2, 4, 1024);
        let f = Footprint::compute(&m, &w);
        let p = m.params();
        assert_eq!(f.params_bf16, 2 * p);
        assert_eq!(f.grads_bf16, 2 * p);
        assert_eq!(f.params_fp32, 4 * p);
        assert_eq!(f.grads_fp32, 4 * p);
        assert_eq!(f.optimizer_fp32, 8 * p);
        assert_eq!(
            f.activations_bf16,
            2 * 2 * 4 * 1024 * (m.layers as u64) * (m.hidden as u64)
        );
        assert_eq!(f.total(), 20 * p + f.activations_bf16);
    }

    #[test]
    fn fixed_cost_is_20p() {
        // Everything except activations is 20 bytes/param.
        let m = qwen25_7b();
        let w = Workload::new(1, 1, 512);
        let f = Footprint::compute(&m, &w);
        assert_eq!(f.total() - f.activations_bf16, 20 * m.params());
    }

    #[test]
    fn activations_scale_linearly_with_context() {
        // Fig. 2's driver: memory grows linearly in C.
        let m = mistral_nemo_12b();
        let f1 = Footprint::compute(&m, &Workload::new(2, 5, 4096));
        let f2 = Footprint::compute(&m, &Workload::new(2, 5, 8192));
        assert_eq!(f2.activations_bf16, 2 * f1.activations_bf16);
        assert_eq!(f1.params_fp32, f2.params_fp32, "P terms don't move with C");
    }

    #[test]
    fn activations_scale_linearly_with_batch_and_gpus() {
        let m = mistral_nemo_12b();
        let base = Footprint::compute(&m, &Workload::new(1, 1, 4096)).activations_bf16;
        assert_eq!(
            Footprint::compute(&m, &Workload::new(2, 1, 4096)).activations_bf16,
            2 * base
        );
        assert_eq!(
            Footprint::compute(&m, &Workload::new(1, 8, 4096)).activations_bf16,
            8 * base
        );
    }

    #[test]
    fn twelve_b_at_32k_needs_several_hundred_gib() {
        // Sanity vs Fig. 2: 12B, B=5, C=32K, 2 GPUs exceeds 512 GB DRAM.
        let m = mistral_nemo_12b();
        let f = Footprint::compute(&m, &Workload::new(2, 5, 32768));
        assert!(f.total() > 300 * GIB, "total {}", f.total() / GIB);
        // the paper's point: the C-dependent activation term has grown to
        // the same order as the whole fixed 20·P cost...
        assert!(f.activations_bf16 * 2 > f.latency_critical());
        // ...and at the Fig. 3 batch scale (B=16) it dominates outright.
        let f16 = Footprint::compute(&m, &Workload::new(2, 16, 32768));
        assert!(f16.activations_bf16 > f16.latency_critical());
    }

    #[test]
    fn class_split_partitions_total() {
        let m = qwen25_7b();
        let f = Footprint::compute(&m, &Workload::new(2, 16, 4096));
        assert_eq!(f.latency_critical() + f.gpu_transfer(), f.total());
        let by: u64 = f.by_class().iter().map(|(_, b)| b).sum();
        assert_eq!(by, f.total());
    }

    #[test]
    fn per_gpu_activations_divide_evenly() {
        let m = qwen25_7b();
        let w = Workload::new(2, 16, 4096);
        let f = Footprint::compute(&m, &w);
        assert_eq!(f.activations_per_gpu(&w) * 2, f.activations_bf16);
    }
}
