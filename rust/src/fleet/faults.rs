//! Fault injection and recovery for the fleet simulator.
//!
//! A [`FaultTrace`] is the hardware-side analogue of [`super::job::FleetTrace`]:
//! a seeded, digest-embedded JSON file of typed events that the simulator
//! injects into its heap loop as first-class events (ordering rule: at one
//! timestamp, faults apply **after completions and before arrivals** — a
//! job that finishes at t is done, a job that arrives at t sees the
//! post-fault machine). Kinds:
//!
//! * [`FaultKind::LinkDegrade`] — a PCIe link retrains at lower width /
//!   throttles; bandwidth scales by `bw_factor`. Degrades compound
//!   multiplicatively and are never restored (a retrained link stays
//!   retrained for the run).
//! * [`FaultKind::NodeOffline`] — CXL AIC hot-remove. The DRAM node is
//!   rejected at validation: a host without DRAM is not degraded, it is
//!   gone.
//! * [`FaultKind::NodeRestore`] — the AIC comes back (hot-add). Only valid
//!   after a prior offline.
//! * [`FaultKind::CapacitySqueeze`] — ECC pressure / reserved-region
//!   growth shrinks a node's usable capacity by `bytes` (any node,
//!   including DRAM; squeezes accumulate and persist across restores).
//!
//! [`Degradation`] accumulates the applied events into per-link factors,
//! per-node offline flags and squeezed bytes, and derives the post-fault
//! hardware as a topology clone (via the `topology::presets` degraded
//! views) plus a deterministic cache key so the `Calibrator` can memoize
//! costs per degradation state.
//!
//! When a fault lands on a resident job's regions, a [`RecoveryPolicy`]
//! (registry shaped like `fleet::scheduler`) decides its fate:
//! `fail-stop`, `checkpoint-restart`, or `evacuate` — mechanics live in
//! `fleet::sim`, the policy is pure choice.

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::jobj;
use crate::topology::{presets as tpresets, LinkId, MemKind, NodeId, SystemTopology};
use crate::util::digest::Fnv64;
use crate::util::json::Json;
use crate::util::prng::Xoshiro256pp;

use super::job::JobSpec;

/// One typed hardware fault.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultKind {
    /// Link `link` retrains: bandwidth scales by `bw_factor` ∈ (0, 1].
    LinkDegrade { link: usize, bw_factor: f64 },
    /// CXL AIC hot-remove (node capacity → 0 until restored).
    NodeOffline { node: usize },
    /// The AIC returns (hot-add).
    NodeRestore { node: usize },
    /// Usable capacity on `node` shrinks by `bytes` (persistent).
    CapacitySqueeze { node: usize, bytes: u64 },
}

impl FaultKind {
    /// Stable kind tag (JSON field and digest component).
    pub fn tag(&self) -> &'static str {
        match self {
            FaultKind::LinkDegrade { .. } => "link-degrade",
            FaultKind::NodeOffline { .. } => "node-offline",
            FaultKind::NodeRestore { .. } => "node-restore",
            FaultKind::CapacitySqueeze { .. } => "capacity-squeeze",
        }
    }
}

/// One fault at one simulated time.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    /// Seconds from trace start (same clock as `JobSpec::arrival_s`).
    pub t_s: f64,
    pub kind: FaultKind,
}

impl FaultEvent {
    pub fn to_json(&self) -> Json {
        match &self.kind {
            FaultKind::LinkDegrade { link, bw_factor } => jobj! {
                "t_s" => self.t_s,
                "kind" => self.kind.tag(),
                "link" => *link,
                "bw_factor" => *bw_factor,
            },
            FaultKind::NodeOffline { node } | FaultKind::NodeRestore { node } => jobj! {
                "t_s" => self.t_s,
                "kind" => self.kind.tag(),
                "node" => *node,
            },
            FaultKind::CapacitySqueeze { node, bytes } => jobj! {
                "t_s" => self.t_s,
                "kind" => self.kind.tag(),
                "node" => *node,
                "bytes" => *bytes,
            },
        }
    }

    pub fn from_json(j: &Json) -> Result<FaultEvent, String> {
        let t_s = j
            .path(&["t_s"])
            .and_then(Json::as_f64)
            .ok_or_else(|| "fault event missing numeric t_s".to_string())?;
        let tag = j
            .path(&["kind"])
            .and_then(Json::as_str)
            .ok_or_else(|| "fault event missing kind".to_string())?;
        let num = |key: &str| {
            j.path(&[key])
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("{tag} fault missing numeric {key:?}"))
        };
        let kind = match tag {
            "link-degrade" => FaultKind::LinkDegrade {
                link: num("link")? as usize,
                bw_factor: j
                    .path(&["bw_factor"])
                    .and_then(Json::as_f64)
                    .ok_or_else(|| "link-degrade fault missing bw_factor".to_string())?,
            },
            "node-offline" => FaultKind::NodeOffline {
                node: num("node")? as usize,
            },
            "node-restore" => FaultKind::NodeRestore {
                node: num("node")? as usize,
            },
            "capacity-squeeze" => FaultKind::CapacitySqueeze {
                node: num("node")? as usize,
                bytes: num("bytes")?,
            },
            other => return Err(format!("unknown fault kind {other:?}")),
        };
        Ok(FaultEvent { t_s, kind })
    }

    fn fold(&self, h: &mut Fnv64) {
        h.write_f64(self.t_s);
        h.write_str(self.kind.tag());
        match &self.kind {
            FaultKind::LinkDegrade { link, bw_factor } => {
                h.write_u64(*link as u64);
                h.write_f64(*bw_factor);
            }
            FaultKind::NodeOffline { node } | FaultKind::NodeRestore { node } => {
                h.write_u64(*node as u64);
            }
            FaultKind::CapacitySqueeze { node, bytes } => {
                h.write_u64(*node as u64);
                h.write_u64(*bytes);
            }
        }
    }
}

/// A replayable fault trace: generator seed (0 for hand-built / derived
/// traces) plus every event, time-sorted.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultTrace {
    pub seed: u64,
    pub events: Vec<FaultEvent>,
}

impl FaultTrace {
    /// The no-fault trace — `simulate_fleet` runs every job under this.
    pub fn empty() -> Self {
        FaultTrace {
            seed: 0,
            events: Vec::new(),
        }
    }

    /// Bit-exact FNV-1a fingerprint (floats by IEEE-754 pattern).
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_u64(self.seed);
        h.write_u64(self.events.len() as u64);
        for e in &self.events {
            e.fold(&mut h);
        }
        h.finish()
    }

    /// Digest-embedded JSON (seed as a decimal string for the same
    /// above-2^53 reason as [`super::job::FleetTrace::to_json`]).
    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self.events.iter().map(FaultEvent::to_json).collect();
        jobj! {
            "seed" => self.seed.to_string(),
            "digest" => format!("{:016x}", self.digest()),
            "events" => Json::Arr(events),
        }
    }

    /// Parse a fault trace, verifying the embedded digest when present.
    pub fn from_json(j: &Json) -> Result<FaultTrace, String> {
        let seed_field = j
            .path(&["seed"])
            .ok_or_else(|| "fault trace missing seed".to_string())?;
        let seed = match seed_field {
            Json::Str(s) => s
                .parse::<u64>()
                .map_err(|e| format!("fault trace seed {s:?}: {e}"))?,
            other => other
                .as_u64()
                .ok_or_else(|| "fault trace seed must be a u64".to_string())?,
        };
        let raw = j
            .path(&["events"])
            .and_then(Json::as_arr)
            .ok_or_else(|| "fault trace missing events array".to_string())?;
        let events = raw
            .iter()
            .map(FaultEvent::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let trace = FaultTrace { seed, events };
        if let Some(want) = j.path(&["digest"]).and_then(Json::as_str) {
            let got = format!("{:016x}", trace.digest());
            if want != got {
                return Err(format!(
                    "fault trace digest mismatch: file says {want}, contents hash to {got}"
                ));
            }
        }
        Ok(trace)
    }

    /// Semantic validation against the machine the trace will run on:
    /// in-range targets, DRAM never offlined, factors/bytes in range,
    /// monotonic times, and offline/restore pairing (no double-offline, no
    /// restore without a prior offline). The simulator refuses invalid
    /// traces up front; `cxlfine lint --trace` reports the same conditions
    /// as P207–P209 diagnostics.
    pub fn validate(&self, topo: &SystemTopology) -> Result<(), String> {
        let mut last_t = f64::NEG_INFINITY;
        let mut offline: BTreeSet<usize> = BTreeSet::new();
        for (i, e) in self.events.iter().enumerate() {
            if !(e.t_s.is_finite() && e.t_s >= 0.0) {
                return Err(format!(
                    "fault {i}: t_s must be a non-negative finite time"
                ));
            }
            if e.t_s < last_t {
                return Err(format!(
                    "fault {i}: t_s {} precedes previous fault at {last_t} (events must be time-sorted)",
                    e.t_s
                ));
            }
            last_t = e.t_s;
            match &e.kind {
                FaultKind::LinkDegrade { link, bw_factor } => {
                    if *link >= topo.links.len() {
                        return Err(format!(
                            "fault {i}: link {link} out of range (topology has {})",
                            topo.links.len()
                        ));
                    }
                    if !(bw_factor.is_finite() && *bw_factor > 0.0 && *bw_factor <= 1.0) {
                        return Err(format!(
                            "fault {i}: bw_factor {bw_factor} must be in (0, 1]"
                        ));
                    }
                }
                FaultKind::NodeOffline { node } => {
                    if *node >= topo.mem_nodes.len() {
                        return Err(format!(
                            "fault {i}: node {node} out of range (topology has {})",
                            topo.mem_nodes.len()
                        ));
                    }
                    if topo.mem_nodes[*node].kind != MemKind::CxlAic {
                        return Err(format!(
                            "fault {i}: node {node} is local DRAM — only CXL AICs can go offline"
                        ));
                    }
                    if !offline.insert(*node) {
                        return Err(format!("fault {i}: node {node} is already offline"));
                    }
                }
                FaultKind::NodeRestore { node } => {
                    if *node >= topo.mem_nodes.len() {
                        return Err(format!(
                            "fault {i}: node {node} out of range (topology has {})",
                            topo.mem_nodes.len()
                        ));
                    }
                    if !offline.remove(node) {
                        return Err(format!(
                            "fault {i}: restore of node {node} without a prior offline"
                        ));
                    }
                }
                FaultKind::CapacitySqueeze { node, bytes } => {
                    if *node >= topo.mem_nodes.len() {
                        return Err(format!(
                            "fault {i}: node {node} out of range (topology has {})",
                            topo.mem_nodes.len()
                        ));
                    }
                    if *bytes == 0 {
                        return Err(format!("fault {i}: capacity squeeze of zero bytes"));
                    }
                }
            }
        }
        Ok(())
    }
}

/// Seeded synthetic fault generator (the hardware-side [`super::job::TraceGen`]).
///
/// Events arrive as a Poisson process over `[0, horizon_s]`; each event's
/// kind is sampled with a fixed order (inter-arrival, kind roll, target,
/// magnitude), so a seed pins the trace bitwise, and the generator tracks
/// the offline set so every emitted trace validates against `topo`.
#[derive(Clone, Debug)]
pub struct FaultGen {
    pub seed: u64,
    pub n_events: usize,
    pub horizon_s: f64,
}

impl FaultGen {
    pub fn new(seed: u64, n_events: usize, horizon_s: f64) -> Self {
        Self {
            seed,
            n_events,
            horizon_s,
        }
    }

    pub fn generate(&self, topo: &SystemTopology) -> FaultTrace {
        let cxl = topo.cxl_nodes();
        assert!(!cxl.is_empty(), "fault generation needs at least one CXL AIC");
        assert!(self.horizon_s > 0.0 && self.n_events > 0);
        let mut rng = Xoshiro256pp::seeded(self.seed);
        let mean_gap = self.horizon_s / self.n_events as f64;
        let mut t = 0.0;
        let mut offline: BTreeSet<usize> = BTreeSet::new();
        let mut events = Vec::with_capacity(self.n_events);
        for _ in 0..self.n_events {
            t += rng.exp_mean(mean_gap);
            let roll = rng.below(4);
            let target = *rng.choice(&cxl);
            let kind = match roll {
                0 => FaultKind::LinkDegrade {
                    link: topo.node(target).link.expect("AIC sits behind a link").0,
                    bw_factor: rng.range_f64(0.25, 1.0),
                },
                1 if !offline.contains(&target.0) => {
                    offline.insert(target.0);
                    FaultKind::NodeOffline { node: target.0 }
                }
                2 if !offline.is_empty() => {
                    let back = *offline.iter().next().expect("nonempty");
                    offline.remove(&back);
                    FaultKind::NodeRestore { node: back }
                }
                _ => FaultKind::CapacitySqueeze {
                    node: target.0,
                    bytes: rng.range_u64(1, topo.node(target).capacity.max(2) / 2),
                },
            };
            events.push(FaultEvent { t_s: t, kind });
        }
        let trace = FaultTrace {
            seed: self.seed,
            events,
        };
        debug_assert!(trace.validate(topo).is_ok(), "generator emits valid traces");
        trace
    }
}

/// Derive the pinned acceptance fault trace from a *no-fault* baseline
/// run: locate the longest window during which the first CXL AIC holds
/// bytes, then degrade its link at 25 % of the window, hot-remove the AIC
/// at 50 %, and restore it at 75 % — guaranteeing the hot-remove lands on
/// resident regions (≥ 1 job is hit under every recovery policy). Pure in
/// the baseline, so the derived trace is as reproducible as the run.
pub fn pinned_faults_from_baseline(
    topo: &SystemTopology,
    baseline: &super::metrics::FleetResult,
) -> FaultTrace {
    let aic = *topo
        .cxl_nodes()
        .first()
        .expect("pinned faults need a CXL AIC");
    let link = topo.node(aic).link.expect("AIC sits behind a link");
    let mut best = (0.0_f64, 0.0_f64);
    let mut cur_start: Option<f64> = None;
    let mut last_t = 0.0_f64;
    for s in &baseline.samples {
        let occupied = s.used.get(aic.0).copied().unwrap_or(0) > 0;
        match (occupied, cur_start) {
            (true, None) => cur_start = Some(s.t_s),
            (false, Some(st)) => {
                if s.t_s - st > best.1 - best.0 {
                    best = (st, s.t_s);
                }
                cur_start = None;
            }
            _ => {}
        }
        last_t = s.t_s;
    }
    if let Some(st) = cur_start {
        if last_t - st > best.1 - best.0 {
            best = (st, last_t);
        }
    }
    assert!(
        best.1 > best.0,
        "baseline never occupies AIC node {} — nothing to fault",
        aic.0
    );
    let at = |frac: f64| best.0 + (best.1 - best.0) * frac;
    FaultTrace {
        seed: 0,
        events: vec![
            FaultEvent {
                t_s: at(0.25),
                kind: FaultKind::LinkDegrade {
                    link: link.0,
                    bw_factor: 0.5,
                },
            },
            FaultEvent {
                t_s: at(0.50),
                kind: FaultKind::NodeOffline { node: aic.0 },
            },
            FaultEvent {
                t_s: at(0.75),
                kind: FaultKind::NodeRestore { node: aic.0 },
            },
        ],
    }
}

/// Accumulated degradation state: what the applied prefix of a fault
/// trace has done to the machine.
#[derive(Clone, Debug, PartialEq)]
pub struct Degradation {
    /// Per-link multiplicative bandwidth factor (1.0 = healthy).
    pub link_factors: Vec<f64>,
    /// Per-node offline flag.
    pub offline: Vec<bool>,
    /// Per-node squeezed-away bytes (accumulated, persistent).
    pub squeezed: Vec<u64>,
}

impl Degradation {
    pub fn pristine(topo: &SystemTopology) -> Self {
        Degradation {
            link_factors: vec![1.0; topo.links.len()],
            offline: vec![false; topo.mem_nodes.len()],
            squeezed: vec![0; topo.mem_nodes.len()],
        }
    }

    pub fn is_pristine(&self) -> bool {
        self.link_factors.iter().all(|f| *f == 1.0)
            && self.offline.iter().all(|o| !o)
            && self.squeezed.iter().all(|s| *s == 0)
    }

    /// Fold one fault in. The caller validates the trace up front, so the
    /// pairing invariants hold here by construction.
    pub fn apply(&mut self, kind: &FaultKind) {
        match kind {
            FaultKind::LinkDegrade { link, bw_factor } => {
                self.link_factors[*link] *= bw_factor;
            }
            FaultKind::NodeOffline { node } => self.offline[*node] = true,
            FaultKind::NodeRestore { node } => self.offline[*node] = false,
            FaultKind::CapacitySqueeze { node, bytes } => {
                self.squeezed[*node] = self.squeezed[*node].saturating_add(*bytes);
            }
        }
    }

    /// The post-fault machine: the pristine topology with every degraded
    /// view applied (link factors first, then offlines, then squeezes).
    /// Not re-validated — offline nodes have zero capacity.
    pub fn degraded_topo(&self, topo: &SystemTopology) -> SystemTopology {
        let mut t = topo.clone();
        for (i, f) in self.link_factors.iter().enumerate() {
            if *f != 1.0 {
                t = tpresets::with_link_bw_factor(t, LinkId(i), *f);
            }
        }
        for (i, off) in self.offline.iter().enumerate() {
            if *off {
                t = tpresets::with_node_offline(t, NodeId(i));
            }
        }
        for (i, s) in self.squeezed.iter().enumerate() {
            if *s > 0 {
                t = tpresets::with_reduced_capacity(t, NodeId(i), *s);
            }
        }
        t
    }

    /// Effective (degraded) capacity of every node: zero when offline,
    /// else the pristine capacity minus accumulated squeezes.
    pub fn effective_caps(&self, topo: &SystemTopology) -> Vec<u64> {
        topo.mem_nodes
            .iter()
            .enumerate()
            .map(|(i, n)| {
                if self.offline[i] {
                    0
                } else {
                    n.capacity.saturating_sub(self.squeezed[i])
                }
            })
            .collect()
    }

    /// Deterministic memoization key of this degradation state — appended
    /// to the `Calibrator` cost-cache key so costs computed on different
    /// post-fault machines never collide. Empty for the pristine machine
    /// (keeping the zero-fault cache keys byte-identical to PR 5's).
    pub fn key(&self) -> String {
        if self.is_pristine() {
            return String::new();
        }
        use std::fmt::Write;
        let mut s = String::new();
        for (i, f) in self.link_factors.iter().enumerate() {
            if *f != 1.0 {
                let _ = write!(s, "L{i}:{:016x};", f.to_bits());
            }
        }
        for (i, off) in self.offline.iter().enumerate() {
            if *off {
                let _ = write!(s, "N{i}:off;");
            }
        }
        for (i, sq) in self.squeezed.iter().enumerate() {
            if *sq > 0 {
                let _ = write!(s, "S{i}:{sq};");
            }
        }
        s
    }
}

/// What happens to a resident job whose regions a fault touches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryAction {
    /// Kill the job; release its regions and GPUs.
    FailStop,
    /// Roll back to the last checkpoint, release everything, re-queue with
    /// exponential backoff (bounded retries, then fail).
    CheckpointRestart,
    /// Re-plan against the degraded free view and migrate the surviving
    /// regions (falls back to checkpoint-restart when nothing fits).
    Evacuate,
}

/// Recovery policy: pure choice of [`RecoveryAction`] per hit job — all
/// mechanics (checkpoint math, migration pricing, backoff) live in
/// `fleet::sim`.
pub trait RecoveryPolicy: Send + Sync {
    /// Registry / CLI name, e.g. `"evacuate"`.
    fn name(&self) -> &'static str;

    /// Decide the fate of `job` at its `interruptions`-th hit (1-based).
    fn decide(&self, job: &JobSpec, interruptions: u32) -> RecoveryAction;
}

/// Shared handle — what the simulator, CLI and benches thread.
pub type RecoveryRef = Arc<dyn RecoveryPolicy>;

/// Baseline: every hit job dies.
pub struct FailStop;

impl RecoveryPolicy for FailStop {
    fn name(&self) -> &'static str {
        "fail-stop"
    }
    fn decide(&self, _job: &JobSpec, _interruptions: u32) -> RecoveryAction {
        RecoveryAction::FailStop
    }
}

/// Roll back to the last checkpoint and re-queue.
pub struct CheckpointRestart;

impl RecoveryPolicy for CheckpointRestart {
    fn name(&self) -> &'static str {
        "checkpoint-restart"
    }
    fn decide(&self, _job: &JobSpec, _interruptions: u32) -> RecoveryAction {
        RecoveryAction::CheckpointRestart
    }
}

/// Live-migrate the hit regions to surviving nodes.
pub struct Evacuate;

impl RecoveryPolicy for Evacuate {
    fn name(&self) -> &'static str {
        "evacuate"
    }
    fn decide(&self, _job: &JobSpec, _interruptions: u32) -> RecoveryAction {
        RecoveryAction::Evacuate
    }
}

/// Iterations between durable checkpoints: progress at an interruption
/// rolls back to the last multiple of this.
pub const CHECKPOINT_INTERVAL_ITERS: u64 = 2;

/// A job is failed outright after this many interruptions under
/// checkpoint-restart (bounded retries).
pub const MAX_RETRIES: u32 = 3;

/// Re-admission backoff after interruption k is `BACKOFF_BASE_S * 2^(k-1)`.
pub const BACKOFF_BASE_S: f64 = 30.0;

/// Canonical names of every registered recovery policy.
pub fn known_names() -> Vec<&'static str> {
    vec!["fail-stop", "checkpoint-restart", "evacuate"]
}

/// Resolve a recovery policy by name.
pub fn by_name(name: &str) -> Option<RecoveryRef> {
    match name {
        "fail-stop" => Some(Arc::new(FailStop)),
        "checkpoint-restart" => Some(Arc::new(CheckpointRestart)),
        "evacuate" => Some(Arc::new(Evacuate)),
        _ => None,
    }
}

/// One instance of every registered recovery policy, in canonical order.
pub fn registry() -> Vec<RecoveryRef> {
    known_names()
        .into_iter()
        .map(|n| by_name(n).expect("known name resolves"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::{config_a, config_b, dev_tiny};
    use crate::util::units::GIB;

    fn sample_trace() -> FaultTrace {
        FaultTrace {
            seed: 42,
            events: vec![
                FaultEvent {
                    t_s: 10.0,
                    kind: FaultKind::LinkDegrade {
                        link: 2,
                        bw_factor: 0.5,
                    },
                },
                FaultEvent {
                    t_s: 20.0,
                    kind: FaultKind::NodeOffline { node: 1 },
                },
                FaultEvent {
                    t_s: 25.0,
                    kind: FaultKind::CapacitySqueeze {
                        node: 0,
                        bytes: GIB,
                    },
                },
                FaultEvent {
                    t_s: 30.0,
                    kind: FaultKind::NodeRestore { node: 1 },
                },
            ],
        }
    }

    #[test]
    fn fault_trace_json_round_trips_and_verifies_digest() {
        let t = sample_trace();
        let text = t.to_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        let back = FaultTrace::from_json(&parsed).unwrap();
        assert_eq!(t, back, "round trip must preserve every field bitwise");
        assert_eq!(t.digest(), back.digest());
        // A tampered trace must be rejected by the digest check.
        let mut t2 = t.clone();
        t2.events[0].t_s += 1.0;
        let mut tampered = t2.to_json();
        if let Json::Obj(o) = &mut tampered {
            o.set("digest", format!("{:016x}", t.digest()));
        }
        let err = FaultTrace::from_json(&tampered).unwrap_err();
        assert!(err.contains("digest mismatch"), "{err}");
    }

    #[test]
    fn huge_seeds_round_trip_via_the_string_field() {
        let mut t = sample_trace();
        t.seed = (1u64 << 53) + 9;
        let back =
            FaultTrace::from_json(&Json::parse(&t.to_json().to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.seed, (1u64 << 53) + 9);
        // A numeric seed (hand-written file) still parses.
        let hand = Json::parse(r#"{"seed": 7, "events": []}"#).unwrap();
        assert_eq!(FaultTrace::from_json(&hand).unwrap().seed, 7);
    }

    #[test]
    fn validate_accepts_the_sample_and_rejects_each_violation() {
        let topo = config_a();
        sample_trace().validate(&topo).unwrap();

        let mk = |events: Vec<FaultEvent>| FaultTrace { seed: 0, events };
        let at = |t_s: f64, kind: FaultKind| FaultEvent { t_s, kind };

        // DRAM offline is rejected.
        let err = mk(vec![at(1.0, FaultKind::NodeOffline { node: 0 })])
            .validate(&topo)
            .unwrap_err();
        assert!(err.contains("local DRAM"), "{err}");
        // Out-of-range targets.
        for kind in [
            FaultKind::NodeOffline { node: 9 },
            FaultKind::NodeRestore { node: 9 },
            FaultKind::CapacitySqueeze { node: 9, bytes: 1 },
            FaultKind::LinkDegrade {
                link: 9,
                bw_factor: 0.5,
            },
        ] {
            let err = mk(vec![at(1.0, kind)]).validate(&topo).unwrap_err();
            assert!(err.contains("out of range"), "{err}");
        }
        // Bad factor / zero squeeze.
        for f in [0.0, 1.5, f64::NAN] {
            let err = mk(vec![at(
                1.0,
                FaultKind::LinkDegrade {
                    link: 2,
                    bw_factor: f,
                },
            )])
            .validate(&topo)
            .unwrap_err();
            assert!(err.contains("bw_factor"), "{err}");
        }
        let err = mk(vec![at(1.0, FaultKind::CapacitySqueeze { node: 1, bytes: 0 })])
            .validate(&topo)
            .unwrap_err();
        assert!(err.contains("zero bytes"), "{err}");
        // Non-monotonic times.
        let err = mk(vec![
            at(5.0, FaultKind::NodeOffline { node: 1 }),
            at(4.0, FaultKind::NodeRestore { node: 1 }),
        ])
        .validate(&topo)
        .unwrap_err();
        assert!(err.contains("time-sorted"), "{err}");
        // Restore without offline; double offline.
        let err = mk(vec![at(1.0, FaultKind::NodeRestore { node: 1 })])
            .validate(&topo)
            .unwrap_err();
        assert!(err.contains("without a prior offline"), "{err}");
        let err = mk(vec![
            at(1.0, FaultKind::NodeOffline { node: 1 }),
            at(2.0, FaultKind::NodeOffline { node: 1 }),
        ])
        .validate(&topo)
        .unwrap_err();
        assert!(err.contains("already offline"), "{err}");
    }

    #[test]
    fn fault_gen_is_seed_deterministic_and_valid() {
        let topo = config_b();
        let a = FaultGen::new(7, 12, 1000.0).generate(&topo);
        let b = FaultGen::new(7, 12, 1000.0).generate(&topo);
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        a.validate(&topo).unwrap();
        let c = FaultGen::new(8, 12, 1000.0).generate(&topo);
        assert_ne!(a.digest(), c.digest(), "a different seed must diverge");
        for w in a.events.windows(2) {
            assert!(w[0].t_s <= w[1].t_s);
        }
    }

    #[test]
    fn degradation_tracks_and_keys_deterministically() {
        let topo = config_a();
        let mut d = Degradation::pristine(&topo);
        assert!(d.is_pristine());
        assert_eq!(d.key(), "", "pristine key must stay empty");
        assert_eq!(
            d.effective_caps(&topo),
            topo.mem_nodes.iter().map(|n| n.capacity).collect::<Vec<_>>()
        );

        for e in &sample_trace().events {
            d.apply(&e.kind);
        }
        // Offline then restore → node 1 back online; squeeze persists.
        assert!(!d.offline[1]);
        assert_eq!(d.squeezed[0], GIB);
        assert_eq!(d.link_factors[2], 0.5);
        assert!(!d.is_pristine());
        let caps = d.effective_caps(&topo);
        assert_eq!(caps[0], 512 * GIB - GIB);
        assert_eq!(caps[1], 512 * GIB);
        // Key is deterministic and distinguishes states.
        let k1 = d.key();
        assert_eq!(k1, d.clone().key());
        d.apply(&FaultKind::NodeOffline { node: 1 });
        assert_ne!(d.key(), k1);
        assert_eq!(d.effective_caps(&topo)[1], 0);
        // Degrades compound multiplicatively.
        d.apply(&FaultKind::LinkDegrade {
            link: 2,
            bw_factor: 0.5,
        });
        assert_eq!(d.link_factors[2], 0.25);
    }

    #[test]
    fn degraded_topo_applies_every_view() {
        let topo = config_a();
        let mut d = Degradation::pristine(&topo);
        d.apply(&FaultKind::LinkDegrade {
            link: 2,
            bw_factor: 0.5,
        });
        d.apply(&FaultKind::NodeOffline { node: 1 });
        d.apply(&FaultKind::CapacitySqueeze {
            node: 0,
            bytes: 2 * GIB,
        });
        let dt = d.degraded_topo(&topo);
        assert_eq!(dt.links[2].per_dir_bw, topo.links[2].per_dir_bw * 0.5);
        assert_eq!(dt.mem_nodes[1].capacity, 0);
        assert_eq!(dt.mem_nodes[0].capacity, 510 * GIB);
        // Pristine degradation is an exact clone.
        let p = Degradation::pristine(&topo).degraded_topo(&topo);
        assert_eq!(p.mem_nodes[1].capacity, topo.mem_nodes[1].capacity);
        assert_eq!(p.links[2].per_dir_bw, topo.links[2].per_dir_bw);
    }

    #[test]
    fn recovery_registry_resolves_every_known_name() {
        for name in known_names() {
            let p = by_name(name).unwrap_or_else(|| panic!("{name} must resolve"));
            assert_eq!(p.name(), name, "canonical name must round-trip");
        }
        assert!(by_name("??").is_none());
        assert_eq!(registry().len(), known_names().len());
        let job = JobSpec {
            id: 0,
            arrival_s: 0.0,
            model: "tiny-2m".into(),
            gpus: 1,
            batch: 1,
            context: 256,
            schedule: "zero-offload".into(),
            engine: "cxl-aware".into(),
            iterations: 1,
        };
        assert_eq!(by_name("fail-stop").unwrap().decide(&job, 1), RecoveryAction::FailStop);
        assert_eq!(
            by_name("checkpoint-restart").unwrap().decide(&job, 2),
            RecoveryAction::CheckpointRestart
        );
        assert_eq!(by_name("evacuate").unwrap().decide(&job, 3), RecoveryAction::Evacuate);
    }

    #[test]
    fn pinned_faults_hit_the_occupied_window() {
        use crate::fleet::metrics::{FleetResult, OccupancySample};
        let topo = dev_tiny();
        let mut res = FleetResult::new("fifo", &topo);
        let sample = |t_s: f64, aic: u64| OccupancySample {
            t_s,
            used: vec![0, aic, 0],
            queue_len: 0,
            running: 0,
        };
        res.samples = vec![
            sample(0.0, 0),
            sample(10.0, 1),
            sample(110.0, 0),
            sample(120.0, 5),
            sample(420.0, 0),
        ];
        let faults = pinned_faults_from_baseline(&topo, &res);
        faults.validate(&topo).unwrap();
        assert_eq!(faults.events.len(), 3);
        // Longest occupied window is [120, 420) → 25/50/75 % marks.
        assert_eq!(faults.events[0].t_s, 195.0);
        assert_eq!(faults.events[1].t_s, 270.0);
        assert_eq!(faults.events[2].t_s, 345.0);
        assert!(matches!(
            faults.events[1].kind,
            FaultKind::NodeOffline { node: 1 }
        ));
        assert!(matches!(
            faults.events[0].kind,
            FaultKind::LinkDegrade { link: 2, .. }
        ));
    }
}
