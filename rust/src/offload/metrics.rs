//! Phase/throughput metrics for iteration runs (the Fig. 7/9/10 quantities).

use crate::jobj;
use crate::util::json::Json;

/// Wall-clock breakdown of one training iteration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PhaseBreakdown {
    /// Forward phase (parameter streaming + kernels + checkpoint offload).
    pub fwd_s: f64,
    /// Backward phase (reloads + recompute + backward + gradient offload).
    pub bwd_s: f64,
    /// CPU optimizer update + bf16 parameter cast.
    pub step_s: f64,
    /// End-to-end iteration time.
    pub iter_s: f64,
    /// Tokens processed this iteration (all GPUs).
    pub tokens: u64,
}

impl PhaseBreakdown {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.iter_s
    }

    /// Throughput relative to a baseline run (the paper's normalized %).
    pub fn relative_to(&self, baseline: &PhaseBreakdown) -> f64 {
        self.tokens_per_sec() / baseline.tokens_per_sec()
    }

    /// Phase share of the iteration, (fwd, bwd, step) fractions.
    pub fn shares(&self) -> (f64, f64, f64) {
        (
            self.fwd_s / self.iter_s,
            self.bwd_s / self.iter_s,
            self.step_s / self.iter_s,
        )
    }

    pub fn to_json(&self) -> Json {
        jobj! {
            "fwd_s" => self.fwd_s,
            "bwd_s" => self.bwd_s,
            "step_s" => self.step_s,
            "iter_s" => self.iter_s,
            "tokens" => self.tokens,
            "tokens_per_sec" => self.tokens_per_sec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bd(fwd: f64, bwd: f64, step: f64, tokens: u64) -> PhaseBreakdown {
        PhaseBreakdown {
            fwd_s: fwd,
            bwd_s: bwd,
            step_s: step,
            iter_s: fwd + bwd + step,
            tokens,
        }
    }

    #[test]
    fn throughput_math() {
        let b = bd(1.0, 2.0, 1.0, 8000);
        assert!((b.tokens_per_sec() - 2000.0).abs() < 1e-9);
        let base = bd(1.0, 1.0, 1.0, 8000);
        assert!((b.relative_to(&base) - 0.75).abs() < 1e-9);
    }

    #[test]
    fn shares_sum_to_one() {
        let b = bd(0.5, 1.5, 0.25, 100);
        let (f, w, s) = b.shares();
        assert!((f + w + s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip() {
        let b = bd(1.0, 2.0, 3.0, 42);
        let j = b.to_json();
        assert_eq!(j.path(&["tokens"]).unwrap().as_u64(), Some(42));
        assert!(j.path(&["tokens_per_sec"]).unwrap().as_f64().unwrap() > 0.0);
    }
}
