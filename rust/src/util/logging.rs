//! Leveled stderr logger controlled by `CXLFINE_LOG` (error|warn|info|debug|trace).
//! Hand-rolled: the vendor set has the `log` facade but no emitter, and a
//! 60-line module beats wiring a facade.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static INIT: OnceLock<()> = OnceLock::new();

/// Read `CXLFINE_LOG` once; later explicit `set_level` calls still win.
pub fn init_from_env() {
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("CXLFINE_LOG") {
            if let Some(l) = Level::from_str(&v) {
                LEVEL.store(l as u8, Ordering::Relaxed);
            }
        }
    });
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    init_from_env();
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{:<5}] {}", l.tag(), args);
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("warn"), Some(Level::Warn));
        assert_eq!(Level::from_str("WARNING"), Some(Level::Warn));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
