//! Paged KV cache with CXL tiering — the serving-side memory subsystem.
//!
//! The cache is paged: each live sequence owns `ceil(kv_tokens /
//! PAGE_TOKENS)` fixed-size pages, growing one page at a time as decode
//! appends tokens. The [`KvPager`] keeps each sequence's *newest* pages
//! (the hot attention window plus the append frontier) in DRAM and
//! demotes older pages to the CXL tier, striping every demoted page
//! across the online AICs capacity-proportionally via
//! [`weighted_split`] — the same largest-remainder splitter the
//! fine-tuning placement engines use. Promotion / demotion byte counters
//! accumulate on the pager, and the simulator prices them at
//! [`SystemTopology::migration_bandwidth`], so KV paging traffic flows
//! through the same degraded-topology views as fleet evacuations.
//!
//! Policies are a registry ([`by_name`], mirroring `fleet::scheduler`):
//! `dram-only` keeps everything hot and admits nothing it cannot hold in
//! DRAM; `tiered[:H]` (alias `ours`) caps the per-sequence hot window at
//! H pages and spills the rest to CXL. Everything is deterministic —
//! sequences live in a `BTreeMap` keyed by request id, so eviction,
//! demotion and promotion orders are a pure function of the trace.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::mem::striping::weighted_split;
use crate::topology::{NodeId, SystemTopology};

/// Tokens per KV page (every sequence's unit of growth and migration).
pub const PAGE_TOKENS: usize = 256;

/// Default hot-window size (pages per sequence) for `tiered`.
pub const DEFAULT_HOT_PAGES: usize = 4;

/// A KV placement policy: how many of each sequence's newest pages stay
/// in DRAM, and whether older pages may spill to the CXL tier at all.
pub trait KvPolicy: Send + Sync {
    /// Registry / CLI name, e.g. `"tiered:4"`.
    fn name(&self) -> &str;

    /// Per-sequence hot-window size in pages (`usize::MAX` = never demote).
    fn hot_pages(&self) -> usize;

    /// Whether demoted pages may live on CXL AICs.
    fn uses_cxl(&self) -> bool;
}

/// Shared handle to a policy.
pub type KvPolicyRef = Arc<dyn KvPolicy>;

/// Everything in DRAM; a request that cannot fit there is rejected.
pub struct DramOnly;

impl KvPolicy for DramOnly {
    fn name(&self) -> &str {
        "dram-only"
    }
    fn hot_pages(&self) -> usize {
        usize::MAX
    }
    fn uses_cxl(&self) -> bool {
        false
    }
}

/// Hot window of `hot` newest pages per sequence in DRAM, older pages
/// striped across the CXL AICs.
pub struct Tiered {
    hot: usize,
    name: String,
}

impl Tiered {
    pub fn new(hot: usize) -> Self {
        assert!(hot >= 1, "the hot window needs at least one page");
        Self {
            hot,
            name: format!("tiered:{hot}"),
        }
    }
}

impl KvPolicy for Tiered {
    fn name(&self) -> &str {
        &self.name
    }
    fn hot_pages(&self) -> usize {
        self.hot
    }
    fn uses_cxl(&self) -> bool {
        true
    }
}

/// Resolve a registry name (`dram-only`, `tiered[:H]`, alias `ours`).
pub fn by_name(name: &str) -> Option<KvPolicyRef> {
    if let Some(rest) = name.strip_prefix("tiered") {
        let h = if rest.is_empty() {
            DEFAULT_HOT_PAGES
        } else {
            rest.strip_prefix(':')?.parse().ok().filter(|&v| v >= 1)?
        };
        return Some(Arc::new(Tiered::new(h)));
    }
    match name {
        "dram-only" => Some(Arc::new(DramOnly)),
        "ours" => Some(Arc::new(Tiered::new(DEFAULT_HOT_PAGES))),
        _ => None,
    }
}

/// Canonical names of every registered policy (CLI help text).
pub fn known_names() -> Vec<&'static str> {
    vec!["dram-only", "tiered[:H]"]
}

/// One concrete instance of every registered policy.
pub fn registry() -> Vec<KvPolicyRef> {
    vec![Arc::new(DramOnly), Arc::new(Tiered::new(DEFAULT_HOT_PAGES))]
}

/// One live sequence's pages. Growth is append-only and demotion always
/// takes the *oldest* hot page, so the layout is always: pages
/// `[0, cold.len())` cold (each a stripe vector), the rest hot in DRAM.
#[derive(Clone, Debug)]
struct SeqKv {
    tokens: usize,
    /// Stripe layout of each cold page, oldest first.
    cold: Vec<Vec<(NodeId, u64)>>,
    /// Pages currently resident in DRAM (the newest pages).
    hot: usize,
}

impl SeqKv {
    fn pages(&self) -> usize {
        self.cold.len() + self.hot
    }
}

/// Cumulative pager counters — monotone, so the simulator can charge
/// migration traffic from per-step deltas and tests can state the page
/// conservation law `resident + evicted + freed == allocated`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct KvCounters {
    /// Pages ever allocated (prefill + decode growth).
    pub allocated_pages: u64,
    /// Pages released by completed requests draining.
    pub freed_pages: u64,
    /// Pages dropped by forced eviction ([`KvPager::evict`]).
    pub evicted_pages: u64,
    /// Bytes moved DRAM → CXL (demotions).
    pub demoted_bytes: u64,
    /// Bytes moved CXL → DRAM (promotions).
    pub promoted_bytes: u64,
}

impl KvCounters {
    /// Pages currently resident (the conservation law, rearranged).
    pub fn resident_pages(&self) -> u64 {
        self.allocated_pages - self.freed_pages - self.evicted_pages
    }

    /// Total migration traffic since construction.
    pub fn migrated_bytes(&self) -> u64 {
        self.demoted_bytes + self.promoted_bytes
    }
}

/// The paged, tiered KV cache for one serving host.
pub struct KvPager {
    policy: KvPolicyRef,
    /// Bytes per page (PAGE_TOKENS × per-token KV bytes for the model).
    page_bytes: u64,
    /// DRAM bytes available to KV (host capacity minus the resident
    /// weights and a working-set reserve — computed by the simulator).
    dram_budget: u64,
    /// Online CXL AICs and their capacities (weights for striping).
    cxl: Vec<NodeId>,
    cxl_caps: Vec<u64>,
    /// Bytes in use per memory node, indexed by `NodeId.0` (0 = DRAM).
    used: Vec<u64>,
    seqs: BTreeMap<u64, SeqKv>,
    counters: KvCounters,
}

impl KvPager {
    /// Build a pager over the (possibly degraded) topology view. AICs
    /// with zero capacity — knocked out by `with_node_offline` — are
    /// excluded from striping entirely.
    pub fn new(
        topo: &SystemTopology,
        page_bytes: u64,
        dram_budget: u64,
        policy: KvPolicyRef,
    ) -> Self {
        assert!(page_bytes > 0, "pages must hold at least one byte");
        let cxl: Vec<NodeId> = topo
            .cxl_nodes()
            .into_iter()
            .filter(|&n| topo.node(n).capacity > 0)
            .collect();
        let cxl_caps = cxl.iter().map(|&n| topo.node(n).capacity).collect();
        Self {
            policy,
            page_bytes,
            dram_budget,
            used: vec![0; topo.mem_nodes.len()],
            cxl,
            cxl_caps,
            seqs: BTreeMap::new(),
            counters: KvCounters::default(),
        }
    }

    pub fn policy(&self) -> &dyn KvPolicy {
        self.policy.as_ref()
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    pub fn counters(&self) -> KvCounters {
        self.counters
    }

    /// Bytes in use per memory node (`NodeId.0`-indexed, 0 = DRAM).
    pub fn used(&self) -> &[u64] {
        &self.used
    }

    /// KV bytes resident in DRAM.
    pub fn dram_used(&self) -> u64 {
        self.used[0]
    }

    /// KV bytes resident on the CXL tier.
    pub fn cxl_used(&self) -> u64 {
        self.used.iter().skip(1).sum()
    }

    pub fn dram_budget(&self) -> u64 {
        self.dram_budget
    }

    /// Total KV capacity the policy can reach (DRAM budget, plus the CXL
    /// tier when the policy spills).
    pub fn capacity(&self) -> u64 {
        let cxl: u64 = if self.policy.uses_cxl() {
            self.cxl_caps.iter().sum()
        } else {
            0
        };
        self.dram_budget + cxl
    }

    pub fn live_sequences(&self) -> usize {
        self.seqs.len()
    }

    fn dram_free(&self) -> u64 {
        self.dram_budget.saturating_sub(self.used[0])
    }

    fn cxl_free(&self) -> u64 {
        self.cxl
            .iter()
            .zip(&self.cxl_caps)
            .map(|(&n, &cap)| cap.saturating_sub(self.used[n.0]))
            .sum()
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(PAGE_TOKENS)
    }

    /// Would a request holding `tokens` KV tokens fit right now? The
    /// admission gate: its hot window must fit DRAM and the remainder
    /// must fit the CXL tier (or DRAM again, for `dram-only`).
    pub fn can_fit(&self, tokens: usize) -> bool {
        let pages = self.pages_for(tokens.max(1)) as u64;
        let hot = pages.min(self.policy.hot_pages() as u64);
        let cold = pages - hot;
        let hot_ok = hot * self.page_bytes <= self.dram_free();
        let cold_ok = if cold == 0 {
            true
        } else if self.policy.uses_cxl() {
            cold * self.page_bytes <= self.cxl_free()
        } else {
            false
        };
        hot_ok && cold_ok
    }

    /// Would a request holding `tokens` KV tokens fit on an *empty*
    /// pager? The admission-feasibility floor: a request failing this can
    /// never be admitted no matter how the queue drains, so the simulator
    /// rejects it at arrival instead of parking it forever.
    pub fn fits_empty(&self, tokens: usize) -> bool {
        let pages = self.pages_for(tokens.max(1)) as u64;
        let hot = pages.min(self.policy.hot_pages() as u64);
        let cold = pages - hot;
        hot * self.page_bytes <= self.dram_budget
            && (cold == 0
                || (self.policy.uses_cxl()
                    && cold * self.page_bytes <= self.cxl_caps.iter().sum::<u64>()))
    }

    /// Demote the oldest hot page of sequence `id` to the CXL tier.
    /// Returns false (state unchanged) if the tier is full or the policy
    /// forbids spilling.
    fn demote_oldest(&mut self, id: u64) -> bool {
        if !self.policy.uses_cxl() {
            return false;
        }
        let free: Vec<u64> = {
            let used = &self.used;
            let mut f = vec![0u64; used.len()];
            for (&n, &cap) in self.cxl.iter().zip(&self.cxl_caps) {
                f[n.0] = cap.saturating_sub(used[n.0]);
            }
            f
        };
        let weights: Vec<f64> = self.cxl_caps.iter().map(|&c| c as f64).collect();
        let (shards, unplaced) = weighted_split(self.page_bytes, &self.cxl, &weights, &free);
        if unplaced > 0 {
            return false;
        }
        let seq = self.seqs.get_mut(&id).expect("demote of unknown sequence");
        assert!(seq.hot > 0, "nothing hot to demote");
        seq.hot -= 1;
        for &(n, b) in &shards {
            self.used[n.0] += b;
        }
        seq.cold.push(shards);
        self.used[0] -= self.page_bytes;
        self.counters.demoted_bytes += self.page_bytes;
        true
    }

    /// Allocate a brand-new sequence holding `tokens` KV tokens (the
    /// prefill footprint). Pages beyond the policy's hot window go
    /// straight to CXL. Returns false — with no partial allocation — if
    /// the request does not fit.
    pub fn alloc(&mut self, id: u64, tokens: usize) -> bool {
        assert!(
            !self.seqs.contains_key(&id),
            "sequence {id} already allocated"
        );
        if !self.can_fit(tokens) {
            return false;
        }
        let pages = self.pages_for(tokens.max(1));
        let hot = pages.min(self.policy.hot_pages());
        self.seqs.insert(
            id,
            SeqKv {
                tokens,
                cold: Vec::new(),
                hot: pages,
            },
        );
        self.used[0] += pages as u64 * self.page_bytes;
        self.counters.allocated_pages += pages as u64;
        // Demote the pre-window prefix oldest-first, exactly as decode
        // growth would have.
        for _ in 0..pages - hot {
            let ok = self.demote_oldest(id);
            assert!(ok, "can_fit admitted a request the tier cannot hold");
        }
        true
    }

    /// Append `new_tokens` decode tokens to sequence `id`, growing it by
    /// however many page boundaries that crosses. The new page lands hot;
    /// if the hot window overflows (or DRAM is out of room), the oldest
    /// hot page demotes. Returns false when the cache is exhausted (the
    /// simulator then truncates the request; pages already granted stay
    /// resident until the sequence is freed).
    pub fn append(&mut self, id: u64, new_tokens: usize) -> bool {
        let (old_pages, old_tokens) = {
            let seq = self.seqs.get(&id).expect("append to unknown sequence");
            (seq.pages(), seq.tokens)
        };
        let new_pages = self.pages_for(old_tokens + new_tokens) - old_pages;
        for _ in 0..new_pages {
            // Make DRAM room for one hot page, demoting oldest-first.
            while self.dram_free() < self.page_bytes {
                let nothing_hot = self.seqs[&id].hot == 0;
                if nothing_hot || !self.demote_oldest(id) {
                    return false;
                }
            }
            let seq = self.seqs.get_mut(&id).expect("append to unknown sequence");
            seq.hot += 1;
            self.used[0] += self.page_bytes;
            self.counters.allocated_pages += 1;
            // Keep the hot window at the policy bound.
            while self.seqs[&id].hot > self.policy.hot_pages() {
                if !self.demote_oldest(id) {
                    break; // CXL full: tolerate an over-wide window
                }
            }
        }
        let seq = self.seqs.get_mut(&id).expect("append to unknown sequence");
        seq.tokens += new_tokens;
        true
    }

    /// Promote cold pages back into under-full hot windows (newest cold
    /// page first, ascending request id) while DRAM has room. Called by
    /// the simulator after completions free space. Returns bytes moved.
    pub fn promote_slack(&mut self) -> u64 {
        let hot_cap = self.policy.hot_pages();
        let mut moved = 0u64;
        let ids: Vec<u64> = self.seqs.keys().copied().collect();
        for id in ids {
            loop {
                let seq = &self.seqs[&id];
                if seq.cold.is_empty() || seq.hot >= hot_cap || self.dram_free() < self.page_bytes
                {
                    break;
                }
                let seq = self.seqs.get_mut(&id).expect("promote of unknown sequence");
                let shards = seq.cold.pop().expect("checked non-empty");
                seq.hot += 1;
                for &(n, b) in &shards {
                    self.used[n.0] -= b;
                }
                self.used[0] += self.page_bytes;
                self.counters.promoted_bytes += self.page_bytes;
                moved += self.page_bytes;
            }
        }
        moved
    }

    fn release(&mut self, id: u64) -> u64 {
        let seq = self.seqs.remove(&id).expect("release of unknown sequence");
        for page in &seq.cold {
            for &(n, b) in page {
                self.used[n.0] -= b;
            }
        }
        self.used[0] -= seq.hot as u64 * self.page_bytes;
        seq.pages() as u64
    }

    /// Release a completed sequence's pages.
    pub fn free(&mut self, id: u64) {
        let pages = self.release(id);
        self.counters.freed_pages += pages;
    }

    /// Forcibly drop a sequence (SLO shed / fault), counting its pages
    /// as evicted rather than freed.
    pub fn evict(&mut self, id: u64) {
        let pages = self.release(id);
        self.counters.evicted_pages += pages;
    }

    /// KV bytes of sequence `id` resident on the CXL tier — what a
    /// decode step must pull across the link to attend over.
    pub fn cold_bytes(&self, id: u64) -> u64 {
        self.seqs
            .get(&id)
            .map(|s| s.cold.len() as u64 * self.page_bytes)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn tiny_pager(policy: &str, dram_budget: u64) -> KvPager {
        let topo = presets::dev_tiny();
        KvPager::new(&topo, 1 << 20, dram_budget, by_name(policy).unwrap())
    }

    #[test]
    fn registry_resolves_and_rejects() {
        assert_eq!(by_name("dram-only").unwrap().name(), "dram-only");
        assert_eq!(
            by_name("tiered").unwrap().name(),
            format!("tiered:{DEFAULT_HOT_PAGES}")
        );
        assert_eq!(by_name("tiered:9").unwrap().name(), "tiered:9");
        assert_eq!(by_name("ours").unwrap().name(), "tiered:4");
        assert!(by_name("tiered:0").is_none());
        assert!(by_name("tiered:x").is_none());
        assert!(by_name("nope").is_none());
        assert_eq!(registry().len(), known_names().len());
    }

    #[test]
    fn tiered_keeps_the_hot_window_in_dram_and_stripes_the_rest() {
        // 1 MiB pages, room for 8 hot pages in DRAM.
        let mut p = tiny_pager("tiered:2", 8 << 20);
        // 6 pages: 2 hot + 4 demoted, striped across both AICs.
        assert!(p.alloc(0, 6 * PAGE_TOKENS));
        assert_eq!(p.dram_used(), 2 << 20);
        assert_eq!(p.cxl_used(), 4 << 20);
        assert_eq!(p.counters().demoted_bytes, 4 << 20);
        assert_eq!(p.cold_bytes(0), 4 << 20);
        // dev_tiny's two AICs are equal-capacity: the stripe must balance.
        assert_eq!(p.used()[1], p.used()[2]);
        // Decode growth: one more page in, one demoted out of the window.
        assert!(p.append(0, PAGE_TOKENS));
        assert_eq!(p.dram_used(), 2 << 20);
        assert_eq!(p.cxl_used(), 5 << 20);
        // Freeing returns every byte on every node.
        p.free(0);
        assert_eq!(p.used(), &[0, 0, 0]);
        assert_eq!(p.counters().resident_pages(), 0);
        assert_eq!(p.counters().allocated_pages, 7);
        assert_eq!(p.counters().freed_pages, 7);
    }

    #[test]
    fn dram_only_rejects_what_the_tiered_policy_accepts() {
        // Budget of 4 pages. A 6-page request only fits by spilling.
        let six = 6 * PAGE_TOKENS;
        let dram = tiny_pager("dram-only", 4 << 20);
        assert!(!dram.can_fit(six), "dram-only must reject a 6-page seq");
        let mut tiered = tiny_pager("tiered:2", 4 << 20);
        assert!(tiered.can_fit(six));
        assert!(tiered.alloc(0, six));
        assert_eq!(tiered.dram_used(), 2 << 20);
    }

    #[test]
    fn append_demotes_under_dram_pressure_and_fails_when_exhausted() {
        // DRAM holds 2 pages; AIC tier in dev_tiny holds 8 GiB total.
        let mut p = tiny_pager("tiered:8", 2 << 20);
        assert!(p.alloc(0, PAGE_TOKENS));
        assert!(p.alloc(1, PAGE_TOKENS));
        assert_eq!(p.dram_used(), 2 << 20);
        // Growing seq 0 must demote its own oldest page despite the
        // window allowing 8 hot pages.
        assert!(p.append(0, PAGE_TOKENS));
        assert_eq!(p.dram_used(), 2 << 20);
        assert_eq!(p.cold_bytes(0), 1 << 20);
        // After a free the slack promoter pulls the cold page back.
        p.free(1);
        let moved = p.promote_slack();
        assert_eq!(moved, 1 << 20);
        assert_eq!(p.cold_bytes(0), 0);
        assert_eq!(p.counters().promoted_bytes, 1 << 20);
        // dram-only exhaustion: appends fail once the budget is spent.
        let mut d = tiny_pager("dram-only", 2 << 20);
        assert!(d.alloc(0, 2 * PAGE_TOKENS));
        assert!(!d.append(0, PAGE_TOKENS), "no spill path for dram-only");
        // The failed append must not have changed anything.
        assert_eq!(d.dram_used(), 2 << 20);
        assert_eq!(d.counters().allocated_pages, 2);
    }

    #[test]
    fn eviction_counts_separately_and_conservation_holds() {
        let mut p = tiny_pager("tiered:1", 8 << 20);
        assert!(p.alloc(0, 3 * PAGE_TOKENS));
        assert!(p.alloc(1, 2 * PAGE_TOKENS));
        p.evict(0);
        p.free(1);
        let c = p.counters();
        assert_eq!(c.allocated_pages, 5);
        assert_eq!(c.evicted_pages, 3);
        assert_eq!(c.freed_pages, 2);
        assert_eq!(c.resident_pages(), 0);
        assert_eq!(p.used(), &[0, 0, 0]);
    }
}
