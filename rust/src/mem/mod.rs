//! Memory management layer: the paper's contribution.
//!
//! * [`region`] — Table I data classes + placements,
//! * [`striping`] — multi-AIC stripe arithmetic (§IV-B),
//! * [`policy`] — DramOnly / NaiveInterleave / CxlAware placement (§IV-A),
//! * [`allocator`] — NUMA capacity tracking and region lifecycle (the
//!   `libnuma` stand-in).

pub mod allocator;
pub mod policy;
pub mod region;
pub mod striping;

pub use allocator::{AllocError, NumaAllocator};
pub use policy::Policy;
pub use region::{Placement, Region, RegionId, RegionRequest, TensorClass};
