//! # cxlfine
//!
//! Reproduction of *"Analysis and Optimized CXL-Attached Memory Allocation
//! for Long-Context LLM Fine-Tuning"* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: ZeRO-Offload-style fine-tuning
//!   workflow engine, CXL-aware memory allocator with multi-AIC striping,
//!   a calibrated discrete-event CXL/NUMA/PCIe simulator, a real
//!   multithreaded CPU Adam, and a PJRT runtime that executes the
//!   AOT-compiled model.
//! * **L2 (python/compile/model.py)** — the JAX transformer (fwd/bwd per
//!   block), lowered once to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas flash-attention and fused
//!   linear-cross-entropy kernels, validated against a pure-jnp oracle.
//!
//! See `DESIGN.md` for the experiment index mapping every paper table and
//! figure to a module and bench target.

pub mod analysis;
pub mod cli;
pub mod fleet;
pub mod mem;
pub mod model;
pub mod offload;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod simcore;
pub mod topology;
pub mod train;
pub mod util;
