//! The Fig. 1 ZeRO-Offload iteration as a schedule DAG — the parity
//! builder that must reproduce the legacy hand-woven engine
//! (`offload::iteration`, now a frozen oracle) **byte-for-byte**.
//!
//! Parity hinges on two things (see `rust/tests/schedule_parity.rs` for
//! the differential lock):
//!
//! 1. **Node construction order = legacy issuance order.** The executor
//!    dispatches simultaneously-runnable nodes in ascending index order,
//!    so nodes are pushed exactly as the legacy state machine issued them:
//!    per GPU the initial prefetch window, then per forward block
//!    `compute → checkpoint-offload → next prefetch`, then the backward
//!    prefetch window, then per backward block `compute → grad-offload →
//!    next reload/ckpt-load`, and the CPU step last. Flow/timer ids — the
//!    DES tie-breakers — then match the legacy stream exactly.
//! 2. **Identical arithmetic.** Kernels carry FLOPs terms in the legacy
//!    operation order (`block + 0.5·head`), transfers carry the plan's
//!    byte counts, and the CPU step carries `(elements, layout)` plus the
//!    cast streams — the executor prices each with the same expressions
//!    the legacy engine inlined.
//!
//! One deliberate cleanup, pinned by the same differential tests as
//! *behavior-preserving on every paper cell*: a checkpoint load is gated
//! on `{its offload, its prefetch-window trigger}` as a pure AND-edge set,
//! where the legacy engine would also start it straight from a
//! late-landing offload completion slightly *before* its window. That
//! path requires an offload still in flight ≥ `depth` whole block-kernels
//! after it was issued — an order of magnitude away from any calibrated
//! configuration.

use super::super::plan::{MemoryPlan, RunConfig};
use super::super::schedule::{FlopsTerm, Op, OpId, OpNode, RegionTouch, Schedule};
use super::ScheduleBuilder;
use crate::mem::RegionId;
use crate::model::flops;
use crate::sim::fabric::Dir;
use crate::topology::{GpuId, NodeId, SystemTopology};

/// Everything one forward+backward pass of one GPU needs; shared by the
/// `grad-accum`, `lora` and `no-act-offload` builders so every scenario
/// keeps the exact streaming structure of Fig. 1.
pub struct PassShape<'a> {
    pub gpu: usize,
    pub layers: usize,
    /// Prefetch depth (already clamped ≥ 1).
    pub depth: usize,
    /// Host stripe fractions for bf16 parameter streams.
    pub p16: &'a [(NodeId, f64)],
    /// Host stripe fractions for bf16 gradient offloads.
    pub g16: &'a [(NodeId, f64)],
    /// Host stripe fractions for this GPU's activation checkpoints.
    pub acts: &'a [(NodeId, f64)],
    /// Plan regions the three streams above belong to (touch annotations
    /// for the tensor-access profiling pass).
    pub p16_region: RegionId,
    pub g16_region: RegionId,
    pub acts_region: RegionId,
    pub param_block_bytes: f64,
    pub act_block_bytes: f64,
    pub grad_block_bytes: f64,
    /// FLOPs of one block forward / backward(+recompute) / embed+head fwd.
    pub f_fwd_block: f64,
    pub f_bwd_block: f64,
    pub f_head: f64,
    /// When false, checkpoints stay in HBM: no offload and no reload
    /// (the `no-act-offload` ablation).
    pub offload_activations: bool,
    /// Span-name suffix, e.g. `" m2"` for micro-batch 2 (`""` = legacy
    /// names, required for byte-parity).
    pub label: String,
    /// The pass starts only after this node (micro-batch chaining).
    pub entry_dep: Option<OpId>,
}

/// Node ids a pass hands back to its caller.
pub struct PassOut {
    /// One gradient offload per block; the optimizer step depends on all.
    pub grads: Vec<OpId>,
    /// The last backward kernel (block 0) — the chain point for the next
    /// micro-batch.
    pub last_bwd: OpId,
}

#[allow(clippy::too_many_arguments)]
fn transfer(
    gpu: usize,
    stripes: &[(NodeId, f64)],
    dir: Dir,
    bytes: f64,
    deps: Vec<OpId>,
    name: String,
    lane: String,
    phase: usize,
    ends_phase: bool,
    region: RegionId,
) -> OpNode {
    OpNode {
        op: Op::Transfer {
            gpu: GpuId(gpu),
            stripes: stripes.to_vec(),
            dir,
            bytes,
        },
        deps,
        name,
        lane,
        phase,
        ends_phase,
        touches: vec![RegionTouch::Dma(region)],
    }
}

/// Emit one GPU's forward+backward pass in legacy issuance order.
pub fn emit_pass(s: &mut Schedule, p: &PassShape<'_>, fwd: usize, bwd: usize) -> PassOut {
    let g = p.gpu;
    let layers = p.layers;
    let depth = p.depth;
    let lab = &p.label;
    let h2d = format!("gpu{g}/h2d");
    let d2h = format!("gpu{g}/d2h");
    let compute = format!("gpu{g}/compute");
    let entry: Vec<OpId> = p.entry_dep.into_iter().collect();

    let mut fwd_load: Vec<Option<OpId>> = vec![None; layers];
    let mut fwd_compute: Vec<Option<OpId>> = vec![None; layers];
    let mut act_off: Vec<Option<OpId>> = vec![None; layers];

    // Initial prefetch window: the first `depth` blocks' parameters.
    for l in 0..depth.min(layers) {
        fwd_load[l] = Some(s.push(transfer(
            g,
            p.p16,
            Dir::HostToGpu,
            p.param_block_bytes,
            entry.clone(),
            format!("param-load{lab} b{l}"),
            h2d.clone(),
            fwd,
            false,
            p.p16_region,
        )));
    }

    // Forward: per block, kernel → checkpoint offload → next prefetch.
    for l in 0..layers {
        let mut deps = vec![fwd_load[l].expect("prefetch covered every block")];
        if l > 0 {
            deps.push(fwd_compute[l - 1].unwrap());
        }
        let mut work = vec![FlopsTerm::new(p.f_fwd_block)];
        if l == 0 || l == layers - 1 {
            // embedding on the first block, LM head + loss on the last
            work.push(FlopsTerm::scaled(p.f_head, 0.5));
        }
        let fc = s.push(OpNode {
            op: Op::Compute {
                gpu: GpuId(g),
                work,
            },
            deps,
            name: format!("fwd{lab} b{l}"),
            lane: compute.clone(),
            phase: fwd,
            ends_phase: l == layers - 1,
            touches: vec![],
        });
        fwd_compute[l] = Some(fc);
        if p.offload_activations {
            act_off[l] = Some(s.push(transfer(
                g,
                p.acts,
                Dir::GpuToHost,
                p.act_block_bytes,
                vec![fc],
                format!("ckpt-offload{lab} b{l}"),
                d2h.clone(),
                fwd,
                false,
                p.acts_region,
            )));
        }
        let nxt = l + depth;
        if nxt < layers {
            fwd_load[nxt] = Some(s.push(transfer(
                g,
                p.p16,
                Dir::HostToGpu,
                p.param_block_bytes,
                vec![fc],
                format!("param-load{lab} b{nxt}"),
                h2d.clone(),
                fwd,
                false,
                p.p16_region,
            )));
        }
    }
    let last_fwd = fwd_compute[layers - 1].unwrap();

    // Backward prefetch window, descending from the top block.
    let mut bwd_load: Vec<Option<OpId>> = vec![None; layers];
    let mut act_load: Vec<Option<OpId>> = vec![None; layers];
    for k in 0..depth.min(layers) {
        let l = layers - 1 - k;
        bwd_load[l] = Some(s.push(transfer(
            g,
            p.p16,
            Dir::HostToGpu,
            p.param_block_bytes,
            vec![last_fwd],
            format!("param-reload{lab} b{l}"),
            h2d.clone(),
            bwd,
            false,
            p.p16_region,
        )));
        if p.offload_activations {
            act_load[l] = Some(s.push(transfer(
                g,
                p.acts,
                Dir::HostToGpu,
                p.act_block_bytes,
                vec![act_off[l].unwrap(), last_fwd],
                format!("ckpt-load{lab} b{l}"),
                h2d.clone(),
                bwd,
                false,
                p.acts_region,
            )));
        }
    }

    // Backward: per block (top down), kernel → grad offload → next
    // reload + checkpoint load `depth` below.
    let mut grads = Vec::with_capacity(layers);
    let mut prev_bwd: Option<OpId> = None;
    for l in (0..layers).rev() {
        let mut deps = vec![bwd_load[l].expect("reload covered every block")];
        if let Some(al) = act_load[l] {
            deps.push(al);
        }
        if let Some(pb) = prev_bwd {
            deps.push(pb);
        }
        let mut work = vec![FlopsTerm::new(p.f_bwd_block)];
        if l == layers - 1 {
            // head backward ≈ 2× its fwd, recompute ≈ fwd; fold as 1×
            work.push(FlopsTerm::new(p.f_head));
        }
        let bc = s.push(OpNode {
            op: Op::Compute {
                gpu: GpuId(g),
                work,
            },
            deps,
            name: format!("bwd{lab} b{l}"),
            lane: compute.clone(),
            phase: bwd,
            ends_phase: false,
            touches: vec![],
        });
        grads.push(s.push(transfer(
            g,
            p.g16,
            Dir::GpuToHost,
            p.grad_block_bytes,
            vec![bc],
            format!("grad-offload{lab} b{l}"),
            d2h.clone(),
            bwd,
            true,
            p.g16_region,
        )));
        if l >= depth {
            let t = l - depth;
            bwd_load[t] = Some(s.push(transfer(
                g,
                p.p16,
                Dir::HostToGpu,
                p.param_block_bytes,
                vec![bc],
                format!("param-reload{lab} b{t}"),
                h2d.clone(),
                bwd,
                false,
                p.p16_region,
            )));
            if p.offload_activations {
                act_load[t] = Some(s.push(transfer(
                    g,
                    p.acts,
                    Dir::HostToGpu,
                    p.act_block_bytes,
                    vec![act_off[t].unwrap(), bc],
                    format!("ckpt-load{lab} b{t}"),
                    h2d.clone(),
                    bwd,
                    false,
                    p.acts_region,
                )));
            }
        }
        prev_bwd = Some(bc);
    }

    PassOut {
        grads,
        last_bwd: prev_bwd.unwrap(),
    }
}

/// Per-block/model quantities every Fig.-1-shaped builder starts from.
pub struct IterQuantities {
    pub layers: usize,
    pub depth: usize,
    pub param_block_bytes: f64,
    pub act_block_bytes: f64,
    pub grad_block_bytes: f64,
    pub f_fwd_block: f64,
    pub f_bwd_block: f64,
    pub f_head: f64,
}

impl IterQuantities {
    pub fn compute(cfg: &RunConfig, plan: &MemoryPlan<'_>) -> Self {
        let layers = cfg.model.layers;
        let b = cfg.workload.batch;
        let c = cfg.workload.context;
        Self {
            layers,
            depth: cfg.prefetch_depth.max(1),
            param_block_bytes: plan.footprint.params_bf16 as f64 / layers as f64,
            act_block_bytes: 2.0 * (b as f64) * (c as f64) * (cfg.model.hidden as f64),
            grad_block_bytes: plan.footprint.grads_bf16 as f64 / layers as f64,
            f_fwd_block: flops::block_fwd_flops(&cfg.model, b, c),
            f_bwd_block: flops::block_bwd_flops(&cfg.model, b, c, true),
            f_head: flops::head_fwd_flops(&cfg.model, b, c),
        }
    }
}

/// Touch annotations of the full-model CPU step: the Adam pass
/// read-modify-writes the merged fp32 P/G/O working set, stream 0 reads
/// the fp32 master, stream 1 writes the bf16 copy, and the bf16 gradients
/// are consumed without separately-priced traffic (the calibrated STEP
/// model folds their read into the Adam pass) — a keepalive so their
/// liveness window extends through the step.
pub fn cpu_step_touches(plan: &MemoryPlan<'_>) -> Vec<RegionTouch> {
    vec![
        RegionTouch::CpuRmw(plan.master),
        RegionTouch::CpuRmw(plan.grads32),
        RegionTouch::CpuRmw(plan.optstates),
        RegionTouch::CpuStream {
            region: plan.master,
            stream: 0,
        },
        RegionTouch::CpuStream {
            region: plan.params16,
            stream: 1,
        },
        RegionTouch::Keepalive(plan.grads16),
    ]
}

/// The full-model CPU optimizer step + bf16 re-cast, as the legacy engine
/// priced it: one Adam pass over all parameters in the plan's merged
/// layout, plus streaming the fp32 master (read) and bf16 copy (write).
pub fn full_model_cpu_step(
    cfg: &RunConfig,
    plan: &MemoryPlan<'_>,
    deps: Vec<OpId>,
    phase: usize,
) -> OpNode {
    OpNode {
        op: Op::CpuStep {
            adam_elements: cfg.model.params(),
            adam_layout: plan.opt_layout(),
            streams: vec![
                (
                    plan.footprint.params_fp32 as f64,
                    plan.region_layout(plan.master),
                ),
                (
                    plan.footprint.params_bf16 as f64,
                    plan.region_layout(plan.params16),
                ),
            ],
        },
        deps,
        name: "optimizer step".into(),
        lane: "cpu/step".into(),
        phase,
        ends_phase: true,
        touches: cpu_step_touches(plan),
    }
}

/// Knobs the Fig.-1-shaped builders vary on top of the shared scaffold.
pub struct Fig1Shape {
    /// Micro-batches per optimizer step (chained on the previous
    /// micro-batch's last backward kernel); tokens scale with it.
    pub micro_batches: usize,
    /// When false, checkpoints stay in HBM (`no-act-offload`).
    pub offload_activations: bool,
    /// Suffix span names with `" m{m}"` (multi-micro-batch traces).
    pub micro_labels: bool,
    /// Override the per-block gradient offload size (`lora` shrinks it to
    /// the adapters); `None` = the plan's full bf16 gradient block.
    pub grad_block_bytes: Option<f64>,
}

impl Default for Fig1Shape {
    fn default() -> Self {
        Self {
            micro_batches: 1,
            offload_activations: true,
            micro_labels: false,
            grad_block_bytes: None,
        }
    }
}

/// Shared scaffold for every Fig.-1-shaped builder: emit all GPUs'
/// (micro-batched) forward+backward passes into a fresh schedule.
/// Returns the schedule, every gradient-offload node (the CPU step's
/// dependency set), and the interned `"step"` phase index — the caller
/// appends its own optimizer-step node. With `Fig1Shape::default()` the
/// node construction order is exactly the legacy engine's issuance order
/// (the byte-parity contract documented at the top of this file).
pub fn build_fig1_passes(
    cfg: &RunConfig,
    plan: &MemoryPlan<'_>,
    shape: &Fig1Shape,
) -> (Schedule, Vec<OpId>, usize) {
    let q = IterQuantities::compute(cfg, plan);
    let k = shape.micro_batches;
    let n_gpus = cfg.workload.n_gpus;
    let p16 = plan.params16_fractions();
    let g16 = plan.grads16_fractions();
    let grad_block_bytes = shape.grad_block_bytes.unwrap_or(q.grad_block_bytes);

    let mut s = Schedule::new(cfg.workload.tokens_per_iter() * k as u64);
    let fwd = s.phase("fwd");
    let bwd = s.phase("bwd");
    let step = s.phase("step");

    let mut all_grads = Vec::with_capacity(n_gpus * k * q.layers);
    for g in 0..n_gpus {
        let acts = plan.activation_fractions(GpuId(g));
        let mut entry = None;
        for m in 0..k {
            let out = emit_pass(
                &mut s,
                &PassShape {
                    gpu: g,
                    layers: q.layers,
                    depth: q.depth,
                    p16: &p16,
                    g16: &g16,
                    acts: &acts,
                    p16_region: plan.params16,
                    g16_region: plan.grads16,
                    acts_region: plan.activations[g],
                    param_block_bytes: q.param_block_bytes,
                    act_block_bytes: q.act_block_bytes,
                    grad_block_bytes,
                    f_fwd_block: q.f_fwd_block,
                    f_bwd_block: q.f_bwd_block,
                    f_head: q.f_head,
                    offload_activations: shape.offload_activations,
                    label: if shape.micro_labels {
                        format!(" m{m}")
                    } else {
                        String::new()
                    },
                    entry_dep: entry,
                },
                fwd,
                bwd,
            );
            entry = Some(out.last_bwd);
            all_grads.extend(out.grads);
        }
    }
    (s, all_grads, step)
}

/// The registry entry.
pub struct ZeroOffload;

impl ScheduleBuilder for ZeroOffload {
    fn name(&self) -> &str {
        "zero-offload"
    }

    fn build(&self, _topo: &SystemTopology, cfg: &RunConfig, plan: &MemoryPlan<'_>) -> Schedule {
        let (mut s, all_grads, step) = build_fig1_passes(cfg, plan, &Fig1Shape::default());
        s.push(full_model_cpu_step(cfg, plan, all_grads, step));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Policy;
    use crate::model::footprint::Workload;
    use crate::model::presets::tiny_2m;
    use crate::topology::presets::dev_tiny;

    #[test]
    fn builds_a_valid_dag_with_expected_shape() {
        let topo = dev_tiny();
        let cfg = RunConfig::new(tiny_2m(), Workload::new(2, 2, 256), Policy::DramOnly);
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let s = ZeroOffload.build(&topo, &cfg, &plan);
        s.validate(&topo).unwrap();
        // per GPU: L loads + L fwd + L ckpt-offloads + L reloads +
        // L ckpt-loads + L bwd + L grads = 7L, plus one CPU step
        let l = cfg.model.layers;
        assert_eq!(s.len(), 2 * 7 * l + 1);
        assert_eq!(s.phases, vec!["fwd", "bwd", "step"]);
        // the step node is last and depends on every grad offload
        let last = &s.nodes[s.len() - 1];
        assert!(matches!(last.op, Op::CpuStep { .. }));
        assert_eq!(last.deps.len(), 2 * l);
    }
}
