//! CPU optimizer: the real vectorized Adam the coordinator runs on the
//! host (L3 owns the optimizer, exactly as ZeRO-Offload does), plus the
//! placed-tensor wrapper that ties parameter groups to memory regions.

pub mod adam;
pub mod group;

pub use adam::{adam_step, adam_step_auto, adam_step_spawning, AdamHp, AdamState};
pub use group::ParamGroup;
