//! Admission / scheduling policies and their registry (the fleet analogue
//! of `mem::engine` and `offload::schedules`).
//!
//! A policy is consulted at every scheduling point (job arrival, job
//! completion) through an [`AdmissionProbe`]: it inspects the queue in
//! arrival order and calls [`AdmissionProbe::try_admit`] for the jobs it
//! wants to start. A successful `try_admit` *immediately* debits the
//! probe's working free view (memory shards + GPU slots), so later picks
//! in the same pass see the updated capacity — policies stay pure
//! decision logic while all placement/capacity arithmetic lives behind
//! the probe (the simulator implements it with real `MemoryPlan` builds).
//!
//! Registered policies:
//!
//! | Name | Accounting | Engine | Queue discipline |
//! |---|---|---|---|
//! | `fifo` | static | requested | strict order, head-of-line blocking |
//! | `backfill` | static | requested | any fitting job may jump the blocked head |
//! | `placement-aware` | lifetime (per-phase peak) | requested, then better-fitting alternatives | backfill order |

use std::sync::Arc;

use super::job::JobSpec;

/// What a policy may ask of the simulator at one scheduling point.
pub trait AdmissionProbe {
    /// Queued jobs, in arrival order. Indices are stable for the whole
    /// pass; already-admitted indices simply refuse further admission.
    fn queue_len(&self) -> usize;

    fn job(&self, idx: usize) -> &JobSpec;

    /// Try to start queued job `idx` now with `engine` (registry name;
    /// `None` = the job's requested engine) under static or lifetime
    /// (per-phase peak) capacity accounting, against the current working
    /// free view. On success the reservation (memory + GPUs) is debited
    /// and recorded; `false` means the job does not fit right now (or the
    /// engine name is unknown, or `idx` was already admitted this pass).
    fn try_admit(&mut self, idx: usize, engine: Option<&str>, lifetime: bool) -> bool;
}

/// An admission/scheduling policy.
pub trait SchedPolicy: Send + Sync {
    /// Registry / CLI name, e.g. `"placement-aware"`.
    fn name(&self) -> &'static str;

    /// Admit zero or more queued jobs at this scheduling point.
    fn schedule(&self, probe: &mut dyn AdmissionProbe);
}

/// Shared handle to a policy — what the simulator, CLI and benches thread.
pub type PolicyRef = Arc<dyn SchedPolicy>;

/// Strict arrival order with head-of-line blocking: admission stops at
/// the first queued job that does not fit (static accounting, requested
/// engine) — the classic batch-queue baseline.
pub struct Fifo;

impl SchedPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn schedule(&self, probe: &mut dyn AdmissionProbe) {
        for i in 0..probe.queue_len() {
            if !probe.try_admit(i, None, false) {
                break;
            }
        }
    }
}

/// Out-of-order backfill: every queued job that fits the current free
/// capacity starts, regardless of a blocked head (EASY-style backfill
/// without reservations; static accounting, requested engine).
pub struct Backfill;

impl SchedPolicy for Backfill {
    fn name(&self) -> &'static str {
        "backfill"
    }

    fn schedule(&self, probe: &mut dyn AdmissionProbe) {
        for i in 0..probe.queue_len() {
            let _ = probe.try_admit(i, None, false);
        }
    }
}

/// The paper-side policy: admit a job only if a *lifetime-aware* plan
/// (`MemoryPlan::fits_lifetime_aware` semantics — per-phase peak, not the
/// static sum) fits, and choose the placement engine per job — the
/// requested engine first, then the profile-driven and adaptive
/// alternatives in a fixed order. Jobs whose static footprint overflows
/// the host but whose liveness windows interleave are exactly the ones
/// this policy serves and the static policies reject.
pub struct PlacementAware;

/// Alternative engines `placement-aware` tries after the requested one,
/// in order.
pub const PLACEMENT_AWARE_ALTERNATIVES: [&str; 3] =
    ["profile-aware", "cxl-aware+striping", "adaptive-spill"];

impl SchedPolicy for PlacementAware {
    fn name(&self) -> &'static str {
        "placement-aware"
    }

    fn schedule(&self, probe: &mut dyn AdmissionProbe) {
        for i in 0..probe.queue_len() {
            let requested = probe.job(i).engine.clone();
            let mut candidates = vec![requested];
            for alt in PLACEMENT_AWARE_ALTERNATIVES {
                if candidates.iter().all(|c| c != alt) {
                    candidates.push(alt.to_string());
                }
            }
            for engine in &candidates {
                if probe.try_admit(i, Some(engine), true) {
                    break;
                }
            }
        }
    }
}

/// Canonical names of every registered policy (CLI help text).
pub fn known_names() -> Vec<&'static str> {
    vec!["fifo", "backfill", "placement-aware"]
}

/// Resolve a policy by name (the CLI/bench entry point; new policies
/// register here, nothing else changes).
pub fn by_name(name: &str) -> Option<PolicyRef> {
    match name {
        "fifo" => Some(Arc::new(Fifo)),
        "backfill" => Some(Arc::new(Backfill)),
        "placement-aware" | "ours" => Some(Arc::new(PlacementAware)),
        _ => None,
    }
}

/// One instance of every registered policy, in canonical order.
pub fn registry() -> Vec<PolicyRef> {
    known_names()
        .into_iter()
        .map(|n| by_name(n).expect("known name resolves"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_every_known_name() {
        for name in known_names() {
            let p = by_name(name).unwrap_or_else(|| panic!("{name} must resolve"));
            assert_eq!(p.name(), name, "canonical name must round-trip");
        }
        assert_eq!(by_name("ours").unwrap().name(), "placement-aware");
        assert!(by_name("??").is_none());
        assert_eq!(registry().len(), known_names().len());
    }

    /// Scripted probe: job `i` fits iff `fits[i]`; records every admission
    /// and the accounting mode / engine it was asked under.
    struct Scripted {
        jobs: Vec<JobSpec>,
        fits: Vec<bool>,
        admitted: Vec<usize>,
        lifetime_seen: Vec<bool>,
        engines_seen: Vec<Vec<String>>,
    }

    impl Scripted {
        fn new(fits: Vec<bool>) -> Self {
            let jobs = (0..fits.len())
                .map(|i| JobSpec {
                    id: i as u64,
                    arrival_s: i as f64,
                    model: "tiny-2m".into(),
                    gpus: 1,
                    batch: 1,
                    context: 256,
                    schedule: "zero-offload".into(),
                    engine: "cxl-aware".into(),
                    iterations: 1,
                })
                .collect();
            Self {
                engines_seen: vec![Vec::new(); fits.len()],
                lifetime_seen: Vec::new(),
                admitted: Vec::new(),
                fits,
                jobs,
            }
        }
    }

    impl AdmissionProbe for Scripted {
        fn queue_len(&self) -> usize {
            self.jobs.len()
        }
        fn job(&self, idx: usize) -> &JobSpec {
            &self.jobs[idx]
        }
        fn try_admit(&mut self, idx: usize, engine: Option<&str>, lifetime: bool) -> bool {
            self.engines_seen[idx]
                .push(engine.unwrap_or(&self.jobs[idx].engine).to_string());
            self.lifetime_seen.push(lifetime);
            if self.fits[idx] && !self.admitted.contains(&idx) {
                self.admitted.push(idx);
                true
            } else {
                false
            }
        }
    }

    #[test]
    fn fifo_blocks_at_the_head() {
        let mut p = Scripted::new(vec![true, false, true]);
        Fifo.schedule(&mut p);
        assert_eq!(p.admitted, vec![0], "job 2 must wait behind blocked job 1");
        assert!(p.lifetime_seen.iter().all(|l| !l), "fifo is static-accounted");
    }

    #[test]
    fn backfill_jumps_the_blocked_head() {
        let mut p = Scripted::new(vec![true, false, true]);
        Backfill.schedule(&mut p);
        assert_eq!(p.admitted, vec![0, 2], "fitting job 2 backfills past job 1");
    }

    #[test]
    fn placement_aware_tries_requested_engine_first_then_alternatives() {
        let mut p = Scripted::new(vec![false, true]);
        PlacementAware.schedule(&mut p);
        assert!(p.lifetime_seen.iter().all(|l| *l), "lifetime accounting only");
        // Job 0 never fits → all four candidates tried, requested first.
        assert_eq!(
            p.engines_seen[0],
            vec!["cxl-aware", "profile-aware", "cxl-aware+striping", "adaptive-spill"]
        );
        // Job 1 fits on the first try → no alternatives consulted.
        assert_eq!(p.engines_seen[1], vec!["cxl-aware"]);
        assert_eq!(p.admitted, vec![1]);
    }
}
