//! The functional fine-tuning loop: the Figure-1 workflow executed for
//! real on the PJRT CPU client.
//!
//! Per step:
//! 1. embed the batch (`embed_fwd`),
//! 2. **FWD** — run blocks in order, storing each block's *input* in the
//!    host checkpoint arena (the "offloaded activation checkpoint"),
//! 3. head + loss (`head_loss`, fused linear-cross-entropy → loss, dx, and
//!    the tied-head embedding gradient),
//! 4. **BWD** — blocks in reverse: reload the checkpoint, `block_bwd`
//!    (which recomputes the forward internally — true gradient
//!    checkpointing), collect per-block gradients,
//! 5. **STEP** — the Rust CPU Adam updates every group.
//!
//! The same placement machinery the simulator uses tags the checkpoint
//! arena and parameter groups with memory regions, so a training run also
//! reports where its bytes would live on the Config-A/B machines.

use anyhow::{bail, Result};

use super::data::CorpusGen;
use super::state::TrainState;
use crate::optim::AdamHp;
use crate::runtime::{Arg, HostTensor, HostTensorI32, Runtime};

/// Trainer configuration.
#[derive(Clone, Debug)]
pub struct TrainerCfg {
    pub batch: usize,
    pub context: usize,
    pub steps: usize,
    pub hp: AdamHp,
    pub threads: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainerCfg {
    fn default() -> Self {
        Self {
            batch: 4,
            context: 128,
            steps: 200,
            hp: AdamHp {
                lr: 3e-3,
                ..Default::default()
            },
            threads: crate::util::threadpool::default_threads(),
            seed: 0,
            log_every: 10,
        }
    }
}

/// One logged step.
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    pub step: usize,
    pub loss: f64,
    pub wall_s: f64,
    /// Bytes held in the host checkpoint arena at the FWD/BWD boundary.
    pub checkpoint_bytes: u64,
}

/// The trainer.
pub struct Trainer<'r> {
    rt: &'r Runtime,
    pub state: TrainState,
    cfg: TrainerCfg,
    data: CorpusGen,
}

impl<'r> Trainer<'r> {
    pub fn new(rt: &'r Runtime, cfg: TrainerCfg) -> Result<Self> {
        let vocab = rt.manifest().meta_usize("vocab")?;
        let state = TrainState::init(rt.manifest(), cfg.seed)?;
        let data = CorpusGen::new(vocab, cfg.seed ^ 0xC0FFEE);
        // shape sanity: the artifacts were lowered for a fixed (B, C)
        let (b, c) = batch_shape(rt)?;
        if (b, c) != (cfg.batch, cfg.context) {
            bail!(
                "artifacts lowered for batch={b} context={c}, trainer configured {}/{}",
                cfg.batch,
                cfg.context
            );
        }
        Ok(Self {
            rt,
            state,
            cfg,
            data,
        })
    }

    /// Run one training step; returns the mean loss.
    pub fn step(&mut self) -> Result<(f64, u64)> {
        let (ids, labels) = self.data.batch(self.cfg.batch, self.cfg.context);
        let shape = vec![self.cfg.batch, self.cfg.context];
        let ids_t = HostTensorI32::new(ids.clone(), shape.clone());
        let labels_t = HostTensorI32::new(labels, shape);

        // (1) embed
        let x0 = self
            .rt
            .exec(
                "embed_fwd",
                &[
                    Arg::I32(ids_t.clone()),
                    Arg::F32(self.state.embed.tensor(0)),
                ],
            )?
            .remove(0);

        // (2) FWD with checkpoint offload: arena keeps each block's input
        let layers = self.state.blocks.len();
        let mut arena: Vec<HostTensor> = Vec::with_capacity(layers);
        let mut x = x0;
        for l in 0..layers {
            arena.push(x.clone()); // the offloaded checkpoint
            let mut args: Vec<Arg> = Vec::with_capacity(1 + self.state.blocks[l].specs.len());
            args.push(Arg::F32(x));
            args.extend(self.state.blocks[l].tensors().into_iter().map(Arg::F32));
            x = self.rt.exec("block_fwd", &args)?.remove(0);
        }
        let checkpoint_bytes: u64 = arena
            .iter()
            .map(|t| 4 * t.element_count() as u64)
            .sum();

        // (3) head + loss (+ tied-head embedding grad)
        let mut head_out = self.rt.exec(
            "head_loss",
            &[
                Arg::F32(x),
                Arg::F32(self.state.final_norm.tensor(0)),
                Arg::F32(self.state.embed.tensor(0)),
                Arg::I32(labels_t),
            ],
        )?;
        // outputs: loss, dx, dlnf, demb_head
        let loss = head_out[0].data[0] as f64;
        let demb_head = head_out.pop().expect("demb_head");
        let dlnf = head_out.pop().expect("dlnf");
        let mut dx = head_out.pop().expect("dx");

        // (4) BWD: reload checkpoints, recompute-and-backprop per block
        let mut block_grads: Vec<Vec<f32>> = Vec::with_capacity(layers);
        for l in (0..layers).rev() {
            let ckpt = arena[l].clone(); // "reload from host memory"
            let mut args: Vec<Arg> = Vec::with_capacity(2 + self.state.blocks[l].specs.len());
            args.push(Arg::F32(ckpt));
            args.extend(self.state.blocks[l].tensors().into_iter().map(Arg::F32));
            args.push(Arg::F32(dx));
            let mut outs = self.rt.exec("block_bwd", &args)?;
            // outputs: dx, then one grad per param tensor
            dx = outs.remove(0);
            let flat = self.state.blocks[l].flatten_grads(&outs)?;
            block_grads.push(flat);
        }
        block_grads.reverse();

        // embedding grad: scatter-add of dx through the embedding + tied head
        let demb = self
            .rt
            .exec("embed_bwd", &[Arg::I32(ids_t), Arg::F32(dx)])?
            .remove(0);
        let mut demb_total = demb.data;
        for (a, b) in demb_total.iter_mut().zip(&demb_head.data) {
            *a += b;
        }

        // (5) STEP: Rust CPU Adam over every group
        for (l, g) in block_grads.iter().enumerate() {
            self.state.blocks[l].step(g, &self.cfg.hp, self.cfg.threads);
        }
        self.state.embed.step(&demb_total, &self.cfg.hp, self.cfg.threads);
        self.state
            .final_norm
            .step(&dlnf.data, &self.cfg.hp, self.cfg.threads);

        Ok((loss, checkpoint_bytes))
    }

    /// Run the configured number of steps, returning the loss curve.
    pub fn train(&mut self) -> Result<Vec<StepLog>> {
        let mut logs = Vec::with_capacity(self.cfg.steps);
        for s in 0..self.cfg.steps {
            let t0 = std::time::Instant::now();
            let (loss, checkpoint_bytes) = self.step()?;
            let wall_s = t0.elapsed().as_secs_f64();
            if !loss.is_finite() {
                bail!("loss diverged at step {s}");
            }
            let log = StepLog {
                step: s,
                loss,
                wall_s,
                checkpoint_bytes,
            };
            if s % self.cfg.log_every == 0 || s + 1 == self.cfg.steps {
                crate::log_info!(
                    "step {:>4}  loss {:.4}  {:.0} tok/s  ckpt {}",
                    s,
                    loss,
                    (self.cfg.batch * self.cfg.context) as f64 / wall_s,
                    crate::util::units::fmt_bytes(checkpoint_bytes)
                );
            }
            logs.push(log);
        }
        Ok(logs)
    }
}

/// Read the lowered (batch, context) from the embed entry.
pub fn batch_shape(rt: &Runtime) -> Result<(usize, usize)> {
    let e = rt.manifest().entry("embed_fwd")?;
    let s = &e.inputs[0].shape;
    if s.len() != 2 {
        bail!("embed_fwd ids should be [B, C], got {s:?}");
    }
    Ok((s[0], s[1]))
}

// Integration tests for the trainer live in rust/tests/e2e_train.rs (they
// need real artifacts from `make artifacts`).
