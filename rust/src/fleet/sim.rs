//! The multi-tenant discrete-event fleet simulator.
//!
//! Jobs arrive over simulated time (heap-ordered events, dslab-style:
//! completions before arrivals at equal timestamps, unique sequence
//! numbers as the final tie-break, `f64::to_bits` as the heap key — exact
//! for the non-negative times the fleet uses), pass the configured
//! admission policy, occupy DRAM/CXL capacity and GPU slots on a
//! [`FleetHost`] for their whole residency, and run `iterations ×
//! iter_s` where `iter_s` comes from a [`Calibrator`]: one *real*
//! `offload::executor` run per distinct (configuration, engine) pair,
//! memoized, so fleets of hundreds of jobs cost hundreds of plan builds
//! but only a handful of executor runs.
//!
//! Determinism contract: the event loop is serial and every tie is broken
//! by explicit keys; calibration cells are pure functions of (topology,
//! config, engine), so pre-warming them in parallel (`--threads`) cannot
//! change any value. Identical traces therefore produce bit-identical
//! [`FleetResult::digest`]s across reruns and thread counts (pinned by
//! `rust/tests/fleet_sim.rs`).
//!
//! Rejection rule: a job is rejected *at arrival* iff the policy cannot
//! place it on an **empty** host (same engines, same accounting) —
//! otherwise it queues, and since the event loop re-schedules at every
//! completion, every queued job eventually starts and the simulation
//! always drains.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};

use super::host::FleetHost;
use super::job::{FleetTrace, JobSpec, TraceGen};
use super::metrics::{FleetResult, JobRecord, JobStatus, OccupancySample};
use super::scheduler::{AdmissionProbe, PolicyRef};
use crate::mem::engine;
use crate::model::presets as mpresets;
use crate::offload::{
    schedules, simulate_iteration, MemoryPlan, PlanReservation, RunConfig, RunProfiles,
};
use crate::topology::SystemTopology;
use crate::util::threadpool::par_map;

/// Calibrated price of one iteration of a (configuration, engine) pair,
/// measured on the empty host.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CalCost {
    pub iter_s: f64,
    pub tokens_per_iter: u64,
}

fn resolve_cfg(spec: &JobSpec, engine_name: &str) -> Option<RunConfig> {
    let model = mpresets::by_name(&spec.model)?;
    let eng = engine::by_name(engine_name)?;
    let schedule = schedules::by_name(&spec.schedule)?;
    Some(RunConfig::new(model, spec.workload(), eng).with_schedule(schedule))
}

/// Placement-independent per-region profiles of a job's configuration
/// (probe-based, so always computed against the real topology whose
/// capacities validate).
fn compute_profiles(topo: &SystemTopology, spec: &JobSpec) -> Option<RunProfiles> {
    if spec.gpus > topo.gpus.len() {
        return None;
    }
    let cfg = resolve_cfg(spec, "baseline-dram")?;
    MemoryPlan::profile_run(topo, &cfg).ok()
}

/// One real executor run on the empty host: the job's calibrated cost.
/// Falls back to a lifetime-aware plan for configurations only timeline
/// accounting can fit at all.
fn compute_cost(
    topo: &SystemTopology,
    spec: &JobSpec,
    engine_name: &str,
    profiles: Option<&RunProfiles>,
) -> Option<CalCost> {
    if spec.gpus > topo.gpus.len() {
        return None;
    }
    let cfg = resolve_cfg(spec, engine_name)?;
    let prof = profiles?;
    let plan = MemoryPlan::build_with_profiles(topo, &cfg, false, prof.clone())
        .or_else(|_| MemoryPlan::build_with_profiles(topo, &cfg, true, prof.clone()))
        .ok()?;
    let bd = simulate_iteration(topo, &cfg, &plan);
    Some(CalCost {
        iter_s: bd.iter_s,
        tokens_per_iter: bd.tokens,
    })
}

/// Memoized per-(configuration, engine) cost model and per-configuration
/// profile cache. Every value is a pure function of the (real, validated)
/// host topology, so cache warm-up order — including the parallel
/// pre-warm — cannot change results.
pub struct Calibrator<'t> {
    topo: &'t SystemTopology,
    profiles: BTreeMap<String, Option<RunProfiles>>,
    costs: BTreeMap<String, Option<CalCost>>,
}

impl<'t> Calibrator<'t> {
    pub fn new(topo: &'t SystemTopology) -> Self {
        Self {
            topo,
            profiles: BTreeMap::new(),
            costs: BTreeMap::new(),
        }
    }

    /// Cached measured profiles of the job's configuration (`None` when
    /// the model/schedule does not resolve or wants more GPUs than exist).
    pub fn profiles(&mut self, spec: &JobSpec) -> Option<RunProfiles> {
        let topo = self.topo;
        self.profiles
            .entry(spec.config_key())
            .or_insert_with(|| compute_profiles(topo, spec))
            .clone()
    }

    /// Cached calibrated cost of (configuration, engine).
    pub fn cost(&mut self, spec: &JobSpec, engine_name: &str) -> Option<CalCost> {
        let key = format!("{}|{engine_name}", spec.config_key());
        if let Some(v) = self.costs.get(&key) {
            return *v;
        }
        let prof = self.profiles(spec);
        let v = compute_cost(self.topo, spec, engine_name, prof.as_ref());
        self.costs.insert(key, v);
        v
    }

    /// Pre-compute the distinct (configuration, requested-engine) cells of
    /// a trace across `threads` workers. Costs the placement-aware policy
    /// derives for substitute engines still fill in lazily (serial).
    pub fn prewarm(&mut self, jobs: &[JobSpec], threads: usize) {
        let mut cells: BTreeMap<String, JobSpec> = BTreeMap::new();
        for j in jobs {
            cells
                .entry(format!("{}|{}", j.config_key(), j.engine))
                .or_insert_with(|| j.clone());
        }
        let cells: Vec<JobSpec> = cells.into_values().collect();
        let topo = self.topo;
        let results = par_map(cells.len(), threads.max(1), |i| {
            let spec = &cells[i];
            let prof = compute_profiles(topo, spec);
            let cost = compute_cost(topo, spec, &spec.engine, prof.as_ref());
            (prof, cost)
        });
        for (spec, (prof, cost)) in cells.iter().zip(results) {
            self.profiles.entry(spec.config_key()).or_insert(prof);
            self.costs
                .entry(format!("{}|{}", spec.config_key(), spec.engine))
                .or_insert(cost);
        }
    }
}

/// A recorded admission decision of one scheduling pass.
struct ProbeAdmission {
    engine: String,
    reservation: PlanReservation,
    cost: CalCost,
}

/// The simulator's [`AdmissionProbe`]: a working free view (memory + GPU
/// slots) that real `MemoryPlan` builds are checked against and debited
/// from as the policy picks jobs.
///
/// `blocked` memoizes failed probes by `(config, engine, accounting)`:
/// between two completion events, free capacity and free GPU slots only
/// *shrink* (admissions debit, arrivals change nothing), and every
/// registered engine is monotone in the free vector, so a failed probe
/// provably fails again until a completion frees capacity — the caller
/// clears the set exactly then. This turns the O(queue × engines) plan
/// rebuilds a long blocked queue would pay at every arrival into set
/// lookups, without changing a single admission decision.
struct Probe<'a, 't> {
    /// Scratch clone of the host topology; only its `mem_nodes[..]
    /// .capacity` fields are rewritten (to the working free bytes) before
    /// each plan build, so probes cost capacity writes, not deep clones.
    view: SystemTopology,
    free: Vec<u64>,
    free_gpus: usize,
    queue: Vec<&'a JobSpec>,
    cal: &'a mut Calibrator<'t>,
    blocked: &'a mut BTreeSet<String>,
    admissions: Vec<Option<ProbeAdmission>>,
}

impl<'a, 't> Probe<'a, 't> {
    fn new(
        topo: &SystemTopology,
        free: Vec<u64>,
        free_gpus: usize,
        queue: Vec<&'a JobSpec>,
        cal: &'a mut Calibrator<'t>,
        blocked: &'a mut BTreeSet<String>,
    ) -> Self {
        let n = queue.len();
        Self {
            view: topo.clone(),
            free,
            free_gpus,
            queue,
            cal,
            blocked,
            admissions: (0..n).map(|_| None).collect(),
        }
    }
}

impl AdmissionProbe for Probe<'_, '_> {
    fn queue_len(&self) -> usize {
        self.queue.len()
    }

    fn job(&self, idx: usize) -> &JobSpec {
        self.queue[idx]
    }

    fn try_admit(&mut self, idx: usize, engine_name: Option<&str>, lifetime: bool) -> bool {
        if self.admissions[idx].is_some() {
            return false;
        }
        let spec = self.queue[idx];
        let engine_name = engine_name.unwrap_or(&spec.engine).to_string();
        let probe_key = format!("{}|{engine_name}|{lifetime}", spec.config_key());
        if self.blocked.contains(&probe_key) {
            return false;
        }
        if spec.gpus > self.free_gpus {
            self.blocked.insert(probe_key);
            return false;
        }
        let admissible = self.cal.profiles(spec).zip(resolve_cfg(spec, &engine_name));
        let Some((profiles, cfg)) = admissible else {
            self.blocked.insert(probe_key);
            return false;
        };
        // Plan against the working free view: capacities = what is left.
        for (node, cap) in self.view.mem_nodes.iter_mut().zip(&self.free) {
            node.capacity = *cap;
        }
        let Ok(plan) = MemoryPlan::build_with_profiles(&self.view, &cfg, lifetime, profiles)
        else {
            self.blocked.insert(probe_key);
            return false;
        };
        let reservation = plan.reservation();
        drop(plan);
        // Price only engines that actually admit: the calibration cell is
        // a real executor run, wasted on candidates whose plan fails.
        let Some(cost) = self.cal.cost(spec, &engine_name) else {
            self.blocked.insert(probe_key);
            return false;
        };
        for (n, b) in &reservation.parts {
            debug_assert!(self.free[n.0] >= *b, "probe view over-promised");
            self.free[n.0] -= *b;
        }
        self.free_gpus -= spec.gpus;
        self.admissions[idx] = Some(ProbeAdmission {
            engine: engine_name,
            reservation,
            cost,
        });
        true
    }
}

/// Can the policy place this job on an EMPTY host? (The reject-at-arrival
/// feasibility check — runs the real policy against a single-job queue
/// with full capacity, so fifo/backfill test the requested engine under
/// static accounting and placement-aware tests its whole engine menu
/// under lifetime accounting.)
fn feasible_on_empty(
    topo: &SystemTopology,
    spec: &JobSpec,
    policy: &PolicyRef,
    cal: &mut Calibrator<'_>,
) -> bool {
    let free: Vec<u64> = topo.mem_nodes.iter().map(|n| n.capacity).collect();
    // A throwaway blocked-set: failures observed at *current* capacity do
    // not apply to the empty-host hypothetical, and vice versa.
    let mut blocked = BTreeSet::new();
    let mut probe = Probe::new(topo, free, topo.gpus.len(), vec![spec], cal, &mut blocked);
    policy.schedule(&mut probe);
    probe.admissions[0].is_some()
}

const EV_COMPLETE: u8 = 0;
const EV_ARRIVE: u8 = 1;

/// Mutable per-job lifecycle state; the immutable [`JobSpec`] stays in the
/// trace (the event loop reads it by reference, never clones it).
struct JobState {
    status: JobStatus,
    engine_used: Option<String>,
    start_s: Option<f64>,
    finish_s: Option<f64>,
    iter_s: Option<f64>,
}

/// Run a whole trace under one policy. `threads` only parallelizes the
/// calibration pre-warm — the event loop itself is serial and the result
/// digest is independent of the worker count.
pub fn simulate_fleet(
    topo: &SystemTopology,
    trace: &FleetTrace,
    policy: &PolicyRef,
    threads: usize,
) -> FleetResult {
    let mut ids = BTreeSet::new();
    for j in &trace.jobs {
        assert!(ids.insert(j.id), "duplicate job id {}", j.id);
        assert!(
            j.arrival_s.is_finite() && j.arrival_s >= 0.0,
            "job {}: arrival must be a non-negative finite time",
            j.id
        );
        assert!(j.iterations >= 1, "job {}: needs at least one iteration", j.id);
        assert!(
            j.gpus >= 1 && j.batch >= 1 && j.context >= 1,
            "job {}: workload dimensions must be positive",
            j.id
        );
    }
    let mut cal = Calibrator::new(topo);
    cal.prewarm(&trace.jobs, threads);
    let mut host = FleetHost::new(topo);
    let mut jobs: Vec<JobState> = trace
        .jobs
        .iter()
        .map(|_| JobState {
            status: JobStatus::Queued,
            engine_used: None,
            start_s: None,
            finish_s: None,
            iter_s: None,
        })
        .collect();

    // Event key: (time bits, kind, seq, job index). Completions sort
    // before arrivals at the same instant so freed capacity is visible to
    // same-time arrivals; `seq` makes every key unique. `+ 0.0` folds a
    // hand-written `-0.0` arrival into `+0.0` — its sign-bit pattern would
    // otherwise sort after every positive time.
    let mut heap: BinaryHeap<Reverse<(u64, u8, u64, usize)>> = BinaryHeap::new();
    for (i, s) in trace.jobs.iter().enumerate() {
        heap.push(Reverse(((s.arrival_s + 0.0).to_bits(), EV_ARRIVE, i as u64, i)));
    }
    // Completion events continue the unique-sequence space after arrivals.
    let mut seq: u64 = trace.jobs.len() as u64;

    let mut queue: Vec<usize> = Vec::new();
    let mut samples: Vec<OccupancySample> = Vec::new();
    let mut feasible: BTreeMap<String, bool> = BTreeMap::new();
    // Failed-probe memo, valid while capacity only shrinks (see [`Probe`]);
    // completions grow capacity, so they invalidate it.
    let mut blocked: BTreeSet<String> = BTreeSet::new();
    let mut n_events: u64 = 0;
    let mut running: usize = 0;

    while let Some(Reverse((tb, kind, _seq, ji))) = heap.pop() {
        let now = f64::from_bits(tb);
        n_events += 1;
        if kind == EV_COMPLETE {
            let released = host.release(trace.jobs[ji].id, trace.jobs[ji].gpus);
            debug_assert!(released, "completed job must have been resident");
            jobs[ji].status = JobStatus::Completed;
            jobs[ji].finish_s = Some(now);
            running -= 1;
            blocked.clear();
        } else {
            // Reject at arrival iff the policy cannot place the job even
            // on an empty host; otherwise it queues.
            let spec = &trace.jobs[ji];
            let key = format!("{}|{}", spec.config_key(), spec.engine);
            let ok = match feasible.get(&key) {
                Some(v) => *v,
                None => {
                    let v = feasible_on_empty(topo, spec, policy, &mut cal);
                    feasible.insert(key, v);
                    v
                }
            };
            if ok {
                queue.push(ji);
            } else {
                jobs[ji].status = JobStatus::Rejected;
            }
        }

        // Scheduling pass: hand the policy the queued specs by reference.
        let snapshot: Vec<&JobSpec> = queue.iter().map(|&i| &trace.jobs[i]).collect();
        let mut probe = Probe::new(
            topo,
            host.free(),
            host.free_gpus(),
            snapshot,
            &mut cal,
            &mut blocked,
        );
        policy.schedule(&mut probe);
        let admissions = probe.admissions;
        let mut started: Vec<usize> = Vec::new();
        for (qpos, adm) in admissions.into_iter().enumerate() {
            let Some(adm) = adm else { continue };
            let ji = queue[qpos];
            let spec = &trace.jobs[ji];
            host.reserve(spec.id, &adm.reservation, spec.gpus)
                .expect("probe debited the identical free view");
            let finish = now + adm.cost.iter_s * spec.iterations as f64;
            jobs[ji].status = JobStatus::Running;
            jobs[ji].engine_used = Some(adm.engine);
            jobs[ji].start_s = Some(now);
            jobs[ji].iter_s = Some(adm.cost.iter_s);
            heap.push(Reverse((finish.to_bits(), EV_COMPLETE, seq, ji)));
            seq += 1;
            running += 1;
            started.push(qpos);
        }
        for &qpos in started.iter().rev() {
            queue.remove(qpos);
        }
        samples.push(OccupancySample {
            t_s: now,
            used: host.used(),
            queue_len: queue.len(),
            running,
        });
    }
    assert!(
        queue.is_empty() && running == 0,
        "fleet failed to drain: {} queued, {running} running",
        queue.len()
    );

    let mut result = FleetResult::new(policy.name(), topo);
    result.n_events = n_events;
    result.samples = samples;
    result.records = trace
        .jobs
        .iter()
        .zip(jobs)
        .map(|(spec, j)| JobRecord {
            id: spec.id,
            model: spec.model.clone(),
            gpus: spec.gpus,
            batch: spec.batch,
            context: spec.context,
            schedule: spec.schedule.clone(),
            engine_requested: spec.engine.clone(),
            engine_used: j.engine_used,
            iterations: spec.iterations,
            arrival_s: spec.arrival_s,
            start_s: j.start_s,
            finish_s: j.finish_s,
            iter_s: j.iter_s,
            total_tokens: spec.total_tokens(),
            status: j.status,
        })
        .collect();
    result
}

/// The pinned evaluation trace: `n_mixed` jobs from [`TraceGen::mixed`]
/// plus `n_xl` "XL" jobs at the first batch rung (context 32768) whose
/// *static* footprint overflows the host but whose per-phase peak fits —
/// the cells only a lifetime-aware admission policy can serve. Returns
/// the mixed trace unchanged when the host has no such rung (ample DRAM);
/// callers that depend on the XL cell assert on `jobs.len()`.
pub fn mixed_trace_with_xl(
    topo: &SystemTopology,
    seed: u64,
    n_mixed: usize,
    n_xl: usize,
) -> FleetTrace {
    let mut tg = TraceGen::mixed(seed, n_mixed);
    // Lighter than the default mix: enough idle capacity that the XL jobs
    // mostly run in windows the static policies would leave empty.
    tg.mean_interarrival_s = 240.0;
    let mut trace = tg.generate();
    if n_xl == 0 {
        return trace;
    }
    let xl_engine = "cxl-aware+striping";
    let context = 32768usize;
    let model = mpresets::by_name("7b").expect("preset");
    let mut xl_batch = None;
    for rung in 1..=40usize {
        let batch = rung * 8;
        let cfg = RunConfig::new(
            model.clone(),
            crate::model::footprint::Workload::new(1, batch, context),
            engine::by_name(xl_engine).expect("registered"),
        );
        // Static fit is monotone in batch (only activations grow), so the
        // first failing rung is THE static/lifetime boundary candidate.
        if !MemoryPlan::fits(topo, &cfg) {
            if MemoryPlan::fits_lifetime_aware(topo, &cfg) {
                xl_batch = Some(batch);
            }
            break;
        }
    }
    let Some(batch) = xl_batch else {
        return trace;
    };
    let span = trace.jobs.last().map(|j| j.arrival_s).unwrap_or(0.0);
    let base_id = trace.jobs.len() as u64;
    for k in 0..n_xl {
        trace.jobs.push(JobSpec {
            id: base_id + k as u64,
            arrival_s: span * (k as f64 + 1.0) / (n_xl as f64 + 1.0),
            model: "7b".into(),
            gpus: 1,
            batch,
            context,
            schedule: "zero-offload".into(),
            engine: xl_engine.into(),
            iterations: 1,
        });
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::scheduler;
    use crate::topology::presets::dev_tiny;
    use crate::util::units::MIB;

    fn job(id: u64, arrival: f64, batch: usize, context: usize) -> JobSpec {
        JobSpec {
            id,
            arrival_s: arrival,
            model: "tiny-2m".into(),
            gpus: 1,
            batch,
            context,
            schedule: "zero-offload".into(),
            engine: "cxl-aware+striping".into(),
            iterations: 2,
        }
    }

    /// dev-tiny shrunk so tiny-2m jobs actually contend for memory.
    fn tight_topo() -> SystemTopology {
        let mut t = dev_tiny();
        t.mem_nodes[0].capacity = 48 * MIB;
        t.mem_nodes[1].capacity = 16 * MIB;
        t.mem_nodes[2].capacity = 16 * MIB;
        t.validate();
        t
    }

    #[test]
    fn single_job_runs_to_completion() {
        let topo = dev_tiny();
        let trace = FleetTrace {
            seed: 0,
            jobs: vec![job(0, 1.0, 2, 256)],
        };
        let policy = scheduler::by_name("fifo").unwrap();
        let res = simulate_fleet(&topo, &trace, &policy, 1);
        assert_eq!(res.completed(), 1);
        assert_eq!(res.rejected(), 0);
        assert_eq!(res.n_events, 2, "one arrival + one completion");
        let r = &res.records[0];
        assert_eq!(r.start_s, Some(1.0), "empty host admits on arrival");
        let iter_s = r.iter_s.unwrap();
        assert!(iter_s > 0.0);
        assert!((r.finish_s.unwrap() - (1.0 + 2.0 * iter_s)).abs() < 1e-9);
        assert_eq!(r.engine_used.as_deref(), Some("cxl-aware+striping"));
        // occupancy returns to zero at the final sample
        let last = res.samples.last().unwrap();
        assert!(last.used.iter().all(|&u| u == 0));
    }

    #[test]
    fn gpu_slots_serialize_a_two_gpu_host() {
        // Three 1-GPU jobs arriving together on a 2-GPU host: two start at
        // once, the third waits for the first completion.
        let topo = dev_tiny();
        let trace = FleetTrace {
            seed: 0,
            jobs: vec![job(0, 0.0, 1, 256), job(1, 0.0, 1, 256), job(2, 0.0, 1, 256)],
        };
        let policy = scheduler::by_name("fifo").unwrap();
        let res = simulate_fleet(&topo, &trace, &policy, 1);
        assert_eq!(res.completed(), 3);
        let starts: Vec<f64> = res.records.iter().map(|r| r.start_s.unwrap()).collect();
        assert_eq!(starts[0], 0.0);
        assert_eq!(starts[1], 0.0);
        assert!(starts[2] > 0.0, "third job must wait for a GPU slot");
        assert_eq!(res.max_queue_len(), 1);
    }

    #[test]
    fn infeasible_jobs_are_rejected_at_arrival() {
        let topo = tight_topo();
        // context 65536 × batch 8 tiny-2m activation checkpoints alone
        // (512·B·C bytes) overflow the whole 80 MiB machine under any
        // accounting; the small job is untouched.
        let trace = FleetTrace {
            seed: 0,
            jobs: vec![job(0, 0.0, 8, 65536), job(1, 1.0, 1, 256)],
        };
        for policy in scheduler::registry() {
            let res = simulate_fleet(&topo, &trace, &policy, 1);
            assert_eq!(res.rejected(), 1, "{}", policy.name());
            assert_eq!(res.completed(), 1, "{}", policy.name());
            assert_eq!(
                res.records[0].status,
                JobStatus::Rejected,
                "{}: the XL job is the rejected one",
                policy.name()
            );
            assert!(res.records[0].start_s.is_none());
        }
    }

    #[test]
    fn backfill_starts_small_jobs_a_blocked_fifo_head_delays() {
        // GPU-slot head-of-line blocking on a 2-GPU host, all arrivals at
        // t=0 (same-time events process in id order): job 0 takes one GPU,
        // job 1 wants both and blocks, job 2 wants the remaining one.
        // Fifo's blocked head also delays job 2; backfill lets it jump.
        let topo = dev_tiny();
        let mut j1 = job(1, 0.0, 1, 256);
        j1.gpus = 2;
        let trace = FleetTrace {
            seed: 0,
            jobs: vec![job(0, 0.0, 1, 256), j1, job(2, 0.0, 1, 256)],
        };
        let fifo = scheduler::by_name("fifo").unwrap();
        let backfill = scheduler::by_name("backfill").unwrap();
        let rf = simulate_fleet(&topo, &trace, &fifo, 1);
        let rb = simulate_fleet(&topo, &trace, &backfill, 1);
        assert_eq!(rf.completed(), 3);
        assert_eq!(rb.completed(), 3);
        let start = |r: &FleetResult, id: usize| r.records[id].start_s.unwrap();
        // Under fifo, job 2 starts only after the blocked 2-GPU head ran.
        assert!(start(&rf, 1) > 0.0, "head must wait for job 0's GPU");
        assert!(start(&rf, 2) >= start(&rf, 1));
        // Backfill starts job 2 immediately, jumping the blocked head.
        assert_eq!(start(&rb, 2), 0.0, "backfill must jump the blocked head");
        assert!(
            start(&rb, 2) < start(&rb, 1),
            "small job first: {} vs {}",
            start(&rb, 2),
            start(&rb, 1)
        );
    }

    #[test]
    fn calibrator_memoizes_costs_and_profiles() {
        let topo = dev_tiny();
        let mut cal = Calibrator::new(&topo);
        let a = job(0, 0.0, 2, 256);
        let c1 = cal.cost(&a, "cxl-aware+striping").unwrap();
        let c2 = cal.cost(&a, "cxl-aware+striping").unwrap();
        assert_eq!(c1, c2);
        assert_eq!(cal.costs.len(), 1, "one (config, engine) cell");
        assert_eq!(cal.profiles.len(), 1);
        // same config, second engine → one more cost cell, no new profile
        cal.cost(&a, "baseline-dram").unwrap();
        assert_eq!(cal.costs.len(), 2);
        assert_eq!(cal.profiles.len(), 1);
        assert!(cal.cost(&a, "no-such-engine").is_none());
        // pre-warm is value-identical to the lazy path
        let mut warm = Calibrator::new(&topo);
        warm.prewarm(&[a.clone()], 4);
        assert_eq!(warm.cost(&a, &a.engine), cal.cost(&a, &a.engine));
    }
}
