"""AOT lowering: JAX/Pallas model → HLO **text** artifacts + manifest.json.

Run once by ``make artifacts``; Python never touches the request path. The
interchange format is HLO text, NOT ``lowered.compile()`` or serialized
protos: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 (the version the Rust ``xla`` crate binds) rejects.
The text parser re-assigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Usage::

    python -m compile.aot --out ../artifacts [--layers 4 --hidden 256 ...]
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(fn, example_args):
    """Lower a python function to HLO text via StableHLO.

    ``return_tuple=True`` so every artifact's root is a tuple — the Rust
    side unwraps uniformly with ``to_tuple()``.
    """
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def tensor_meta(name, s):
    return {
        "name": name,
        "shape": list(s.shape),
        "dtype": {"float32": "f32", "int32": "i32"}[str(s.dtype)],
    }


def build_entries(cfg: M.TinyConfig):
    """Define every entry point: (fn, named input specs, output names)."""
    f32, i32 = jnp.float32, jnp.int32
    b, c, h, v = cfg.batch, cfg.context, cfg.hidden, cfg.vocab
    ids = ("ids", spec((b, c), i32))
    x = ("x", spec((b, c, h), f32))
    emb = ("emb", spec((v, h), f32))
    labels = ("labels", spec((b, c), i32))
    block_params = [
        (name, spec(shape, f32)) for name, shape in M.block_param_shapes(cfg).items()
    ]
    dy = ("dy", spec((b, c, h), f32))

    entries = {}
    entries["embed_fwd"] = (
        functools.partial(M.embed_fwd, cfg),
        [ids, emb],
        ["x"],
    )
    entries["block_fwd"] = (
        lambda x, *p: (M.block_fwd(cfg, x, *p),),
        [x] + block_params,
        ["y"],
    )
    entries["block_bwd"] = (
        functools.partial(M.block_bwd, cfg),
        [x] + block_params + [dy],
        ["dx"] + [f"d{n}" for n, _ in block_params],
    )
    entries["head_loss"] = (
        functools.partial(M.head_loss, cfg),
        [x, ("lnf", spec((h,), f32)), emb, labels],
        ["loss", "dx", "dlnf", "demb"],
    )
    entries["embed_bwd"] = (
        functools.partial(M.embed_bwd, cfg),
        [ids, ("dx", spec((b, c, h), f32))],
        ["demb"],
    )
    return entries


def lower_all(cfg: M.TinyConfig, out_dir: str, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "model": {
            "layers": cfg.layers,
            "hidden": cfg.hidden,
            "heads": cfg.heads,
            "vocab": cfg.vocab,
            "ffn": cfg.ffn,
            "batch": cfg.batch,
            "context": cfg.context,
            "n_params": cfg.n_params(),
        },
        "entries": {},
    }
    for name, (fn, inputs, out_names) in build_entries(cfg).items():
        example_args = [s for _, s in inputs]
        text = to_hlo_text(fn, example_args)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        # output specs via eval_shape (no execution)
        out_shapes = jax.eval_shape(fn, *example_args)
        outputs = [
            tensor_meta(n, s) for n, s in zip(out_names, jax.tree.leaves(out_shapes))
        ]
        manifest["entries"][name] = {
            "file": fname,
            "inputs": [tensor_meta(n, s) for n, s in inputs],
            "outputs": outputs,
        }
        if verbose:
            print(f"  lowered {name:<10} -> {fname} ({len(text)/1e6:.2f} MB hlo text)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(
            f"wrote manifest with {len(manifest['entries'])} entries; "
            f"model has {cfg.n_params()/1e6:.2f}M params"
        )
    return manifest


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--layers", type=int, default=4)
    p.add_argument("--hidden", type=int, default=256)
    p.add_argument("--heads", type=int, default=4)
    p.add_argument("--vocab", type=int, default=2048)
    p.add_argument("--ffn", type=int, default=704)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--context", type=int, default=128)
    a = p.parse_args()
    cfg = M.TinyConfig(
        layers=a.layers,
        hidden=a.hidden,
        heads=a.heads,
        vocab=a.vocab,
        ffn=a.ffn,
        batch=a.batch,
        context=a.context,
    )
    lower_all(cfg, a.out)


if __name__ == "__main__":
    main()
