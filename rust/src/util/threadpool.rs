//! Data-parallel helpers (no `rayon` offline).
//!
//! Three shapes of parallelism:
//!   * [`Pool`] — a persistent worker pool with a scoped batch API; the
//!     per-step optimizer hot path ([`crate::optim::adam_step`]) submits
//!     its chunks here instead of spawning fresh OS threads every step
//!     (spawn cost is ~10–30 µs/thread — pure overhead at small N, where a
//!     1M-element Adam step itself is only a few hundred µs).
//!   * [`par_chunks_mut`] — split a mutable slice into near-equal chunks
//!     and run a closure per chunk on its own scoped thread.
//!   * [`par_map`] — map a closure over indexed work items with a bounded
//!     worker count and collect results in order (the sweep fan-out).
//!
//! `par_chunks_mut`/`par_map` deliberately stay on `std::thread::scope`:
//! their callers (sweep cells, property tests) are multi-millisecond tasks
//! where spawn cost is noise, and scoped spawning guarantees real OS
//! threads for tests that assert genuine multi-threading.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Number of worker threads to use by default: physical parallelism,
/// clamped to something sane.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 128)
}

/// A task whose borrows are scoped to one [`Pool::run_scoped`] call.
pub type ScopedTask<'s> = Box<dyn FnOnce() + Send + 's>;

type StaticTask = Box<dyn FnOnce() + Send + 'static>;

/// Completion state of one submitted batch.
struct BatchState {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

struct PoolShared {
    queue: Mutex<VecDeque<(Arc<BatchState>, StaticTask)>>,
    work_ready: Condvar,
}

/// A persistent worker pool with a *scoped* batch API.
///
/// Workers are spawned once and parked on a condvar between batches, so a
/// caller that fans out every few hundred microseconds (the CPU Adam step)
/// pays a wakeup instead of `nthreads` × thread-spawn per call.
///
/// [`Pool::run_scoped`] accepts non-`'static` tasks: their lifetimes are
/// erased for the trip through the worker queue, which is sound because
/// the call blocks until every task in the batch has finished executing —
/// no borrow can outlive the stack frame that owns it (the same contract
/// `std::thread::scope` enforces, minus the per-call spawns).
///
/// Deadlock-freedom: the submitting thread *helps* — it drains the shared
/// queue itself until empty, then waits only for tasks other threads are
/// actively running. Nested `run_scoped` calls (a pool task that itself
/// submits a batch) therefore always make progress, even on a pool with
/// zero idle workers.
pub struct Pool {
    shared: Arc<PoolShared>,
    workers: usize,
}

impl Pool {
    /// Spawn a pool with `workers` daemon worker threads (they idle-park
    /// forever; the process exits without joining them).
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            work_ready: Condvar::new(),
        });
        for i in 0..workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("cxlfine-pool-{i}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawn pool worker");
        }
        Self { shared, workers }
    }

    /// The process-wide pool (sized by [`default_threads`]), created on
    /// first use.
    pub fn global() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        POOL.get_or_init(|| Pool::new(default_threads()))
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run every task in `tasks` to completion, in parallel across the
    /// pool's workers plus the calling thread. Panics (after the whole
    /// batch has settled) if any task panicked.
    pub fn run_scoped<'s>(&self, tasks: Vec<ScopedTask<'s>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        let batch = Arc::new(BatchState {
            remaining: Mutex::new(n),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            for t in tasks {
                // SAFETY: every queued task is executed (by a worker or by
                // the help-loop below) and counted down in `remaining`
                // before this function returns; the borrows inside `t`
                // therefore never outlive the caller's scope.
                let t: StaticTask = unsafe { erase_task_lifetime(t) };
                q.push_back((Arc::clone(&batch), t));
            }
        }
        self.shared.work_ready.notify_all();
        // Help: drain the shared queue on the submitting thread too.
        loop {
            let next = self.shared.queue.lock().unwrap().pop_front();
            match next {
                Some((b, task)) => run_task(&b, task),
                None => break,
            }
        }
        // Wait for stragglers currently running on workers.
        let mut rem = batch.remaining.lock().unwrap();
        while *rem > 0 {
            rem = batch.done.wait(rem).unwrap();
        }
        drop(rem);
        if batch.panicked.load(Ordering::SeqCst) {
            panic!("threadpool task panicked");
        }
    }
}

/// SAFETY: caller must guarantee the task finishes executing before the
/// lifetime `'s` ends (see [`Pool::run_scoped`]). `Box<dyn ...>` fat
/// pointers are layout-identical across trait-object lifetimes.
unsafe fn erase_task_lifetime<'s>(t: ScopedTask<'s>) -> StaticTask {
    std::mem::transmute::<ScopedTask<'s>, StaticTask>(t)
}

fn run_task(batch: &Arc<BatchState>, task: StaticTask) {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
    if result.is_err() {
        batch.panicked.store(true, Ordering::SeqCst);
    }
    let mut rem = batch.remaining.lock().unwrap();
    *rem -= 1;
    if *rem == 0 {
        batch.done.notify_all();
    }
}

fn worker_loop(sh: &PoolShared) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                q = sh.work_ready.wait(q).unwrap();
            }
        };
        run_task(&job.0, job.1);
    }
}

/// Split `data` into `nthreads` near-equal contiguous chunks and invoke
/// `f(chunk_index, element_offset, chunk)` on each, in parallel.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], nthreads: usize, f: F)
where
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let nthreads = nthreads.max(1).min(n);
    if nthreads == 1 {
        f(0, 0, data);
        return;
    }
    let base = n / nthreads;
    let extra = n % nthreads;
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut offset = 0usize;
        for i in 0..nthreads {
            let len = base + usize::from(i < extra);
            let (chunk, tail) = rest.split_at_mut(len);
            rest = tail;
            let fr = &f;
            let off = offset;
            scope.spawn(move || fr(i, off, chunk));
            offset += len;
        }
    });
}

/// Parallel map over `nitems` indexed work items with at most `nworkers`
/// threads; results are returned in item order. Work stealing is a shared
/// atomic cursor — items should be coarse enough to amortize it.
pub fn par_map<R: Send, F>(nitems: usize, nworkers: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    if nitems == 0 {
        return Vec::new();
    }
    let nworkers = nworkers.max(1).min(nitems);
    if nworkers == 1 {
        return (0..nitems).map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..nitems).map(|_| None).collect();
    {
        // Hand each worker disjoint &mut access via raw parts; simpler and
        // still safe is a mutex-free approach with per-item cells:
        let cells: Vec<std::sync::Mutex<&mut Option<R>>> =
            slots.iter_mut().map(std::sync::Mutex::new).collect();
        std::thread::scope(|scope| {
            for _ in 0..nworkers {
                let cursor = &cursor;
                let cells = &cells;
                let f = &f;
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= nitems {
                        break;
                    }
                    let r = f(i);
                    **cells[i].lock().unwrap() = Some(r);
                });
            }
        });
    }
    slots.into_iter().map(|s| s.expect("worker filled slot")).collect()
}

/// [`par_map`] with an explicit dispatch order: workers pull item indices
/// from `order` (a permutation of `0..nitems`) instead of ascending index,
/// so expensive items can be started first and stragglers don't serialize
/// the tail. Results still come back in *item* order — slots are indexed
/// by item, not by dispatch position — so for value-pure closures the
/// output is byte-identical to [`par_map`] at every worker count.
pub fn par_map_ordered<R: Send, F>(nitems: usize, nworkers: usize, order: &[usize], f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    debug_assert_eq!(order.len(), nitems, "order must be a permutation");
    if nitems == 0 {
        return Vec::new();
    }
    let nworkers = nworkers.max(1).min(nitems);
    if nworkers == 1 {
        // Serial path iterates in *item* order, exactly like `par_map`'s
        // serial shortcut, so `threads = 1` is the reference ordering.
        return (0..nitems).map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..nitems).map(|_| None).collect();
    {
        let cells: Vec<std::sync::Mutex<&mut Option<R>>> =
            slots.iter_mut().map(std::sync::Mutex::new).collect();
        std::thread::scope(|scope| {
            for _ in 0..nworkers {
                let cursor = &cursor;
                let cells = &cells;
                let f = &f;
                scope.spawn(move || loop {
                    let k = cursor.fetch_add(1, Ordering::Relaxed);
                    if k >= nitems {
                        break;
                    }
                    let i = order[k];
                    let r = f(i);
                    **cells[i].lock().unwrap() = Some(r);
                });
            }
        });
    }
    slots.into_iter().map(|s| s.expect("worker filled slot")).collect()
}

/// Parallel fold: run `f(chunk_index, range)` per contiguous index range and
/// combine the per-thread results with `combine`.
pub fn par_ranges<R: Send, F, C>(n: usize, nthreads: usize, f: F, combine: C) -> Option<R>
where
    F: Fn(usize, std::ops::Range<usize>) -> R + Sync,
    C: Fn(R, R) -> R,
{
    if n == 0 {
        return None;
    }
    let nthreads = nthreads.max(1).min(n);
    let base = n / nthreads;
    let extra = n % nthreads;
    let mut results: Vec<R> = Vec::with_capacity(nthreads);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(nthreads);
        let mut start = 0usize;
        for i in 0..nthreads {
            let len = base + usize::from(i < extra);
            let range = start..start + len;
            start += len;
            let fr = &f;
            handles.push(scope.spawn(move || fr(i, range)));
        }
        for h in handles {
            results.push(h.join().expect("worker panicked"));
        }
    });
    results.into_iter().reduce(combine)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let mut v = vec![0u32; 10_007];
        par_chunks_mut(&mut v, 8, |_, _, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_offsets_are_correct() {
        let mut v: Vec<usize> = vec![0; 1000];
        par_chunks_mut(&mut v, 7, |_, offset, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = offset + i;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn chunks_single_thread_path() {
        let mut v = vec![1u64; 17];
        par_chunks_mut(&mut v, 1, |idx, off, chunk| {
            assert_eq!((idx, off), (0, 0));
            assert_eq!(chunk.len(), 17);
        });
    }

    #[test]
    fn map_preserves_order() {
        let out = par_map(100, 8, |i| i * i);
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn map_utilizes_multiple_threads() {
        // The parallel-sweep contract: par_map genuinely fans work across
        // worker threads. Each closure rendezvouses (yielding, bounded)
        // until a second worker has checked in, so the assertion holds even
        // on throttled single-core CI runners — one worker cannot satisfy
        // the rendezvous by draining the queue alone.
        let arrived = AtomicUsize::new(0);
        let ids = par_map(4, 4, |_| {
            arrived.fetch_add(1, Ordering::SeqCst);
            let t0 = std::time::Instant::now();
            while arrived.load(Ordering::SeqCst) < 2 && t0.elapsed().as_secs() < 5 {
                std::thread::yield_now();
            }
            std::thread::current().id()
        });
        let distinct: std::collections::HashSet<_> = ids.into_iter().collect();
        assert!(
            distinct.len() >= 2,
            "expected ≥2 worker threads, saw {}",
            distinct.len()
        );
    }

    #[test]
    fn map_empty() {
        let out: Vec<u32> = par_map(0, 8, |_| unreachable!());
        assert!(out.is_empty());
    }

    #[test]
    fn ordered_map_matches_par_map_at_every_worker_count() {
        // Dispatch heaviest-last (a reversed order) and heaviest-first;
        // both must produce the same item-ordered output as par_map.
        let n = 97;
        let reference: Vec<usize> = par_map(n, 1, |i| i * 3 + 1);
        let reversed: Vec<usize> = (0..n).rev().collect();
        for workers in [1, 2, 8] {
            let out = par_map_ordered(n, workers, &reversed, |i| i * 3 + 1);
            assert_eq!(out, reference);
        }
        let identity: Vec<usize> = (0..n).collect();
        assert_eq!(par_map_ordered(n, 8, &identity, |i| i * 3 + 1), reference);
    }

    #[test]
    fn ordered_map_empty() {
        let out: Vec<u32> = par_map_ordered(0, 8, &[], |_| unreachable!());
        assert!(out.is_empty());
    }

    #[test]
    fn pool_runs_every_task_exactly_once() {
        let pool = Pool::new(4);
        let counters: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        let tasks: Vec<ScopedTask<'_>> = counters
            .iter()
            .map(|c| Box::new(move || {
                c.fetch_add(1, Ordering::SeqCst);
            }) as ScopedTask<'_>)
            .collect();
        pool.run_scoped(tasks);
        assert!(counters.iter().all(|c| c.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn pool_scoped_borrows_mutate_local_state() {
        // The adam_step shape: disjoint &mut chunks of a stack-owned vec.
        let pool = Pool::new(3);
        let mut v = vec![0u64; 10_001];
        {
            let tasks: Vec<ScopedTask<'_>> = v
                .chunks_mut(997)
                .map(|chunk| {
                    Box::new(move || {
                        for x in chunk {
                            *x += 2;
                        }
                    }) as ScopedTask<'_>
                })
                .collect();
            pool.run_scoped(tasks);
        }
        assert!(v.iter().all(|&x| x == 2));
    }

    #[test]
    fn pool_reuses_threads_across_batches() {
        // The whole point of the pool: consecutive batches run on the same
        // worker set, not freshly spawned threads.
        let pool = Pool::new(2);
        let collect_ids = |pool: &Pool| {
            let ids = std::sync::Mutex::new(std::collections::HashSet::new());
            let barrier = std::sync::Barrier::new(3);
            let tasks: Vec<ScopedTask<'_>> = (0..3)
                .map(|_| {
                    let ids = &ids;
                    let barrier = &barrier;
                    Box::new(move || {
                        ids.lock().unwrap().insert(std::thread::current().id());
                        barrier.wait();
                    }) as ScopedTask<'_>
                })
                .collect();
            pool.run_scoped(tasks);
            ids.into_inner().unwrap()
        };
        let first = collect_ids(&pool);
        let second = collect_ids(&pool);
        // 3 tasks, 2 workers + submitter, barrier forces all three threads
        assert_eq!(first.len(), 3);
        assert_eq!(second, first, "same worker threads must serve both batches");
    }

    #[test]
    fn pool_zero_workers_degrades_to_inline() {
        let pool = Pool::new(0);
        let mut total = 0u64;
        {
            let total = &mut total;
            pool.run_scoped(vec![Box::new(move || {
                *total = 41;
            }) as ScopedTask<'_>]);
        }
        assert_eq!(total, 41);
    }

    #[test]
    fn pool_nested_batches_make_progress() {
        let pool = Pool::new(1);
        let hits = AtomicUsize::new(0);
        {
            let hits = &hits;
            let pool_ref = &pool;
            let outer: Vec<ScopedTask<'_>> = (0..2)
                .map(|_| {
                    Box::new(move || {
                        let inner: Vec<ScopedTask<'_>> = (0..4)
                            .map(|_| {
                                Box::new(move || {
                                    hits.fetch_add(1, Ordering::SeqCst);
                                }) as ScopedTask<'_>
                            })
                            .collect();
                        pool_ref.run_scoped(inner);
                    }) as ScopedTask<'_>
                })
                .collect();
            pool.run_scoped(outer);
        }
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn pool_propagates_panics_after_batch_settles() {
        let pool = Pool::new(2);
        let survivors = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let survivors = &survivors;
            let mut tasks: Vec<ScopedTask<'_>> = vec![Box::new(|| panic!("boom"))];
            for _ in 0..7 {
                tasks.push(Box::new(move || {
                    survivors.fetch_add(1, Ordering::SeqCst);
                }));
            }
            pool.run_scoped(tasks);
        }));
        assert!(result.is_err(), "panic must propagate to the submitter");
        assert_eq!(
            survivors.load(Ordering::SeqCst),
            7,
            "all sibling tasks still ran to completion"
        );
    }

    #[test]
    fn pool_empty_batch_is_noop() {
        Pool::new(1).run_scoped(Vec::new());
        Pool::global().run_scoped(Vec::new());
    }

    #[test]
    fn ranges_fold_sum() {
        let total = par_ranges(1_000, 6, |_, r| r.sum::<usize>(), |a, b| a + b).unwrap();
        assert_eq!(total, 999 * 1000 / 2);
    }

    #[test]
    fn more_threads_than_items() {
        let mut v = vec![0u8; 3];
        par_chunks_mut(&mut v, 64, |_, _, c| {
            for x in c {
                *x = 7;
            }
        });
        assert_eq!(v, vec![7, 7, 7]);
        let out = par_map(2, 64, |i| i + 1);
        assert_eq!(out, vec![1, 2]);
    }
}
