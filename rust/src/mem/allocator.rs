//! The NUMA-aware allocator: tracks per-node capacity, commits placements
//! computed by a [`PlacementEngine`], and reports utilization. This is the
//! library's stand-in for `libnuma`/`numactl` in the real system — plus the
//! paper's CXL-aware logic layered on top.

use std::collections::HashMap;

use super::engine::{EngineRef, PlacementEngine};
use super::region::{Placement, Region, RegionId, RegionRequest};
use crate::topology::{NodeId, SystemTopology};
use crate::util::units::fmt_bytes;

/// Allocation failure.
#[derive(Debug, Clone, PartialEq)]
pub struct AllocError {
    pub request: String,
    pub bytes: u64,
    pub shortfall: u64,
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "cannot place {} ({}): short {}",
            self.request,
            fmt_bytes(self.bytes),
            fmt_bytes(self.shortfall)
        )
    }
}
impl std::error::Error for AllocError {}

/// Per-node capacity tracker + region table.
pub struct NumaAllocator<'t> {
    topo: &'t SystemTopology,
    engine: EngineRef,
    free: Vec<u64>,
    regions: HashMap<usize, Region>,
    next_id: usize,
}

impl<'t> NumaAllocator<'t> {
    pub fn new(topo: &'t SystemTopology, engine: impl Into<EngineRef>) -> Self {
        Self {
            topo,
            engine: engine.into(),
            free: topo.mem_nodes.iter().map(|n| n.capacity).collect(),
            regions: HashMap::new(),
            next_id: 0,
        }
    }

    /// The placement engine this allocator routes requests through.
    pub fn engine(&self) -> &dyn PlacementEngine {
        self.engine.as_ref()
    }

    pub fn topo(&self) -> &SystemTopology {
        self.topo
    }

    /// Free bytes on a node.
    pub fn free_on(&self, node: NodeId) -> u64 {
        self.free[node.0]
    }

    /// Used bytes on a node.
    pub fn used_on(&self, node: NodeId) -> u64 {
        self.topo.node(node).capacity - self.free[node.0]
    }

    /// Place and commit a region.
    pub fn alloc(&mut self, req: RegionRequest) -> Result<RegionId, AllocError> {
        let placement = self
            .engine
            .place(self.topo, &req, &self.free)
            .map_err(|shortfall| AllocError {
                request: req.name.clone(),
                bytes: req.bytes,
                shortfall,
            })?;
        placement.validate(req.bytes);
        self.commit(req, placement)
    }

    /// Commit an explicitly computed placement (used by tests and by the
    /// engine when it needs policy-independent staging buffers).
    pub fn commit(
        &mut self,
        req: RegionRequest,
        placement: Placement,
    ) -> Result<RegionId, AllocError> {
        for (n, b) in &placement.parts {
            if *b > self.free[n.0] {
                return Err(AllocError {
                    request: req.name.clone(),
                    bytes: req.bytes,
                    shortfall: *b - self.free[n.0],
                });
            }
        }
        for (n, b) in &placement.parts {
            self.free[n.0] -= *b;
        }
        let id = RegionId(self.next_id);
        self.next_id += 1;
        self.regions.insert(
            id.0,
            Region {
                id,
                name: req.name,
                class: req.class,
                bytes: req.bytes,
                gpu: req.gpu,
                placement,
            },
        );
        Ok(id)
    }

    /// Release a region, returning its bytes to the nodes.
    pub fn release(&mut self, id: RegionId) -> bool {
        match self.regions.remove(&id.0) {
            Some(r) => {
                for (n, b) in &r.placement.parts {
                    self.free[n.0] += *b;
                    debug_assert!(self.free[n.0] <= self.topo.node(*n).capacity);
                }
                true
            }
            None => false,
        }
    }

    pub fn region(&self, id: RegionId) -> Option<&Region> {
        self.regions.get(&id.0)
    }

    pub fn regions(&self) -> impl Iterator<Item = &Region> {
        self.regions.values()
    }

    pub fn n_regions(&self) -> usize {
        self.regions.len()
    }

    /// Total bytes allocated across all nodes.
    pub fn total_used(&self) -> u64 {
        self.topo
            .all_nodes()
            .iter()
            .map(|&n| self.used_on(n))
            .sum()
    }

    /// Utilization table (for reports / `cxlfine plan`).
    pub fn describe(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(s, "allocator ({}):", self.engine.name());
        for n in self.topo.all_nodes() {
            let spec = self.topo.node(n);
            let used = self.used_on(n);
            let _ = writeln!(
                s,
                "  {}: {} / {} used ({:.1}%)",
                spec.name,
                fmt_bytes(used),
                fmt_bytes(spec.capacity),
                100.0 * used as f64 / spec.capacity as f64
            );
        }
        let mut regions: Vec<&Region> = self.regions.values().collect();
        regions.sort_by_key(|r| r.id.0);
        for r in regions {
            let parts: Vec<String> = r
                .placement
                .parts
                .iter()
                .map(|(n, b)| format!("{}={}", self.topo.node(*n).name, fmt_bytes(*b)))
                .collect();
            let _ = writeln!(
                s,
                "  region {} [{}] {}: {}",
                r.name,
                r.class.name(),
                fmt_bytes(r.bytes),
                parts.join(" + ")
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::region::TensorClass;
    use crate::mem::Policy;
    use crate::topology::presets::{config_a, dev_tiny};
    use crate::util::units::GIB;

    #[test]
    fn alloc_release_roundtrip() {
        let topo = config_a();
        let mut a = NumaAllocator::new(&topo, Policy::DramOnly);
        let before = a.free_on(NodeId(0));
        let id = a
            .alloc(RegionRequest::new("p", TensorClass::MasterParams, 4 * GIB))
            .unwrap();
        assert_eq!(a.free_on(NodeId(0)), before - 4 * GIB);
        assert_eq!(a.region(id).unwrap().bytes, 4 * GIB);
        assert!(a.release(id));
        assert_eq!(a.free_on(NodeId(0)), before);
        assert!(!a.release(id), "double free must be rejected");
    }

    #[test]
    fn oom_error_carries_shortfall() {
        let topo = dev_tiny(); // 8 GiB DRAM
        let mut a = NumaAllocator::new(&topo, Policy::DramOnly);
        let err = a
            .alloc(RegionRequest::new("big", TensorClass::MasterParams, 100 * GIB))
            .unwrap_err();
        assert_eq!(err.shortfall, 92 * GIB);
        assert!(err.to_string().contains("short"));
    }

    #[test]
    fn sequential_allocs_respect_capacity() {
        let topo = dev_tiny();
        let mut a = NumaAllocator::new(&topo, Policy::CxlAware { striping: true });
        // fill CXL (4+4 GiB) with activations, then overflow to DRAM
        let mut ids = Vec::new();
        for i in 0..5 {
            let id = a
                .alloc(RegionRequest::new(
                    format!("act{i}"),
                    TensorClass::Activations,
                    2 * GIB,
                ))
                .unwrap();
            ids.push(id);
        }
        // 10 GiB of activations: 8 on CXL, 2 on DRAM
        let on_cxl: u64 = ids
            .iter()
            .map(|&id| {
                let r = a.region(id).unwrap();
                r.placement.bytes_on(NodeId(1)) + r.placement.bytes_on(NodeId(2))
            })
            .sum();
        assert_eq!(on_cxl, 8 * GIB);
        assert_eq!(a.total_used(), 10 * GIB);
    }

    #[test]
    fn used_plus_free_is_capacity_invariant() {
        use crate::util::proptest_lite::*;
        let topo = dev_tiny();
        let gen = VecOf {
            inner: PairOf(
                U64Range {
                    lo: 1,
                    hi: 3 * GIB,
                },
                UsizeRange { lo: 0, hi: 11 },
            ),
            min_len: 1,
            max_len: 12,
        };
        forall("used+free=cap", 21, 60, &gen, |ops| {
            let mut a = NumaAllocator::new(&topo, Policy::CxlAware { striping: true });
            let mut live = Vec::new();
            for (bytes, sel) in ops {
                let class = TensorClass::all()[sel % 6];
                if sel % 2 == 0 || live.is_empty() {
                    if let Ok(id) = a.alloc(RegionRequest::new("r", class, *bytes)) {
                        live.push(id);
                    }
                } else {
                    let id = live.remove(sel % live.len());
                    a.release(id);
                }
                // invariant: per-node used + free == capacity
                for n in a.topo().all_nodes() {
                    let cap = a.topo().node(n).capacity;
                    if a.free_on(n) + a.used_on(n) != cap {
                        return Err(format!("node {} accounting broken", n.0));
                    }
                }
                // invariant: sum of region placements == total used
                let sum: u64 = a.regions().map(|r| r.placement.total_bytes()).sum();
                if sum != a.total_used() {
                    return Err("region sum != used".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn describe_lists_regions() {
        let topo = config_a();
        let mut a = NumaAllocator::new(&topo, Policy::CxlAware { striping: false });
        a.alloc(RegionRequest::new("opt", TensorClass::OptimizerStates, GIB))
            .unwrap();
        let d = a.describe();
        assert!(d.contains("opt"));
        assert!(d.contains("optimizer-states-fp32"));
    }
}
