//! Fig. 6: bandwidth of system-memory → GPU transfers vs request size.
//!
//! (a) single GPU: CXL ≈ DRAM, both climbing to the PCIe limit with size;
//! (b) two GPUs on one AIC: aggregate collapses to ~25 GiB/s, far below
//!     2× DRAM.

use cxlfine::sim::{Dir, Fabric};
use cxlfine::topology::presets::config_a;
use cxlfine::topology::{GpuId, NodeId};
use cxlfine::trow;
use cxlfine::util::bench::{points_json, BenchReport};
use cxlfine::util::table::Table;
use cxlfine::util::units::{fmt_bytes, GIB, KIB, MIB};

fn single(topo: &cxlfine::topology::SystemTopology, node: NodeId, bytes: f64) -> f64 {
    let mut fab = Fabric::new(topo);
    let f = fab.transfer(GpuId(0), node, Dir::HostToGpu, bytes, 0);
    fab.sim.run_to_idle();
    fab.sim.stats(f).unwrap().e2e_throughput()
}

fn dual_aggregate(topo: &cxlfine::topology::SystemTopology, node: NodeId, bytes: f64) -> f64 {
    let mut fab = Fabric::new(topo);
    fab.transfer(GpuId(0), node, Dir::HostToGpu, bytes, 0);
    fab.transfer(GpuId(1), node, Dir::HostToGpu, bytes, 1);
    fab.sim.run_to_idle();
    2.0 * bytes / fab.now()
}

fn main() {
    let mut report = BenchReport::new("fig6_gpu_bandwidth");
    let topo = config_a();
    let cxl = topo.cxl_nodes()[0];
    let dram = NodeId(0);
    let sizes: Vec<u64> = vec![
        64 * KIB,
        256 * KIB,
        MIB,
        4 * MIB,
        16 * MIB,
        64 * MIB,
        256 * MIB,
        GIB,
        4 * GIB,
    ];
    let gib = GIB as f64;

    // ---- panel (a): single GPU -------------------------------------
    let mut ta = Table::new(&["size", "DRAM GiB/s", "CXL GiB/s", "cxl/dram"]);
    let (mut xs, mut d1, mut c1) = (vec![], vec![], vec![]);
    for &s in &sizes {
        let bd = single(&topo, dram, s as f64) / gib;
        let bc = single(&topo, cxl, s as f64) / gib;
        ta.row(trow![
            fmt_bytes(s),
            format!("{bd:.2}"),
            format!("{bc:.2}"),
            format!("{:.3}", bc / bd)
        ]);
        xs.push(s as f64);
        d1.push(bd);
        c1.push(bc);
    }
    // shape: parity within 10% at every size; monotone climb; big sizes
    // approach the PCIe practical limit (~54 GB/s ≈ 50 GiB/s)
    for (bd, bc) in d1.iter().zip(&c1) {
        assert!((bc / bd - 1.0).abs() < 0.10, "single-GPU parity broken");
    }
    assert!(d1.windows(2).all(|w| w[1] >= w[0] * 0.999), "not monotone");
    assert!(*d1.last().unwrap() > 45.0, "large copies should near the link rate");
    report.section(
        "a_single_gpu",
        ta,
        points_json(&xs, &[("dram_gibs", &d1), ("cxl_gibs", &c1)]),
    );

    // ---- panel (b): two concurrent GPUs ----------------------------
    let mut tb = Table::new(&["size", "2xDRAM agg GiB/s", "2xCXL agg GiB/s"]);
    let (mut d2, mut c2) = (vec![], vec![]);
    for &s in &sizes {
        let bd = dual_aggregate(&topo, dram, s as f64) / gib;
        let bc = dual_aggregate(&topo, cxl, s as f64) / gib;
        tb.row(trow![fmt_bytes(s), format!("{bd:.2}"), format!("{bc:.2}")]);
        d2.push(bd);
        c2.push(bc);
    }
    // shape: large-transfer CXL aggregate lands near the paper's 25 GiB/s,
    // while DRAM aggregates near 2× a single link
    let cxl_agg = *c2.last().unwrap();
    let dram_agg = *d2.last().unwrap();
    assert!(
        (20.0..32.0).contains(&cxl_agg),
        "contended CXL aggregate {cxl_agg} GiB/s (paper: ~25)"
    );
    assert!(dram_agg > 1.8 * *d1.last().unwrap(), "DRAM should scale to 2 GPUs");
    println!(
        "dual-GPU aggregates at {}: DRAM {dram_agg:.1} GiB/s vs CXL {cxl_agg:.1} GiB/s",
        fmt_bytes(*sizes.last().unwrap())
    );
    report.section(
        "b_dual_gpu",
        tb,
        points_json(&xs, &[("dram_agg_gibs", &d2), ("cxl_agg_gibs", &c2)]),
    );
    report.finish();
}
