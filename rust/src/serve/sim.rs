//! The request-level serving simulator: continuous batching with
//! SLO-aware admission over the CXL-tiered paged KV cache, an adapter
//! over the shared [`crate::simcore`] event core like `fleet::sim`.
//!
//! Requests arrive over simulated time (a [`simcore::EventQueue`] ordered
//! by [`simcore::EventKey`]; batch-step completions sort before arrivals
//! at equal timestamps, unique sequence numbers break the remaining
//! ties). The host runs one batch *step* at a time:
//!
//! * a step carries every newly admitted request's **prefill** (full
//!   prompt forward pass, KV written back per block) plus **one decode
//!   token** for every request already past prefill — the continuous
//!   batching discipline: completions leave and admissions join at step
//!   boundaries, never mid-step;
//! * step membership is frozen when the step is scheduled, so requests
//!   admitted while a step is in flight simply join the next one;
//! * the step's duration is priced from *calibrated* costs: one real
//!   schedule build + executor run per distinct (model, phase, batch
//!   bucket, context bucket) cell via a [`ServeCalibrator`] — the same
//!   `Memo` machinery as the fleet's [`crate::fleet::Calibrator`] — plus
//!   the KV pager's cold-page attention reads and promotion/demotion
//!   traffic priced at [`SystemTopology::migration_bandwidth`], so tier
//!   traffic flows through the same degraded-topology views as fleet
//!   evacuations.
//!
//! Admission is a policy registry mirroring `fleet::scheduler`: `fcfs`
//! admits strictly in arrival order and stops at the first refusal;
//! `slo-strict` (alias `ours`) first sheds queued requests whose
//! *projected* TTFT already exceeds their SLO — they can no longer meet
//! it, so spending KV on them only hurts the rest — then backfills every
//! queued request that fits. A request whose full KV footprint exceeds
//! what the policy's tiers can *ever* hold is rejected at arrival; a
//! request whose decode outgrows the cache mid-flight is truncated (it
//! still completes, flagged).
//!
//! Determinism contract: the event loop is serial, every tie is broken by
//! explicit keys, live sequences sit in `BTreeMap`s, and calibration
//! cells are pure functions of (topology, model, phase, buckets) — so
//! pre-warming them in parallel (`--threads`) cannot change any value.
//! Identical traces produce bit-identical [`ServeResult::digest`]s across
//! reruns and thread counts (pinned by `rust/tests/serve_sim.rs`).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use super::kv::{KvPager, KvPolicyRef, PAGE_TOKENS};
use super::metrics::{RequestRecord, RequestStatus, ServeResult};
use super::request::{RequestSpec, RequestTrace};
use crate::fleet::OccupancySample;
use crate::mem::engine;
use crate::model::footprint::Workload;
use crate::model::presets as mpresets;
use crate::offload::schedules::inference::kv_bytes_per_token;
use crate::offload::{schedules, simulate_iteration, MemoryPlan, RunConfig};
use crate::simcore::{lanes, EventKey, EventQueue};
use crate::topology::SystemTopology;
use crate::util::memo::Memo;

/// Event kinds: step completions apply before arrivals at one timestamp
/// (a slot freed at `t` is visible to a request arriving at `t`).
const EV_STEP: u8 = 0;
const EV_ARRIVE: u8 = 1;

/// Fraction of DRAM held back from KV as working-set reserve
/// (activations, fragmentation slack): capacity / `DRAM_RESERVE_DIV`.
const DRAM_RESERVE_DIV: u64 = 20;

/// DRAM bytes the KV pager may use on `topo` when serving `model`:
/// capacity minus the resident bf16 weights minus the working-set
/// reserve. Zero when the weights alone don't fit.
pub fn dram_kv_budget(topo: &SystemTopology, model: &str) -> u64 {
    let Some(m) = mpresets::by_name(model) else {
        return 0;
    };
    let params_bytes = m.params() * 2;
    let cap = topo.dram().capacity;
    cap.saturating_sub(params_bytes)
        .saturating_sub(cap / DRAM_RESERVE_DIV)
}

/// Round a token/batch count to its calibration bucket: the next power
/// of two, floored so tiny prompts share a cell.
fn bucket(x: usize, floor: usize) -> usize {
    x.max(1).next_power_of_two().max(floor)
}

const CTX_BUCKET_FLOOR: usize = 256;

/// Memoized per-(model, phase, batch bucket, context bucket) step-cost
/// model: one real schedule build + executor run per cell, priced with
/// the `prefill` / `decode` builders from `offload::schedules::inference`
/// on the `baseline-dram` engine (serving weights are DRAM-resident).
/// Every value is a pure function of the topology, so cache warm-up
/// order — including the parallel pre-warm — cannot change results.
pub struct ServeCalibrator<'t> {
    topo: &'t SystemTopology,
    costs: Memo<String, Option<f64>>,
}

fn compute_step_cost(
    topo: &SystemTopology,
    model: &str,
    phase: &str,
    batch: usize,
    ctx: usize,
) -> Option<f64> {
    let m = mpresets::by_name(model)?;
    let eng = engine::by_name("baseline-dram")?;
    let sched = schedules::by_name(phase)?;
    let cfg = RunConfig::new(m, Workload::new(1, batch, ctx), eng).with_schedule(sched);
    let prof = MemoryPlan::profile_run(topo, &cfg).ok()?;
    let plan = MemoryPlan::build_with_profiles(topo, &cfg, false, prof.clone())
        .or_else(|_| MemoryPlan::build_with_profiles(topo, &cfg, true, prof))
        .ok()?;
    Some(simulate_iteration(topo, &cfg, &plan).iter_s)
}

impl<'t> ServeCalibrator<'t> {
    pub fn new(topo: &'t SystemTopology) -> Self {
        Self {
            topo,
            costs: Memo::new(),
        }
    }

    fn cell(&mut self, model: &str, phase: &str, batch: usize, ctx: usize) -> Option<f64> {
        let topo = self.topo;
        let key = format!("{model}|{phase}|{batch}|{ctx}");
        self.costs
            .get_or_insert_with(key, || compute_step_cost(topo, model, phase, batch, ctx))
    }

    /// Calibrated prompt-pass seconds for one request: the bucket cell's
    /// full-prompt cost scaled linearly to the actual token count
    /// (documented approximation — attention's quadratic term is priced
    /// at the bucket's shape).
    pub fn prefill_s(&mut self, model: &str, prompt_tokens: usize) -> Option<f64> {
        let b = bucket(prompt_tokens, CTX_BUCKET_FLOOR);
        let cell = self.cell(model, "prefill", 1, b)?;
        Some(cell * prompt_tokens as f64 / b as f64)
    }

    /// Calibrated seconds for one batched decode step (one token per
    /// sequence) at the given batch size and maximum live context.
    pub fn decode_step_s(
        &mut self,
        model: &str,
        batch: usize,
        max_ctx: usize,
    ) -> Option<f64> {
        let bb = bucket(batch, 1);
        let cb = bucket(max_ctx, CTX_BUCKET_FLOOR);
        self.cell(model, "decode", bb, cb)
    }

    /// Pre-compute the distinct prefill cells of a trace across `threads`
    /// workers. Decode cells (whose buckets depend on runtime batch
    /// composition) still fill in lazily, serially. Seeding is
    /// counter-neutral and value-pure, so the digest is independent of
    /// the worker count.
    pub fn prewarm(&mut self, requests: &[RequestSpec], threads: usize) {
        let mut cells: BTreeMap<String, (String, usize)> = BTreeMap::new();
        for r in requests {
            let b = bucket(r.prompt_tokens, CTX_BUCKET_FLOOR);
            cells
                .entry(format!("{}|prefill|1|{b}", r.model))
                .or_insert_with(|| (r.model.clone(), b));
        }
        let cells: Vec<(String, (String, usize))> = cells.into_iter().collect();
        let topo = self.topo;
        let results = lanes::par_indexed(cells.len(), threads, |i| {
            let (model, b) = &cells[i].1;
            compute_step_cost(topo, model, "prefill", 1, *b)
        });
        for ((key, _), cost) in cells.into_iter().zip(results) {
            self.costs.seed(key, cost);
        }
    }
}

/// What the admission policy sees and does during one scheduling pass.
/// Indices are positions in the current queue and stay stable for the
/// whole pass — admitted / shed entries are compacted afterwards.
pub trait ServeProbe {
    fn now_s(&self) -> f64;
    fn queue_len(&self) -> usize;
    fn request(&self, idx: usize) -> &RequestSpec;
    /// Wait so far plus the request's calibrated prefill, milliseconds:
    /// the best TTFT it could still achieve if admitted right now.
    fn projected_ttft_ms(&self, idx: usize) -> f64;
    /// Try to admit: checks a free batch slot and the KV fit of the
    /// prompt, allocates on success. Idempotently false once decided.
    fn try_admit(&mut self, idx: usize) -> bool;
    /// Drop the request from the queue (recorded as `Shed` with a
    /// projected-TTFT reason).
    fn shed(&mut self, idx: usize);
}

/// An SLO-aware admission policy: pure decision logic over a
/// [`ServeProbe`], exactly like `fleet::SchedPolicy` over its probe.
pub trait AdmitPolicy: Send + Sync {
    fn name(&self) -> &'static str;
    fn admit(&self, probe: &mut dyn ServeProbe);
}

pub type AdmitRef = Arc<dyn AdmitPolicy>;

/// Strict arrival order: admit from the head, stop at the first refusal.
/// Never sheds.
pub struct Fcfs;

impl AdmitPolicy for Fcfs {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn admit(&self, probe: &mut dyn ServeProbe) {
        for idx in 0..probe.queue_len() {
            if !probe.try_admit(idx) {
                break;
            }
        }
    }
}

/// Shed queued requests that can no longer meet their TTFT SLO (their
/// wait plus calibrated prefill already exceeds it), then backfill: try
/// every remaining request, not just the head.
pub struct SloStrict;

impl AdmitPolicy for SloStrict {
    fn name(&self) -> &'static str {
        "slo-strict"
    }

    fn admit(&self, probe: &mut dyn ServeProbe) {
        for idx in 0..probe.queue_len() {
            let slo = probe.request(idx).slo_ms;
            if probe.projected_ttft_ms(idx) > slo {
                probe.shed(idx);
            }
        }
        for idx in 0..probe.queue_len() {
            probe.try_admit(idx);
        }
    }
}

/// Resolve an admission-policy name (`fcfs`, `slo-strict`, alias `ours`).
pub fn admission_by_name(name: &str) -> Option<AdmitRef> {
    match name {
        "fcfs" => Some(Arc::new(Fcfs)),
        "slo-strict" | "ours" => Some(Arc::new(SloStrict)),
        _ => None,
    }
}

/// Canonical admission-policy names (CLI help text).
pub fn admission_known_names() -> Vec<&'static str> {
    vec!["fcfs", "slo-strict"]
}

/// Per-pass decision state of one queue entry.
#[derive(Clone, Copy, PartialEq)]
enum Decision {
    Pending,
    Admitted,
    Shed,
}

/// The concrete probe: queue indices → trace requests, KV fit through
/// the pager, projections from pre-computed prefill estimates.
struct QueueProbe<'a> {
    now_s: f64,
    specs: &'a [RequestSpec],
    /// Trace indices of queued requests, arrival order.
    queue: &'a [usize],
    /// Calibrated prefill estimate per queue entry, seconds
    /// (`f64::INFINITY` when calibration failed).
    prefill_est_s: &'a [f64],
    pager: &'a mut KvPager,
    slots_free: usize,
    decisions: Vec<Decision>,
}

impl ServeProbe for QueueProbe<'_> {
    fn now_s(&self) -> f64 {
        self.now_s
    }

    fn queue_len(&self) -> usize {
        self.queue.len()
    }

    fn request(&self, idx: usize) -> &RequestSpec {
        &self.specs[self.queue[idx]]
    }

    fn projected_ttft_ms(&self, idx: usize) -> f64 {
        let r = self.request(idx);
        (self.now_s - r.arrival_s + self.prefill_est_s[idx]) * 1e3
    }

    fn try_admit(&mut self, idx: usize) -> bool {
        if self.decisions[idx] != Decision::Pending || self.slots_free == 0 {
            return false;
        }
        let r = &self.specs[self.queue[idx]];
        if !self.pager.can_fit(r.prompt_tokens) || !self.pager.alloc(r.id, r.prompt_tokens) {
            return false;
        }
        self.slots_free -= 1;
        self.decisions[idx] = Decision::Admitted;
        true
    }

    fn shed(&mut self, idx: usize) {
        if self.decisions[idx] == Decision::Pending {
            self.decisions[idx] = Decision::Shed;
        }
    }
}

/// One running request's progress.
struct RunState {
    /// Output tokens generated so far (0 until its prefill step lands).
    generated: usize,
    /// Prefill has executed (set at the end of its first step).
    prefilled: bool,
}

/// The step in flight: membership frozen at schedule time.
struct StepPlan {
    /// Trace indices running their prefill in this step.
    prefills: Vec<usize>,
    /// Trace indices decoding one token in this step.
    decodes: Vec<usize>,
    /// CXL cold-page bytes the decode reads pulled (for records).
    cold_read: Vec<(usize, u64)>,
}

/// One scheduling pass: pre-compute TTFT projections, run the policy
/// over the probe, apply its decisions, compact the queue.
#[allow(clippy::too_many_arguments)]
fn admit_pass(
    specs: &[RequestSpec],
    admission: &AdmitRef,
    max_batch: usize,
    now: f64,
    queue: &mut Vec<usize>,
    running: &mut BTreeMap<usize, RunState>,
    records: &mut [RequestRecord],
    pager: &mut KvPager,
    cal: &mut ServeCalibrator<'_>,
) {
    if queue.is_empty() || running.len() >= max_batch {
        return;
    }
    let est: Vec<f64> = queue
        .iter()
        .map(|&i| {
            cal.prefill_s(&specs[i].model, specs[i].prompt_tokens)
                .unwrap_or(f64::INFINITY)
        })
        .collect();
    let mut probe = QueueProbe {
        now_s: now,
        specs,
        queue: queue.as_slice(),
        prefill_est_s: &est,
        pager,
        slots_free: max_batch - running.len(),
        decisions: vec![Decision::Pending; queue.len()],
    };
    admission.admit(&mut probe);
    let decisions = probe.decisions;
    let mut kept = Vec::with_capacity(queue.len());
    for (idx, &i) in queue.iter().enumerate() {
        match decisions[idx] {
            Decision::Pending => kept.push(i),
            Decision::Admitted => {
                records[i].start_s = Some(now);
                records[i].status = RequestStatus::Running;
                running.insert(
                    i,
                    RunState {
                        generated: 0,
                        prefilled: false,
                    },
                );
            }
            Decision::Shed => {
                records[i].status = RequestStatus::Shed;
                records[i].reason = Some(format!(
                    "projected TTFT {:.0}ms exceeds SLO {:.0}ms",
                    (now - specs[i].arrival_s + est[idx]) * 1e3,
                    specs[i].slo_ms
                ));
            }
        }
    }
    *queue = kept;
}

/// Freeze the next step's membership and price it. `None` when nothing
/// is running.
fn schedule_step(
    specs: &[RequestSpec],
    model: &str,
    migration_bw: f64,
    running: &BTreeMap<usize, RunState>,
    pager: &KvPager,
    cal: &mut ServeCalibrator<'_>,
    charged_migrated: &mut u64,
) -> Option<(StepPlan, f64)> {
    if running.is_empty() {
        return None;
    }
    let mut plan = StepPlan {
        prefills: Vec::new(),
        decodes: Vec::new(),
        cold_read: Vec::new(),
    };
    let mut dt = 0.0f64;
    let mut max_ctx = 0usize;
    for (&i, st) in running {
        if !st.prefilled {
            plan.prefills.push(i);
            dt += cal
                .prefill_s(&specs[i].model, specs[i].prompt_tokens)
                .unwrap_or(1.0);
        } else {
            plan.decodes.push(i);
            max_ctx = max_ctx.max(specs[i].prompt_tokens + st.generated);
        }
    }
    if !plan.decodes.is_empty() {
        dt += cal
            .decode_step_s(model, plan.decodes.len(), max_ctx)
            .unwrap_or(1.0);
        // Cold-page attention reads ride the CXL links.
        let mut cold_total = 0u64;
        for &i in &plan.decodes {
            let cold = pager.cold_bytes(specs[i].id);
            if cold > 0 {
                plan.cold_read.push((i, cold));
                cold_total += cold;
            }
        }
        dt += cold_total as f64 / migration_bw;
    }
    // Promotion/demotion traffic since the last step rides the same
    // links (charged once, to the step that follows it).
    let migrated = pager.counters().migrated_bytes();
    dt += (migrated - *charged_migrated) as f64 / migration_bw;
    *charged_migrated = migrated;
    debug_assert!(dt > 0.0, "a non-empty step must take time");
    Some((plan, dt.max(1e-9)))
}

/// Run a whole request trace under one (KV policy, admission policy)
/// pair. `threads` only parallelizes the calibration pre-warm — the
/// event loop itself is serial and the result digest is independent of
/// the worker count. `max_batch` caps concurrently running requests.
pub fn simulate_serving(
    topo: &SystemTopology,
    trace: &RequestTrace,
    kv_policy: &KvPolicyRef,
    admission: &AdmitRef,
    max_batch: usize,
    threads: usize,
) -> ServeResult {
    assert!(max_batch >= 1, "need at least one batch slot");
    let mut ids = BTreeSet::new();
    for r in &trace.requests {
        assert!(ids.insert(r.id), "duplicate request id {}", r.id);
        assert!(
            r.arrival_s.is_finite() && r.arrival_s >= 0.0,
            "request {}: arrival must be a non-negative finite time",
            r.id
        );
        assert!(
            r.validity_issues().is_empty(),
            "request {}: {:?}",
            r.id,
            r.validity_issues()
        );
        assert!(
            r.registry_issues().is_empty(),
            "request {}: {:?}",
            r.id,
            r.registry_issues()
        );
    }
    let model = trace
        .requests
        .first()
        .map(|r| r.model.clone())
        .unwrap_or_else(|| "7b".to_string());
    assert!(
        trace.requests.iter().all(|r| r.model == model),
        "one serving host holds one resident model; mixed-model traces \
         need one simulator per model"
    );

    let mut cal = ServeCalibrator::new(topo);
    cal.prewarm(&trace.requests, threads);

    let page_bytes = (PAGE_TOKENS as u64) * kv_bytes_per_token(
        &mpresets::by_name(&model).expect("validated above"),
    );
    let budget = dram_kv_budget(topo, &model);
    let mut pager = KvPager::new(topo, page_bytes.max(1), budget, kv_policy.clone());
    let migration_bw = topo.migration_bandwidth();

    let mut result = ServeResult::new(kv_policy.name(), admission.name(), topo);
    result.dram_kv_budget = budget;
    result.records = trace
        .requests
        .iter()
        .map(|r| RequestRecord {
            id: r.id,
            model: r.model.clone(),
            prompt_tokens: r.prompt_tokens,
            max_output_tokens: r.max_output_tokens,
            slo_ms: r.slo_ms,
            arrival_s: r.arrival_s,
            start_s: None,
            first_token_s: None,
            finish_s: None,
            output_tokens: 0,
            truncated: false,
            status: RequestStatus::Queued,
            reason: None,
            cold_read_bytes: 0,
        })
        .collect();

    let mut events: EventQueue<usize> = EventQueue::new();
    for (i, r) in trace.requests.iter().enumerate() {
        events.push(EventKey::new(r.arrival_s, EV_ARRIVE, i as u64), i);
    }
    let mut seq: u64 = trace.requests.len() as u64;

    let mut queue: Vec<usize> = Vec::new();
    let mut running: BTreeMap<usize, RunState> = BTreeMap::new();
    let mut step: Option<StepPlan> = None;
    // Migration bytes already charged to a scheduled step.
    let mut charged_migrated: u64 = pager.counters().migrated_bytes();
    let specs = &trace.requests;

    while let Some((key, payload)) = events.pop() {
        let now = key.time();
        result.n_events += 1;
        match key.kind() {
            EV_ARRIVE => {
                let i = payload;
                let r = &specs[i];
                // Reject immediately iff the request can never be held:
                // its full KV footprint exceeds what the policy's tiers
                // can ever reach, or its prompt cannot be admitted even
                // onto an empty pager (it would otherwise park forever).
                let full_pages = r.total_kv_tokens().div_ceil(PAGE_TOKENS) as u64;
                if full_pages * pager.page_bytes() > pager.capacity()
                    || !pager.fits_empty(r.prompt_tokens)
                {
                    result.records[i].status = RequestStatus::Rejected;
                    result.records[i].reason = Some(format!(
                        "kv footprint of {full_pages} pages exceeds what the \
                         {} policy can hold",
                        kv_policy.name()
                    ));
                } else {
                    queue.push(i);
                    admit_pass(
                        specs,
                        admission,
                        max_batch,
                        now,
                        &mut queue,
                        &mut running,
                        &mut result.records,
                        &mut pager,
                        &mut cal,
                    );
                }
            }
            EV_STEP => {
                let plan = step.take().expect("EV_STEP without a step in flight");
                result.n_steps += 1;
                let mut finished: Vec<usize> = Vec::new();
                for &i in &plan.prefills {
                    let st = running.get_mut(&i).expect("prefill member running");
                    st.prefilled = true;
                    // Prefill emits the first output token.
                    st.generated = 1;
                    result.records[i].first_token_s = Some(now);
                    if !pager.append(specs[i].id, 1) {
                        result.records[i].truncated = true;
                        finished.push(i);
                    } else if st.generated >= specs[i].max_output_tokens {
                        finished.push(i);
                    }
                }
                for (i, cold) in &plan.cold_read {
                    result.records[*i].cold_read_bytes += cold;
                }
                for &i in &plan.decodes {
                    let st = running.get_mut(&i).expect("decode member running");
                    st.generated += 1;
                    if !pager.append(specs[i].id, 1) {
                        result.records[i].truncated = true;
                        finished.push(i);
                    } else if st.generated >= specs[i].max_output_tokens {
                        finished.push(i);
                    }
                }
                for &i in &finished {
                    let st = running.remove(&i).expect("finishing request running");
                    result.records[i].finish_s = Some(now);
                    result.records[i].output_tokens = st.generated as u64;
                    result.records[i].status = RequestStatus::Completed;
                    pager.free(specs[i].id);
                }
                if !finished.is_empty() {
                    pager.promote_slack();
                }
                admit_pass(
                    specs,
                    admission,
                    max_batch,
                    now,
                    &mut queue,
                    &mut running,
                    &mut result.records,
                    &mut pager,
                    &mut cal,
                );
            }
            other => unreachable!("unknown event kind {other}"),
        }
        // Start the next step if none is in flight and work remains.
        if step.is_none() {
            if let Some((plan, dt)) = schedule_step(
                specs,
                &model,
                migration_bw,
                &running,
                &pager,
                &mut cal,
                &mut charged_migrated,
            ) {
                events.push(EventKey::new(now + dt, EV_STEP, seq), usize::MAX);
                seq += 1;
                step = Some(plan);
            }
        }
        result.samples.push(OccupancySample {
            t_s: now,
            used: pager.used().to_vec(),
            queue_len: queue.len(),
            running: running.len(),
        });
    }

    assert!(
        running.is_empty() && queue.is_empty(),
        "event heap drained with live requests"
    );
    result.kv = pager.counters();
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::kv;
    use crate::serve::request::RequestGen;
    use crate::topology::presets;

    fn tiny_topo(dram: u64) -> SystemTopology {
        presets::with_dram_capacity(presets::dev_tiny(), dram)
    }

    fn run(
        topo: &SystemTopology,
        trace: &RequestTrace,
        kv_name: &str,
        adm: &str,
        threads: usize,
    ) -> ServeResult {
        simulate_serving(
            topo,
            trace,
            &kv::by_name(kv_name).unwrap(),
            &admission_by_name(adm).unwrap(),
            8,
            threads,
        )
    }

    #[test]
    fn admission_registry_round_trips() {
        assert_eq!(admission_by_name("fcfs").unwrap().name(), "fcfs");
        assert_eq!(admission_by_name("slo-strict").unwrap().name(), "slo-strict");
        assert_eq!(admission_by_name("ours").unwrap().name(), "slo-strict");
        assert!(admission_by_name("nope").is_none());
        for n in admission_known_names() {
            assert_eq!(admission_by_name(n).unwrap().name(), n);
        }
    }

    #[test]
    fn every_request_reaches_a_terminal_state() {
        let topo = presets::dev_tiny();
        let trace = RequestGen::mixed(21, 24, "tiny-2m").generate();
        let r = run(&topo, &trace, "tiered", "fcfs", 1);
        assert_eq!(r.arrived(), 24);
        assert_eq!(r.unfinished(), 0);
        assert_eq!(r.completed() + r.rejected() + r.shed(), 24);
        // Plenty of DRAM: nothing rejected, everything completes.
        assert_eq!(r.completed(), 24);
        assert_eq!(r.kv.resident_pages(), 0, "drained cache must be empty");
        for rec in &r.records {
            assert!(rec.ttft_ms().unwrap() > 0.0);
            assert_eq!(rec.output_tokens as usize, rec.max_output_tokens);
        }
        // The occupancy curve ends empty.
        let last = r.samples.last().unwrap();
        assert!(last.used.iter().all(|&u| u == 0));
    }

    #[test]
    fn digests_are_bitwise_stable_across_reruns_and_threads() {
        let topo = tiny_topo(64 << 20);
        let trace = RequestGen::mixed(33, 20, "tiny-2m").generate();
        let a = run(&topo, &trace, "tiered:2", "slo-strict", 1);
        let b = run(&topo, &trace, "tiered:2", "slo-strict", 1);
        let c = run(&topo, &trace, "tiered:2", "slo-strict", 4);
        assert_eq!(a.digest(), b.digest(), "rerun must be bit-identical");
        assert_eq!(a.digest(), c.digest(), "thread count must not leak");
        let d = run(&topo, &trace, "dram-only", "slo-strict", 1);
        assert_ne!(a.digest(), d.digest(), "policy is digest-material");
    }

    #[test]
    fn slo_strict_sheds_what_fcfs_leaves_waiting() {
        // One batch slot, long prefills, impatient SLOs: the queue backs
        // up and slo-strict must shed hopeless requests.
        let topo = presets::dev_tiny();
        let mut gen = RequestGen::mixed(9, 12, "tiny-2m");
        gen.mean_interarrival_s = 0.001; // everyone arrives at once
        gen.slo_ms = 1.0; // nobody tolerates a queue
        let trace = gen.generate();
        let strict = simulate_serving(
            &topo,
            &trace,
            &kv::by_name("tiered").unwrap(),
            &admission_by_name("slo-strict").unwrap(),
            1,
            1,
        );
        assert!(strict.shed() > 0, "backlogged SLOs must shed");
        assert_eq!(strict.unfinished(), 0);
        let fcfs = simulate_serving(
            &topo,
            &trace,
            &kv::by_name("tiered").unwrap(),
            &admission_by_name("fcfs").unwrap(),
            1,
            1,
        );
        assert_eq!(fcfs.shed(), 0, "fcfs never sheds");
        assert_eq!(fcfs.completed(), 12);
    }

    #[test]
    fn tiering_admits_what_dram_only_rejects() {
        let topo = tiny_topo(48 << 20);
        let budget = dram_kv_budget(&topo, "tiny-2m");
        let m = mpresets::by_name("tiny-2m").unwrap();
        let page = PAGE_TOKENS as u64 * kv_bytes_per_token(&m);
        let dram_pages = budget / page;
        // A request bigger than the DRAM budget but far below DRAM+CXL.
        let big_tokens = (dram_pages as usize + 8) * PAGE_TOKENS;
        let trace = RequestTrace {
            seed: 0,
            requests: vec![RequestSpec {
                id: 0,
                arrival_s: 0.5,
                model: "tiny-2m".into(),
                prompt_tokens: big_tokens,
                max_output_tokens: 16,
                slo_ms: 60_000.0,
            }],
        };
        let dram = run(&topo, &trace, "dram-only", "fcfs", 1);
        assert_eq!(dram.rejected(), 1);
        assert!(dram.records[0]
            .reason
            .as_deref()
            .unwrap()
            .contains("exceeds"));
        let tiered = run(&topo, &trace, "tiered", "fcfs", 1);
        assert_eq!(tiered.completed(), 1);
        assert!(tiered.kv.demoted_bytes > 0, "the big prompt must spill");
        assert!(
            tiered.records[0].cold_read_bytes > 0,
            "decode must pay for cold pages"
        );
    }
}
