//! Plan / allocator lints: placement integrity, lifetime hygiene, and
//! per-phase capacity fit — the checks that used to live as asserts (or
//! not at all) inside the allocator.

use super::diag::{Anchor, Diagnostics, Severity};
use crate::mem::{NumaAllocator, Placement, RegionRequest};
use crate::offload::plan::MemoryPlan;
use crate::topology::NodeId;
use crate::util::units::fmt_bytes;

/// Lint a built plan: every committed region's placement must be
/// internally consistent (P101/P105), lifetimes should be doing useful
/// work (P102/P103), and committed occupancy must fit every memory node
/// at every phase (P104). See DESIGN.md §12 for the catalog.
pub fn lint_plan(plan: &MemoryPlan<'_>) -> Diagnostics {
    let mut ds = Diagnostics::new();
    let alloc = &plan.alloc;
    let n_phases = alloc.n_phases();
    for r in alloc.regions() {
        let anchor = Anchor::Region {
            name: r.name.clone(),
        };
        if let Err(msg) = r.placement.check(r.bytes) {
            let code = if msg.contains("duplicate") {
                "P105"
            } else {
                "P101"
            };
            ds.push(code, Severity::Error, anchor.clone(), msg);
        }
        match &r.lifetime {
            Some(lt)
                if n_phases > 1
                    && lt.birth_phase == 0
                    && lt.death_phase as usize == n_phases - 1 =>
            {
                ds.push(
                    "P102",
                    Severity::Info,
                    anchor,
                    format!(
                        "scoped lifetime {lt} spans the whole {n_phases}-phase timeline — \
                         the region is never released"
                    ),
                );
            }
            None if n_phases > 1 => {
                // An eternal region whose measured liveness is narrower
                // holds capacity through phases where it is dead.
                if let Some(p) = plan.profiles.as_ref().and_then(|ps| ps.get(&r.name)) {
                    if (p.lifetime.span() as usize) < n_phases {
                        ds.push(
                            "P103",
                            Severity::Warn,
                            anchor,
                            format!(
                                "committed eternally but its measured liveness window is only \
                                 {} — phase-scoped accounting would release {} outside it",
                                p.lifetime,
                                fmt_bytes(r.bytes)
                            ),
                        );
                    }
                }
            }
            _ => {}
        }
    }
    // Committed occupancy vs node capacity, per phase. Unreachable through
    // `commit` (it refuses overflow), so any hit here means accounting has
    // been corrupted.
    for (node_idx, spec) in alloc.topo().mem_nodes.iter().enumerate() {
        for ph in 0..n_phases {
            let used = alloc.used_on_at(NodeId(node_idx), ph);
            if used > spec.capacity {
                ds.push(
                    "P104",
                    Severity::Error,
                    Anchor::Phase { index: ph },
                    format!(
                        "committed occupancy on node{node_idx} ({}) is {} at phase {ph}, over \
                         its {} capacity",
                        spec.name,
                        fmt_bytes(used),
                        fmt_bytes(spec.capacity)
                    ),
                );
            }
        }
    }
    ds
}

/// Pre-commit check: would committing `req` under `placement` overflow any
/// memory node at any phase of the request's liveness window? Emits the
/// same placement-integrity codes as [`lint_plan`] plus P104 for each
/// (node, phase) that would go over capacity — all without mutating the
/// allocator, so a caller can surface the diagnostic *before* the commit
/// is attempted.
pub fn lint_commit(
    alloc: &NumaAllocator<'_>,
    req: &RegionRequest,
    placement: &Placement,
) -> Diagnostics {
    let mut ds = Diagnostics::new();
    if let Err(msg) = placement.check(req.bytes) {
        let code = if msg.contains("duplicate") {
            "P105"
        } else {
            "P101"
        };
        ds.push(
            code,
            Severity::Error,
            Anchor::Region {
                name: req.name.clone(),
            },
            msg,
        );
    }
    let n_phases = alloc.n_phases();
    let last = n_phases.saturating_sub(1);
    let (lo, hi) = match &req.lifetime {
        Some(lt) => (
            (lt.birth_phase as usize).min(last),
            (lt.death_phase as usize).min(last),
        ),
        None => (0, last),
    };
    for (node, bytes) in &placement.parts {
        let cap = alloc.topo().node(*node).capacity;
        for ph in lo..=hi {
            let used = alloc.used_on_at(*node, ph);
            if used + bytes > cap {
                ds.push(
                    "P104",
                    Severity::Error,
                    Anchor::Phase { index: ph },
                    format!(
                        "committing '{}' would raise node{} occupancy to {} at phase {ph}, \
                         over its {} capacity",
                        req.name,
                        node.0,
                        fmt_bytes(used + bytes),
                        fmt_bytes(cap)
                    ),
                );
            }
        }
    }
    ds
}
