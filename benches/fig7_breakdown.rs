//! Fig. 7: per-phase latency breakdown (FWD/BWD/STEP) of CPU offloading —
//! local DRAM baseline vs naive CXL interleave, (a) 1 GPU and (b) 2 GPUs.
//!
//! Paper shape:
//! (a) single GPU — STEP suffers the most (latency-bound CPU optimizer);
//! (b) dual GPU — FWD/BWD degrade too (shared-AIC bandwidth contention),
//!     STEP stays latency-limited.

use cxlfine::jobj;
use cxlfine::mem::Policy;
use cxlfine::model::footprint::Workload;
use cxlfine::model::presets::mistral_nemo_12b;
use cxlfine::offload::{simulate_iteration, MemoryPlan, PhaseBreakdown, RunConfig};
use cxlfine::topology::presets::{config_a, with_dram_capacity};
use cxlfine::trow;
use cxlfine::util::bench::BenchReport;
use cxlfine::util::table::Table;
use cxlfine::util::units::GIB;

fn run(topo: &cxlfine::topology::SystemTopology, gpus: usize, batch: usize, policy: Policy) -> PhaseBreakdown {
    let cfg = RunConfig::new(mistral_nemo_12b(), Workload::new(gpus, batch, 4096), policy);
    let plan = MemoryPlan::build(topo, &cfg).expect("plan fits");
    simulate_iteration(topo, &cfg, &plan)
}

fn main() {
    let mut report = BenchReport::new("fig7_breakdown");
    let base_topo = config_a();
    let cxl_topo = with_dram_capacity(config_a(), 128 * GIB);

    // Panel (a) uses the paper's B=16; panel (b) probes the transfer-bound
    // regime (B=1) where the shared-AIC contention is visible — at large
    // batch the GPU kernels hide the slower transfers almost entirely (the
    // same reason Fig. 9's large-batch cells degrade least).
    for (panel, gpus, batch) in [("a_single_gpu", 1usize, 16usize), ("b_dual_gpu", 2, 1)] {
        let base = run(&base_topo, gpus, batch, Policy::DramOnly);
        let naive = run(&cxl_topo, gpus, batch, Policy::NaiveInterleave);
        let mut t = Table::new(&["phase", "DRAM (s)", "naive CXL (s)", "inflation"]).left(0);
        let rows = [
            ("FWD", base.fwd_s, naive.fwd_s),
            ("BWD", base.bwd_s, naive.bwd_s),
            ("STEP", base.step_s, naive.step_s),
            ("iteration", base.iter_s, naive.iter_s),
        ];
        for (name, b, n) in rows {
            t.row(trow![
                name,
                format!("{b:.2}"),
                format!("{n:.2}"),
                format!("{:.2}x", n / b)
            ]);
        }
        let step_inf = naive.step_s / base.step_s;
        let fwd_inf = naive.fwd_s / base.fwd_s;
        let bwd_inf = naive.bwd_s / base.bwd_s;
        if gpus == 1 {
            // (a) STEP inflates the most
            assert!(step_inf > fwd_inf && step_inf > bwd_inf,
                "single-GPU: STEP must dominate the slowdown (step {step_inf:.2} fwd {fwd_inf:.2} bwd {bwd_inf:.2})");
            assert!(step_inf > 1.5, "STEP inflation {step_inf:.2}");
        } else {
            // (b) transfer phases degrade markedly under contention
            assert!(fwd_inf > 1.10, "dual-GPU FWD inflation {fwd_inf:.2}");
            assert!(step_inf > 1.5, "STEP stays latency-limited: {step_inf:.2}");
        }
        println!("{panel}: FWD {fwd_inf:.2}x BWD {bwd_inf:.2}x STEP {step_inf:.2}x");
        report.section(
            panel,
            t,
            jobj! {
                "base" => base.to_json(),
                "naive" => naive.to_json(),
                "gpus" => gpus,
                "batch" => batch,
            },
        );
    }
    report.finish();
}
