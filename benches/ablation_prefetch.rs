//! Ablation: parameter prefetch depth (the DESIGN.md §5 design-choice
//! study). ZeRO-Offload overlaps the next block's H2D copy with the
//! current block's kernel; depth 0–1 exposes transfer latency, excessive
//! depth buys nothing (and would cost GPU memory).

use cxlfine::mem::Policy;
use cxlfine::model::footprint::Workload;
use cxlfine::model::presets::qwen25_7b;
use cxlfine::offload::{simulate_iteration, MemoryPlan, RunConfig};
use cxlfine::topology::presets::{config_a, with_dram_capacity};
use cxlfine::trow;
use cxlfine::util::bench::{points_json, BenchReport};
use cxlfine::util::table::Table;
use cxlfine::util::units::GIB;

fn main() {
    let mut report = BenchReport::new("ablation_prefetch");
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    // small batch: parameter streaming dominates → prefetch matters most
    let w = Workload::new(1, 1, 4096);
    let mut t = Table::new(&["prefetch_depth", "iter_s", "tokens_per_sec", "vs depth=1"]);
    let (mut xs, mut tps) = (vec![], vec![]);
    let mut depth1 = 0.0f64;
    for depth in [1usize, 2, 3, 4, 6, 8] {
        let mut cfg = RunConfig::new(
            qwen25_7b(),
            w,
            Policy::CxlAware { striping: false },
        );
        cfg.prefetch_depth = depth;
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let b = simulate_iteration(&topo, &cfg, &plan);
        if depth == 1 {
            depth1 = b.tokens_per_sec();
        }
        t.row(trow![
            depth,
            format!("{:.3}", b.iter_s),
            format!("{:.0}", b.tokens_per_sec()),
            format!("{:+.1}%", 100.0 * (b.tokens_per_sec() / depth1 - 1.0))
        ]);
        xs.push(depth as f64);
        tps.push(b.tokens_per_sec());
    }
    // diminishing returns: depth 2 ≥ depth 1; depth 8 ≈ depth 4
    assert!(tps[1] >= tps[0], "prefetch 2 must not lose to 1");
    let tail = (tps[5] / tps[3] - 1.0).abs();
    assert!(tail < 0.05, "depth 8 vs 4 should be flat, got {tail:.3}");
    report.section("throughput_vs_depth", t, points_json(&xs, &[("tokens_per_sec", &tps)]));
    report.finish();
}
