//! Differential parity suite for the shared `simcore` event core
//! (DESIGN.md §14): both adapters — `sim::flow::FlowSim` and
//! `fleet::sim::simulate_fleet_faulted` — are replayed against their
//! frozen pre-port oracles (`sim::reference::RefFlowSim`,
//! `fleet::reference::ref_simulate_fleet_faulted`) and must agree
//! bit-for-bit.
//!
//! What this adds over `golden_trace.rs` (which already differentials the
//! flow engines at workflow scale):
//!
//! * a timer storm deep enough to cross `WHEEL_UPGRADE_LEN`, so the
//!   calendar-wheel backend (not just the heap) is the thing being
//!   diffed against the frozen engine,
//! * the fleet loop: every scheduler × recovery-policy cell on a faulted
//!   trace, the pinned 100-job faulted cell across thread counts, and
//!   the zero-fault bitwise no-op, all against the frozen reference,
//! * self-blessing golden pins (`rust/tests/golden/*.digest`) so the
//!   agreed digests also become cross-build regression gates.

mod common;

use cxlfine::fleet::reference::ref_simulate_fleet_faulted;
use cxlfine::fleet::{
    faults, mixed_trace_with_xl, pinned_faults_from_baseline, scheduler, simulate_fleet,
    simulate_fleet_faulted, FaultGen, FaultTrace, FleetTrace, PolicyRef, RecoveryRef,
};
use cxlfine::sim::flow::{CapacityModel, Event, FlowSim, ResourceId};
use cxlfine::sim::reference::RefFlowSim;
use cxlfine::simcore::queue::WHEEL_UPGRADE_LEN;
use cxlfine::topology::presets::{config_a, with_dram_capacity};
use cxlfine::topology::SystemTopology;
use cxlfine::util::digest::Fnv64;
use cxlfine::util::units::GIB;

const GB: f64 = 1e9;

fn assert_golden_digest(name: &str, digest: u64) {
    common::assert_golden_digest("simcore_parity", name, digest);
}

// ---------------------------------------------------------------------
// Flow engines: a timer storm that forces the wheel backend.
// ---------------------------------------------------------------------

/// A minimal common surface over the two flow engines (the full trait
/// lives in `golden_trace.rs`; this suite only needs the replay calls).
trait Des {
    fn add_resource(&mut self, name: &str, model: CapacityModel) -> ResourceId;
    fn start_flow(&mut self, path: &[ResourceId], bytes: f64, setup: f64, tag: u64);
    fn add_timer(&mut self, delay: f64, tag: u64);
    fn next_event(&mut self) -> Option<Event>;
    fn now(&self) -> f64;
}

macro_rules! impl_des {
    ($t:ty) => {
        impl Des for $t {
            fn add_resource(&mut self, name: &str, model: CapacityModel) -> ResourceId {
                <$t>::add_resource(self, name, model)
            }
            fn start_flow(&mut self, path: &[ResourceId], bytes: f64, setup: f64, tag: u64) {
                <$t>::start_flow(self, path, bytes, setup, tag);
            }
            fn add_timer(&mut self, delay: f64, tag: u64) {
                <$t>::add_timer(self, delay, tag);
            }
            fn next_event(&mut self) -> Option<Event> {
                <$t>::next_event(self)
            }
            fn now(&self) -> f64 {
                <$t>::now(self)
            }
        }
    };
}

impl_des!(FlowSim);
impl_des!(RefFlowSim);

/// `n_timers` pending timers (well past the auto-upgrade threshold, so
/// the timers `EventQueue` runs on the calendar wheel) plus a band of
/// flows; deadlines repeat exactly (`i % 977` scaled) so duplicate
/// timestamps, bucket cohorts and cursor rewinds are all exercised.
fn timer_storm<S: Des>(sim: &mut S, n_timers: u64) -> Vec<(Event, u64)> {
    let dram = sim.add_resource("dram-ctrl", CapacityModel::Fixed(204.0 * GB));
    let aic = sim.add_resource(
        "aic-tx",
        CapacityModel::Contended { single: 54.0 * GB, contended: 26.0 * GB },
    );
    for i in 0..n_timers {
        sim.add_timer((i % 977) as f64 * 1e-3, i);
    }
    for i in 0..64u64 {
        let path = if i % 2 == 0 { [dram] } else { [aic] };
        let setup = 1e-5 * (i % 9) as f64; // zero-setup flows activate inline
        sim.start_flow(&path, 1e8 + i as f64 * 1e6, setup, 10_000 + i);
    }
    let mut out = Vec::new();
    while let Some(e) = sim.next_event() {
        out.push((e, sim.now().to_bits()));
    }
    out
}

fn stream_digest(events: &[(Event, u64)]) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(events.len() as u64);
    for (e, now_bits) in events {
        match e {
            Event::FlowDone { id, tag } => {
                h.write_u64(0).write_u64(id.0).write_u64(*tag);
            }
            Event::TimerFired { id, tag } => {
                h.write_u64(1).write_u64(id.0).write_u64(*tag);
            }
        }
        h.write_u64(*now_bits);
    }
    h.finish()
}

#[test]
fn timer_storm_on_the_wheel_backend_is_bit_identical_to_reference() {
    const STORM: u64 = 3_200;
    assert!(
        STORM as usize > WHEEL_UPGRADE_LEN,
        "the storm must cross the wheel auto-upgrade threshold"
    );
    let mut new_sim = FlowSim::new();
    let mut ref_sim = RefFlowSim::new();
    let a = timer_storm(&mut new_sim, STORM);
    let b = timer_storm(&mut ref_sim, STORM);
    assert_eq!(a.len(), b.len(), "timer storm: event counts diverge");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(
            x,
            y,
            "timer storm: event #{i} diverges — new {:?} @ {} vs reference {:?} @ {}",
            x.0,
            f64::from_bits(x.1),
            y.0,
            f64::from_bits(y.1)
        );
    }
    assert_eq!(a.len() as u64, STORM + 64, "every timer and flow must complete");
    assert_golden_digest("simcore_timer_storm_events", stream_digest(&a));
}

// ---------------------------------------------------------------------
// Fleet loop: simcore adapter vs the frozen pre-port reference.
// ---------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn assert_fleet_pair(
    topo: &SystemTopology,
    trace: &FleetTrace,
    policy: &PolicyRef,
    fault_trace: &FaultTrace,
    recovery: &RecoveryRef,
    threads: usize,
    what: &str,
) -> u64 {
    let new = simulate_fleet_faulted(topo, trace, policy, fault_trace, recovery, threads);
    let old = ref_simulate_fleet_faulted(topo, trace, policy, fault_trace, recovery, threads);
    assert_eq!(
        new.digest(),
        old.digest(),
        "{what}: the simcore adapter loop drifted from the frozen reference"
    );
    new.digest()
}

#[test]
fn fleet_matrix_every_scheduler_and_recovery_matches_the_frozen_loop() {
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let trace = mixed_trace_with_xl(&topo, 1013, 28, 2);
    assert_eq!(trace.jobs.len(), 30);
    // A seeded synthetic fault trace spanning the arrival window, so the
    // degradation / evacuation / requeue arms all run on both loops.
    let horizon =
        trace.jobs.last().map(|j| j.arrival_s).unwrap_or(0.0).max(1.0);
    let fault_trace = FaultGen::new(29, 6, horizon).generate(&topo);
    fault_trace.validate(&topo).unwrap();
    for policy in scheduler::registry() {
        for recovery in faults::registry() {
            assert_fleet_pair(
                &topo,
                &trace,
                &policy,
                &fault_trace,
                &recovery,
                2,
                &format!("30-job matrix {}/{}", policy.name(), recovery.name()),
            );
        }
    }
}

#[test]
fn fleet_pinned_faulted_cell_matches_reference_across_thread_counts() {
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let trace = mixed_trace_with_xl(&topo, 1007, 92, 8);
    assert_eq!(trace.jobs.len(), 100);
    let policy = scheduler::by_name("placement-aware").unwrap();
    let baseline = simulate_fleet(&topo, &trace, &policy, 2);
    let fault_trace = pinned_faults_from_baseline(&topo, &baseline);
    fault_trace.validate(&topo).unwrap();
    let recovery = faults::by_name("evacuate").unwrap();
    let d1 = assert_fleet_pair(
        &topo,
        &trace,
        &policy,
        &fault_trace,
        &recovery,
        1,
        "pinned evacuate, 1 thread",
    );
    let d4 = simulate_fleet_faulted(&topo, &trace, &policy, &fault_trace, &recovery, 4);
    assert_eq!(d1, d4.digest(), "thread count must not change the digest");
    assert_golden_digest("simcore_fleet_pinned_evacuate", d1);
}

#[test]
fn fleet_zero_fault_trace_is_a_bitwise_noop_on_both_loops() {
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let trace = mixed_trace_with_xl(&topo, 1007, 10, 0);
    assert_eq!(trace.jobs.len(), 10);
    let policy = scheduler::by_name("backfill").unwrap();
    let empty = FaultTrace::empty();
    let mut digests = Vec::new();
    for recovery in faults::registry() {
        let d = assert_fleet_pair(
            &topo,
            &trace,
            &policy,
            &empty,
            &recovery,
            2,
            &format!("zero-fault {}", recovery.name()),
        );
        digests.push(d);
    }
    // The digest excludes the recovery-policy name, so a zero-fault run
    // is one bit pattern whatever the recovery policy.
    assert!(
        digests.windows(2).all(|w| w[0] == w[1]),
        "zero-fault digests must agree across recovery policies: {digests:x?}"
    );
    let faultless = simulate_fleet(&topo, &trace, &policy, 2);
    assert_eq!(
        faultless.digest(),
        digests[0],
        "simulate_fleet must equal the zero-fault faulted run"
    );
}
