//! The schedule-graph IR: a fine-tuning iteration as a declarative task
//! DAG instead of a hand-woven state machine.
//!
//! A [`Schedule`] is a list of typed [`OpNode`]s — host↔GPU transfers, GPU
//! kernels, CPU optimizer phases, and barriers — joined by explicit
//! dependency edges and grouped under named phases. The nodes carry *model*
//! quantities (bytes, FLOPs, element counts), never wall-clock times: the
//! [`crate::offload::executor`] prices them against a [`crate::topology::
//! SystemTopology`] when it walks the graph over the fabric.
//!
//! Determinism contract (DESIGN.md §9): node indices are the executor's
//! dispatch priority — whenever several nodes become runnable from the same
//! completion event they are issued in ascending [`OpId`] order, so a
//! builder that lists nodes in the legacy engine's issuance order
//! reproduces the legacy event stream byte-for-byte. Builders for new
//! scenarios only need *some* fixed order; parity-critical builders
//! (`schedules::zero_offload`) document theirs.

use crate::mem::RegionId;
use crate::sim::fabric::Dir;
use crate::sim::memmodel::OptLayout;
use crate::topology::{GpuId, NodeId, SystemTopology};

/// Index of a node inside one [`Schedule`] (also its dispatch priority and
/// its event tag in the executor).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct OpId(pub u32);

/// One FLOPs contribution to a GPU kernel: `scale · (flops / gpu_flops)`
/// seconds. Kernels are sums of terms so builders can express the legacy
/// engine's exact arithmetic (e.g. "block forward plus half an LM-head")
/// and the executor can price each term against *that node's own GPU*.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlopsTerm {
    pub flops: f64,
    pub scale: f64,
}

impl FlopsTerm {
    pub fn new(flops: f64) -> Self {
        Self { flops, scale: 1.0 }
    }
    pub fn scaled(flops: f64, scale: f64) -> Self {
        Self { flops, scale }
    }
}

/// The typed operations a schedule node can perform.
#[derive(Clone, Debug)]
pub enum Op {
    /// A host↔GPU DMA striped over memory nodes (fractions sum to 1).
    /// Becomes one flow per positive stripe; the node completes when the
    /// last stripe lands.
    Transfer {
        gpu: GpuId,
        stripes: Vec<(NodeId, f64)>,
        dir: Dir,
        bytes: f64,
    },
    /// A GPU kernel: Σ scaleᵢ·(flopsᵢ / gpu-effective-FLOPs) seconds,
    /// priced with the *owning GPU's* rating (a slow card lengthens its
    /// own lane only).
    Compute { gpu: GpuId, work: Vec<FlopsTerm> },
    /// A CPU phase timed by the calibrated memory model: one Adam update
    /// over `adam_elements` placed as `adam_layout`, plus pure streaming
    /// passes (the fp32→bf16 casts) summed in order.
    CpuStep {
        adam_elements: u64,
        adam_layout: OptLayout,
        streams: Vec<(f64, OptLayout)>,
    },
    /// Pure synchronization: completes the instant its deps complete, emits
    /// no fabric event and no trace span.
    Barrier,
}

/// Which memory-plan region a node's traffic is attributed to.
///
/// Touch annotations are *descriptive*: the executor prices ops from their
/// payloads alone and ignores touches entirely, so a builder that omits
/// them changes nothing about simulated time. They exist for the
/// tensor-access profiling pass ([`crate::mem::profile::profile_schedule`])
/// and the executor's per-region traffic ledger, which together close the
/// loop between the schedule and the memory subsystem.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegionTouch {
    /// The node's `Op::Transfer` bytes move to/from this region.
    Dma(RegionId),
    /// The node's `Op::CpuStep` Adam pass read-modify-writes this region
    /// (each listed region carries the node's full `adam_elements`).
    CpuRmw(RegionId),
    /// The `stream`-th entry of the node's `Op::CpuStep` streams
    /// reads/writes this region.
    CpuStream { region: RegionId, stream: usize },
    /// Liveness-only: the node consumes the region's contents without
    /// modeled traffic (e.g. the optimizer reading bf16 gradients, which
    /// the calibrated STEP model folds into the Adam pass). Extends the
    /// region's lifetime window but not its traffic counters.
    Keepalive(RegionId),
}

impl RegionTouch {
    /// The region this touch refers to.
    pub fn region(&self) -> RegionId {
        match self {
            RegionTouch::Dma(r)
            | RegionTouch::CpuRmw(r)
            | RegionTouch::Keepalive(r)
            | RegionTouch::CpuStream { region: r, .. } => *r,
        }
    }
}

/// A schedule node: the op, its dependency edges, and its reporting labels.
#[derive(Clone, Debug)]
pub struct OpNode {
    pub op: Op,
    /// All of these must complete before the node is issued.
    pub deps: Vec<OpId>,
    /// Trace span label, e.g. `"param-load b3"`.
    pub name: String,
    /// Trace lane, e.g. `"gpu0/h2d"`.
    pub lane: String,
    /// Index into [`Schedule::phases`].
    pub phase: usize,
    /// Marks a phase *boundary* node: the phase's boundary time is the max
    /// completion over its marked nodes (legacy FWD/BWD/STEP semantics).
    pub ends_phase: bool,
    /// Plan regions whose traffic/liveness this node represents (may be
    /// empty for unattributed ops; never affects executor timing).
    pub touches: Vec<RegionTouch>,
}

/// A whole iteration as a task DAG.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    /// Phase names in declaration order (`PhaseReport` preserves it).
    pub phases: Vec<String>,
    pub nodes: Vec<OpNode>,
    /// Tokens processed by one execution of this schedule (all GPUs, all
    /// micro-batches).
    pub tokens: u64,
}

impl Schedule {
    pub fn new(tokens: u64) -> Self {
        Self {
            phases: Vec::new(),
            nodes: Vec::new(),
            tokens,
        }
    }

    /// Intern a phase name, returning its index.
    pub fn phase(&mut self, name: &str) -> usize {
        if let Some(i) = self.phases.iter().position(|p| p == name) {
            return i;
        }
        self.phases.push(name.to_string());
        self.phases.len() - 1
    }

    /// Append a node; its index is its dispatch priority.
    pub fn push(&mut self, node: OpNode) -> OpId {
        assert!(
            self.nodes.len() < u32::MAX as usize,
            "schedule node count overflows OpId"
        );
        self.nodes.push(node);
        OpId(self.nodes.len() as u32 - 1)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Structural validation: in-bounds edges, an acyclic graph, sane op
    /// payloads, and (given the topology) valid GPU / memory-node indices.
    /// Returns a human-readable description of the first violation.
    pub fn validate(&self, topo: &SystemTopology) -> Result<(), String> {
        self.validated_adjacency(topo).map(|_| ())
    }

    /// [`Schedule::validate`] that additionally hands back the dependency
    /// bookkeeping it had to build anyway — `(indegree, dependents)` per
    /// node — so the executor does not rebuild the O(V+E) adjacency.
    pub(crate) fn validated_adjacency(
        &self,
        topo: &SystemTopology,
    ) -> Result<(Vec<u32>, Vec<Vec<u32>>), String> {
        if self.nodes.is_empty() {
            return Err("schedule has no nodes".into());
        }
        let n = self.nodes.len();
        for (i, node) in self.nodes.iter().enumerate() {
            if node.phase >= self.phases.len() {
                return Err(format!(
                    "node {i} ({}) references phase {} but only {} are declared",
                    node.name,
                    node.phase,
                    self.phases.len()
                ));
            }
            for d in &node.deps {
                if d.0 as usize >= n {
                    return Err(format!(
                        "node {i} ({}) depends on out-of-range node {}",
                        node.name, d.0
                    ));
                }
                if d.0 as usize == i {
                    return Err(format!("node {i} ({}) depends on itself", node.name));
                }
            }
            match &node.op {
                Op::Transfer {
                    gpu,
                    stripes,
                    bytes,
                    ..
                } => {
                    if gpu.0 >= topo.gpus.len() {
                        return Err(format!(
                            "node {i} ({}) targets gpu {} but topology has {}",
                            node.name,
                            gpu.0,
                            topo.gpus.len()
                        ));
                    }
                    if stripes.is_empty() {
                        return Err(format!("node {i} ({}) has no stripes", node.name));
                    }
                    let total: f64 = stripes.iter().map(|(_, f)| *f).sum();
                    if (total - 1.0).abs() > 1e-6 {
                        return Err(format!(
                            "node {i} ({}) stripe fractions sum to {total}",
                            node.name
                        ));
                    }
                    for (mem, _) in stripes {
                        if mem.0 >= topo.mem_nodes.len() {
                            return Err(format!(
                                "node {i} ({}) stripes onto unknown memory node {}",
                                node.name, mem.0
                            ));
                        }
                    }
                    if !bytes.is_finite() || *bytes < 0.0 {
                        return Err(format!("node {i} ({}) has bad byte count {bytes}", node.name));
                    }
                }
                Op::Compute { gpu, work } => {
                    if gpu.0 >= topo.gpus.len() {
                        return Err(format!(
                            "node {i} ({}) computes on gpu {} but topology has {}",
                            node.name,
                            gpu.0,
                            topo.gpus.len()
                        ));
                    }
                    if work.is_empty() {
                        return Err(format!("node {i} ({}) has no FLOPs terms", node.name));
                    }
                    for t in work {
                        if !t.flops.is_finite() || t.flops < 0.0 || !t.scale.is_finite() {
                            return Err(format!(
                                "node {i} ({}) has bad FLOPs term {t:?}",
                                node.name
                            ));
                        }
                    }
                }
                Op::CpuStep { streams, .. } => {
                    for (bytes, _) in streams {
                        if !bytes.is_finite() || *bytes < 0.0 {
                            return Err(format!(
                                "node {i} ({}) has bad stream byte count {bytes}",
                                node.name
                            ));
                        }
                    }
                }
                Op::Barrier => {}
            }
            for t in &node.touches {
                match t {
                    RegionTouch::Dma(_) => {
                        if !matches!(node.op, Op::Transfer { .. }) {
                            return Err(format!(
                                "node {i} ({}) has a Dma touch on a non-Transfer op",
                                node.name
                            ));
                        }
                    }
                    RegionTouch::CpuRmw(_) => {
                        if !matches!(node.op, Op::CpuStep { .. }) {
                            return Err(format!(
                                "node {i} ({}) has a CpuRmw touch on a non-CpuStep op",
                                node.name
                            ));
                        }
                    }
                    RegionTouch::CpuStream { stream, .. } => match &node.op {
                        Op::CpuStep { streams, .. } => {
                            if *stream >= streams.len() {
                                return Err(format!(
                                    "node {i} ({}) stream touch {} out of range ({} streams)",
                                    node.name,
                                    stream,
                                    streams.len()
                                ));
                            }
                        }
                        _ => {
                            return Err(format!(
                                "node {i} ({}) has a CpuStream touch on a non-CpuStep op",
                                node.name
                            ));
                        }
                    },
                    RegionTouch::Keepalive(_) => {}
                }
            }
        }
        // Kahn's algorithm: every node must be reachable through the edge
        // partial order, otherwise there is a cycle.
        let mut indeg: Vec<u32> = vec![0; n];
        let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (i, node) in self.nodes.iter().enumerate() {
            indeg[i] = node.deps.len() as u32;
            for d in &node.deps {
                dependents[d.0 as usize].push(i as u32);
            }
        }
        let mut scratch = indeg.clone();
        let mut queue: Vec<u32> = (0..n as u32).filter(|&i| scratch[i as usize] == 0).collect();
        let mut seen = 0usize;
        while let Some(i) = queue.pop() {
            seen += 1;
            for &j in &dependents[i as usize] {
                scratch[j as usize] -= 1;
                if scratch[j as usize] == 0 {
                    queue.push(j);
                }
            }
        }
        if seen != n {
            return Err(format!(
                "schedule graph has a cycle ({} of {n} nodes reachable)",
                seen
            ));
        }
        Ok((indeg, dependents))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::dev_tiny;

    fn transfer(deps: Vec<OpId>, phase: usize) -> OpNode {
        OpNode {
            op: Op::Transfer {
                gpu: GpuId(0),
                stripes: vec![(NodeId(0), 1.0)],
                dir: Dir::HostToGpu,
                bytes: 1e6,
            },
            deps,
            name: "t".into(),
            lane: "gpu0/h2d".into(),
            phase,
            ends_phase: false,
            touches: vec![],
        }
    }

    #[test]
    fn phases_intern_stably() {
        let mut s = Schedule::new(0);
        assert_eq!(s.phase("fwd"), 0);
        assert_eq!(s.phase("bwd"), 1);
        assert_eq!(s.phase("fwd"), 0, "re-interning returns the same index");
        assert_eq!(s.phases, vec!["fwd".to_string(), "bwd".to_string()]);
    }

    #[test]
    fn valid_chain_passes() {
        let topo = dev_tiny();
        let mut s = Schedule::new(128);
        s.phase("fwd");
        let a = s.push(transfer(vec![], 0));
        let b = s.push(transfer(vec![a], 0));
        s.push(transfer(vec![a, b], 0));
        assert!(s.validate(&topo).is_ok());
    }

    #[test]
    fn cycle_is_rejected() {
        let topo = dev_tiny();
        let mut s = Schedule::new(0);
        s.phase("fwd");
        // 0 → 1 → 0 (forward reference then back-edge)
        s.push(transfer(vec![OpId(1)], 0));
        s.push(transfer(vec![OpId(0)], 0));
        let err = s.validate(&topo).unwrap_err();
        assert!(err.contains("cycle"), "{err}");
    }

    #[test]
    fn self_dep_is_rejected() {
        let topo = dev_tiny();
        let mut s = Schedule::new(0);
        s.phase("fwd");
        s.push(transfer(vec![OpId(0)], 0));
        assert!(s.validate(&topo).unwrap_err().contains("itself"));
    }

    #[test]
    fn out_of_range_dep_is_rejected() {
        let topo = dev_tiny();
        let mut s = Schedule::new(0);
        s.phase("fwd");
        s.push(transfer(vec![OpId(7)], 0));
        assert!(s.validate(&topo).unwrap_err().contains("out-of-range"));
    }

    #[test]
    fn bad_stripes_and_phase_are_rejected() {
        let topo = dev_tiny();
        let mut s = Schedule::new(0);
        s.phase("fwd");
        let mut n = transfer(vec![], 0);
        if let Op::Transfer { stripes, .. } = &mut n.op {
            stripes[0].1 = 0.5; // does not sum to 1
        }
        s.push(n);
        assert!(s.validate(&topo).unwrap_err().contains("stripe fractions"));

        let mut s2 = Schedule::new(0);
        s2.phase("fwd");
        let mut n2 = transfer(vec![], 0);
        n2.phase = 3; // never declared
        s2.push(n2);
        assert!(s2.validate(&topo).unwrap_err().contains("phase 3"));
    }

    #[test]
    fn unknown_gpu_is_rejected() {
        let topo = dev_tiny(); // 2 GPUs
        let mut s = Schedule::new(0);
        s.phase("fwd");
        let mut n = transfer(vec![], 0);
        if let Op::Transfer { gpu, .. } = &mut n.op {
            *gpu = GpuId(5);
        }
        s.push(n);
        assert!(s.validate(&topo).unwrap_err().contains("gpu 5"));
    }

    #[test]
    fn empty_schedule_is_rejected() {
        let topo = dev_tiny();
        let s = Schedule::new(0);
        assert!(s.validate(&topo).is_err());
    }

    #[test]
    fn touch_kind_must_match_op_kind() {
        use crate::mem::RegionId;
        let topo = dev_tiny();
        // Dma touch on a Transfer: fine.
        let mut s = Schedule::new(0);
        s.phase("fwd");
        let mut n = transfer(vec![], 0);
        n.touches = vec![RegionTouch::Dma(RegionId(0)), RegionTouch::Keepalive(RegionId(1))];
        s.push(n);
        assert!(s.validate(&topo).is_ok());
        // CpuRmw touch on a Transfer: rejected.
        let mut s2 = Schedule::new(0);
        s2.phase("fwd");
        let mut n2 = transfer(vec![], 0);
        n2.touches = vec![RegionTouch::CpuRmw(RegionId(0))];
        s2.push(n2);
        assert!(s2.validate(&topo).unwrap_err().contains("CpuRmw"));
        // CpuStream index out of range: rejected.
        let mut s3 = Schedule::new(0);
        s3.phase("step");
        s3.push(OpNode {
            op: Op::CpuStep {
                adam_elements: 10,
                adam_layout: OptLayout::dram_only(),
                streams: vec![(1e6, OptLayout::dram_only())],
            },
            deps: vec![],
            name: "step".into(),
            lane: "cpu/step".into(),
            phase: 0,
            ends_phase: true,
            touches: vec![RegionTouch::CpuStream {
                region: RegionId(0),
                stream: 1,
            }],
        });
        assert!(s3.validate(&topo).unwrap_err().contains("stream touch"));
    }

    #[test]
    fn touch_region_accessor() {
        use crate::mem::RegionId;
        assert_eq!(RegionTouch::Dma(RegionId(3)).region(), RegionId(3));
        assert_eq!(RegionTouch::CpuRmw(RegionId(1)).region(), RegionId(1));
        assert_eq!(RegionTouch::Keepalive(RegionId(2)).region(), RegionId(2));
        assert_eq!(
            RegionTouch::CpuStream {
                region: RegionId(4),
                stream: 0
            }
            .region(),
            RegionId(4)
        );
    }
}
