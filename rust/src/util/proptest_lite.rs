//! A miniature property-testing harness (no `proptest` offline).
//!
//! Provides value generators driven by [`Xoshiro256pp`] and a `forall`
//! runner with greedy shrinking: on failure it repeatedly asks the
//! generator's paired shrinker for smaller candidates, keeping any that
//! still fail, and reports the minimal one. Enough machinery for the
//! coordinator invariants this crate cares about (routing, batching,
//! placement, striping, simulator state).

use super::prng::Xoshiro256pp;

/// A generator of values plus a shrinking strategy.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value;
    /// Candidate smaller values, most aggressive first. Default: none.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Uniform u64 in `[lo, hi]` with halving shrink toward `lo`.
pub struct U64Range {
    pub lo: u64,
    pub hi: u64,
}

impl Gen for U64Range {
    type Value = u64;
    fn generate(&self, rng: &mut Xoshiro256pp) -> u64 {
        rng.range_u64(self.lo, self.hi)
    }
    fn shrink(&self, value: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        let v = *value;
        if v > self.lo {
            out.push(self.lo);
            let mid = self.lo + (v - self.lo) / 2;
            if mid != self.lo && mid != v {
                out.push(mid);
            }
            if v - 1 != self.lo && v - 1 != mid {
                out.push(v - 1);
            }
        }
        out
    }
}

/// usize variant.
pub struct UsizeRange {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeRange {
    type Value = usize;
    fn generate(&self, rng: &mut Xoshiro256pp) -> usize {
        rng.range_usize(self.lo, self.hi)
    }
    fn shrink(&self, value: &usize) -> Vec<usize> {
        U64Range {
            lo: self.lo as u64,
            hi: self.hi as u64,
        }
        .shrink(&(*value as u64))
        .into_iter()
        .map(|v| v as usize)
        .collect()
    }
}

/// Uniform f64 in `[lo, hi)`; shrinks toward lo and simple round values.
pub struct F64Range {
    pub lo: f64,
    pub hi: f64,
}

impl Gen for F64Range {
    type Value = f64;
    fn generate(&self, rng: &mut Xoshiro256pp) -> f64 {
        rng.range_f64(self.lo, self.hi)
    }
    fn shrink(&self, value: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        if *value != self.lo {
            out.push(self.lo);
            let mid = self.lo + (value - self.lo) / 2.0;
            if mid != self.lo && mid != *value {
                out.push(mid);
            }
        }
        out
    }
}

/// Vec of another generator with length in `[min_len, max_len]`; shrinks by
/// dropping halves/elements then shrinking elements.
pub struct VecOf<G> {
    pub inner: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value {
        let len = rng.range_usize(self.min_len, self.max_len);
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        let n = value.len();
        if n > self.min_len {
            // drop second half, first half, single elements
            let keep = (n / 2).max(self.min_len);
            out.push(value[..keep].to_vec());
            out.push(value[n - keep..].to_vec());
            if n >= 1 && n - 1 >= self.min_len {
                let mut v = value.clone();
                v.pop();
                out.push(v);
            }
        }
        // shrink the first shrinkable element
        for (i, el) in value.iter().enumerate().take(4) {
            for smaller in self.inner.shrink(el) {
                let mut v = value.clone();
                v[i] = smaller;
                out.push(v);
            }
        }
        out
    }
}

/// Pair generator.
pub struct PairOf<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairOf<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        for a in self.0.shrink(&value.0) {
            out.push((a, value.1.clone()));
        }
        for b in self.1.shrink(&value.1) {
            out.push((value.0.clone(), b));
        }
        out
    }
}

/// One of a fixed set of choices (no shrinking beyond first element).
pub struct OneOf<T: Clone + std::fmt::Debug>(pub Vec<T>);

impl<T: Clone + std::fmt::Debug> Gen for OneOf<T> {
    type Value = T;
    fn generate(&self, rng: &mut Xoshiro256pp) -> T {
        rng.choice(&self.0).clone()
    }
}

/// Result of a property run.
#[derive(Debug)]
pub struct Failure<V> {
    pub seed: u64,
    pub case_index: usize,
    pub original: V,
    pub minimal: V,
    pub message: String,
}

/// Run `prop` on `cases` generated values; on failure, shrink and panic with
/// the minimal counterexample. `name` labels the property in the panic.
pub fn forall<G, F>(name: &str, seed: u64, cases: usize, gen: &G, prop: F)
where
    G: Gen,
    F: Fn(&G::Value) -> Result<(), String>,
{
    if let Some(fail) = forall_quiet(seed, cases, gen, &prop) {
        panic!(
            "property {name:?} failed (seed={}, case={}):\n  original: {:?}\n  minimal:  {:?}\n  error: {}",
            fail.seed, fail.case_index, fail.original, fail.minimal, fail.message
        );
    }
}

/// Like [`forall`] but returns the failure instead of panicking (testable).
pub fn forall_quiet<G, F>(
    seed: u64,
    cases: usize,
    gen: &G,
    prop: &F,
) -> Option<Failure<G::Value>>
where
    G: Gen,
    F: Fn(&G::Value) -> Result<(), String>,
{
    let mut rng = Xoshiro256pp::seeded(seed);
    for case_index in 0..cases {
        let value = gen.generate(&mut rng);
        if let Err(message) = prop(&value) {
            let (minimal, message) = shrink_loop(gen, value.clone(), message, prop);
            return Some(Failure {
                seed,
                case_index,
                original: value,
                minimal,
                message,
            });
        }
    }
    None
}

fn shrink_loop<G, F>(gen: &G, mut current: G::Value, mut msg: String, prop: &F) -> (G::Value, String)
where
    G: Gen,
    F: Fn(&G::Value) -> Result<(), String>,
{
    // Greedy descent, bounded to avoid pathological loops.
    for _ in 0..1000 {
        let mut improved = false;
        for cand in gen.shrink(&current) {
            if let Err(m) = prop(&cand) {
                current = cand;
                msg = m;
                improved = true;
                break;
            }
        }
        if !improved {
            break;
        }
    }
    (current, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_returns_none() {
        let g = U64Range { lo: 0, hi: 100 };
        assert!(forall_quiet(1, 200, &g, &|v| {
            if *v <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        })
        .is_none());
    }

    #[test]
    fn shrinks_u64_to_boundary() {
        // Property: v < 37. Minimal counterexample should be exactly 37.
        let g = U64Range { lo: 0, hi: 10_000 };
        let fail = forall_quiet(7, 500, &g, &|v| {
            if *v < 37 {
                Ok(())
            } else {
                Err(format!("{v} >= 37"))
            }
        })
        .expect("must fail");
        assert_eq!(fail.minimal, 37, "greedy shrink should reach the boundary");
    }

    #[test]
    fn shrinks_vec_length() {
        // Property: len < 3. Minimal counterexample has exactly len 3.
        let g = VecOf {
            inner: U64Range { lo: 0, hi: 5 },
            min_len: 0,
            max_len: 40,
        };
        let fail = forall_quiet(11, 200, &g, &|v: &Vec<u64>| {
            if v.len() < 3 {
                Ok(())
            } else {
                Err("too long".into())
            }
        })
        .expect("must fail");
        assert_eq!(fail.minimal.len(), 3);
    }

    #[test]
    fn pair_shrinks_both_sides() {
        let g = PairOf(U64Range { lo: 0, hi: 100 }, U64Range { lo: 0, hi: 100 });
        let fail = forall_quiet(13, 500, &g, &|(a, b)| {
            if a + b < 50 {
                Ok(())
            } else {
                Err("sum too big".into())
            }
        })
        .expect("must fail");
        assert_eq!(fail.minimal.0 + fail.minimal.1, 50);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_panics_with_context() {
        let g = U64Range { lo: 0, hi: 10 };
        forall("always-fails", 3, 10, &g, |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_given_seed() {
        let g = VecOf {
            inner: U64Range { lo: 0, hi: 1000 },
            min_len: 1,
            max_len: 10,
        };
        let mut r1 = Xoshiro256pp::seeded(99);
        let mut r2 = Xoshiro256pp::seeded(99);
        assert_eq!(g.generate(&mut r1), g.generate(&mut r2));
    }
}
