//! Fleet-simulator scale bench: jobs/sec and sim-events/sec at 10-, 100-
//! and 1000-job traces for every registered admission policy, on the
//! §V-B-shaped host (config-a, 128 GiB DRAM).
//!
//! Gates (enforced in CI via `--smoke`):
//! * `placement-aware` ≥ `fifo` on aggregate tokens/sec at the pinned
//!   100-job mixed-context trace, and strictly fewer rejected jobs (the
//!   XL jobs in the static/lifetime gap are the difference).
//! * bit-identical result digests across reruns (the determinism
//!   contract at bench scale).
//! * the `simcore` event-core rung (DESIGN.md §14): the adapter loop is
//!   byte-identical to the frozen `fleet::reference` loop, and the full
//!   (non-smoke) run enforces ≥10× events/sec at the 100_000-job trace.
//!
//! Results land in `bench_out/fleet_scale/` and in `BENCH_fleet.json`
//! (override: `CXLFINE_BENCH_FLEET_OUT`), which the CI bench-smoke job
//! uploads on every push so the fleet-throughput trajectory is recorded
//! alongside the DES, schedule and capacity ones.

use std::time::Instant;

use cxlfine::fleet::reference::ref_simulate_fleet;
use cxlfine::fleet::{mixed_trace_with_xl, scheduler, simulate_fleet};
use cxlfine::topology::presets::{config_a, with_dram_capacity};
use cxlfine::trow;
use cxlfine::util::bench::BenchReport;
use cxlfine::util::json::{Json, JsonObj};
use cxlfine::util::table::Table;
use cxlfine::util::units::GIB;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = BenchReport::new("fleet_scale");
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let threads = cxlfine::util::threadpool::default_threads();

    // Every scale carries 8 XL jobs (statically infeasible, lifetime
    // feasible) except the 10-job smoke point, which stays pure mixed.
    let scales: Vec<(usize, usize)> = if smoke {
        vec![(10, 0), (92, 8)]
    } else {
        vec![(10, 0), (92, 8), (992, 8)]
    };

    let mut json_scales = Vec::new();
    for (n_mixed, n_xl) in scales {
        let n_jobs = n_mixed + n_xl;
        let trace = mixed_trace_with_xl(&topo, 1007, n_mixed, n_xl);
        assert_eq!(
            trace.jobs.len(),
            n_jobs,
            "the XL static/lifetime gap cell must exist at 128 GiB DRAM"
        );
        let mut t = Table::new(&[
            "policy",
            "wall",
            "jobs/s",
            "events/s",
            "agg tok/s",
            "completed",
            "rejected",
        ])
        .left(0);
        let mut raws = Vec::new();
        let mut by_policy = Vec::new();
        for policy in scheduler::registry() {
            let t0 = Instant::now();
            let res = simulate_fleet(&topo, &trace, &policy, threads);
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            t.row(trow![
                policy.name(),
                format!("{wall:.2}s"),
                format!("{:.0}", n_jobs as f64 / wall),
                format!("{:.0}", res.n_events as f64 / wall),
                format!("{:.0}", res.aggregate_tokens_per_sec()),
                res.completed(),
                res.rejected()
            ]);
            let mut cell = JsonObj::new();
            cell.set("policy", policy.name());
            cell.set("wall_s", wall);
            cell.set("jobs_per_sec", n_jobs as f64 / wall);
            cell.set("events_per_sec", res.n_events as f64 / wall);
            cell.set("aggregate_tokens_per_sec", res.aggregate_tokens_per_sec());
            cell.set("completed", res.completed());
            cell.set("rejected", res.rejected());
            cell.set("digest", format!("{:016x}", res.digest()));
            raws.push(Json::Obj(cell));
            by_policy.push((policy.name().to_string(), res));
        }
        // The admission gate at the pinned 100-job mixed trace.
        if n_xl > 0 {
            let get = |name: &str| {
                by_policy
                    .iter()
                    .find(|(n, _)| n == name)
                    .map(|(_, r)| r)
                    .expect("registered policy ran")
            };
            let (fifo, pa) = (get("fifo"), get("placement-aware"));
            assert!(
                pa.rejected() < fifo.rejected(),
                "{n_jobs} jobs: placement-aware must strictly beat fifo on rejections \
                 ({} vs {})",
                pa.rejected(),
                fifo.rejected()
            );
            if n_jobs <= 100 {
                assert!(
                    pa.aggregate_tokens_per_sec() + 1e-9 >= fifo.aggregate_tokens_per_sec(),
                    "100-job trace: placement-aware lost aggregate throughput: {:.1} vs {:.1}",
                    pa.aggregate_tokens_per_sec(),
                    fifo.aggregate_tokens_per_sec()
                );
            }
        }
        // Determinism at the smallest scale: a rerun is bit-identical.
        if n_jobs <= 10 {
            let policy = scheduler::by_name("fifo").unwrap();
            let a = simulate_fleet(&topo, &trace, &policy, 1);
            let b = simulate_fleet(&topo, &trace, &policy, threads);
            assert_eq!(a.digest(), b.digest(), "rerun must be bit-identical");
        }
        println!("{n_jobs}-job trace on {} ({} XL jobs)", topo.name, n_xl);
        report.section(&format!("jobs_{n_jobs}"), t, Json::Arr(raws.clone()));
        json_scales.push(Json::Obj({
            let mut o = JsonObj::new();
            o.set("n_jobs", n_jobs);
            o.set("n_xl", n_xl);
            o.set("trace_digest", format!("{:016x}", trace.digest()));
            o.set("policies", Json::Arr(raws));
            o
        }));
    }

    // The §14 event-core rung: the simcore adapter loop diffed against
    // the frozen pre-port loop (`fleet::reference`) on one big trace.
    // Smoke diffs a 2_000-job prefix so CI stays fast; the full run
    // takes the 100_000-job rung and enforces the ≥10× events/sec gate.
    let (big_mixed, big_xl) = if smoke { (1_992, 8) } else { (99_992, 8) };
    let n_big = big_mixed + big_xl;
    let big = mixed_trace_with_xl(&topo, 1007, big_mixed, big_xl);
    assert_eq!(
        big.jobs.len(),
        n_big,
        "the XL static/lifetime gap cell must exist at the simcore rung"
    );
    let policy = scheduler::by_name("placement-aware").unwrap();
    let t0 = Instant::now();
    let new = simulate_fleet(&topo, &big, &policy, threads);
    let wall_new = t0.elapsed().as_secs_f64().max(1e-9);
    let t0 = Instant::now();
    let old = ref_simulate_fleet(&topo, &big, &policy, threads);
    let wall_ref = t0.elapsed().as_secs_f64().max(1e-9);
    assert_eq!(
        new.digest(),
        old.digest(),
        "{n_big}-job trace: the simcore adapter loop drifted from the frozen reference"
    );
    let eps_new = new.n_events as f64 / wall_new;
    let eps_ref = old.n_events as f64 / wall_ref;
    let speedup = eps_new / eps_ref;
    if !smoke {
        assert!(
            speedup >= 10.0,
            "100_000-job rung: the simcore loop must be ≥10x the frozen loop \
             on events/sec (got {speedup:.2}x: {eps_new:.0} vs {eps_ref:.0})"
        );
    }
    let mut t = Table::new(&["engine", "wall", "events/s", "speedup", "digest"]);
    t = t.left(0);
    let mut raws = Vec::new();
    for (engine, wall, eps, res) in [
        ("simcore", wall_new, eps_new, &new),
        ("reference", wall_ref, eps_ref, &old),
    ] {
        t.row(trow![
            engine,
            format!("{wall:.2}s"),
            format!("{eps:.0}"),
            format!("{:.2}x", eps / eps_ref),
            format!("{:016x}", res.digest())
        ]);
        let mut cell = JsonObj::new();
        cell.set("engine", engine);
        cell.set("wall_s", wall);
        cell.set("events_per_sec", eps);
        cell.set("n_events", res.n_events);
        cell.set("digest", format!("{:016x}", res.digest()));
        raws.push(Json::Obj(cell));
    }
    println!("simcore rung: {n_big}-job trace, {speedup:.2}x events/sec vs reference");
    report.section("simcore_rung", t, Json::Arr(raws.clone()));
    let simcore_rung = Json::Obj({
        let mut o = JsonObj::new();
        o.set("n_jobs", n_big);
        o.set("policy", policy.name());
        o.set("trace_digest", format!("{:016x}", big.digest()));
        o.set("speedup", speedup);
        o.set("engines", Json::Arr(raws));
        o
    });

    let mut root = JsonObj::new();
    root.set("bench", "fleet_scale");
    root.set("smoke", smoke);
    root.set("scales", Json::Arr(json_scales));
    root.set("simcore_rung", simcore_rung);
    let out =
        std::env::var("CXLFINE_BENCH_FLEET_OUT").unwrap_or_else(|_| "BENCH_fleet.json".into());
    let payload = Json::Obj(root).to_string_pretty();
    match std::fs::write(&out, &payload) {
        Ok(()) => println!("\n[fleet_scale] wrote {out}"),
        Err(e) => eprintln!("warn: could not write {out}: {e}"),
    }
    report.finish();
}
