//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them on
//! the CPU PJRT client. This is the only place Python-authored compute
//! enters the Rust process — as compiled executables, never as Python.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! re-assigns ids (see /opt/xla-example/README.md and DESIGN.md §3).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::artifact::{Entry, Manifest};

/// A host tensor: f32 data + shape (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl HostTensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data/shape mismatch"
        );
        Self { data, shape }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self {
            data: vec![0.0; shape.iter().product()],
            shape: shape.to_vec(),
        }
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            data: vec![v],
            shape: vec![],
        }
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }
}

/// Integer tensor (token ids / labels) — lowered as i32.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensorI32 {
    pub data: Vec<i32>,
    pub shape: Vec<usize>,
}

impl HostTensorI32 {
    pub fn new(data: Vec<i32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Self { data, shape }
    }
}

/// An argument to an entry point.
#[derive(Clone, Debug)]
pub enum Arg {
    F32(HostTensor),
    I32(HostTensorI32),
}

impl Arg {
    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Arg::F32(t) => {
                let lit = xla::Literal::vec1(&t.data);
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                Ok(lit.reshape(&dims)?)
            }
            Arg::I32(t) => {
                let lit = xla::Literal::vec1(&t.data);
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                Ok(lit.reshape(&dims)?)
            }
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Arg::F32(t) => &t.shape,
            Arg::I32(t) => &t.shape,
        }
    }
}

impl From<HostTensor> for Arg {
    fn from(t: HostTensor) -> Self {
        Arg::F32(t)
    }
}
impl From<HostTensorI32> for Arg {
    fn from(t: HostTensorI32) -> Self {
        Arg::I32(t)
    }
}

/// The runtime: one PJRT CPU client + all compiled entry points.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load every entry in an artifact directory and compile it.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = HashMap::new();
        for (name, entry) in &manifest.entries {
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .with_context(|| format!("parsing HLO text {}", entry.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling entry {name}"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Runtime {
            client,
            manifest,
            exes,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn entry_checked(&self, name: &str, args: &[Arg]) -> Result<&Entry> {
        let entry = self.manifest.entry(name)?;
        if args.len() != entry.inputs.len() {
            bail!(
                "entry {name}: expected {} inputs, got {}",
                entry.inputs.len(),
                args.len()
            );
        }
        for (i, (a, spec)) in args.iter().zip(&entry.inputs).enumerate() {
            if a.shape() != spec.shape.as_slice() {
                bail!(
                    "entry {name} input {i} ({}): shape {:?} != expected {:?}",
                    spec.name,
                    a.shape(),
                    spec.shape
                );
            }
        }
        Ok(entry)
    }

    /// Execute an entry point; returns its outputs as f32 host tensors
    /// (all our model outputs are f32; losses are scalars).
    pub fn exec(&self, name: &str, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let entry = self.entry_checked(name, args)?;
        let exe = self.exes.get(name).expect("compiled with manifest");
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(Arg::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {name}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        // aot.py lowers with return_tuple=True → root is always a tuple.
        let parts = root.to_tuple()?;
        if parts.len() != entry.outputs.len() {
            bail!(
                "entry {name}: manifest promises {} outputs, executable returned {}",
                entry.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&entry.outputs) {
            let data: Vec<f32> = lit
                .to_vec()
                .with_context(|| format!("reading output {} of {name}", spec.name))?;
            if data.len() != spec.element_count() {
                bail!(
                    "entry {name} output {}: got {} elements, expected {}",
                    spec.name,
                    data.len(),
                    spec.element_count()
                );
            }
            out.push(HostTensor::new(data, spec.shape.clone()));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_validates_shape() {
        let t = HostTensor::new(vec![1.0; 6], vec![2, 3]);
        assert_eq!(t.element_count(), 6);
        let z = HostTensor::zeros(&[4, 4]);
        assert_eq!(z.data.len(), 16);
    }

    #[test]
    #[should_panic(expected = "data/shape mismatch")]
    fn host_tensor_rejects_bad_shape() {
        HostTensor::new(vec![1.0; 5], vec![2, 3]);
    }

    #[test]
    fn scalar_tensor() {
        let s = HostTensor::scalar(2.5);
        assert!(s.shape.is_empty());
        assert_eq!(s.element_count(), 1);
    }

    // Execution against real artifacts is covered by rust/tests/ (needs
    // `make artifacts` to have run).
}
