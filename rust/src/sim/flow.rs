//! Flow-level discrete-event simulator with max-min fair bandwidth sharing.
//!
//! Transfers are modeled as *fluid flows* over a set of resources (PCIe
//! links, DRAM controllers). At every event boundary the simulator solves
//! the max-min fair allocation ("progressive filling"): repeatedly find the
//! bottleneck resource, fix the fair share of all its unassigned flows, and
//! subtract. Resource capacity may depend on the number of concurrent flows
//! (the CXL-AIC contention collapse of Fig. 6b).
//!
//! The workflow engine drives the simulator interactively: it starts flows
//! and timers, then consumes completion events one at a time, starting
//! dependent work as each finishes — exactly how the real coordinator
//! overlaps transfers with compute.
//!
//! # Hot-path architecture (DESIGN.md §7, §14)
//!
//! Every sweep cell and ablation bottoms out in this event loop, so it is
//! built for events/sec while holding a hard determinism contract. Since
//! the `simcore` unification this engine is a thin adapter over
//! [`crate::simcore`] — the same primitives that run the fleet simulator:
//!
//! * **Slab flows** — flows live in a [`crate::simcore::Slab`] with
//!   free-list recycling; `active` is a small id-sorted index vector, so
//!   every per-event pass (rate assignment, drain, max-min) is a
//!   cache-linear walk with no hashing and no per-event id collect+sort.
//! * **simcore event queues** — pending activations and timers are
//!   [`crate::simcore::EventQueue`]s keyed [`crate::simcore::EventKey`]
//!   `(time_bits, kind, id)`; the tie-break that used to be an O(n)
//!   `min_by` scan is encoded in the key, and timer-heavy mixes upgrade
//!   to the calendar-wheel backend automatically. Equal-time activation
//!   bursts are drained as one cohort — a single max-min recompute per
//!   timestamp instead of one per activation.
//! * **Earliest-completion index** — the next completion candidate is
//!   maintained incrementally: refreshed inside the rate-assignment loop
//!   after each max-min solve and inside the drain loop when time advances,
//!   so `next_event` never runs a separate scan over all active flows. The
//!   determinism contract bounds how much more can be cached: completion
//!   timestamps are defined as `now + remaining/rate` over the *stepwise
//!   drained* remaining bytes, so any event that moves time must touch
//!   every active flow anyway — the index rides along in that same pass.
//! * **Allocation-free max-min** — all progressive-filling state (remaining
//!   caps, per-resource flow counts, partition lists, per-slot rates) lives
//!   in [`MaxminScratch`] buffers owned by the sim and reused across calls;
//!   paths are stored inline ([`PathVec`], spilling to the heap only past 4
//!   hops) so the drain loop clones nothing.
//!
//! The pre-refactor HashMap engine is frozen in [`super::reference`]; the
//! two are locked together bit-for-bit (ids, tags, `to_bits` timestamps) by
//! `rust/tests/golden_trace.rs` and `rust/tests/simcore_parity.rs`, and
//! `benches/sim_hotpath.rs` measures the speedup (≥3× required at ≥1e5
//! flows).

use std::collections::HashMap;

use crate::simcore::{EventKey, EventQueue, Slab};

/// Seconds since simulation start.
pub type SimTime = f64;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ResourceId(pub usize);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FlowId(pub u64);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TimerId(pub u64);

/// How a resource's usable capacity responds to load.
#[derive(Clone, Debug)]
pub enum CapacityModel {
    /// Fixed capacity regardless of load (DRAM controllers, GPU links).
    Fixed(f64),
    /// A CXL AIC link (Fig. 6b): delivers `single` as long as the *offered
    /// load* (what its flows would pull if this link were infinite) stays
    /// within `single`; once oversubscribed by ≥2 independent DMA streams,
    /// competing request queues defeat the device-side scheduling and the
    /// aggregate collapses to `contended`. This load-dependence is exactly
    /// why multi-AIC striping works (§IV-B): striped transfers offer each
    /// card ≤ its capacity, so no card ever enters the collapsed regime.
    Contended { single: f64, contended: f64 },
}

impl CapacityModel {
    /// Capacity in the uncollapsed regime.
    pub fn base_capacity(&self) -> f64 {
        match *self {
            CapacityModel::Fixed(c) => c,
            CapacityModel::Contended { single, .. } => single,
        }
    }

    /// Capacity given the collapse decision for this resource.
    pub fn capacity(&self, collapsed: bool) -> f64 {
        match *self {
            CapacityModel::Fixed(c) => c,
            CapacityModel::Contended { single, contended } => {
                if collapsed {
                    contended
                } else {
                    single
                }
            }
        }
    }

    pub fn is_contended_model(&self) -> bool {
        matches!(self, CapacityModel::Contended { .. })
    }
}

/// Oversubscription slack before a contended resource collapses.
const COLLAPSE_THRESHOLD: f64 = 1.02;

/// Inline path capacity; real paths here are 1–2 hops (host side + GPU
/// side), so 4 keeps every practical flow heap-free.
const PATH_INLINE: usize = 4;

/// A flow's resource path: inline small-vec, heap spill only past
/// [`PATH_INLINE`] hops. Replaces the `Vec<ResourceId>` whose per-drain
/// clone was a measurable share of the old engine's event cost.
#[derive(Clone, Debug)]
enum PathVec {
    Inline { len: u8, ids: [ResourceId; PATH_INLINE] },
    Heap(Box<[ResourceId]>),
}

impl PathVec {
    fn new(path: &[ResourceId]) -> Self {
        if path.len() <= PATH_INLINE {
            let mut ids = [ResourceId(0); PATH_INLINE];
            ids[..path.len()].copy_from_slice(path);
            PathVec::Inline {
                len: path.len() as u8,
                ids,
            }
        } else {
            PathVec::Heap(path.to_vec().into_boxed_slice())
        }
    }

    #[inline]
    fn as_slice(&self) -> &[ResourceId] {
        match self {
            PathVec::Inline { len, ids } => &ids[..*len as usize],
            PathVec::Heap(b) => b,
        }
    }
}

/// Event-key kind ranks for the two queues (the queues are separate, so
/// the rank never arbitrates between them — it simply keeps the keys
/// honest instances of the shared `time_bits · kind · seq` encoding).
const KIND_ACTIVATE: u8 = 0;
const KIND_TIMER: u8 = 1;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SlotState {
    Free,
    /// Setup latency not yet elapsed; queued in the `pending` heap.
    Pending,
    /// Transferring; indexed by the id-sorted `active` list.
    Active,
}

#[derive(Clone, Debug)]
struct FlowSlot {
    /// Stable public id (monotonic, shared counter with timers) — slab slot
    /// indices are reused, ids never are.
    id: u64,
    state: SlotState,
    path: PathVec,
    bytes: f64,
    remaining: f64,
    rate: f64, // bytes/s, recomputed at each event boundary
    start: SimTime,
    issued: SimTime,
    tag: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A flow transferred its last byte.
    FlowDone { id: FlowId, tag: u64 },
    /// A timer elapsed.
    TimerFired { id: TimerId, tag: u64 },
}

impl Event {
    pub fn tag(&self) -> u64 {
        match self {
            Event::FlowDone { tag, .. } | Event::TimerFired { tag, .. } => *tag,
        }
    }
}

/// Statistics for a completed flow.
#[derive(Clone, Copy, Debug)]
pub struct FlowStats {
    pub issued: SimTime,
    pub started: SimTime,
    pub finished: SimTime,
    pub bytes: f64,
}

impl FlowStats {
    /// Mean throughput over the flow's active (post-setup) phase.
    pub fn throughput(&self) -> f64 {
        if self.finished > self.started {
            self.bytes / (self.finished - self.started)
        } else {
            f64::INFINITY
        }
    }
    /// End-to-end (issue → finish) throughput, including setup latency —
    /// what a `cudaMemcpyAsync` benchmark actually observes (Fig. 6).
    pub fn e2e_throughput(&self) -> f64 {
        if self.finished > self.issued {
            self.bytes / (self.finished - self.issued)
        } else {
            f64::INFINITY
        }
    }
}

#[derive(Clone, Debug)]
struct Resource {
    name: String,
    model: CapacityModel,
}

/// Reusable progressive-filling scratch (DESIGN.md §7): owned by the sim so
/// steady-state rate recomputation performs zero heap allocation.
#[derive(Default)]
struct MaxminScratch {
    base_caps: Vec<f64>,
    caps: Vec<f64>,
    rem_cap: Vec<f64>,
    n_unassigned: Vec<usize>,
    count: Vec<usize>,
    collapsed: Vec<bool>,
    unassigned: Vec<u32>,
    keep: Vec<u32>,
    /// Rate per slab slot (only entries for active slots are meaningful).
    rates: Vec<f64>,
}

/// Completion time of one flow at instant `now` — the exact expression the
/// pre-refactor scan used; the determinism contract is defined over it.
#[inline]
fn completion_time(now: SimTime, remaining: f64, rate: f64) -> f64 {
    if remaining <= 0.0 {
        now
    } else if rate > 0.0 {
        now + remaining / rate
    } else {
        f64::INFINITY
    }
}

/// Progressive filling over the slab, op-for-op equivalent to
/// `RefFlowSim::maxmin` (flows visited in ascending-id order, identical
/// arithmetic sequence on `rem_cap`), but writing into reusable buffers.
#[allow(clippy::too_many_arguments)]
fn maxmin_fill(
    slots: &[FlowSlot],
    active: &[u32],
    caps: &[f64],
    rem_cap: &mut Vec<f64>,
    n_unassigned: &mut Vec<usize>,
    unassigned: &mut Vec<u32>,
    keep: &mut Vec<u32>,
    rates: &mut [f64],
) {
    for &si in active {
        rates[si as usize] = 0.0;
    }
    if active.is_empty() {
        return;
    }
    rem_cap.clear();
    rem_cap.extend_from_slice(caps);
    unassigned.clear();
    unassigned.extend_from_slice(active);
    n_unassigned.clear();
    n_unassigned.resize(caps.len(), 0);
    while !unassigned.is_empty() {
        for c in n_unassigned.iter_mut() {
            *c = 0;
        }
        for &si in unassigned.iter() {
            for r in slots[si as usize].path.as_slice() {
                n_unassigned[r.0] += 1;
            }
        }
        // bottleneck resource = min fair share among resources w/ flows
        let mut best: Option<(usize, f64)> = None;
        for (ri, &n) in n_unassigned.iter().enumerate() {
            if n > 0 {
                let share = (rem_cap[ri] / n as f64).max(0.0);
                if best.map_or(true, |(_, s)| share < s) {
                    best = Some((ri, share));
                }
            }
        }
        let Some((bottleneck, share)) = best else { break };
        // fix the rate of all unassigned flows through the bottleneck;
        // non-bottleneck flows are kept for the next round in id order
        keep.clear();
        for &si in unassigned.iter() {
            let s = &slots[si as usize];
            if s.path.as_slice().iter().any(|r| r.0 == bottleneck) {
                rates[si as usize] = share;
                for r in s.path.as_slice() {
                    rem_cap[r.0] = (rem_cap[r.0] - share).max(0.0);
                }
            } else {
                keep.push(si);
            }
        }
        std::mem::swap(unassigned, keep);
    }
}

/// The simulator.
pub struct FlowSim {
    now: SimTime,
    resources: Vec<Resource>,
    /// Slab: flows in all states; released slots recycle via the free list.
    slots: Slab<FlowSlot>,
    /// Active slot indices, sorted by ascending flow id (the deterministic
    /// iteration order every per-event pass uses).
    active: Vec<u32>,
    /// Flows whose setup latency has not elapsed: keyed
    /// (activate_at, KIND_ACTIVATE, id); payload is the slot index.
    pending: EventQueue<u32>,
    /// Timers: keyed (fire_at, KIND_TIMER, id); payload is the caller tag.
    timers: EventQueue<u64>,
    next_id: u64,
    rates_dirty: bool,
    /// Earliest-completion candidate `(time, slot)` — valid whenever rates
    /// are clean; refreshed by the rate-assignment and drain passes.
    cand_t: f64,
    cand_slot: Option<u32>,
    finished: HashMap<u64, FlowStats>,
    /// Total bytes moved through each resource (utilization accounting).
    resource_bytes: Vec<f64>,
    events: u64,
    scratch: MaxminScratch,
}

impl FlowSim {
    pub fn new() -> Self {
        Self {
            now: 0.0,
            resources: Vec::new(),
            slots: Slab::new(),
            active: Vec::new(),
            pending: EventQueue::new(),
            timers: EventQueue::new(),
            next_id: 0,
            rates_dirty: true,
            cand_t: f64::INFINITY,
            cand_slot: None,
            finished: HashMap::new(),
            resource_bytes: Vec::new(),
            events: 0,
            scratch: MaxminScratch::default(),
        }
    }

    /// Return the sim to its freshly-constructed state while retaining
    /// every backing allocation (slab entries, event-queue storage, the
    /// max-min scratch, the finished map's table). The arena-reuse
    /// contract: after `reset`, every observable — event streams, ids,
    /// tags, `to_bits` timestamps — is byte-identical to a brand-new
    /// [`FlowSim::new`] driven by the same call sequence. Resources are
    /// cleared too: drivers (e.g. `sim::fabric::Fabric`) re-add them per
    /// run, so a reused arena replays resource ids from zero exactly like
    /// a fresh engine.
    pub fn reset(&mut self) {
        self.now = 0.0;
        self.resources.clear();
        self.slots.clear();
        self.active.clear();
        self.pending.clear();
        self.timers.clear();
        self.next_id = 0;
        self.rates_dirty = true;
        self.cand_t = f64::INFINITY;
        self.cand_slot = None;
        self.finished.clear();
        self.resource_bytes.clear();
        self.events = 0;
        // `scratch` is pure per-call workspace — every consumer clears or
        // resizes it before reading — so it carries over untouched.
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn add_resource(&mut self, name: &str, model: CapacityModel) -> ResourceId {
        self.resources.push(Resource {
            name: name.to_string(),
            model,
        });
        self.resource_bytes.push(0.0);
        ResourceId(self.resources.len() - 1)
    }

    pub fn resource_name(&self, id: ResourceId) -> &str {
        &self.resources[id.0].name
    }

    /// Total bytes that traversed a resource so far.
    pub fn resource_bytes(&self, id: ResourceId) -> f64 {
        self.resource_bytes[id.0]
    }

    /// Insert `si` into the id-sorted active list.
    fn activate_slot(&mut self, si: u32, id: u64) {
        self.slots[si as usize].state = SlotState::Active;
        let pos = self
            .active
            .binary_search_by_key(&id, |&a| self.slots[a as usize].id)
            .unwrap_err();
        self.active.insert(pos, si);
        self.rates_dirty = true;
    }

    /// Start a flow of `bytes` over `path`, activating after `setup`
    /// seconds of latency (DMA setup + device latency). `tag` is an opaque
    /// caller token carried back in the completion event.
    pub fn start_flow(&mut self, path: &[ResourceId], bytes: f64, setup: f64, tag: u64) -> FlowId {
        assert!(
            !path.is_empty(),
            "flows need ≥1 resource; use timers for pure delays"
        );
        assert!(bytes >= 0.0 && setup >= 0.0);
        for r in path {
            assert!(r.0 < self.resources.len(), "dangling resource id");
        }
        let id = self.next_id;
        self.next_id += 1;
        let start = self.now + setup;
        let slot = FlowSlot {
            id,
            state: SlotState::Pending,
            path: PathVec::new(path),
            bytes,
            remaining: bytes,
            rate: 0.0,
            start,
            issued: self.now,
            tag,
        };
        let si = self.slots.insert(slot);
        if setup > 0.0 {
            self.pending.push(EventKey::new(start, KIND_ACTIVATE, id), si);
        } else {
            self.activate_slot(si, id);
        }
        FlowId(id)
    }

    /// Schedule a timer `delay` seconds from now.
    pub fn add_timer(&mut self, delay: f64, tag: u64) -> TimerId {
        assert!(delay >= 0.0);
        let id = self.next_id;
        self.next_id += 1;
        self.timers.push(EventKey::new(self.now + delay, KIND_TIMER, id), tag);
        TimerId(id)
    }

    /// Stats of a completed flow, without consuming them (see
    /// [`FlowSim::take_stats`] for the leak-free variant).
    pub fn stats(&self, id: FlowId) -> Option<FlowStats> {
        self.finished.get(&id.0).copied()
    }

    /// Remove and return a completed flow's stats. Long-running drivers
    /// (`offload::iteration`, multi-epoch `train::loop_`) must consume
    /// stats through this (or [`FlowSim::drain_finished`]) — the finished
    /// map otherwise accrues one entry per flow forever.
    pub fn take_stats(&mut self, id: FlowId) -> Option<FlowStats> {
        self.finished.remove(&id.0)
    }

    /// Drain all completed-flow stats, ascending by flow id.
    pub fn drain_finished(&mut self) -> Vec<(FlowId, FlowStats)> {
        let mut out: Vec<(FlowId, FlowStats)> = self
            .finished
            .drain()
            .map(|(id, st)| (FlowId(id), st))
            .collect();
        out.sort_unstable_by_key(|(id, _)| id.0);
        out
    }

    /// Number of completed flows whose stats have not been consumed.
    pub fn finished_len(&self) -> usize {
        self.finished.len()
    }

    /// Total events (completions + timer firings) delivered so far.
    pub fn events_processed(&self) -> u64 {
        self.events
    }

    /// All outstanding work: active flows, pending activations, **and**
    /// timers. (The pre-simcore `n_active()` omitted timers while its
    /// `idle()` counted them — a pure-timer workload reported length 0
    /// yet not idle.)
    pub fn len(&self) -> usize {
        self.active.len() + self.pending.len() + self.timers.len()
    }

    /// True iff no work is outstanding — exactly `len() == 0`.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rate assignment with the load-dependent CXL collapse: first decide,
    /// per contended resource, whether its offered load (max-min rates with
    /// that resource uncapped) exceeds its base capacity; then solve the
    /// final max-min with collapsed resources at their degraded capacity.
    ///
    /// Also refreshes the earliest-completion candidate in the same pass
    /// that assigns rates. The offered-load solves are skipped entirely
    /// unless some contended resource carries ≥2 flows (the fast path: a
    /// single full max-min, no extra solves, no allocation).
    fn recompute_rates(&mut self) {
        if !self.rates_dirty {
            return;
        }
        self.rates_dirty = false;
        if self.active.is_empty() {
            self.cand_t = f64::INFINITY;
            self.cand_slot = None;
            return;
        }
        let nr = self.resources.len();
        let sc = &mut self.scratch;
        if sc.rates.len() < self.slots.slot_count() {
            sc.rates.resize(self.slots.slot_count(), 0.0);
        }
        sc.base_caps.clear();
        sc.base_caps
            .extend(self.resources.iter().map(|r| r.model.base_capacity()));
        // count flows per resource (collapse decisions + fast path)
        sc.count.clear();
        sc.count.resize(nr, 0);
        for &si in &self.active {
            for r in self.slots[si as usize].path.as_slice() {
                sc.count[r.0] += 1;
            }
        }
        sc.collapsed.clear();
        sc.collapsed.resize(nr, false);
        let any_hot = self
            .resources
            .iter()
            .enumerate()
            .any(|(ri, r)| r.model.is_contended_model() && sc.count[ri] >= 2);
        if any_hot {
            for ri in 0..nr {
                if !self.resources[ri].model.is_contended_model() || sc.count[ri] < 2 {
                    continue;
                }
                // offered load = what the flows would pull if this link
                // were free
                sc.caps.clear();
                sc.caps.extend_from_slice(&sc.base_caps);
                sc.caps[ri] = f64::INFINITY;
                maxmin_fill(
                    self.slots.entries(),
                    &self.active,
                    &sc.caps,
                    &mut sc.rem_cap,
                    &mut sc.n_unassigned,
                    &mut sc.unassigned,
                    &mut sc.keep,
                    &mut sc.rates,
                );
                let mut offered = 0.0;
                for &si in &self.active {
                    let s = &self.slots[si as usize];
                    if s.path.as_slice().iter().any(|r| r.0 == ri) {
                        offered += sc.rates[si as usize];
                    }
                }
                if offered > sc.base_caps[ri] * COLLAPSE_THRESHOLD {
                    sc.collapsed[ri] = true;
                }
            }
        }
        sc.caps.clear();
        sc.caps.extend(
            self.resources
                .iter()
                .enumerate()
                .map(|(i, r)| r.model.capacity(sc.collapsed[i])),
        );
        maxmin_fill(
            self.slots.entries(),
            &self.active,
            &sc.caps,
            &mut sc.rem_cap,
            &mut sc.n_unassigned,
            &mut sc.unassigned,
            &mut sc.keep,
            &mut sc.rates,
        );
        // assign rates + refresh the earliest-completion candidate in one
        // id-ordered pass (ties → smallest id, first-minimum wins)
        let now = self.now;
        let mut best_t = f64::INFINITY;
        let mut best_id = u64::MAX;
        let mut best_slot: Option<u32> = None;
        for &si in &self.active {
            let s = &mut self.slots[si as usize];
            s.rate = sc.rates[si as usize];
            let t = completion_time(now, s.remaining, s.rate);
            if t < best_t || (t == best_t && s.id < best_id) {
                best_t = t;
                best_id = s.id;
                best_slot = Some(si);
            }
        }
        self.cand_t = best_t;
        self.cand_slot = best_slot;
    }

    /// Advance to and return the next event; `None` when idle.
    pub fn next_event(&mut self) -> Option<Event> {
        loop {
            self.recompute_rates();
            // Freeze this iteration's completion candidate before the drain
            // pass below refreshes the index for the *next* instant — the
            // event returned now must be the pre-drain winner (the drained
            // winner's remaining can be an ulp above zero, which would
            // otherwise re-rank it).
            let t_complete = self.cand_t;
            let who = self.cand_slot;
            let t_activate = self.pending.peek_key().map_or(f64::INFINITY, |k| k.time());
            let t_timer = self.timers.peek_key().map_or(f64::INFINITY, |k| k.time());

            let t_next = t_complete.min(t_activate).min(t_timer);
            if !t_next.is_finite() {
                assert!(
                    self.active.is_empty(),
                    "deadlock: active flows with zero rate and nothing pending"
                );
                return None;
            }

            // Drain transferred bytes up to t_next, refreshing the
            // earliest-completion candidate at the new instant in the same
            // pass. A zero-width step is a bitwise no-op (moved = 0), so it
            // is skipped outright — same-instant event bursts (striped
            // arrivals, simultaneous timers) cost no flow pass at all.
            let dt = (t_next - self.now).max(0.0);
            if dt > 0.0 {
                let slots = &mut self.slots;
                let resource_bytes = &mut self.resource_bytes;
                let mut best_t = f64::INFINITY;
                let mut best_id = u64::MAX;
                let mut best_slot: Option<u32> = None;
                for &si in &self.active {
                    let s = &mut slots[si as usize];
                    let moved = s.rate * dt;
                    s.remaining = (s.remaining - moved).max(0.0);
                    for r in s.path.as_slice() {
                        resource_bytes[r.0] += moved;
                    }
                    let t = completion_time(t_next, s.remaining, s.rate);
                    if t < best_t || (t == best_t && s.id < best_id) {
                        best_t = t;
                        best_id = s.id;
                        best_slot = Some(si);
                    }
                }
                self.cand_t = best_t;
                self.cand_slot = best_slot;
            }
            self.now = t_next;

            // Activations first (internal — loop again for a visible event).
            // The whole equal-timestamp activation cohort drains at once:
            // same-time activations only ever stack onto the active list
            // (ties favor activation above, so no timer/completion can
            // interleave), and the max-min solve is a pure function of the
            // final active set — one recompute per cohort replaces one per
            // activation, bitwise identically.
            if t_activate <= t_timer && t_activate <= t_complete && t_activate.is_finite() {
                let (key, si) = self.pending.pop().expect("peeked activation must pop");
                debug_assert_eq!(self.slots[si as usize].id, key.seq());
                self.activate_slot(si, key.seq());
                while let Some(k) = self.pending.peek_key() {
                    if k.time_bits() != key.time_bits() {
                        break;
                    }
                    let (k, nsi) = self.pending.pop().expect("peeked activation must pop");
                    debug_assert_eq!(self.slots[nsi as usize].id, k.seq());
                    self.activate_slot(nsi, k.seq());
                }
                continue;
            }

            // Timers before completions at equal timestamps (a timer set for
            // the same instant a transfer ends should observe the pre-completion
            // state; deterministic either way, this order is just fixed).
            if t_timer <= t_complete && t_timer.is_finite() {
                let (key, tag) = self.timers.pop().expect("peeked timer must pop");
                self.events += 1;
                return Some(Event::TimerFired { id: TimerId(key.seq()), tag });
            }

            // Completion.
            let si = who.expect("completion without candidate flow");
            let (id, tag, stats) = {
                let s = &self.slots[si as usize];
                debug_assert_eq!(s.state, SlotState::Active);
                (
                    s.id,
                    s.tag,
                    FlowStats {
                        issued: s.issued,
                        started: s.start,
                        finished: self.now,
                        bytes: s.bytes,
                    },
                )
            };
            let pos = self
                .active
                .binary_search_by_key(&id, |&a| self.slots[a as usize].id)
                .expect("candidate not in active list");
            self.active.remove(pos);
            self.slots[si as usize].state = SlotState::Free;
            self.slots.release(si);
            self.rates_dirty = true;
            self.finished.insert(id, stats);
            self.events += 1;
            return Some(Event::FlowDone { id: FlowId(id), tag });
        }
    }

    /// Run until idle, returning all events in order.
    pub fn run_to_idle(&mut self) -> Vec<Event> {
        let mut out = Vec::new();
        while let Some(e) = self.next_event() {
            out.push(e);
        }
        out
    }
}

impl Default for FlowSim {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GB: f64 = 1e9;

    #[test]
    fn single_flow_exact_time() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(10.0 * GB));
        let f = sim.start_flow(&[link], 5.0 * GB, 0.0, 1);
        let events = sim.run_to_idle();
        assert_eq!(events, vec![Event::FlowDone { id: f, tag: 1 }]);
        assert!((sim.now() - 0.5).abs() < 1e-12);
        let st = sim.stats(f).unwrap();
        assert!((st.throughput() - 10.0 * GB).abs() / GB < 1e-9);
    }

    #[test]
    fn setup_latency_delays_completion() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(10.0 * GB));
        let f = sim.start_flow(&[link], 1.0 * GB, 0.25, 0);
        sim.run_to_idle();
        let st = sim.stats(f).unwrap();
        assert!((st.finished - 0.35).abs() < 1e-12);
        // e2e throughput is lower than active throughput
        assert!(st.e2e_throughput() < st.throughput());
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(10.0 * GB));
        let a = sim.start_flow(&[link], 5.0 * GB, 0.0, 1);
        let b = sim.start_flow(&[link], 5.0 * GB, 0.0, 2);
        sim.run_to_idle();
        // both at 5 GB/s → both finish at t=1.0
        assert!((sim.stats(a).unwrap().finished - 1.0).abs() < 1e-9);
        assert!((sim.stats(b).unwrap().finished - 1.0).abs() < 1e-9);
    }

    #[test]
    fn short_flow_releases_bandwidth() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(10.0 * GB));
        let small = sim.start_flow(&[link], 1.0 * GB, 0.0, 1);
        let big = sim.start_flow(&[link], 9.0 * GB, 0.0, 2);
        sim.run_to_idle();
        // phase 1: both at 5 GB/s until small done at t=0.2 (1GB/5GB/s)
        assert!((sim.stats(small).unwrap().finished - 0.2).abs() < 1e-9);
        // big: 1 GB done in phase 1, then 8 GB at 10 GB/s → t = 0.2 + 0.8
        assert!((sim.stats(big).unwrap().finished - 1.0).abs() < 1e-9);
    }

    #[test]
    fn multi_resource_path_takes_min() {
        let mut sim = FlowSim::new();
        let fast = sim.add_resource("fast", CapacityModel::Fixed(100.0 * GB));
        let slow = sim.add_resource("slow", CapacityModel::Fixed(10.0 * GB));
        let f = sim.start_flow(&[fast, slow], 10.0 * GB, 0.0, 0);
        sim.run_to_idle();
        assert!((sim.stats(f).unwrap().finished - 1.0).abs() < 1e-9);
    }

    #[test]
    fn contended_capacity_collapses_aggregate() {
        // Fig. 6b shape: one flow gets `single`; two flows share `contended`
        // (< single) so the aggregate DROPS when a second GPU joins.
        let mut sim = FlowSim::new();
        let aic = sim.add_resource(
            "aic",
            CapacityModel::Contended {
                single: 54.0 * GB,
                contended: 26.0 * GB,
            },
        );
        let g0 = sim.add_resource("gpu0", CapacityModel::Fixed(54.0 * GB));
        let g1 = sim.add_resource("gpu1", CapacityModel::Fixed(54.0 * GB));
        let a = sim.start_flow(&[aic, g0], 13.0 * GB, 0.0, 0);
        let b = sim.start_flow(&[aic, g1], 13.0 * GB, 0.0, 1);
        sim.run_to_idle();
        // each gets 13 GB/s → 26 GB total at 26 GB/s aggregate → 1.0 s
        assert!((sim.stats(a).unwrap().finished - 1.0).abs() < 1e-9);
        assert!((sim.stats(b).unwrap().finished - 1.0).abs() < 1e-9);
        // solo flow for comparison
        let mut sim2 = FlowSim::new();
        let aic2 = sim2.add_resource(
            "aic",
            CapacityModel::Contended {
                single: 54.0 * GB,
                contended: 26.0 * GB,
            },
        );
        let g = sim2.add_resource("gpu", CapacityModel::Fixed(54.0 * GB));
        let solo = sim2.start_flow(&[aic2, g], 13.0 * GB, 0.0, 0);
        sim2.run_to_idle();
        let solo_tp = sim2.stats(solo).unwrap().throughput();
        assert!(solo_tp > 26.0 * GB, "single stream should beat contended aggregate");
    }

    #[test]
    fn max_min_fairness_three_flows_two_links() {
        // Classic max-min example: flows A(link1), B(link1+link2), C(link2);
        // cap(link1)=10, cap(link2)=4. B is bottlenecked on link2 → B=C=2;
        // A gets the rest of link1 → 8.
        let mut sim = FlowSim::new();
        let l1 = sim.add_resource("l1", CapacityModel::Fixed(10.0));
        let l2 = sim.add_resource("l2", CapacityModel::Fixed(4.0));
        let a = sim.start_flow(&[l1], 8.0, 0.0, 0);
        let b = sim.start_flow(&[l1, l2], 2.0, 0.0, 1);
        let c = sim.start_flow(&[l2], 2.0, 0.0, 2);
        sim.run_to_idle();
        // with rates A=8,B=2,C=2 all complete exactly at t=1
        for f in [a, b, c] {
            assert!(
                (sim.stats(f).unwrap().finished - 1.0).abs() < 1e-9,
                "flow {f:?} finished at {}",
                sim.stats(f).unwrap().finished
            );
        }
    }

    #[test]
    fn timers_interleave_with_flows() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(1.0 * GB));
        sim.start_flow(&[link], 1.0 * GB, 0.0, 10);
        sim.add_timer(0.5, 20);
        sim.add_timer(2.0, 30);
        let events = sim.run_to_idle();
        let tags: Vec<u64> = events.iter().map(|e| e.tag()).collect();
        assert_eq!(tags, vec![20, 10, 30]);
        assert!((sim.now() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn zero_byte_flow_completes_after_setup() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(1.0));
        let f = sim.start_flow(&[link], 0.0, 0.125, 0);
        sim.run_to_idle();
        assert!((sim.stats(f).unwrap().finished - 0.125).abs() < 1e-12);
    }

    #[test]
    fn resource_byte_accounting_conserves() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(7.0 * GB));
        sim.start_flow(&[link], 3.0 * GB, 0.0, 0);
        sim.start_flow(&[link], 4.0 * GB, 0.1, 1);
        sim.run_to_idle();
        assert!((sim.resource_bytes(link) - 7.0 * GB).abs() / GB < 1e-6);
    }

    #[test]
    fn deterministic_event_order() {
        let run = || {
            let mut sim = FlowSim::new();
            let l = sim.add_resource("l", CapacityModel::Fixed(1.0));
            for i in 0..10 {
                sim.start_flow(&[l], 1.0, 0.0, i);
            }
            sim.run_to_idle().iter().map(|e| e.tag()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn interactive_dependent_flows() {
        // Start flow B only after flow A completes (the engine's pattern).
        let mut sim = FlowSim::new();
        let l = sim.add_resource("l", CapacityModel::Fixed(2.0));
        sim.start_flow(&[l], 2.0, 0.0, 1);
        let e = sim.next_event().unwrap();
        assert_eq!(e.tag(), 1);
        assert!((sim.now() - 1.0).abs() < 1e-12);
        sim.start_flow(&[l], 4.0, 0.0, 2);
        let e2 = sim.next_event().unwrap();
        assert_eq!(e2.tag(), 2);
        assert!((sim.now() - 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "flows need")]
    fn empty_path_rejected() {
        let mut sim = FlowSim::new();
        sim.start_flow(&[], 1.0, 0.0, 0);
    }

    // ---- slab/heap-specific behavior --------------------------------

    #[test]
    fn slots_are_recycled_but_ids_are_stable() {
        let mut sim = FlowSim::new();
        let l = sim.add_resource("l", CapacityModel::Fixed(1.0));
        let a = sim.start_flow(&[l], 1.0, 0.0, 0);
        assert_eq!(sim.run_to_idle().len(), 1);
        // slab has exactly one slot now; the next flow must reuse it while
        // getting a fresh id
        let b = sim.start_flow(&[l], 1.0, 0.0, 1);
        assert_ne!(a, b, "ids must never be reused");
        assert_eq!(sim.slots.slot_count(), 1, "slot must be recycled");
        sim.run_to_idle();
        // both flows' stats are independently retrievable
        assert!(sim.stats(a).is_some() && sim.stats(b).is_some());
    }

    #[test]
    fn take_stats_consumes_exactly_once() {
        let mut sim = FlowSim::new();
        let l = sim.add_resource("l", CapacityModel::Fixed(2.0));
        let f = sim.start_flow(&[l], 2.0, 0.0, 7);
        sim.run_to_idle();
        assert_eq!(sim.finished_len(), 1);
        let st = sim.take_stats(f).expect("stats present");
        assert!((st.finished - 1.0).abs() < 1e-12);
        assert!(sim.take_stats(f).is_none(), "second take must be empty");
        assert_eq!(sim.finished_len(), 0);
        assert!(sim.stats(f).is_none(), "stats() sees the drained map");
    }

    #[test]
    fn drain_finished_is_id_sorted_and_empties() {
        let mut sim = FlowSim::new();
        let l = sim.add_resource("l", CapacityModel::Fixed(10.0));
        let ids: Vec<FlowId> = (0..5).map(|i| sim.start_flow(&[l], 1.0 + i as f64, 0.0, i)).collect();
        sim.run_to_idle();
        let drained = sim.drain_finished();
        assert_eq!(drained.len(), 5);
        let order: Vec<u64> = drained.iter().map(|(id, _)| id.0).collect();
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(order, sorted, "drain must be ascending by id");
        assert_eq!(sim.finished_len(), 0);
        for id in ids {
            assert!(sim.stats(id).is_none());
        }
    }

    #[test]
    fn len_counts_pure_timer_workloads_and_matches_is_empty() {
        // Regression: the pre-simcore `n_active()` omitted timers while
        // `idle()` counted them, so a pure-timer sim claimed "0 items
        // outstanding" yet "not idle". `len`/`is_empty` must agree.
        let mut sim = FlowSim::new();
        assert!(sim.is_empty());
        assert_eq!(sim.len(), 0);
        sim.add_timer(0.25, 1);
        sim.add_timer(0.5, 2);
        assert_eq!(sim.len(), 2, "timers are outstanding work");
        assert!(!sim.is_empty());
        let e = sim.next_event().unwrap();
        assert_eq!(e.tag(), 1);
        assert_eq!(sim.len(), 1);
        assert!(!sim.is_empty());
        sim.run_to_idle();
        assert_eq!(sim.len(), 0);
        assert!(sim.is_empty());
        // A mixed workload counts all three populations.
        let l = sim.add_resource("l", CapacityModel::Fixed(1.0));
        sim.start_flow(&[l], 1.0, 0.0, 10); // active
        sim.start_flow(&[l], 1.0, 0.5, 11); // pending activation
        sim.add_timer(2.0, 12); // timer
        assert_eq!(sim.len(), 3);
        sim.run_to_idle();
        assert!(sim.is_empty());
    }

    #[test]
    fn events_processed_counts_flows_and_timers() {
        let mut sim = FlowSim::new();
        let l = sim.add_resource("l", CapacityModel::Fixed(1.0));
        sim.start_flow(&[l], 1.0, 0.0, 0);
        sim.start_flow(&[l], 2.0, 0.25, 1);
        sim.add_timer(0.125, 2);
        let n = sim.run_to_idle().len();
        assert_eq!(n, 3);
        assert_eq!(sim.events_processed(), 3);
    }

    #[test]
    fn simultaneous_timers_fire_in_id_order() {
        // Same-instant bursts take the no-drain fast path; ordering must
        // still be (time, id) exactly.
        let mut sim = FlowSim::new();
        let l = sim.add_resource("l", CapacityModel::Fixed(1.0));
        sim.start_flow(&[l], 1.0, 0.0, 99);
        let t0 = sim.add_timer(0.5, 10);
        let t1 = sim.add_timer(0.5, 11);
        let t2 = sim.add_timer(0.5, 12);
        let events = sim.run_to_idle();
        let tags: Vec<u64> = events.iter().map(|e| e.tag()).collect();
        assert_eq!(tags, vec![10, 11, 12, 99]);
        assert!(t0.0 < t1.0 && t1.0 < t2.0);
    }

    #[test]
    fn long_path_spills_to_heap() {
        let mut sim = FlowSim::new();
        let rs: Vec<ResourceId> = (0..6)
            .map(|i| sim.add_resource(&format!("r{i}"), CapacityModel::Fixed(6.0)))
            .collect();
        let f = sim.start_flow(&rs, 6.0, 0.0, 0);
        sim.run_to_idle();
        assert!((sim.stats(f).unwrap().finished - 1.0).abs() < 1e-9);
        for r in rs {
            assert!((sim.resource_bytes(r) - 6.0).abs() < 1e-9);
        }
    }

    #[test]
    fn pending_activation_order_is_time_then_id() {
        let mut sim = FlowSim::new();
        let l = sim.add_resource("l", CapacityModel::Fixed(1.0));
        // equal setup latencies → activation (internal) order by id; both
        // then share the link and the smaller transfer finishes first
        sim.start_flow(&[l], 0.3, 0.5, 1);
        sim.start_flow(&[l], 0.1, 0.5, 2);
        let tags: Vec<u64> = sim.run_to_idle().iter().map(|e| e.tag()).collect();
        assert_eq!(tags, vec![2, 1]);
    }

    // ---- arena reuse (`FlowSim::reset`) ------------------------------

    /// Fig. 6-shaped drive: contended AIC + DRAM + two GPUs, mixed setup
    /// latencies, one timer. Returns every event with its `to_bits`
    /// timestamp — the full observable stream.
    fn drive_fig6_shape(sim: &mut FlowSim) -> Vec<(Event, u64)> {
        let d = sim.add_resource("dram", CapacityModel::Fixed(204.0 * GB));
        let x = sim.add_resource(
            "aic",
            CapacityModel::Contended { single: 54.0 * GB, contended: 26.0 * GB },
        );
        let g0 = sim.add_resource("g0", CapacityModel::Fixed(54.0 * GB));
        let g1 = sim.add_resource("g1", CapacityModel::Fixed(54.0 * GB));
        sim.start_flow(&[x, g0], 3.0 * GB, 10e-6, 1);
        sim.start_flow(&[x, g1], 2.0 * GB, 10e-6, 2);
        sim.start_flow(&[d, g0], 5.0 * GB, 0.0, 3);
        sim.add_timer(0.01, 4);
        let mut ev = Vec::new();
        while let Some(e) = sim.next_event() {
            ev.push((e, sim.now().to_bits()));
        }
        ev
    }

    /// Workflow-shaped drive: the executor's interactive pattern — consume
    /// one event at a time and issue dependent flows/timers as each
    /// completes, consuming stats through `take_stats` like the executor.
    fn drive_workflow_shape(sim: &mut FlowSim) -> Vec<(Event, u64, u64)> {
        let d = sim.add_resource("dram", CapacityModel::Fixed(204.0 * GB));
        let g = sim.add_resource("g0-rx", CapacityModel::Fixed(54.0 * GB));
        let gtx = sim.add_resource("g0-tx", CapacityModel::Fixed(54.0 * GB));
        sim.start_flow(&[d, g], 1.5 * GB, 10e-6, 100);
        sim.add_timer(0.005, 101);
        let mut ev = Vec::new();
        let mut spawned = 0u64;
        while let Some(e) = sim.next_event() {
            let mut consumed = 0u64;
            if let Event::FlowDone { id, tag } = &e {
                consumed = sim.take_stats(*id).expect("stats once").finished.to_bits();
                // Dependency chain: each completion launches the next
                // stage until three stages have run.
                if spawned < 3 {
                    spawned += 1;
                    sim.start_flow(&[gtx, d], 0.5 * GB * spawned as f64, 10e-6, tag + 1);
                    sim.add_timer(0.001 * spawned as f64, 200 + spawned);
                }
            }
            ev.push((e, sim.now().to_bits(), consumed));
        }
        assert_eq!(sim.finished_len(), 0, "workflow drive consumes every stat");
        ev
    }

    #[test]
    fn reset_replays_fig6_shape_bitwise() {
        let mut fresh = FlowSim::new();
        let golden = drive_fig6_shape(&mut fresh);
        // Dirty a sim with a different workload first, then reset it.
        let mut reused = FlowSim::new();
        let l = reused.add_resource("other", CapacityModel::Fixed(3.0 * GB));
        for i in 0..17 {
            reused.start_flow(&[l], 0.25 * GB * (i + 1) as f64, 0.001, i);
        }
        reused.add_timer(0.5, 99);
        reused.run_to_idle();
        reused.reset();
        assert_eq!(drive_fig6_shape(&mut reused), golden);
        // A second reuse cycle is just as clean.
        reused.reset();
        assert_eq!(drive_fig6_shape(&mut reused), golden);
    }

    #[test]
    fn reset_replays_workflow_shape_bitwise() {
        let mut fresh = FlowSim::new();
        let golden = drive_workflow_shape(&mut fresh);
        let mut reused = FlowSim::new();
        // Dirty enough state to exercise every cleared structure: pending
        // activations, timers, unconsumed finished stats, resource bytes.
        let a = reused.add_resource("a", CapacityModel::Fixed(1.0 * GB));
        let b = reused.add_resource(
            "b",
            CapacityModel::Contended { single: 2.0 * GB, contended: 1.0 * GB },
        );
        for i in 0..9 {
            reused.start_flow(&[a, b], 0.5 * GB, 0.01 * i as f64, i);
        }
        reused.run_to_idle();
        assert!(reused.finished_len() > 0, "left stats unconsumed on purpose");
        reused.reset();
        assert_eq!(reused.len(), 0);
        assert_eq!(reused.finished_len(), 0);
        assert_eq!(reused.events_processed(), 0);
        assert_eq!(drive_workflow_shape(&mut reused), golden);
    }

    #[test]
    fn matches_reference_engine_bitwise_on_contended_mix() {
        // Close-to-home differential check (the broad randomized version
        // lives in rust/tests/golden_trace.rs): identical call sequence →
        // identical events and bit-identical timestamps.
        use crate::sim::reference::RefFlowSim;
        let mut a = FlowSim::new();
        let mut b = RefFlowSim::new();
        let build_new = |s: &mut FlowSim| {
            (
                s.add_resource("dram", CapacityModel::Fixed(204.0 * GB)),
                s.add_resource("aic", CapacityModel::Contended { single: 54.0 * GB, contended: 26.0 * GB }),
                s.add_resource("g0", CapacityModel::Fixed(54.0 * GB)),
                s.add_resource("g1", CapacityModel::Fixed(54.0 * GB)),
            )
        };
        let (d0, x0, g00, g10) = build_new(&mut a);
        let d1 = b.add_resource("dram", CapacityModel::Fixed(204.0 * GB));
        let x1 = b.add_resource("aic", CapacityModel::Contended { single: 54.0 * GB, contended: 26.0 * GB });
        let g01 = b.add_resource("g0", CapacityModel::Fixed(54.0 * GB));
        let g11 = b.add_resource("g1", CapacityModel::Fixed(54.0 * GB));
        assert_eq!((d0, x0, g00, g10), (d1, x1, g01, g11));
        let drive_a = {
            a.start_flow(&[x0, g00], 3.0 * GB, 10e-6, 1);
            a.start_flow(&[x0, g10], 2.0 * GB, 10e-6, 2);
            a.start_flow(&[d0, g00], 5.0 * GB, 0.0, 3);
            a.add_timer(0.01, 4);
            let mut ev = Vec::new();
            while let Some(e) = a.next_event() {
                ev.push((e, a.now().to_bits()));
            }
            ev
        };
        let drive_b = {
            b.start_flow(&[x1, g01], 3.0 * GB, 10e-6, 1);
            b.start_flow(&[x1, g11], 2.0 * GB, 10e-6, 2);
            b.start_flow(&[d1, g01], 5.0 * GB, 0.0, 3);
            b.add_timer(0.01, 4);
            let mut ev = Vec::new();
            while let Some(e) = b.next_event() {
                ev.push((e, b.now().to_bits()));
            }
            ev
        };
        assert_eq!(drive_a, drive_b);
    }
}
