//! ASCII table rendering for bench / report output.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// Simple row/column table with per-column alignment and a separator line
/// under the header. Cells are plain strings; format numbers upstream.
#[derive(Clone, Debug)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            aligns: headers.iter().map(|_| Align::Right).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Mark a column (by index) left-aligned (labels usually are).
    pub fn left(mut self, col: usize) -> Self {
        self.aligns[col] = Align::Left;
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                let pad = widths[c].saturating_sub(cell.chars().count());
                match aligns[c] {
                    Align::Left => {
                        line.push_str(cell);
                        line.extend(std::iter::repeat(' ').take(pad));
                    }
                    Align::Right => {
                        line.extend(std::iter::repeat(' ').take(pad));
                        line.push_str(cell);
                    }
                }
            }
            // avoid trailing spaces
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths, &self.aligns));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.extend(std::iter::repeat('-').take(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths, &self.aligns));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (quotes cells containing commas/quotes).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains([',', '"', '\n']) {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Convenience macro for building string rows.
#[macro_export]
macro_rules! trow {
    ($($cell:expr),* $(,)?) => {
        vec![ $( format!("{}", $cell) ),* ]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]).left(0);
        t.row(trow!["alpha", 1]);
        t.row(trow!["b", 12345]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[1].chars().all(|c| c == '-'));
        assert!(lines[2].starts_with("alpha"));
        // right-aligned numbers end at the same column
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(trow!["only-one"]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["k", "v"]);
        t.row(trow!["has,comma", "has\"quote"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    fn unicode_widths_dont_panic() {
        let mut t = Table::new(&["µs", "GiB/s"]);
        t.row(trow!["1.5 µs", "25.00"]);
        assert!(t.render().contains("µs"));
    }
}
