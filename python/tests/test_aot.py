"""AOT pipeline: lowering produces parseable HLO text and a manifest that
matches eval_shape reality (the Rust runtime trusts this contract)."""

import json
import os

import jax
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

SMALL = M.TinyConfig(layers=2, hidden=32, heads=2, vocab=128, ffn=48, batch=1, context=16)


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_all(SMALL, str(out), verbose=False)
    return out, manifest


def test_all_entries_emitted(lowered):
    out, manifest = lowered
    assert set(manifest["entries"]) == {
        "embed_fwd",
        "block_fwd",
        "block_bwd",
        "head_loss",
        "embed_bwd",
    }
    for e in manifest["entries"].values():
        path = os.path.join(out, e["file"])
        assert os.path.exists(path)
        text = open(path).read()
        assert text.startswith("HloModule"), f"{e['file']} is not HLO text"
        # the contract: root is a tuple (return_tuple=True)
        assert "ROOT" in text


def test_manifest_roundtrips_as_json(lowered):
    out, _ = lowered
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    assert m["model"]["layers"] == 2
    assert m["model"]["n_params"] == SMALL.n_params()


def test_block_bwd_signature_is_fwd_plus_dy(lowered):
    _, manifest = lowered
    fwd_in = manifest["entries"]["block_fwd"]["inputs"]
    bwd_in = manifest["entries"]["block_bwd"]["inputs"]
    assert [i["name"] for i in bwd_in[:-1]] == [i["name"] for i in fwd_in]
    assert bwd_in[-1]["name"] == "dy"
    # outputs: dx + one gradient per parameter
    bwd_out = manifest["entries"]["block_bwd"]["outputs"]
    assert len(bwd_out) == len(fwd_in)  # dx + 9 grads == x + 9 params
    assert bwd_out[0]["name"] == "dx"


def test_shapes_consistent_between_entries(lowered):
    _, manifest = lowered
    e = manifest["entries"]
    x_shape = e["block_fwd"]["inputs"][0]["shape"]
    assert e["embed_fwd"]["outputs"][0]["shape"] == x_shape
    assert e["block_fwd"]["outputs"][0]["shape"] == x_shape
    assert e["head_loss"]["inputs"][0]["shape"] == x_shape
    assert e["head_loss"]["outputs"][0]["shape"] == []  # scalar loss
    # gradient shapes mirror parameter shapes
    for pin, pout in zip(
        e["block_fwd"]["inputs"], e["block_bwd"]["outputs"]
    ):
        assert pin["shape"] == pout["shape"], (pin, pout)


def test_param_order_matches_contract(lowered):
    _, manifest = lowered
    names = [i["name"] for i in manifest["entries"]["block_fwd"]["inputs"][1:]]
    assert tuple(names) == M.BLOCK_PARAM_NAMES


def test_dtypes(lowered):
    _, manifest = lowered
    e = manifest["entries"]
    assert e["embed_fwd"]["inputs"][0]["dtype"] == "i32"
    assert e["embed_fwd"]["inputs"][1]["dtype"] == "f32"
    assert e["head_loss"]["inputs"][3]["dtype"] == "i32"


def test_hlo_has_no_custom_calls(lowered):
    """interpret=True must have eliminated Mosaic custom-calls — otherwise
    the CPU PJRT client cannot execute the artifact."""
    out, manifest = lowered
    for e in manifest["entries"].values():
        text = open(os.path.join(out, e["file"])).read()
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower(), (
            f"{e['file']} contains a Mosaic custom-call"
        )
