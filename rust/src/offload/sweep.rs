//! (context, batch) grid sweeps — the machinery behind Figures 9 and 10.
//!
//! For each grid cell the sweep simulates one iteration under each
//! placement engine and normalizes throughput against the DRAM-only
//! baseline, reproducing the paper's "% of baseline" bars.
//!
//! Grid points are independent, so the sweep fans them out across
//! [`crate::util::threadpool::par_map_ordered`] — one task per cell,
//! dispatched heaviest-first (largest context × batch, the cells that
//! dominate the critical path) but collected in deterministic
//! (context-major, batch-minor) order regardless of worker interleaving.
//! A full Fig. 9 panel (16 cells × 3 engines) drops from sum-of-cells to
//! max-of-cells wall-clock on a multicore host.
//!
//! Since the incremental engine landed, the default path evaluates every
//! cell through a shared [`EvalCtx`] (see [`super::evalcache`]): probe
//! passes, plans, schedule DAGs and DES results are interned under
//! digest keys, and re-sweeping an unchanged grid is pure memo traffic.
//! Every memoized value is value-pure, so cached, uncached
//! ([`sweep_grid_matrix_nocache`]) and warm sweeps produce bit-identical
//! [`SweepResult::digest`]s at any thread count — the contract
//! `rust/tests/sweep_incremental.rs` and `benches/sweep_scale.rs` pin.

use super::evalcache::{topo_digest, EvalCtx};
use super::metrics::PhaseBreakdown;
use super::plan::{MemoryPlan, RunConfig};
use super::schedules::{self, ScheduleRef};
use super::simulate_iteration;
use crate::jobj;
use crate::mem::EngineRef;
use crate::model::footprint::Workload;
use crate::model::ModelConfig;
use crate::topology::SystemTopology;
use crate::util::digest::Fnv64;
use crate::util::json::Json;
use crate::util::threadpool::{default_threads, par_map, par_map_ordered};

/// One grid cell result.
#[derive(Clone, Debug)]
pub struct GridPoint {
    pub context: usize,
    pub batch: usize,
    /// Breakdown per engine, ordered as the `policies` argument.
    pub runs: Vec<Option<PhaseBreakdown>>,
    /// Why a column did not run, parallel to `runs`: `Some(reason)` for
    /// OOM cells (the [`super::plan::PlanError`] rendering), `None` for
    /// cells that ran. Frontier plots use this to tell OOM from not-run.
    pub oom: Vec<Option<String>>,
}

/// A whole sweep.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub model: String,
    pub n_gpus: usize,
    /// Engine names, ordered as the runs inside each [`GridPoint`].
    pub policies: Vec<String>,
    pub points: Vec<GridPoint>,
}

impl SweepResult {
    /// Normalized throughput of `policy_idx` vs `baseline_idx` at a point
    /// (None if either run did not fit in memory).
    pub fn normalized(&self, point: &GridPoint, policy_idx: usize, baseline_idx: usize) -> Option<f64> {
        let run = point.runs.get(policy_idx)?.as_ref()?;
        let base = point.runs.get(baseline_idx)?.as_ref()?;
        Some(run.relative_to(base))
    }

    /// Bit-exact FNV-1a digest of the whole grid: cell coordinates, engine
    /// names, and every breakdown's `to_bits` timings. Two sweeps match iff
    /// they are bit-identical — this is how the parallel/serial contract
    /// and the DES determinism contract (DESIGN.md §7) are asserted at the
    /// full-figure granularity.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_str(&self.model);
        h.write_u64(self.n_gpus as u64);
        h.write_u64(self.policies.len() as u64);
        for p in &self.policies {
            h.write_str(p);
        }
        h.write_u64(self.points.len() as u64);
        for pt in &self.points {
            h.write_u64(pt.context as u64);
            h.write_u64(pt.batch as u64);
            for run in &pt.runs {
                match run {
                    None => {
                        h.write_u64(0);
                    }
                    Some(b) => {
                        h.write_u64(1);
                        h.write_f64(b.fwd_s);
                        h.write_f64(b.bwd_s);
                        h.write_f64(b.step_s);
                        h.write_f64(b.iter_s);
                        h.write_u64(b.tokens);
                    }
                }
            }
        }
        h.finish()
    }

    /// Machine-readable form of the whole sweep (written by `cxlfine sweep
    /// --json`): cell coordinates, per-column breakdowns (an `{"oom":
    /// reason}` object for cells whose plan did not fit, `null` only for
    /// columns that never ran), and the bitwise digest so perf-trajectory
    /// files are self-certifying. The digest ignores the reason strings —
    /// it hashes the same bytes it always has.
    pub fn to_json(&self) -> Json {
        let policies: Vec<Json> = self.policies.iter().map(|p| Json::Str(p.clone())).collect();
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|pt| {
                let runs: Vec<Json> = pt
                    .runs
                    .iter()
                    .enumerate()
                    .map(|(i, r)| match r {
                        None => match pt.oom.get(i).and_then(|o| o.as_deref()) {
                            Some(reason) => jobj! { "oom" => reason },
                            None => Json::Null,
                        },
                        Some(b) => b.to_json(),
                    })
                    .collect();
                jobj! {
                    "context" => pt.context,
                    "batch" => pt.batch,
                    "runs" => Json::Arr(runs),
                }
            })
            .collect();
        jobj! {
            "model" => self.model.as_str(),
            "n_gpus" => self.n_gpus,
            "policies" => Json::Arr(policies),
            "digest" => format!("{:016x}", self.digest()),
            "points" => Json::Arr(points),
        }
    }

    /// (min, max) normalized throughput of a policy across all points that
    /// have both runs — the paper's "X %–Y % of baseline" ranges.
    pub fn normalized_range(&self, policy_idx: usize, baseline_idx: usize) -> Option<(f64, f64)> {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut any = false;
        for p in &self.points {
            if let Some(r) = self.normalized(p, policy_idx, baseline_idx) {
                lo = lo.min(r);
                hi = hi.max(r);
                any = true;
            }
        }
        any.then_some((lo, hi))
    }
}

/// Run the grid with the default worker count (one task per grid cell).
///
/// Baseline engines (`is_baseline()`) run on `baseline_topo` (all-DRAM
/// host); the rest use `policy_topo` (the DRAM-constrained + CXL host).
/// Cells whose plan does not fit are recorded as `None` — exactly the cells
/// the paper could not run without CXL.
pub fn sweep_grid(
    baseline_topo: &SystemTopology,
    policy_topo: &SystemTopology,
    model: &ModelConfig,
    n_gpus: usize,
    contexts: &[usize],
    batches: &[usize],
    policies: &[EngineRef],
) -> SweepResult {
    sweep_grid_with_threads(
        baseline_topo,
        policy_topo,
        model,
        n_gpus,
        contexts,
        batches,
        policies,
        default_threads(),
    )
}

/// [`sweep_grid`] with an explicit worker count (`1` = fully serial; used
/// by the determinism tests to prove parallel == serial bit-for-bit).
#[allow(clippy::too_many_arguments)]
pub fn sweep_grid_with_threads(
    baseline_topo: &SystemTopology,
    policy_topo: &SystemTopology,
    model: &ModelConfig,
    n_gpus: usize,
    contexts: &[usize],
    batches: &[usize],
    policies: &[EngineRef],
    nthreads: usize,
) -> SweepResult {
    sweep_grid_matrix(
        baseline_topo,
        policy_topo,
        model,
        n_gpus,
        contexts,
        batches,
        policies,
        &[schedules::zero_offload()],
        nthreads,
    )
}

/// Column labels, engine-major schedule-minor. A single-schedule
/// `zero-offload` sweep keeps plain engine labels (bit-compatible with
/// pre-IR sweep digests); any other schedule set labels **every** column
/// `engine@schedule`, so the normalization root (column 0) is always
/// unambiguous.
fn column_labels(policies: &[EngineRef], schedules: &[ScheduleRef]) -> Vec<String> {
    let plain_labels = schedules.len() == 1 && schedules[0].name() == "zero-offload";
    policies
        .iter()
        .flat_map(|p| {
            schedules.iter().map(move |s| {
                if plain_labels {
                    p.name().to_string()
                } else {
                    format!("{}@{}", p.name(), s.name())
                }
            })
        })
        .collect()
}

/// The context-major, batch-minor cell list — the historical serial
/// (and result) ordering of every sweep.
fn grid_cells(contexts: &[usize], batches: &[usize]) -> Vec<(usize, usize)> {
    contexts
        .iter()
        .flat_map(|&c| batches.iter().map(move |&b| (c, b)))
        .collect()
}

/// Dispatch order: heaviest cells first (largest context × batch — DES
/// cost grows with both), ascending index as the deterministic
/// tie-break. Long-pole cells start immediately instead of landing on
/// whichever worker drains the tail, which squeezes the makespan of
/// skewed grids; results are still merged in grid order, so dispatch
/// order never shows in the output.
fn cost_order(grid: &[(usize, usize)]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..grid.len()).collect();
    order.sort_by(|&a, &b| {
        let ka = grid[a].0 * grid[a].1;
        let kb = grid[b].0 * grid[b].1;
        kb.cmp(&ka).then(a.cmp(&b))
    });
    order
}

/// The full engine × schedule sweep: every grid cell runs every
/// combination, columns ordered engine-major, schedule-minor (labels per
/// [`column_labels`]). Per cell the memory plan is built once per engine
/// and shared by its schedules — placement is schedule-independent.
///
/// This is the incremental path: a fresh [`EvalCtx`] per call, so
/// within-sweep sharing (probe passes, plan shapes, schedule DAGs)
/// already applies. Callers that re-sweep — the CLI, the benches, a
/// frontier search — should hold their own context and use
/// [`sweep_grid_matrix_with_ctx`] to make later sweeps warm.
#[allow(clippy::too_many_arguments)]
pub fn sweep_grid_matrix(
    baseline_topo: &SystemTopology,
    policy_topo: &SystemTopology,
    model: &ModelConfig,
    n_gpus: usize,
    contexts: &[usize],
    batches: &[usize],
    policies: &[EngineRef],
    schedules: &[ScheduleRef],
    nthreads: usize,
) -> SweepResult {
    let ctx = EvalCtx::new();
    sweep_grid_matrix_with_ctx(
        &ctx,
        baseline_topo,
        policy_topo,
        model,
        n_gpus,
        contexts,
        batches,
        policies,
        schedules,
        nthreads,
    )
}

/// [`sweep_grid_matrix`] against a caller-held [`EvalCtx`]: every probe
/// pass, plan build, schedule DAG and DES result already interned in
/// `ctx` is reused, so an unchanged cell costs four memo lookups. The
/// cache is value-pure — results (and [`SweepResult::digest`]s) are
/// bit-identical to [`sweep_grid_matrix_nocache`] whatever the cache
/// holds and whatever `nthreads` is.
#[allow(clippy::too_many_arguments)]
pub fn sweep_grid_matrix_with_ctx(
    ctx: &EvalCtx,
    baseline_topo: &SystemTopology,
    policy_topo: &SystemTopology,
    model: &ModelConfig,
    n_gpus: usize,
    contexts: &[usize],
    batches: &[usize],
    policies: &[EngineRef],
    schedules: &[ScheduleRef],
    nthreads: usize,
) -> SweepResult {
    assert!(!schedules.is_empty(), "need at least one schedule");
    let grid = grid_cells(contexts, batches);
    let order = cost_order(&grid);
    let baseline_d = topo_digest(baseline_topo);
    let policy_d = topo_digest(policy_topo);
    let points = par_map_ordered(grid.len(), nthreads.max(1), &order, |i| {
        let (c, b) = grid[i];
        let w = Workload::new(n_gpus, b, c);
        let ncols = policies.len() * schedules.len();
        let mut runs = Vec::with_capacity(ncols);
        let mut oom = Vec::with_capacity(ncols);
        for engine in policies {
            let (topo, topo_d) = if engine.is_baseline() {
                (baseline_topo, baseline_d)
            } else {
                (policy_topo, policy_d)
            };
            let (mut col, reason) =
                ctx.eval_engine_cell(topo, topo_d, model, w, engine, schedules);
            for _ in 0..col.len() {
                oom.push(reason.clone());
            }
            runs.append(&mut col);
        }
        GridPoint {
            context: c,
            batch: b,
            runs,
            oom,
        }
    });
    SweepResult {
        model: model.name.clone(),
        n_gpus,
        policies: column_labels(policies, schedules),
        points,
    }
}

/// The pre-incremental sweep, kept as the differential oracle (and the
/// CLI's `--no-cache` path): no memoization, no arena reuse, static
/// `par_map` chunking. `rust/tests/sweep_incremental.rs` pins the cached
/// path bit-identical to this one; `benches/sweep_scale.rs` measures the
/// speedup against it.
#[allow(clippy::too_many_arguments)]
pub fn sweep_grid_matrix_nocache(
    baseline_topo: &SystemTopology,
    policy_topo: &SystemTopology,
    model: &ModelConfig,
    n_gpus: usize,
    contexts: &[usize],
    batches: &[usize],
    policies: &[EngineRef],
    schedules: &[ScheduleRef],
    nthreads: usize,
) -> SweepResult {
    assert!(!schedules.is_empty(), "need at least one schedule");
    let grid = grid_cells(contexts, batches);
    let points = par_map(grid.len(), nthreads.max(1), |i| {
        let (c, b) = grid[i];
        let w = Workload::new(n_gpus, b, c);
        let ncols = policies.len() * schedules.len();
        let mut runs = Vec::with_capacity(ncols);
        let mut oom = Vec::with_capacity(ncols);
        for engine in policies {
            let topo = if engine.is_baseline() {
                baseline_topo
            } else {
                policy_topo
            };
            let cfg = RunConfig::new(model.clone(), w, engine.clone());
            let plan = MemoryPlan::build(topo, &cfg).map_err(|e| e.to_string());
            for sched in schedules {
                match &plan {
                    Ok(plan) => {
                        let cfg = cfg.clone().with_schedule(sched.clone());
                        runs.push(Some(simulate_iteration(topo, &cfg, plan)));
                        oom.push(None);
                    }
                    Err(reason) => {
                        runs.push(None);
                        oom.push(Some(reason.clone()));
                    }
                }
            }
        }
        GridPoint {
            context: c,
            batch: b,
            runs,
            oom,
        }
    });
    SweepResult {
        model: model.name.clone(),
        n_gpus,
        policies: column_labels(policies, schedules),
        points,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{engine, Policy};
    use crate::model::presets::qwen25_7b;
    use crate::topology::presets::{config_a, config_b, with_dram_capacity};
    use crate::util::units::GIB;

    fn engines(ps: &[Policy]) -> Vec<EngineRef> {
        ps.iter().map(|&p| EngineRef::from(p)).collect()
    }

    #[test]
    fn fig9a_band_shape() {
        // Small slice of the Fig. 9a grid; check the paper's ordering and
        // that "ours" lands close to baseline.
        let base = config_a();
        let cxl = with_dram_capacity(config_a(), 128 * GIB);
        let policies = engines(&[
            Policy::DramOnly,
            Policy::NaiveInterleave,
            Policy::CxlAware { striping: false },
        ]);
        let res = sweep_grid(
            &base,
            &cxl,
            &qwen25_7b(),
            1,
            &[4096, 8192],
            &[4, 8],
            &policies,
        );
        assert_eq!(res.points.len(), 4);
        let (nlo, nhi) = res.normalized_range(1, 0).unwrap();
        let (olo, ohi) = res.normalized_range(2, 0).unwrap();
        assert!(nhi < 1.0, "naive never reaches baseline: {nhi}");
        assert!(olo > nlo, "ours lower bound beats naive's: {olo} vs {nlo}");
        assert!(ohi > 0.94, "ours upper bound near baseline: {ohi}");
    }

    #[test]
    fn unfittable_cells_are_none() {
        // Force baseline OOM with a tiny DRAM-only machine.
        let tiny_base = with_dram_capacity(config_a(), 8 * GIB);
        let cxl = with_dram_capacity(config_a(), 128 * GIB);
        let res = sweep_grid(
            &tiny_base,
            &cxl,
            &qwen25_7b(),
            1,
            &[4096],
            &[8],
            &engines(&[Policy::DramOnly, Policy::CxlAware { striping: false }]),
        );
        assert!(res.points[0].runs[0].is_none(), "baseline must OOM");
        assert!(res.points[0].runs[1].is_some(), "CXL plan must fit");
        assert!(res.normalized_range(1, 0).is_none());
    }

    #[test]
    fn parallel_sweep_is_bit_identical_to_serial_in_same_order() {
        // The tentpole's contract: fanning grid points across workers
        // changes neither the results nor their order.
        let base = config_a();
        let cxl = with_dram_capacity(config_a(), 128 * GIB);
        let policies = engines(&[
            Policy::DramOnly,
            Policy::NaiveInterleave,
            Policy::CxlAware { striping: false },
        ]);
        let run = |threads| {
            sweep_grid_with_threads(
                &base,
                &cxl,
                &qwen25_7b(),
                1,
                &[4096, 8192, 16384],
                &[2, 8],
                &policies,
                threads,
            )
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(serial.points.len(), 6);
        assert_eq!(serial.policies, parallel.policies);
        for (s, p) in serial.points.iter().zip(&parallel.points) {
            assert_eq!((s.context, s.batch), (p.context, p.batch), "order must match");
            for (rs, rp) in s.runs.iter().zip(&p.runs) {
                match (rs, rp) {
                    (None, None) => {}
                    (Some(a), Some(b)) => {
                        assert_eq!(a.iter_s.to_bits(), b.iter_s.to_bits());
                        assert_eq!(a.fwd_s.to_bits(), b.fwd_s.to_bits());
                        assert_eq!(a.step_s.to_bits(), b.step_s.to_bits());
                    }
                    other => panic!("fit/OOM divergence: {other:?}"),
                }
            }
        }
        // order is context-major, batch-minor
        let cells: Vec<(usize, usize)> = serial.points.iter().map(|p| (p.context, p.batch)).collect();
        assert_eq!(
            cells,
            vec![(4096, 2), (4096, 8), (8192, 2), (8192, 8), (16384, 2), (16384, 8)]
        );
        // the digest is the one-number form of the same contract
        assert_eq!(serial.digest(), parallel.digest());
    }

    #[test]
    fn digest_locks_the_grid_bitwise() {
        let base = config_a();
        let cxl = with_dram_capacity(config_a(), 128 * GIB);
        let policies = engines(&[Policy::DramOnly, Policy::NaiveInterleave]);
        let run = || {
            sweep_grid(&base, &cxl, &qwen25_7b(), 1, &[4096], &[4, 8], &policies)
        };
        let a = run();
        let b = run();
        assert_eq!(a.digest(), b.digest(), "same grid → same digest");
        // a different cell set must change the digest
        let c = sweep_grid(&base, &cxl, &qwen25_7b(), 1, &[4096], &[4], &policies);
        assert_ne!(a.digest(), c.digest());
    }

    #[test]
    fn schedule_matrix_sweeps_engine_by_schedule() {
        let base = config_a();
        let cxl = with_dram_capacity(config_a(), 128 * GIB);
        let policies = engines(&[Policy::DramOnly, Policy::CxlAware { striping: false }]);
        let scheds = vec![
            crate::offload::schedules::by_name("zero-offload").unwrap(),
            crate::offload::schedules::by_name("lora").unwrap(),
            crate::offload::schedules::by_name("no-act-offload").unwrap(),
        ];
        let res = sweep_grid_matrix(
            &base,
            &cxl,
            &qwen25_7b(),
            1,
            &[4096],
            &[4],
            &policies,
            &scheds,
            2,
        );
        // engine-major, schedule-minor columns; multi-schedule sweeps
        // label every column explicitly so the normalization root is
        // never ambiguous
        assert_eq!(
            res.policies,
            vec![
                "baseline-dram@zero-offload",
                "baseline-dram@lora:16",
                "baseline-dram@no-act-offload",
                "cxl-aware@zero-offload",
                "cxl-aware@lora:16",
                "cxl-aware@no-act-offload",
            ]
        );
        let runs = &res.points[0].runs;
        assert_eq!(runs.len(), 6);
        for r in runs {
            assert!(r.is_some(), "every cell fits");
        }
        // same tokens, strictly less work → lora and the ablation beat the
        // full schedule under the same engine
        let (zo, lora, noact) = (
            runs[3].as_ref().unwrap(),
            runs[4].as_ref().unwrap(),
            runs[5].as_ref().unwrap(),
        );
        assert!(lora.iter_s < zo.iter_s, "lora must be faster than full FT");
        assert!(noact.iter_s <= zo.iter_s * 1.001);
        // matrix with only zero-offload matches the legacy sweep bitwise
        let plain = sweep_grid(&base, &cxl, &qwen25_7b(), 1, &[4096], &[4], &policies);
        let matrix_zo = sweep_grid_matrix(
            &base,
            &cxl,
            &qwen25_7b(),
            1,
            &[4096],
            &[4],
            &policies,
            &[crate::offload::schedules::zero_offload()],
            1,
        );
        assert_eq!(plain.digest(), matrix_zo.digest());
    }

    #[test]
    fn sweep_json_is_parseable_and_self_certifying() {
        let cxl = with_dram_capacity(config_a(), 128 * GIB);
        let tiny_base = with_dram_capacity(config_a(), 8 * GIB); // forces an OOM null
        let policies = engines(&[Policy::DramOnly, Policy::NaiveInterleave]);
        let res = sweep_grid(&tiny_base, &cxl, &qwen25_7b(), 1, &[4096], &[4], &policies);
        let j = res.to_json();
        let text = j.to_string_pretty();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(
            parsed.path(&["digest"]).unwrap().as_str(),
            Some(format!("{:016x}", res.digest()).as_str())
        );
        let points = parsed.path(&["points"]).unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 1);
        let runs = points[0].path(&["runs"]).unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 2);
        // OOM cells carry their PlanError rendering instead of a bare null,
        // so frontier plots can tell OOM from not-run.
        let reason = runs[0]
            .path(&["oom"])
            .expect("OOM cell must serialize as an {\"oom\": reason} object")
            .as_str()
            .unwrap();
        assert!(!reason.is_empty());
        assert!(
            reason.contains("baseline-dram"),
            "reason names the failing policy: {reason}"
        );
        assert_eq!(res.points[0].oom[0].as_deref(), Some(reason));
        assert_eq!(res.points[0].oom[1], None);
        assert!(runs[1].path(&["iter_s"]).unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn cached_sweep_matches_nocache_bitwise_including_oom_reasons() {
        // The incremental engine's core contract at module granularity
        // (the pinned cross-thread matrix lives in
        // rust/tests/sweep_incremental.rs): cached and legacy paths agree
        // bitwise, including which cells OOM and why.
        let tiny_base = with_dram_capacity(config_a(), 8 * GIB);
        let cxl = with_dram_capacity(config_a(), 128 * GIB);
        let policies = engines(&[Policy::DramOnly, Policy::CxlAware { striping: true }]);
        let scheds = vec![
            crate::offload::schedules::by_name("zero-offload").unwrap(),
            crate::offload::schedules::by_name("lora").unwrap(),
        ];
        let cached = sweep_grid_matrix(
            &tiny_base,
            &cxl,
            &qwen25_7b(),
            1,
            &[4096, 8192],
            &[4],
            &policies,
            &scheds,
            2,
        );
        let legacy = sweep_grid_matrix_nocache(
            &tiny_base,
            &cxl,
            &qwen25_7b(),
            1,
            &[4096, 8192],
            &[4],
            &policies,
            &scheds,
            2,
        );
        assert_eq!(cached.digest(), legacy.digest());
        for (c, l) in cached.points.iter().zip(&legacy.points) {
            assert_eq!(c.oom, l.oom, "OOM reasons must match the legacy path");
        }
        // baseline OOMs on the tiny host; its reason is repeated per
        // schedule column of the engine.
        assert!(cached.points[0].oom[0].is_some());
        assert_eq!(cached.points[0].oom[0], cached.points[0].oom[1]);
        assert!(cached.points[0].oom[2].is_none());
    }

    #[test]
    fn shared_ctx_resweep_is_pure_memo_traffic() {
        let base = config_a();
        let cxl = with_dram_capacity(config_a(), 128 * GIB);
        let policies = engines(&[Policy::DramOnly, Policy::NaiveInterleave]);
        let ctx = crate::offload::evalcache::EvalCtx::new();
        let run = |threads| {
            sweep_grid_matrix_with_ctx(
                &ctx,
                &base,
                &cxl,
                &qwen25_7b(),
                1,
                &[4096, 8192],
                &[4],
                &policies,
                &[schedules::zero_offload()],
                threads,
            )
        };
        let cold = run(2);
        let after_cold = ctx.stats();
        assert_eq!(after_cold.exec_hits, 0, "cold sweep cannot hit");
        let warm = run(1);
        let after_warm = ctx.stats();
        assert_eq!(cold.digest(), warm.digest(), "warm re-sweep is bit-identical");
        assert_eq!(
            after_warm.misses(),
            after_cold.misses(),
            "warm re-sweep must not compute anything"
        );
        assert_eq!(after_warm.exec_hits, 4, "2 cells x 2 engines all hit");
    }

    #[test]
    fn cost_order_is_heaviest_first_with_stable_ties() {
        let grid = vec![(4096, 2), (4096, 8), (8192, 2), (8192, 8), (16384, 1)];
        // costs: 8192, 32768, 16384, 65536, 16384
        assert_eq!(cost_order(&grid), vec![3, 1, 2, 4, 0]);
    }

    #[test]
    fn registry_engines_sweep_end_to_end() {
        // The adaptive engine flows through the whole sweep machinery by
        // name, and behaves sanely (at least as good as naive interleave).
        let base = config_b();
        let cxl = with_dram_capacity(config_b(), 128 * GIB);
        let policies: Vec<EngineRef> = vec![
            engine::by_name("baseline-dram").unwrap(),
            engine::by_name("naive-cxl").unwrap(),
            engine::by_name("adaptive-spill").unwrap(),
        ];
        let res = sweep_grid(&base, &cxl, &qwen25_7b(), 1, &[4096, 8192], &[8], &policies);
        assert_eq!(res.policies[2], "adaptive-spill");
        let (alo, _ahi) = res.normalized_range(2, 0).expect("adaptive range");
        let (_nlo, nhi) = res.normalized_range(1, 0).expect("naive range");
        assert!(alo > 0.5, "adaptive floor {alo}");
        for p in &res.points {
            if let (Some(n), Some(a)) = (res.normalized(p, 1, 0), res.normalized(p, 2, 0)) {
                assert!(a >= n - 1e-9, "adaptive ({a:.3}) must not lose to naive ({n:.3})");
            }
        }
        assert!(nhi < 1.0);
    }

    #[test]
    fn profile_aware_engine_sweeps_end_to_end() {
        // The profile-driven engine is a first-class sweep column: profiles
        // are computed per cell inside the plan builder, so the sweep
        // machinery needs no special-casing — and the placements it drives
        // must never lose to naive interleave on any shared cell.
        let base = config_a();
        let cxl = with_dram_capacity(config_a(), 128 * GIB);
        let policies: Vec<EngineRef> = vec![
            engine::by_name("baseline-dram").unwrap(),
            engine::by_name("naive-cxl").unwrap(),
            engine::by_name("profile-aware").unwrap(),
        ];
        let res = sweep_grid(&base, &cxl, &qwen25_7b(), 1, &[4096, 8192], &[4], &policies);
        assert_eq!(res.policies[2], "profile-aware");
        for p in &res.points {
            let (n, ours) = (res.normalized(p, 1, 0), res.normalized(p, 2, 0));
            let (n, ours) = (n.expect("naive fits"), ours.expect("profile-aware fits"));
            assert!(
                ours >= n - 1e-9,
                "c{}b{}: profile-aware ({ours:.3}) lost to naive ({n:.3})",
                p.context,
                p.batch
            );
        }
        // parallel == serial bitwise even with the profiling pass in play
        let serial = sweep_grid_with_threads(
            &base, &cxl, &qwen25_7b(), 1, &[4096, 8192], &[4], &policies, 1,
        );
        let parallel = sweep_grid_with_threads(
            &base, &cxl, &qwen25_7b(), 1, &[4096, 8192], &[4], &policies, 4,
        );
        assert_eq!(serial.digest(), parallel.digest());
        assert_eq!(serial.digest(), res.digest());
    }
}
