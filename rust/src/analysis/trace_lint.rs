//! Fleet-trace lints: integrity (digest), well-formedness (job fields,
//! duplicate ids), registry resolution (models / schedules / engines),
//! and arrival-order hygiene. Fault traces get the same treatment via
//! [`lint_fault_trace`]: target validity (P207), time ordering (P208) and
//! offline/restore pairing (P209) — the conditions
//! [`FaultTrace::validate`] aborts on, reported exhaustively instead.
//! Serving request traces get theirs via [`lint_request_trace`]: arrival
//! ordering (P210), token/SLO positivity (P211) and digest integrity
//! (P212), reusing P202/P204/P205/P206 for the shared shapes.
//!
//! Operates on parsed JSON rather than a [`FleetTrace`] so it can keep
//! going where `FleetTrace::from_json` must abort: one malformed job
//! becomes one P205 diagnostic and the remaining jobs are still checked.

use super::diag::{Anchor, Diagnostics, Severity};
use crate::fleet::{FaultEvent, FaultKind, FaultTrace, FleetTrace, JobSpec};
use crate::serve::{RequestSpec, RequestTrace};
use crate::topology::{MemKind, SystemTopology};
use crate::util::json::Json;

/// Lint a fleet trace as parsed JSON. See DESIGN.md §12 for the catalog.
pub fn lint_trace(j: &Json) -> Diagnostics {
    let mut ds = Diagnostics::new();
    let Some(obj) = j.as_obj() else {
        ds.push(
            "P205",
            Severity::Error,
            Anchor::Trace,
            "trace is not a JSON object",
        );
        return ds;
    };
    // Canonical traces carry the seed as a decimal string (u64 survives
    // round-tripping); a plain number is tolerated like `from_json` does.
    let seed = match obj.get("seed") {
        Some(Json::Str(s)) => s.parse::<u64>().ok(),
        Some(v) => v.as_u64(),
        None => None,
    };
    if seed.is_none() {
        ds.push(
            "P205",
            Severity::Error,
            Anchor::Trace,
            "trace is missing a u64 'seed'",
        );
    }
    let Some(jobs_json) = obj.get("jobs").and_then(|v| v.as_arr()) else {
        ds.push(
            "P205",
            Severity::Error,
            Anchor::Trace,
            "trace is missing a 'jobs' array",
        );
        return ds;
    };
    let mut jobs: Vec<JobSpec> = Vec::new();
    let mut all_parsed = true;
    for (idx, jj) in jobs_json.iter().enumerate() {
        match JobSpec::from_json(jj) {
            Ok(job) => {
                for issue in job.registry_issues() {
                    ds.push("P204", Severity::Error, Anchor::Job { id: job.id }, issue);
                }
                jobs.push(job);
            }
            Err(e) => {
                all_parsed = false;
                ds.push(
                    "P205",
                    Severity::Error,
                    Anchor::Trace,
                    format!("jobs[{idx}]: {e}"),
                );
            }
        }
    }
    let mut seen_ids = std::collections::BTreeSet::new();
    for job in &jobs {
        if !seen_ids.insert(job.id) {
            ds.push(
                "P202",
                Severity::Error,
                Anchor::Job { id: job.id },
                "duplicate job id",
            );
        }
    }
    // Arrival order: the fleet host replays jobs in listed order, so an
    // out-of-order arrival is legal (and exercised by the XL generator)
    // but usually means the trace was edited by hand.
    for w in jobs.windows(2) {
        if w[1].arrival_s < w[0].arrival_s {
            ds.push(
                "P203",
                Severity::Warn,
                Anchor::Job { id: w[1].id },
                format!(
                    "arrives at {:.3}s, before preceding job {} at {:.3}s \
                     (arrivals are not sorted)",
                    w[1].arrival_s, w[0].id, w[0].arrival_s
                ),
            );
        }
    }
    match obj.get("digest").and_then(|v| v.as_str()) {
        Some(want) => {
            // Recomputing requires every job to have parsed; P205 already
            // covers the trace when one did not.
            if let (Some(seed), true) = (seed, all_parsed) {
                let got = format!("{:016x}", FleetTrace { seed, jobs }.digest());
                if got != want {
                    ds.push(
                        "P201",
                        Severity::Error,
                        Anchor::Trace,
                        format!("digest mismatch: file says {want}, contents hash to {got}"),
                    );
                }
            }
        }
        None => ds.push(
            "P206",
            Severity::Info,
            Anchor::Trace,
            "trace carries no digest — integrity cannot be verified",
        ),
    }
    ds
}

/// Lint a serving request trace as parsed JSON. See DESIGN.md §12 for the
/// catalog. Shares the fleet trace's codes for the shared shapes (P205
/// malformed, P202 duplicate ids, P204 registry resolution, P206 missing
/// digest) and adds the serving-specific ones: P210 (arrivals out of
/// order — legal for the replay loop but usually a hand-edit), P211
/// (non-positive token counts / SLO, which `simulate_serving` aborts on),
/// P212 (digest mismatch).
pub fn lint_request_trace(j: &Json) -> Diagnostics {
    let mut ds = Diagnostics::new();
    let Some(obj) = j.as_obj() else {
        ds.push(
            "P205",
            Severity::Error,
            Anchor::Trace,
            "request trace is not a JSON object",
        );
        return ds;
    };
    let seed = match obj.get("seed") {
        Some(Json::Str(s)) => s.parse::<u64>().ok(),
        Some(v) => v.as_u64(),
        None => None,
    };
    if seed.is_none() {
        ds.push(
            "P205",
            Severity::Error,
            Anchor::Trace,
            "request trace is missing a u64 'seed'",
        );
    }
    let Some(reqs_json) = obj.get("requests").and_then(|v| v.as_arr()) else {
        ds.push(
            "P205",
            Severity::Error,
            Anchor::Trace,
            "request trace is missing a 'requests' array",
        );
        return ds;
    };
    let mut requests: Vec<RequestSpec> = Vec::new();
    let mut all_parsed = true;
    for (idx, rj) in reqs_json.iter().enumerate() {
        match RequestSpec::from_json(rj) {
            Ok(r) => {
                for issue in r.registry_issues() {
                    ds.push("P204", Severity::Error, Anchor::Job { id: r.id }, issue);
                }
                // `from_json` is value-lenient so one bad count stays one
                // diagnostic; the simulator itself refuses such traces.
                for issue in r.validity_issues() {
                    ds.push("P211", Severity::Error, Anchor::Job { id: r.id }, issue);
                }
                requests.push(r);
            }
            Err(e) => {
                all_parsed = false;
                ds.push(
                    "P205",
                    Severity::Error,
                    Anchor::Trace,
                    format!("requests[{idx}]: {e}"),
                );
            }
        }
    }
    let mut seen_ids = std::collections::BTreeSet::new();
    for r in &requests {
        if !seen_ids.insert(r.id) {
            ds.push(
                "P202",
                Severity::Error,
                Anchor::Job { id: r.id },
                "duplicate request id",
            );
        }
    }
    for w in requests.windows(2) {
        if w[1].arrival_s < w[0].arrival_s {
            ds.push(
                "P210",
                Severity::Warn,
                Anchor::Job { id: w[1].id },
                format!(
                    "arrives at {:.3}s, before preceding request {} at {:.3}s \
                     (arrivals are not sorted)",
                    w[1].arrival_s, w[0].id, w[0].arrival_s
                ),
            );
        }
    }
    match obj.get("digest").and_then(|v| v.as_str()) {
        Some(want) => {
            if let (Some(seed), true) = (seed, all_parsed) {
                let got = format!("{:016x}", RequestTrace { seed, requests }.digest());
                if got != want {
                    ds.push(
                        "P212",
                        Severity::Error,
                        Anchor::Trace,
                        format!("digest mismatch: file says {want}, contents hash to {got}"),
                    );
                }
            }
        }
        None => ds.push(
            "P206",
            Severity::Info,
            Anchor::Trace,
            "request trace carries no digest — integrity cannot be verified",
        ),
    }
    ds
}

/// Lint a fault trace as parsed JSON. `topo` enables the machine-specific
/// target checks (P207); without it only shape, ordering and pairing are
/// checked. See DESIGN.md §12 for the catalog.
pub fn lint_fault_trace(j: &Json, topo: Option<&SystemTopology>) -> Diagnostics {
    let mut ds = Diagnostics::new();
    let Some(obj) = j.as_obj() else {
        ds.push(
            "P205",
            Severity::Error,
            Anchor::Trace,
            "fault trace is not a JSON object",
        );
        return ds;
    };
    let seed = match obj.get("seed") {
        Some(Json::Str(s)) => s.parse::<u64>().ok(),
        Some(v) => v.as_u64(),
        None => None,
    };
    if seed.is_none() {
        ds.push(
            "P205",
            Severity::Error,
            Anchor::Trace,
            "fault trace is missing a u64 'seed'",
        );
    }
    let Some(events_json) = obj.get("events").and_then(|v| v.as_arr()) else {
        ds.push(
            "P205",
            Severity::Error,
            Anchor::Trace,
            "fault trace is missing an 'events' array",
        );
        return ds;
    };
    let mut events: Vec<FaultEvent> = Vec::new();
    let mut all_parsed = true;
    for (idx, ej) in events_json.iter().enumerate() {
        match FaultEvent::from_json(ej) {
            Ok(ev) => events.push(ev),
            Err(e) => {
                all_parsed = false;
                ds.push(
                    "P205",
                    Severity::Error,
                    Anchor::Trace,
                    format!("events[{idx}]: {e}"),
                );
            }
        }
    }
    // P208: fault times must be monotonically non-decreasing (the event
    // heap would reorder them, silently changing which jobs are hit).
    let mut last = f64::NEG_INFINITY;
    for (idx, ev) in events.iter().enumerate() {
        if !(ev.t_s.is_finite() && ev.t_s >= 0.0) {
            ds.push(
                "P208",
                Severity::Error,
                Anchor::Trace,
                format!("events[{idx}]: t_s {} is not a non-negative finite time", ev.t_s),
            );
            continue;
        }
        if ev.t_s < last {
            ds.push(
                "P208",
                Severity::Error,
                Anchor::Trace,
                format!(
                    "events[{idx}]: t_s {} precedes the previous fault at {last} \
                     (fault events must be time-sorted)",
                    ev.t_s
                ),
            );
        }
        last = ev.t_s;
    }
    // P207: every fault must target hardware that exists (and magnitudes
    // must be meaningful); P209: restores must pair with a prior offline.
    let mut offline = std::collections::BTreeSet::new();
    for (idx, ev) in events.iter().enumerate() {
        let node_exists = |node: usize| topo.map(|t| node < t.mem_nodes.len());
        match &ev.kind {
            FaultKind::LinkDegrade { link, bw_factor } => {
                if topo.map(|t| *link >= t.links.len()) == Some(true) {
                    ds.push(
                        "P207",
                        Severity::Error,
                        Anchor::Trace,
                        format!("events[{idx}]: link {link} does not exist on this topology"),
                    );
                }
                if !(bw_factor.is_finite() && *bw_factor > 0.0 && *bw_factor <= 1.0) {
                    ds.push(
                        "P207",
                        Severity::Error,
                        Anchor::Trace,
                        format!("events[{idx}]: bw_factor {bw_factor} must be in (0, 1]"),
                    );
                }
            }
            FaultKind::NodeOffline { node } => {
                match node_exists(*node) {
                    Some(false) => ds.push(
                        "P207",
                        Severity::Error,
                        Anchor::Trace,
                        format!("events[{idx}]: node {node} does not exist on this topology"),
                    ),
                    Some(true)
                        if topo.is_some_and(|t| t.mem_nodes[*node].kind != MemKind::CxlAic) =>
                    {
                        ds.push(
                            "P207",
                            Severity::Error,
                            Anchor::Trace,
                            format!(
                                "events[{idx}]: node {node} is local DRAM — only CXL AICs \
                                 can go offline"
                            ),
                        )
                    }
                    _ => {}
                }
                if !offline.insert(*node) {
                    ds.push(
                        "P209",
                        Severity::Error,
                        Anchor::Trace,
                        format!("events[{idx}]: node {node} is already offline"),
                    );
                }
            }
            FaultKind::NodeRestore { node } => {
                if node_exists(*node) == Some(false) {
                    ds.push(
                        "P207",
                        Severity::Error,
                        Anchor::Trace,
                        format!("events[{idx}]: node {node} does not exist on this topology"),
                    );
                }
                if !offline.remove(node) {
                    ds.push(
                        "P209",
                        Severity::Error,
                        Anchor::Trace,
                        format!(
                            "events[{idx}]: restore of node {node} without a prior offline"
                        ),
                    );
                }
            }
            FaultKind::CapacitySqueeze { node, bytes } => {
                if node_exists(*node) == Some(false) {
                    ds.push(
                        "P207",
                        Severity::Error,
                        Anchor::Trace,
                        format!("events[{idx}]: node {node} does not exist on this topology"),
                    );
                }
                if *bytes == 0 {
                    ds.push(
                        "P207",
                        Severity::Error,
                        Anchor::Trace,
                        format!("events[{idx}]: capacity squeeze of zero bytes"),
                    );
                }
            }
        }
    }
    match obj.get("digest").and_then(|v| v.as_str()) {
        Some(want) => {
            if let (Some(seed), true) = (seed, all_parsed) {
                let got = format!("{:016x}", FaultTrace { seed, events }.digest());
                if got != want {
                    ds.push(
                        "P201",
                        Severity::Error,
                        Anchor::Trace,
                        format!("digest mismatch: file says {want}, contents hash to {got}"),
                    );
                }
            }
        }
        None => ds.push(
            "P206",
            Severity::Info,
            Anchor::Trace,
            "fault trace carries no digest — integrity cannot be verified",
        ),
    }
    ds
}
