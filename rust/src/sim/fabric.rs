//! Fabric: instantiates [`FlowSim`] resources from a [`SystemTopology`] and
//! exposes typed host↔GPU transfer operations.
//!
//! Resource mapping:
//! * local DRAM → one `Fixed(peak_bw)` resource (the integrated memory
//!   controllers; both DMA directions share it),
//! * each CXL AIC → two `Contended` resources (PCIe link TX and RX with the
//!   Fig. 6b concurrency collapse),
//! * each GPU → two `Fixed` resources (its own PCIe link per direction).
//!
//! A host→GPU copy from node *n* traverses `[n.tx, gpu.rx]`; a GPU→host
//! copy into node *n* traverses `[gpu.tx, n.rx]`. Per-transfer setup time
//! models DMA descriptor launch plus device latency.

use super::flow::{CapacityModel, Event, FlowId, FlowSim, FlowStats, ResourceId};
use crate::topology::{GpuId, MemKind, NodeId, SystemTopology};

/// Direction of a host↔GPU DMA relative to the host.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// Host memory → GPU HBM (parameter/activation load).
    HostToGpu,
    /// GPU HBM → host memory (activation checkpoint / gradient offload).
    GpuToHost,
}

#[derive(Clone, Copy, Debug)]
struct NodeRes {
    tx: ResourceId, // host memory → device direction (reads from the node)
    rx: ResourceId, // device → host memory direction (writes into the node)
}

#[derive(Clone, Copy, Debug)]
struct GpuRes {
    rx: ResourceId, // data arriving at the GPU
    tx: ResourceId, // data leaving the GPU
}

/// Per-transfer fixed overhead: driver/DMA descriptor setup for a
/// `cudaMemcpyAsync` on a page-locked buffer (~10 µs observed on PCIe
/// systems); device load-to-use latency is added on top.
pub const DMA_SETUP_S: f64 = 10e-6;

pub struct Fabric {
    pub sim: FlowSim,
    nodes: Vec<NodeRes>,
    gpus: Vec<GpuRes>,
    latency_s: Vec<f64>, // per node
}

impl Fabric {
    pub fn new(topo: &SystemTopology) -> Self {
        Self::new_in(topo, FlowSim::new())
    }

    /// Build the fabric inside a reused DES arena: `sim` is reset (which
    /// makes it observationally identical to a fresh engine while keeping
    /// its allocations) and its resource table rebuilt from `topo`. The
    /// sweep's per-worker arenas thread the engine back out through the
    /// public `sim` field after each run.
    pub fn new_in(topo: &SystemTopology, mut sim: FlowSim) -> Self {
        sim.reset();
        let mut nodes = Vec::new();
        let mut latency_s = Vec::new();
        for n in &topo.mem_nodes {
            let res = match n.kind {
                MemKind::LocalDram => {
                    // one shared controller resource for both directions
                    let r = sim.add_resource(
                        &format!("{}-ctrl", n.name),
                        CapacityModel::Fixed(n.peak_bw),
                    );
                    NodeRes { tx: r, rx: r }
                }
                MemKind::CxlAic => {
                    let link = topo.link(n.link.expect("validated"));
                    let model = || CapacityModel::Contended {
                        single: link.capacity(1),
                        contended: link.capacity(2),
                    };
                    NodeRes {
                        tx: sim.add_resource(&format!("{}-tx", n.name), model()),
                        rx: sim.add_resource(&format!("{}-rx", n.name), model()),
                    }
                }
            };
            nodes.push(res);
            latency_s.push(n.latency_ns * 1e-9);
        }
        let mut gpus = Vec::new();
        for g in &topo.gpus {
            let link = topo.link(g.link);
            let cap = CapacityModel::Fixed(link.capacity(1));
            gpus.push(GpuRes {
                rx: sim.add_resource(&format!("{}-rx", g.name), cap.clone()),
                tx: sim.add_resource(&format!("{}-tx", g.name), cap),
            });
        }
        Self {
            sim,
            nodes,
            gpus,
            latency_s,
        }
    }

    /// Issue a DMA of `bytes` between `node` and `gpu`. Returns the flow id;
    /// completion is reported through [`Fabric::next_event`] with `tag`.
    pub fn transfer(
        &mut self,
        gpu: GpuId,
        node: NodeId,
        dir: Dir,
        bytes: f64,
        tag: u64,
    ) -> FlowId {
        let n = self.nodes[node.0];
        let g = self.gpus[gpu.0];
        let path = match dir {
            Dir::HostToGpu => [n.tx, g.rx],
            Dir::GpuToHost => [g.tx, n.rx],
        };
        let setup = DMA_SETUP_S + self.latency_s[node.0];
        self.sim.start_flow(&path, bytes, setup, tag)
    }

    /// A transfer whose host side is striped across several nodes: one flow
    /// per stripe, sized by the stripe fraction. Returns all flow ids; the
    /// logical transfer completes when every stripe flow has completed.
    pub fn transfer_striped(
        &mut self,
        gpu: GpuId,
        stripes: &[(NodeId, f64)], // (node, fraction of bytes)
        dir: Dir,
        bytes: f64,
        tag: u64,
    ) -> Vec<FlowId> {
        assert!(!stripes.is_empty());
        let total: f64 = stripes.iter().map(|(_, f)| *f).sum();
        assert!((total - 1.0).abs() < 1e-6, "stripe fractions must sum to 1");
        stripes
            .iter()
            .filter(|(_, frac)| *frac > 0.0)
            .map(|(node, frac)| self.transfer(gpu, *node, dir, bytes * frac, tag))
            .collect()
    }

    /// Pure compute delay (GPU kernel, CPU phase) as a timer.
    pub fn compute(&mut self, seconds: f64, tag: u64) -> super::flow::TimerId {
        self.sim.add_timer(seconds, tag)
    }

    /// Remove and return a completed transfer's stats. Long-running drivers
    /// must consume stats this way (or via [`FlowSim::drain_finished`]) so
    /// the per-flow stats map does not grow for the whole run — one entry
    /// per DMA adds up fast across multi-epoch training loops.
    pub fn take_stats(&mut self, id: FlowId) -> Option<FlowStats> {
        self.sim.take_stats(id)
    }

    pub fn next_event(&mut self) -> Option<Event> {
        self.sim.next_event()
    }

    pub fn now(&self) -> f64 {
        self.sim.now()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::{config_a, config_b};
    use crate::util::units::GIB;

    const GB: f64 = 1e9;

    fn dram() -> NodeId {
        NodeId(0)
    }

    #[test]
    fn single_gpu_dram_vs_cxl_parity_large_transfer() {
        // Fig. 6a: one GPU, large page-locked copies — CXL ≈ DRAM (both
        // interface-bound at the GPU link rate).
        let topo = config_a();
        let cxl = topo.cxl_nodes()[0];
        let mut t_dram = 0.0;
        let mut t_cxl = 0.0;
        for (node, out) in [(dram(), &mut t_dram), (cxl, &mut t_cxl)] {
            let mut fab = Fabric::new(&topo);
            let f = fab.transfer(GpuId(0), node, Dir::HostToGpu, 1.0 * GIB as f64, 0);
            fab.sim.run_to_idle();
            *out = fab.sim.stats(f).unwrap().finished;
        }
        let ratio = t_cxl / t_dram;
        assert!((0.95..1.10).contains(&ratio), "single-GPU parity broken: {ratio}");
    }

    #[test]
    fn dual_gpu_cxl_contention_collapses_aggregate() {
        // Fig. 6b: both GPUs reading the same AIC → aggregate ~25 GiB/s.
        let topo = config_a();
        let cxl = topo.cxl_nodes()[0];
        let mut fab = Fabric::new(&topo);
        let bytes = 4.0 * GIB as f64;
        let a = fab.transfer(GpuId(0), cxl, Dir::HostToGpu, bytes, 0);
        let b = fab.transfer(GpuId(1), cxl, Dir::HostToGpu, bytes, 1);
        fab.sim.run_to_idle();
        let fin = fab
            .sim
            .stats(a)
            .unwrap()
            .finished
            .max(fab.sim.stats(b).unwrap().finished);
        let aggregate = 2.0 * bytes / fin / GIB as f64;
        assert!(
            (20.0..32.0).contains(&aggregate),
            "aggregate {aggregate} GiB/s (expected ~25)"
        );
    }

    #[test]
    fn dual_gpu_dram_does_not_collapse() {
        let topo = config_a();
        let mut fab = Fabric::new(&topo);
        let bytes = 4.0 * GIB as f64;
        let a = fab.transfer(GpuId(0), dram(), Dir::HostToGpu, bytes, 0);
        let b = fab.transfer(GpuId(1), dram(), Dir::HostToGpu, bytes, 1);
        fab.sim.run_to_idle();
        let fin = fab
            .sim
            .stats(a)
            .unwrap()
            .finished
            .max(fab.sim.stats(b).unwrap().finished);
        let aggregate = 2.0 * bytes / fin;
        // each GPU link sustains ~54 GB/s; DRAM (204 GB/s) is not limiting
        assert!(aggregate > 100.0 * GB, "aggregate {} GB/s", aggregate / GB);
    }

    #[test]
    fn small_transfers_are_latency_bound() {
        // Fig. 6 ramp: effective bandwidth grows with request size.
        let topo = config_a();
        let cxl = topo.cxl_nodes()[0];
        let mut rates = Vec::new();
        for size in [64.0 * 1024.0, 1e6, 64e6, 1e9] {
            let mut fab = Fabric::new(&topo);
            let f = fab.transfer(GpuId(0), cxl, Dir::HostToGpu, size, 0);
            fab.sim.run_to_idle();
            rates.push(fab.sim.stats(f).unwrap().e2e_throughput());
        }
        for w in rates.windows(2) {
            assert!(w[1] > w[0], "bandwidth should grow with size: {rates:?}");
        }
    }

    #[test]
    fn striping_across_two_aics_beats_single_aic() {
        // Fig. 8b / Fig. 10: dual-GPU traffic striped over two AICs avoids
        // the contention collapse.
        let topo = config_b();
        let cxl = topo.cxl_nodes();
        let bytes = 4.0 * GIB as f64;

        // contended: both GPUs on AIC0
        let mut fab = Fabric::new(&topo);
        fab.transfer(GpuId(0), cxl[0], Dir::HostToGpu, bytes, 0);
        fab.transfer(GpuId(1), cxl[0], Dir::HostToGpu, bytes, 1);
        fab.sim.run_to_idle();
        let t_contended = fab.now();

        // striped: each GPU splits its transfer across both AICs
        let mut fab2 = Fabric::new(&topo);
        let stripes = [(cxl[0], 0.5), (cxl[1], 0.5)];
        fab2.transfer_striped(GpuId(0), &stripes, Dir::HostToGpu, bytes, 0);
        fab2.transfer_striped(GpuId(1), &stripes, Dir::HostToGpu, bytes, 1);
        fab2.sim.run_to_idle();
        let t_striped = fab2.now();

        assert!(
            t_striped < t_contended * 0.75,
            "striping should relieve contention: striped {t_striped:.3}s vs contended {t_contended:.3}s"
        );
    }

    #[test]
    fn opposite_directions_do_not_contend() {
        // Full-duplex PCIe: H2D and D2H on the same GPU link overlap.
        let topo = config_a();
        let mut fab = Fabric::new(&topo);
        let bytes = 2.0 * GIB as f64;
        let a = fab.transfer(GpuId(0), dram(), Dir::HostToGpu, bytes, 0);
        let b = fab.transfer(GpuId(0), dram(), Dir::GpuToHost, bytes, 1);
        fab.sim.run_to_idle();
        let t_both = fab
            .sim
            .stats(a)
            .unwrap()
            .finished
            .max(fab.sim.stats(b).unwrap().finished);
        let mut fab2 = Fabric::new(&topo);
        let solo = fab2.transfer(GpuId(0), dram(), Dir::HostToGpu, bytes, 0);
        fab2.sim.run_to_idle();
        let t_solo = fab2.sim.stats(solo).unwrap().finished;
        assert!(t_both < t_solo * 1.2, "duplex broken: {t_both} vs {t_solo}");
    }

    #[test]
    fn take_stats_keeps_long_runs_bounded() {
        // The iteration driver consumes stats per completion event; after a
        // burst of transfers the finished map must be fully drained.
        let topo = config_a();
        let mut fab = Fabric::new(&topo);
        let mut flows = Vec::new();
        for i in 0..16u64 {
            flows.push(fab.transfer(GpuId(0), dram(), Dir::HostToGpu, 1e8, i));
        }
        fab.sim.run_to_idle();
        assert_eq!(fab.sim.finished_len(), 16);
        for f in &flows {
            assert!(fab.take_stats(*f).is_some());
        }
        assert_eq!(fab.sim.finished_len(), 0, "all stats consumed");
        assert!(fab.take_stats(flows[0]).is_none(), "take is exactly-once");
    }

    #[test]
    fn new_in_reused_arena_matches_fresh_fabric_bitwise() {
        let topo = config_a();
        let cxl = topo.cxl_nodes()[0];
        let drive = |fab: &mut Fabric| {
            fab.transfer(GpuId(0), dram(), Dir::HostToGpu, 3.0 * GIB as f64, 0);
            fab.transfer(GpuId(1), cxl, Dir::HostToGpu, 2.0 * GIB as f64, 1);
            fab.compute(0.002, 2);
            let mut ev = Vec::new();
            while let Some(e) = fab.next_event() {
                ev.push((e, fab.now().to_bits()));
            }
            ev
        };
        let mut fresh = Fabric::new(&topo);
        let golden = drive(&mut fresh);
        // Dirty an arena on a different topology, then rebuild in place.
        let mut dirty = Fabric::new(&config_b());
        dirty.transfer(GpuId(0), dram(), Dir::GpuToHost, 1.0 * GIB as f64, 9);
        dirty.sim.run_to_idle();
        let mut reused = Fabric::new_in(&topo, dirty.sim);
        assert_eq!(drive(&mut reused), golden);
    }

    #[test]
    fn stripe_fractions_validated() {
        let topo = config_b();
        let cxl = topo.cxl_nodes();
        let mut fab = Fabric::new(&topo);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fab.transfer_striped(GpuId(0), &[(cxl[0], 0.7)], Dir::HostToGpu, 1e9, 0)
        }));
        assert!(r.is_err(), "fractions not summing to 1 must be rejected");
    }
}
