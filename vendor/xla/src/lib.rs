//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The container image carries no XLA/PJRT shared libraries, so this crate
//! provides the exact API surface `cxlfine::runtime` compiles against and
//! **fails gracefully at runtime**: [`PjRtClient::cpu`] returns an error, so
//! `Runtime::load` reports "runtime unavailable" instead of the binary
//! failing to link. Artifact-driven tests and examples already skip
//! themselves when no artifacts are present, so the simulation/placement
//! layers stay fully testable. Swapping this for the real bindings is a
//! one-line Cargo change; no source edits.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla_rs::Error` closely enough for `?` conversion
/// into `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct Error {
    message: String,
}

impl Error {
    fn unavailable(what: &str) -> Self {
        Error {
            message: format!(
                "{what}: XLA PJRT runtime is not available in this offline build \
                 (vendor/xla is a stub; link the real xla-rs bindings to execute artifacts)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host literal (tensor) handle.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (text form).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!(
            "parsing HLO text {}",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation built from a module proto.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer returned by an execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with one argument list on the default device.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug, Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The CPU client — in this stub, always an error (no PJRT plugin).
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn literal_surface_typechecks() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        assert!(l.to_vec::<f32>().is_err());
        let li = Literal::vec1(&[1i32]);
        assert!(li.to_tuple().is_err());
    }

    #[test]
    fn hlo_parse_is_graceful() {
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
    }
}
