//! Incremental-sweep scale bench: cells/sec and cache hit-rates for the
//! legacy uncached sweep, a cold incremental sweep, and a warm re-sweep
//! on the same [`EvalCtx`], at small / medium / large engine × schedule
//! grids on the §V-shaped hosts (config-a baseline, 128 GiB CXL host).
//!
//! Gates (enforced in CI via `--smoke`):
//! * every path — legacy, cold cached, warm cached — produces the same
//!   `SweepResult::digest` at every grid, and the digest is invariant in
//!   the worker count (the cache-transparency contract pinned in
//!   `rust/tests/sweep_incremental.rs`);
//! * a warm re-sweep computes nothing: zero new cache misses;
//! * full (non-smoke) runs enforce the ≥5× wall-clock gate of the warm
//!   re-sweep against the legacy path at the pinned 8-context ×
//!   4-batch grid.
//!
//! Results land in `bench_out/sweep_scale/` and in `BENCH_sweep.json`
//! (override: `CXLFINE_BENCH_SWEEP_OUT`), uploaded by the CI bench-smoke
//! job so the sweep-throughput trajectory is recorded alongside the DES,
//! schedule, capacity and fleet ones.

use std::time::Instant;

use cxlfine::mem::{EngineRef, Policy};
use cxlfine::model::presets::qwen25_7b;
use cxlfine::offload::{
    schedules, sweep_grid_matrix_nocache, sweep_grid_matrix_with_ctx, EvalCtx, ScheduleRef,
    SweepResult,
};
use cxlfine::topology::presets::{config_a, with_dram_capacity};
use cxlfine::trow;
use cxlfine::util::bench::BenchReport;
use cxlfine::util::json::{Json, JsonObj};
use cxlfine::util::table::Table;
use cxlfine::util::units::GIB;

struct Grid {
    name: &'static str,
    contexts: Vec<usize>,
    batches: Vec<usize>,
    /// The full-run ≥5× warm-path gate applies only to the pinned
    /// 8-context × 4-batch grid named by the PR-9 issue.
    gated: bool,
}

fn grids(smoke: bool) -> Vec<Grid> {
    let mut out = vec![
        Grid {
            name: "small",
            contexts: vec![4096],
            batches: vec![4, 8],
            gated: false,
        },
        Grid {
            name: "medium",
            contexts: vec![4096, 8192],
            batches: vec![4, 8],
            gated: false,
        },
    ];
    if !smoke {
        out.push(Grid {
            name: "large",
            contexts: vec![1024, 2048, 4096, 6144, 8192, 12288, 16384, 24576],
            batches: vec![1, 2, 4, 8],
            gated: true,
        });
    }
    out
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = BenchReport::new("sweep_scale");
    let base = config_a();
    let cxl = with_dram_capacity(config_a(), 128 * GIB);
    let model = qwen25_7b();
    let threads = cxlfine::util::threadpool::default_threads();

    let policies: Vec<EngineRef> = vec![
        EngineRef::from(Policy::DramOnly),
        EngineRef::from(Policy::NaiveInterleave),
        EngineRef::from(Policy::CxlAware { striping: true }),
    ];
    let scheds: Vec<ScheduleRef> = vec![
        schedules::by_name("zero-offload").unwrap(),
        schedules::by_name("lora").unwrap(),
    ];

    let mut json_grids = Vec::new();
    for grid in grids(smoke) {
        let n_cells = grid.contexts.len() * grid.batches.len();
        let n_cols = n_cells * policies.len() * scheds.len();

        let run_legacy = |nthreads: usize| -> SweepResult {
            sweep_grid_matrix_nocache(
                &base,
                &cxl,
                &model,
                1,
                &grid.contexts,
                &grid.batches,
                &policies,
                &scheds,
                nthreads,
            )
        };
        let run_cached = |ctx: &EvalCtx, nthreads: usize| -> SweepResult {
            sweep_grid_matrix_with_ctx(
                ctx,
                &base,
                &cxl,
                &model,
                1,
                &grid.contexts,
                &grid.batches,
                &policies,
                &scheds,
                nthreads,
            )
        };

        let t0 = Instant::now();
        let legacy = run_legacy(threads);
        let wall_legacy = t0.elapsed().as_secs_f64().max(1e-9);

        let ctx = EvalCtx::new();
        let t0 = Instant::now();
        let cold = run_cached(&ctx, threads);
        let wall_cold = t0.elapsed().as_secs_f64().max(1e-9);
        let stats_cold = ctx.stats();

        let t0 = Instant::now();
        let warm = run_cached(&ctx, threads);
        let wall_warm = t0.elapsed().as_secs_f64().max(1e-9);
        let stats_warm = ctx.stats();

        // Transparency gates, always on (smoke included): the cache and
        // the dispatch order may only change wall-clock, never a byte.
        assert_eq!(
            legacy.digest(),
            cold.digest(),
            "{}: cold cached sweep drifted from the legacy path",
            grid.name
        );
        assert_eq!(
            cold.digest(),
            warm.digest(),
            "{}: warm re-sweep drifted from its own cold pass",
            grid.name
        );
        assert_eq!(
            stats_warm.misses(),
            stats_cold.misses(),
            "{}: a warm re-sweep must not compute anything",
            grid.name
        );
        let single = run_cached(&EvalCtx::new(), 1);
        assert_eq!(
            single.digest(),
            legacy.digest(),
            "{}: digests must be invariant in the worker count",
            grid.name
        );

        let cold_speedup = wall_legacy / wall_cold;
        let warm_speedup = wall_legacy / wall_warm;
        if !smoke && grid.gated {
            assert!(
                warm_speedup >= 5.0,
                "{}-grid warm re-sweep gate: expected >=5x vs the legacy sweep, \
                 got {warm_speedup:.2}x ({wall_legacy:.3}s vs {wall_warm:.3}s)",
                grid.name
            );
        }

        let hit_rate = |h: u64, m: u64| -> f64 {
            if h + m == 0 {
                0.0
            } else {
                h as f64 / (h + m) as f64
            }
        };
        let mut t = Table::new(&["path", "wall", "cells/s", "speedup", "exec hit", "digest"])
            .left(0);
        let mut raws = Vec::new();
        for (path, wall, stats) in [
            ("legacy", wall_legacy, None),
            ("cold", wall_cold, Some(stats_cold)),
            ("warm", wall_warm, Some(stats_warm)),
        ] {
            let exec_hit = stats
                .map(|s| hit_rate(s.exec_hits, s.exec_misses))
                .unwrap_or(0.0);
            t.row(trow![
                path,
                format!("{wall:.3}s"),
                format!("{:.1}", n_cells as f64 / wall),
                format!("{:.2}x", wall_legacy / wall),
                if stats.is_some() {
                    format!("{:.0}%", 100.0 * exec_hit)
                } else {
                    "-".to_string()
                },
                format!("{:016x}", legacy.digest())
            ]);
            let mut cell = JsonObj::new();
            cell.set("path", path);
            cell.set("wall_s", wall);
            cell.set("cells_per_sec", n_cells as f64 / wall);
            cell.set("speedup_vs_legacy", wall_legacy / wall);
            if let Some(s) = stats {
                cell.set("probe_hit_rate", hit_rate(s.probe_hits, s.probe_misses));
                cell.set("plan_hit_rate", hit_rate(s.plan_hits, s.plan_misses));
                cell.set("sched_hit_rate", hit_rate(s.sched_hits, s.sched_misses));
                cell.set("exec_hit_rate", exec_hit);
                cell.set("cache_summary", s.summary_line());
            }
            cell.set("digest", format!("{:016x}", legacy.digest()));
            raws.push(Json::Obj(cell));
        }
        println!(
            "{} grid: {n_cells} cells x {} cols, cold {cold_speedup:.2}x, warm {warm_speedup:.2}x",
            grid.name,
            n_cols / n_cells
        );
        report.section(grid.name, t, Json::Arr(raws.clone()));
        json_grids.push(Json::Obj({
            let mut o = JsonObj::new();
            o.set("grid", grid.name);
            o.set("n_cells", n_cells);
            o.set("n_columns", n_cols);
            o.set("cold_speedup", cold_speedup);
            o.set("warm_speedup", warm_speedup);
            o.set("digest", format!("{:016x}", legacy.digest()));
            o.set("paths", Json::Arr(raws));
            o
        }));
    }

    let mut root = JsonObj::new();
    root.set("bench", "sweep_scale");
    root.set("smoke", smoke);
    root.set("model", model.name.as_str());
    root.set("threads", threads);
    root.set("grids", Json::Arr(json_grids));
    let out =
        std::env::var("CXLFINE_BENCH_SWEEP_OUT").unwrap_or_else(|_| "BENCH_sweep.json".into());
    let payload = Json::Obj(root).to_string_pretty();
    match std::fs::write(&out, &payload) {
        Ok(()) => println!("\n[sweep_scale] wrote {out}"),
        Err(e) => eprintln!("warn: could not write {out}: {e}"),
    }
    report.finish();
}
