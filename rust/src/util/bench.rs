//! Bench harness (criterion is not in the offline vendor set).
//!
//! Each `benches/*.rs` target uses `harness = false` and drives this
//! runner. It provides warmup + timed iterations with outlier-robust
//! summary statistics, renders ASCII tables, and persists machine-readable
//! results under `bench_out/<bench>/<series>.{json,csv}` so EXPERIMENTS.md
//! can reference stable files.

use std::path::{Path, PathBuf};
use std::time::Instant;

use super::stats::Sample;
use super::table::Table;
use crate::jobj;
use crate::util::json::{Json, JsonObj};

/// Timing summary for one measured closure.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Measurement {
    pub fn to_json(&self) -> Json {
        jobj! {
            "name" => self.name.as_str(),
            "iters" => self.iters,
            "mean_s" => self.mean_s,
            "median_s" => self.median_s,
            "stddev_s" => self.stddev_s,
            "min_s" => self.min_s,
            "max_s" => self.max_s,
        }
    }
}

/// Measure `f` with `warmup` unmeasured and `iters` measured invocations.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut sample = Sample::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        sample.push(t0.elapsed().as_secs_f64());
    }
    Measurement {
        name: name.to_string(),
        iters,
        mean_s: sample.mean(),
        median_s: sample.median(),
        stddev_s: sample.stddev(),
        min_s: sample.min(),
        max_s: sample.max(),
    }
}

/// Auto-scaled measurement: picks an iteration count so total measured time
/// is roughly `target_s`, then measures. Good for very fast bodies.
pub fn measure_auto<F: FnMut()>(name: &str, target_s: f64, mut f: F) -> Measurement {
    // estimate per-call cost
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_s / once) as usize).clamp(3, 10_000);
    measure(name, (iters / 10).max(1), iters, f)
}

/// A bench "report": accumulates named tables (one per figure panel) and
/// writes them to `bench_out/`.
pub struct BenchReport {
    bench_name: String,
    out_dir: PathBuf,
    sections: Vec<(String, Table, Json)>,
}

impl BenchReport {
    pub fn new(bench_name: &str) -> Self {
        let out_dir = std::env::var("CXLFINE_BENCH_OUT")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("bench_out"))
            .join(bench_name);
        Self {
            bench_name: bench_name.to_string(),
            out_dir,
            sections: Vec::new(),
        }
    }

    /// Add a rendered section (table + raw json payload) to the report.
    pub fn section(&mut self, series: &str, table: Table, raw: Json) {
        self.sections.push((series.to_string(), table, raw));
    }

    /// Print all sections to stdout and persist them. Returns output dir.
    pub fn finish(self) -> PathBuf {
        println!("\n=== bench: {} ===", self.bench_name);
        std::fs::create_dir_all(&self.out_dir).ok();
        for (series, table, raw) in &self.sections {
            println!("\n--- {series} ---");
            print!("{}", table.render());
            write_text(&self.out_dir.join(format!("{series}.csv")), &table.to_csv());
            write_text(
                &self.out_dir.join(format!("{series}.json")),
                &raw.to_string_pretty(),
            );
        }
        println!(
            "\n[bench {}] wrote {} series to {}",
            self.bench_name,
            self.sections.len(),
            self.out_dir.display()
        );
        self.out_dir
    }
}

fn write_text(path: &Path, text: &str) {
    if let Err(e) = std::fs::write(path, text) {
        eprintln!("warn: could not write {}: {e}", path.display());
    }
}

/// Helper: a JSON array of {x, <series>: y...} points.
pub fn points_json(xs: &[f64], series: &[(&str, &[f64])]) -> Json {
    let mut arr = Vec::with_capacity(xs.len());
    for (i, &x) in xs.iter().enumerate() {
        let mut o = JsonObj::new();
        o.set("x", x);
        for (name, ys) in series {
            o.set(*name, ys[i]);
        }
        arr.push(Json::Obj(o));
    }
    Json::Arr(arr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iterations() {
        let mut calls = 0usize;
        let m = measure("noop", 2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(m.iters, 5);
        assert!(m.mean_s >= 0.0 && m.min_s <= m.max_s);
    }

    #[test]
    fn measure_auto_bounded() {
        let m = measure_auto("fast", 0.01, || {
            std::hint::black_box(1 + 1);
        });
        assert!(m.iters >= 3);
    }

    #[test]
    fn report_writes_files() {
        let dir = std::env::temp_dir().join(format!("cxlfine_bench_test_{}", std::process::id()));
        std::env::set_var("CXLFINE_BENCH_OUT", &dir);
        let mut r = BenchReport::new("unit");
        let mut t = Table::new(&["a"]);
        t.row(vec!["1".into()]);
        r.section("s1", t, jobj! {"k" => 1u64});
        let out = r.finish();
        assert!(out.join("s1.csv").exists());
        assert!(out.join("s1.json").exists());
        std::fs::remove_dir_all(&dir).ok();
        std::env::remove_var("CXLFINE_BENCH_OUT");
    }

    #[test]
    fn points_json_shape() {
        let j = points_json(&[1.0, 2.0], &[("y", &[10.0, 20.0])]);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[1].path(&["y"]).unwrap().as_f64(), Some(20.0));
    }
}
