//! §Perf microbench: the real Rust CPU Adam hot path on this host.
//!
//! Reports effective bandwidth (28 B moved per element) vs thread count
//! and element count — the L3 optimization target of DESIGN.md §8
//! (≥ 60 % of practical host memory bandwidth at large N).

use cxlfine::optim::{adam_step, adam_step_spawning, AdamHp, AdamState};
use cxlfine::sim::memmodel::ADAM_BYTES_PER_ELEM;
use cxlfine::trow;
use cxlfine::util::bench::{points_json, BenchReport};
use cxlfine::util::table::Table;
use cxlfine::util::threadpool::default_threads;

fn bench_once(n: usize, threads: usize, iters: usize) -> f64 {
    let mut p = vec![1.0f32; n];
    let g: Vec<f32> = (0..n).map(|i| (i as f32 % 7.0) * 0.01).collect();
    let mut st = AdamState::new(n);
    let hp = AdamHp::default();
    adam_step(&mut p, &g, &mut st, &hp, threads); // warm
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        adam_step(&mut p, &g, &mut st, &hp, threads);
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    n as f64 / dt // elements/sec
}

fn main() {
    let mut report = BenchReport::new("adam_hotpath");
    let max_threads = default_threads();

    // ---- thread scaling at a fixed large N ---------------------------
    let n = 50_000_000;
    let mut t = Table::new(&["threads", "Gelem/s", "GB/s moved", "scaling"]);
    let mut threads_list = vec![1usize];
    let mut cur = 2;
    while cur <= max_threads {
        threads_list.push(cur);
        cur *= 2;
    }
    let (mut xs, mut rates) = (vec![], vec![]);
    let mut base = 0.0f64;
    for &threads in &threads_list {
        let eps = bench_once(n, threads, 3);
        if threads == 1 {
            base = eps;
        }
        t.row(trow![
            threads,
            format!("{:.2}", eps / 1e9),
            format!("{:.1}", eps * ADAM_BYTES_PER_ELEM / 1e9),
            format!("{:.2}x", eps / base)
        ]);
        xs.push(threads as f64);
        rates.push(eps);
    }
    let peak = rates.iter().cloned().fold(0.0, f64::max);
    println!(
        "peak: {:.2} Gelem/s = {:.1} GB/s moved ({} threads available)",
        peak / 1e9,
        peak * ADAM_BYTES_PER_ELEM / 1e9,
        max_threads
    );
    assert!(
        peak >= base,
        "adding threads must never lose throughput at 50M elements"
    );
    report.section("thread_scaling_50m", t, points_json(&xs, &[("elem_per_s", &rates)]));

    // ---- size sweep at max threads -----------------------------------
    let mut t2 = Table::new(&["elements", "Gelem/s", "GB/s moved"]);
    let (mut xs2, mut rates2) = (vec![], vec![]);
    for &n in &[1_000_000usize, 5_000_000, 20_000_000, 50_000_000, 100_000_000] {
        let eps = bench_once(n, max_threads, if n <= 5_000_000 { 10 } else { 3 });
        t2.row(trow![
            n,
            format!("{:.2}", eps / 1e9),
            format!("{:.1}", eps * ADAM_BYTES_PER_ELEM / 1e9)
        ]);
        xs2.push(n as f64);
        rates2.push(eps);
    }
    report.section("size_sweep", t2, points_json(&xs2, &[("elem_per_s", &rates2)]));

    // ---- small-N per-step overhead: persistent pool vs spawn-per-step
    // At ≤1M elements the update body is a few hundred µs, so the old
    // spawn-per-step fan-out (~10–30 µs × threads) was a visible tax; the
    // persistent pool pays a condvar wakeup instead.
    let mut t_small = Table::new(&["elements", "pool µs/step", "spawn µs/step", "spawn/pool"]);
    let (mut xs_s, mut pool_us, mut spawn_us) = (vec![], vec![], vec![]);
    for &n in &[65_536usize, 262_144, 1_048_576] {
        let iters = if n <= 262_144 { 200 } else { 50 };
        let time_step = |use_pool: bool| {
            let mut p = vec![1.0f32; n];
            let g: Vec<f32> = (0..n).map(|i| (i as f32 % 5.0) * 0.01).collect();
            let mut st = AdamState::new(n);
            let hp = AdamHp::default();
            let step = |p: &mut [f32], st: &mut AdamState| {
                if use_pool {
                    adam_step(p, &g, st, &hp, max_threads);
                } else {
                    adam_step_spawning(p, &g, st, &hp, max_threads);
                }
            };
            step(&mut p, &mut st); // warm
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                step(&mut p, &mut st);
            }
            t0.elapsed().as_secs_f64() / iters as f64
        };
        let pooled = time_step(true);
        let spawned = time_step(false);
        t_small.row(trow![
            n,
            format!("{:.1}", pooled * 1e6),
            format!("{:.1}", spawned * 1e6),
            format!("{:.2}x", spawned / pooled)
        ]);
        xs_s.push(n as f64);
        pool_us.push(pooled * 1e6);
        spawn_us.push(spawned * 1e6);
    }
    println!(
        "small-N per-step overhead (pool vs spawn at {} threads): see table",
        max_threads
    );
    report.section(
        "small_n_step_overhead",
        t_small,
        points_json(&xs_s, &[("pool_us", &pool_us), ("spawn_us", &spawn_us)]),
    );

    // ---- §Perf iteration log: serial reference vs the tuned chunk ----
    let n = 20_000_000;
    let serial = {
        use cxlfine::optim::adam::adam_update_serial;
        let mut p = vec![1.0f32; n];
        let g = vec![0.1f32; n];
        let (mut m, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
        let hp = AdamHp::default();
        adam_update_serial(&mut p, &g, &mut m, &mut v, &hp, 1);
        let t0 = std::time::Instant::now();
        for s in 2..5u64 {
            adam_update_serial(&mut p, &g, &mut m, &mut v, &hp, s);
        }
        n as f64 * 3.0 / t0.elapsed().as_secs_f64()
    };
    let unrolled = bench_once(n, 1, 3);
    let mut t3 = Table::new(&["variant", "Gelem/s"]).left(0);
    t3.row(trow!["serial reference", format!("{:.2}", serial / 1e9)]);
    t3.row(trow!["hot-path chunk (zipped)", format!("{:.2}", unrolled / 1e9)]);
    println!(
        "serial {:.2} vs hot-path {:.2} Gelem/s ({:+.0}%)",
        serial / 1e9,
        unrolled / 1e9,
        100.0 * (unrolled / serial - 1.0)
    );
    report.section(
        "serial_vs_unrolled_20m",
        t3,
        points_json(&[1.0, 2.0], &[("elem_per_s", &[serial, unrolled])]),
    );
    report.finish();
}
