//! (context, batch) grid sweeps — the machinery behind Figures 9 and 10.
//!
//! For each grid cell the sweep simulates one iteration under each policy
//! and normalizes throughput against the DRAM-only baseline, reproducing
//! the paper's "% of baseline" bars.

use super::iteration::simulate_iteration;
use super::metrics::PhaseBreakdown;
use super::plan::{MemoryPlan, RunConfig};
use crate::mem::Policy;
use crate::model::footprint::Workload;
use crate::model::ModelConfig;
use crate::topology::SystemTopology;

/// One grid cell result.
#[derive(Clone, Debug)]
pub struct GridPoint {
    pub context: usize,
    pub batch: usize,
    /// Breakdown per policy, ordered as the `policies` argument.
    pub runs: Vec<Option<PhaseBreakdown>>,
}

/// A whole sweep.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub model: String,
    pub n_gpus: usize,
    pub policies: Vec<Policy>,
    pub points: Vec<GridPoint>,
}

impl SweepResult {
    /// Normalized throughput of `policy_idx` vs `baseline_idx` at a point
    /// (None if either run did not fit in memory).
    pub fn normalized(&self, point: &GridPoint, policy_idx: usize, baseline_idx: usize) -> Option<f64> {
        let run = point.runs.get(policy_idx)?.as_ref()?;
        let base = point.runs.get(baseline_idx)?.as_ref()?;
        Some(run.relative_to(base))
    }

    /// (min, max) normalized throughput of a policy across all points that
    /// have both runs — the paper's "X %–Y % of baseline" ranges.
    pub fn normalized_range(&self, policy_idx: usize, baseline_idx: usize) -> Option<(f64, f64)> {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut any = false;
        for p in &self.points {
            if let Some(r) = self.normalized(p, policy_idx, baseline_idx) {
                lo = lo.min(r);
                hi = hi.max(r);
                any = true;
            }
        }
        any.then_some((lo, hi))
    }
}

/// Run the grid. Baseline runs use `baseline_topo` (all-DRAM host); policy
/// runs use `policy_topo` (the DRAM-constrained + CXL host). Cells whose
/// plan does not fit are recorded as `None` — exactly the cells the paper
/// could not run without CXL.
pub fn sweep_grid(
    baseline_topo: &SystemTopology,
    policy_topo: &SystemTopology,
    model: &ModelConfig,
    n_gpus: usize,
    contexts: &[usize],
    batches: &[usize],
    policies: &[Policy],
) -> SweepResult {
    let mut points = Vec::new();
    for &c in contexts {
        for &b in batches {
            let w = Workload::new(n_gpus, b, c);
            let mut runs = Vec::with_capacity(policies.len());
            for &policy in policies {
                let topo = if policy == Policy::DramOnly {
                    baseline_topo
                } else {
                    policy_topo
                };
                let cfg = RunConfig::new(model.clone(), w, policy);
                let run = MemoryPlan::build(topo, &cfg)
                    .ok()
                    .map(|plan| simulate_iteration(topo, &cfg, &plan));
                runs.push(run);
            }
            points.push(GridPoint {
                context: c,
                batch: b,
                runs,
            });
        }
    }
    SweepResult {
        model: model.name.clone(),
        n_gpus,
        policies: policies.to_vec(),
        points,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets::qwen25_7b;
    use crate::topology::presets::{config_a, with_dram_capacity};
    use crate::util::units::GIB;

    #[test]
    fn fig9a_band_shape() {
        // Small slice of the Fig. 9a grid; check the paper's ordering and
        // that "ours" lands close to baseline.
        let base = config_a();
        let cxl = with_dram_capacity(config_a(), 128 * GIB);
        let policies = [
            Policy::DramOnly,
            Policy::NaiveInterleave,
            Policy::CxlAware { striping: false },
        ];
        let res = sweep_grid(
            &base,
            &cxl,
            &qwen25_7b(),
            1,
            &[4096, 8192],
            &[4, 8],
            &policies,
        );
        assert_eq!(res.points.len(), 4);
        let (nlo, nhi) = res.normalized_range(1, 0).unwrap();
        let (olo, ohi) = res.normalized_range(2, 0).unwrap();
        assert!(nhi < 1.0, "naive never reaches baseline: {nhi}");
        assert!(olo > nlo, "ours lower bound beats naive's: {olo} vs {nlo}");
        assert!(ohi > 0.94, "ours upper bound near baseline: {ohi}");
    }

    #[test]
    fn unfittable_cells_are_none() {
        // Force baseline OOM with a tiny DRAM-only machine.
        let tiny_base = with_dram_capacity(config_a(), 8 * GIB);
        let cxl = with_dram_capacity(config_a(), 128 * GIB);
        let res = sweep_grid(
            &tiny_base,
            &cxl,
            &qwen25_7b(),
            1,
            &[4096],
            &[8],
            &[Policy::DramOnly, Policy::CxlAware { striping: false }],
        );
        assert!(res.points[0].runs[0].is_none(), "baseline must OOM");
        assert!(res.points[0].runs[1].is_some(), "CXL plan must fit");
        assert!(res.normalized_range(1, 0).is_none());
    }
}
