//! Phase/throughput metrics for iteration runs (the Fig. 7/9/10 quantities).

use crate::jobj;
use crate::util::json::Json;

/// Wall-clock breakdown of one training iteration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PhaseBreakdown {
    /// Forward phase (parameter streaming + kernels + checkpoint offload).
    pub fwd_s: f64,
    /// Backward phase (reloads + recompute + backward + gradient offload).
    pub bwd_s: f64,
    /// CPU optimizer update + bf16 parameter cast.
    pub step_s: f64,
    /// End-to-end iteration time.
    pub iter_s: f64,
    /// Tokens processed this iteration (all GPUs).
    pub tokens: u64,
}

impl PhaseBreakdown {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.iter_s
    }

    /// Throughput relative to a baseline run (the paper's normalized %).
    pub fn relative_to(&self, baseline: &PhaseBreakdown) -> f64 {
        self.tokens_per_sec() / baseline.tokens_per_sec()
    }

    /// Phase share of the iteration, (fwd, bwd, step) fractions.
    ///
    /// Only meaningful when the three phases *partition* the iteration
    /// (`fwd_s + bwd_s + step_s == iter_s`), which the boundary-based
    /// legacy decomposition guarantees by construction. Generalized
    /// schedules (gradient accumulation, overlapping micro-batches) break
    /// that assumption — use [`PhaseReport::shares`], which measures each
    /// phase's trace extent and is explicit about overlap, instead of
    /// assuming these three fractions sum to one.
    pub fn shares(&self) -> (f64, f64, f64) {
        (
            self.fwd_s / self.iter_s,
            self.bwd_s / self.iter_s,
            self.step_s / self.iter_s,
        )
    }

    /// Whether the triple actually partitions the iteration (the premise
    /// of [`PhaseBreakdown::shares`]).
    pub fn is_partition(&self) -> bool {
        ((self.fwd_s + self.bwd_s + self.step_s) - self.iter_s).abs() <= 1e-9 * self.iter_s.abs()
    }

    pub fn to_json(&self) -> Json {
        jobj! {
            "fwd_s" => self.fwd_s,
            "bwd_s" => self.bwd_s,
            "step_s" => self.step_s,
            "iter_s" => self.iter_s,
            "tokens" => self.tokens,
            "tokens_per_sec" => self.tokens_per_sec(),
        }
    }
}

/// One named phase of a generalized schedule, measured from the executed
/// trace rather than assumed boundaries.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseSpan {
    pub name: String,
    /// Earliest span start attributed to the phase (0 if it emitted none).
    pub start_s: f64,
    /// Latest span end attributed to the phase.
    pub end_s: f64,
    /// Sum of span durations attributed to the phase. Spans inside one
    /// phase overlap freely (transfer/compute overlap is the whole point),
    /// so this can exceed `extent_s`.
    pub busy_s: f64,
    /// Completion time of the phase's designated boundary nodes (the
    /// legacy FWD/BWD/STEP semantics); falls back to `end_s` when the
    /// schedule marks none.
    pub boundary_s: f64,
}

impl PhaseSpan {
    /// Wall-clock window the phase was active, `end - start`.
    pub fn extent_s(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// Generalized per-phase timing of one executed schedule: named phases
/// (not hardwired fwd/bwd/step), measured from trace extents so phases may
/// overlap — gradient accumulation interleaves `fwd` and `bwd` windows,
/// and `Σ extent > iter_s` is then expected, not an accounting bug.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseReport {
    /// Phases in schedule declaration order.
    pub phases: Vec<PhaseSpan>,
    /// End-to-end schedule time (last node completion).
    pub iter_s: f64,
    /// Tokens processed (all GPUs, all micro-batches).
    pub tokens: u64,
}

impl PhaseReport {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.iter_s
    }

    pub fn phase(&self, name: &str) -> Option<&PhaseSpan> {
        self.phases.iter().find(|p| p.name == name)
    }

    /// Per-phase extent share of the iteration. Unlike
    /// [`PhaseBreakdown::shares`] this does NOT assume phases partition the
    /// iteration: overlapping phases each report their full extent and the
    /// total may exceed 1.
    pub fn shares(&self) -> Vec<(String, f64)> {
        self.phases
            .iter()
            .map(|p| (p.name.clone(), p.extent_s() / self.iter_s))
            .collect()
    }

    /// Do two named phases overlap in wall-clock time?
    pub fn overlaps(&self, a: &str, b: &str) -> bool {
        match (self.phase(a), self.phase(b)) {
            (Some(x), Some(y)) => x.start_s < y.end_s && y.start_s < x.end_s,
            _ => false,
        }
    }

    /// Legacy triple view via phase *boundaries*: exact for schedules whose
    /// `fwd`/`bwd`/`step` boundary nodes partition time (the ZeRO-Offload
    /// builder reproduces the pre-IR engine bit-for-bit through this), and
    /// a boundary-ordered approximation for anything else.
    pub fn to_breakdown(&self) -> PhaseBreakdown {
        let b_fwd = self.phase("fwd").map(|p| p.boundary_s).unwrap_or(0.0);
        let b_bwd = self.phase("bwd").map(|p| p.boundary_s).unwrap_or(b_fwd);
        PhaseBreakdown {
            fwd_s: b_fwd,
            bwd_s: b_bwd - b_fwd,
            step_s: self.iter_s - b_bwd,
            iter_s: self.iter_s,
            tokens: self.tokens,
        }
    }

    pub fn to_json(&self) -> Json {
        let phases: Vec<Json> = self
            .phases
            .iter()
            .map(|p| {
                jobj! {
                    "name" => p.name.as_str(),
                    "start_s" => p.start_s,
                    "end_s" => p.end_s,
                    "extent_s" => p.extent_s(),
                    "busy_s" => p.busy_s,
                    "boundary_s" => p.boundary_s,
                }
            })
            .collect();
        jobj! {
            "phases" => Json::Arr(phases),
            "iter_s" => self.iter_s,
            "tokens" => self.tokens,
            "tokens_per_sec" => self.tokens_per_sec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bd(fwd: f64, bwd: f64, step: f64, tokens: u64) -> PhaseBreakdown {
        PhaseBreakdown {
            fwd_s: fwd,
            bwd_s: bwd,
            step_s: step,
            iter_s: fwd + bwd + step,
            tokens,
        }
    }

    #[test]
    fn throughput_math() {
        let b = bd(1.0, 2.0, 1.0, 8000);
        assert!((b.tokens_per_sec() - 2000.0).abs() < 1e-9);
        let base = bd(1.0, 1.0, 1.0, 8000);
        assert!((b.relative_to(&base) - 0.75).abs() < 1e-9);
    }

    #[test]
    fn shares_sum_to_one() {
        let b = bd(0.5, 1.5, 0.25, 100);
        let (f, w, s) = b.shares();
        assert!((f + w + s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip() {
        let b = bd(1.0, 2.0, 3.0, 42);
        let j = b.to_json();
        assert_eq!(j.path(&["tokens"]).unwrap().as_u64(), Some(42));
        assert!(j.path(&["tokens_per_sec"]).unwrap().as_f64().unwrap() > 0.0);
    }

    fn span(name: &str, start: f64, end: f64, boundary: f64) -> PhaseSpan {
        PhaseSpan {
            name: name.into(),
            start_s: start,
            end_s: end,
            busy_s: end - start,
            boundary_s: boundary,
        }
    }

    #[test]
    fn report_shares_do_not_assume_a_partition() {
        // fwd and bwd extents overlap (a grad-accum-like interleave): the
        // extent shares exceed 1 in total, and overlaps() sees it.
        let r = PhaseReport {
            phases: vec![
                span("fwd", 0.0, 6.0, 6.0),
                span("bwd", 2.0, 9.0, 9.0),
                span("step", 9.0, 10.0, 10.0),
            ],
            iter_s: 10.0,
            tokens: 100,
        };
        assert!(r.overlaps("fwd", "bwd"));
        assert!(!r.overlaps("fwd", "step"));
        let total: f64 = r.shares().iter().map(|(_, s)| s).sum();
        assert!(total > 1.0, "overlapping extents must exceed 1: {total}");
        // the naive triple view built from the same report would claim a
        // partition — is_partition() exposes that it still sums by
        // construction, while the extent view reports the real overlap
        let bd = r.to_breakdown();
        assert!(bd.is_partition());
        assert!((bd.fwd_s - 6.0).abs() < 1e-12);
        assert!((bd.bwd_s - 3.0).abs() < 1e-12);
        assert!((bd.step_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn report_breakdown_handles_missing_phases() {
        let r = PhaseReport {
            phases: vec![span("warmup", 0.0, 4.0, 4.0)],
            iter_s: 4.0,
            tokens: 8,
        };
        let bd = r.to_breakdown();
        assert_eq!(bd.fwd_s, 0.0);
        assert_eq!(bd.bwd_s, 0.0);
        assert!((bd.step_s - 4.0).abs() < 1e-12);
        assert_eq!(bd.tokens, 8);
    }

    #[test]
    fn report_json_shape() {
        let r = PhaseReport {
            phases: vec![span("fwd", 0.0, 1.0, 1.0), span("step", 1.0, 2.0, 2.0)],
            iter_s: 2.0,
            tokens: 10,
        };
        let j = r.to_json();
        let phases = j.path(&["phases"]).unwrap().as_arr().unwrap();
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0].path(&["name"]).unwrap().as_str(), Some("fwd"));
        assert_eq!(phases[1].path(&["extent_s"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.path(&["tokens"]).unwrap().as_u64(), Some(10));
    }

    #[test]
    fn partition_detector() {
        assert!(bd(1.0, 2.0, 1.0, 1).is_partition());
        let skew = PhaseBreakdown {
            fwd_s: 1.0,
            bwd_s: 2.0,
            step_s: 1.0,
            iter_s: 3.5, // overlapping phases: triple no longer partitions
            tokens: 1,
        };
        assert!(!skew.is_partition());
    }
}
