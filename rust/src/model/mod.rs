//! Transformer model descriptions: architecture presets, the Table-I
//! system-memory footprint model, and the FLOPs model that feeds GPU
//! compute times in the simulator.

pub mod flops;
pub mod footprint;
pub mod presets;

/// Decoder-only transformer architecture (GQA, gated MLP — the Qwen2.5 /
/// Mistral-NeMo family shape).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Number of transformer blocks (Table I's `L`).
    pub layers: usize,
    /// Hidden size (`H`).
    pub hidden: usize,
    /// Attention heads.
    pub heads: usize,
    /// KV heads (grouped-query attention).
    pub kv_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// Gated-MLP intermediate size.
    pub ffn_hidden: usize,
    /// Vocabulary size (`V`).
    pub vocab: usize,
    /// Whether input embedding and LM head share weights.
    pub tie_embeddings: bool,
}

impl ModelConfig {
    /// Parameters in one attention block (q/k/v/o projections).
    pub fn attn_params(&self) -> u64 {
        let h = self.hidden as u64;
        let qo = self.heads as u64 * self.head_dim as u64;
        let kv = self.kv_heads as u64 * self.head_dim as u64;
        h * qo      // Wq
            + h * kv // Wk
            + h * kv // Wv
            + qo * h // Wo
    }

    /// Parameters in one gated MLP (gate, up, down).
    pub fn mlp_params(&self) -> u64 {
        3 * self.hidden as u64 * self.ffn_hidden as u64
    }

    /// Norm parameters per block (two RMSNorms).
    pub fn norm_params(&self) -> u64 {
        2 * self.hidden as u64
    }

    /// Parameters per transformer block.
    pub fn block_params(&self) -> u64 {
        self.attn_params() + self.mlp_params() + self.norm_params()
    }

    /// Embedding (and untied LM head) parameters, plus final norm.
    pub fn embedding_params(&self) -> u64 {
        let e = self.vocab as u64 * self.hidden as u64;
        let head = if self.tie_embeddings { 0 } else { e };
        e + head + self.hidden as u64
    }

    /// Total parameter count (Table I's `P`).
    pub fn params(&self) -> u64 {
        self.layers as u64 * self.block_params() + self.embedding_params()
    }

    /// Short human label like "12.2B".
    pub fn params_label(&self) -> String {
        let p = self.params() as f64;
        if p >= 1e9 {
            format!("{:.1}B", p / 1e9)
        } else if p >= 1e6 {
            format!("{:.1}M", p / 1e6)
        } else {
            format!("{:.0}K", p / 1e3)
        }
    }

    pub fn validate(&self) {
        assert!(self.layers > 0 && self.hidden > 0 && self.vocab > 0);
        assert_eq!(
            self.hidden % self.heads,
            0,
            "hidden must divide evenly into heads for this family"
        );
        assert!(
            self.heads % self.kv_heads == 0,
            "GQA requires kv_heads | heads"
        );
        assert!(self.head_dim > 0 && self.ffn_hidden > 0);
    }
}

#[cfg(test)]
mod tests {
    use super::presets::*;

    #[test]
    fn qwen25_7b_param_count() {
        let m = qwen25_7b();
        m.validate();
        let p = m.params() as f64 / 1e9;
        // Qwen2.5-7B is 7.6B total parameters.
        assert!((7.4..7.8).contains(&p), "qwen param count {p}B");
    }

    #[test]
    fn mistral_nemo_12b_param_count() {
        let m = mistral_nemo_12b();
        m.validate();
        let p = m.params() as f64 / 1e9;
        // Mistral NeMo is 12.2B total parameters.
        assert!((11.9..12.6).contains(&p), "nemo param count {p}B");
    }

    #[test]
    fn tiny_is_small() {
        let m = tiny_20m();
        m.validate();
        assert!(m.params() < 40_000_000);
    }

    #[test]
    fn block_params_dominated_by_mlp() {
        let m = qwen25_7b();
        assert!(m.mlp_params() > m.attn_params());
    }

    #[test]
    fn by_name_resolves() {
        assert_eq!(by_name("7b").unwrap().name, qwen25_7b().name);
        assert_eq!(by_name("12b").unwrap().name, mistral_nemo_12b().name);
        assert!(by_name("tiny").is_some());
        assert!(by_name("nope").is_none());
    }
}
