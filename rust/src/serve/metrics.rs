//! Serving metrics: per-request records, TTFT / TPOT latency statistics,
//! per-tier KV occupancy curves, bitwise digests and the
//! digest-self-certifying JSON form (the serving analogue of
//! `fleet::metrics`).

use crate::fleet::OccupancySample;
use crate::jobj;
use crate::serve::kv::KvCounters;
use crate::topology::SystemTopology;
use crate::trow;
use crate::util::digest::Fnv64;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::util::units::fmt_bytes;

/// Lifecycle state of a request. `Queued`/`Running` are transient; a
/// finished simulation leaves only `Completed`, `Rejected` and `Shed`
/// (asserted by the serving invariant tests). A request truncated by KV
/// exhaustion still *completes* — the truncation rides the record flag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestStatus {
    Queued,
    Running,
    Completed,
    /// Never admitted: its full KV footprint exceeds what the policy's
    /// tiers can ever hold.
    Rejected,
    /// Dropped from the queue by the SLO-aware admission policy.
    Shed,
}

impl RequestStatus {
    pub fn name(self) -> &'static str {
        match self {
            RequestStatus::Queued => "queued",
            RequestStatus::Running => "running",
            RequestStatus::Completed => "completed",
            RequestStatus::Rejected => "rejected",
            RequestStatus::Shed => "shed",
        }
    }

    fn code(self) -> u64 {
        match self {
            RequestStatus::Queued => 0,
            RequestStatus::Running => 1,
            RequestStatus::Completed => 2,
            RequestStatus::Rejected => 3,
            RequestStatus::Shed => 4,
        }
    }
}

/// Everything the simulator knows about one request at the end of a run.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub model: String,
    pub prompt_tokens: usize,
    pub max_output_tokens: usize,
    pub slo_ms: f64,
    pub arrival_s: f64,
    /// Admission time (prefill starts here).
    pub start_s: Option<f64>,
    /// End of the step that emitted the first output token.
    pub first_token_s: Option<f64>,
    pub finish_s: Option<f64>,
    /// Output tokens actually generated (< `max_output_tokens` iff
    /// truncated).
    pub output_tokens: u64,
    /// Decode was cut short because the KV cache was exhausted.
    pub truncated: bool,
    pub status: RequestStatus,
    /// Why the request was rejected or shed. `None` for clean lifecycles.
    pub reason: Option<String>,
    /// CXL-resident KV bytes this request's decode steps pulled across
    /// the link (cold-page attention reads).
    pub cold_read_bytes: u64,
}

impl RequestRecord {
    /// Time to first token (the SLO metric); `None` unless prefill ran.
    pub fn ttft_ms(&self) -> Option<f64> {
        Some((self.first_token_s? - self.arrival_s) * 1e3)
    }

    /// Mean time per output token over the decode phase; `None` unless
    /// the request decoded at least two tokens.
    pub fn tpot_ms(&self) -> Option<f64> {
        if self.output_tokens < 2 {
            return None;
        }
        let span = self.finish_s? - self.first_token_s?;
        Some(span * 1e3 / (self.output_tokens - 1) as f64)
    }

    fn fold(&self, h: &mut Fnv64) {
        h.write_u64(self.id);
        h.write_str(&self.model);
        h.write_u64(self.prompt_tokens as u64);
        h.write_u64(self.max_output_tokens as u64);
        h.write_f64(self.slo_ms);
        h.write_f64(self.arrival_s);
        for opt in [self.start_s, self.first_token_s, self.finish_s] {
            match opt {
                Some(v) => {
                    h.write_u64(1);
                    h.write_f64(v);
                }
                None => {
                    h.write_u64(0);
                }
            }
        }
        h.write_u64(self.output_tokens);
        h.write_u64(self.truncated as u64);
        h.write_u64(self.status.code());
        match &self.reason {
            Some(r) => {
                h.write_u64(1);
                h.write_str(r);
            }
            None => {
                h.write_u64(0);
            }
        }
        h.write_u64(self.cold_read_bytes);
    }

    fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        jobj! {
            "id" => self.id,
            "model" => self.model.as_str(),
            "prompt_tokens" => self.prompt_tokens,
            "max_output_tokens" => self.max_output_tokens,
            "slo_ms" => self.slo_ms,
            "arrival_s" => self.arrival_s,
            "start_s" => opt(self.start_s),
            "first_token_s" => opt(self.first_token_s),
            "finish_s" => opt(self.finish_s),
            "ttft_ms" => opt(self.ttft_ms()),
            "tpot_ms" => opt(self.tpot_ms()),
            "output_tokens" => self.output_tokens,
            "truncated" => self.truncated,
            "status" => self.status.name(),
            "reason" => self.reason.as_deref().map(Json::from).unwrap_or(Json::Null),
            "cold_read_bytes" => self.cold_read_bytes,
        }
    }
}

/// The complete outcome of one serving simulation.
#[derive(Clone, Debug)]
pub struct ServeResult {
    pub kv_policy: String,
    pub admission: String,
    pub topology: String,
    pub node_names: Vec<String>,
    pub node_caps: Vec<u64>,
    /// DRAM bytes the pager could give to KV (capacity minus resident
    /// weights and reserve).
    pub dram_kv_budget: u64,
    pub records: Vec<RequestRecord>,
    /// Per-tier KV occupancy after every processed event (same shape as
    /// the fleet curve: used bytes per `NodeId.0`).
    pub samples: Vec<OccupancySample>,
    /// Discrete events processed (arrivals + batch steps).
    pub n_events: u64,
    /// Batch steps executed.
    pub n_steps: u64,
    /// Final pager counters (page conservation + migration traffic).
    pub kv: KvCounters,
}

impl ServeResult {
    pub fn new(kv_policy: &str, admission: &str, topo: &SystemTopology) -> Self {
        Self {
            kv_policy: kv_policy.to_string(),
            admission: admission.to_string(),
            topology: topo.name.clone(),
            node_names: topo.mem_nodes.iter().map(|n| n.name.clone()).collect(),
            node_caps: topo.mem_nodes.iter().map(|n| n.capacity).collect(),
            dram_kv_budget: 0,
            records: Vec::new(),
            samples: Vec::new(),
            n_events: 0,
            n_steps: 0,
            kv: KvCounters::default(),
        }
    }

    pub fn arrived(&self) -> usize {
        self.records.len()
    }

    fn count(&self, s: RequestStatus) -> usize {
        self.records.iter().filter(|r| r.status == s).count()
    }

    pub fn completed(&self) -> usize {
        self.count(RequestStatus::Completed)
    }

    pub fn rejected(&self) -> usize {
        self.count(RequestStatus::Rejected)
    }

    pub fn shed(&self) -> usize {
        self.count(RequestStatus::Shed)
    }

    pub fn truncated(&self) -> usize {
        self.records.iter().filter(|r| r.truncated).count()
    }

    /// Requests still transient when the event heap drained (0 for a
    /// finished simulation — pinned by the invariant tests).
    pub fn unfinished(&self) -> usize {
        self.count(RequestStatus::Queued) + self.count(RequestStatus::Running)
    }

    /// Simulated-clock end of the run: the last completion time.
    pub fn makespan_s(&self) -> f64 {
        self.records
            .iter()
            .filter_map(|r| r.finish_s)
            .fold(0.0, f64::max)
    }

    /// TTFTs of all completed requests, milliseconds.
    pub fn ttfts_ms(&self) -> Vec<f64> {
        self.records
            .iter()
            .filter(|r| r.status == RequestStatus::Completed)
            .filter_map(RequestRecord::ttft_ms)
            .collect()
    }

    pub fn mean_ttft_ms(&self) -> Option<f64> {
        let xs = self.ttfts_ms();
        (!xs.is_empty()).then(|| xs.iter().sum::<f64>() / xs.len() as f64)
    }

    pub fn p99_ttft_ms(&self) -> Option<f64> {
        Self::p99(self.ttfts_ms())
    }

    /// TPOTs of all completed multi-token requests, milliseconds.
    pub fn tpots_ms(&self) -> Vec<f64> {
        self.records
            .iter()
            .filter(|r| r.status == RequestStatus::Completed)
            .filter_map(RequestRecord::tpot_ms)
            .collect()
    }

    pub fn mean_tpot_ms(&self) -> Option<f64> {
        let xs = self.tpots_ms();
        (!xs.is_empty()).then(|| xs.iter().sum::<f64>() / xs.len() as f64)
    }

    pub fn p99_tpot_ms(&self) -> Option<f64> {
        Self::p99(self.tpots_ms())
    }

    fn p99(mut xs: Vec<f64>) -> Option<f64> {
        if xs.is_empty() {
            return None;
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((xs.len() - 1) as f64 * 0.99).round() as usize;
        Some(xs[idx])
    }

    /// The headline serving metric: completed requests per simulated
    /// second over the makespan.
    pub fn sustained_req_per_s(&self) -> f64 {
        let span = self.makespan_s();
        if span > 0.0 {
            self.completed() as f64 / span
        } else {
            0.0
        }
    }

    /// Output tokens generated by completed requests.
    pub fn generated_tokens(&self) -> u64 {
        self.records
            .iter()
            .filter(|r| r.status == RequestStatus::Completed)
            .map(|r| r.output_tokens)
            .sum()
    }

    pub fn generated_tokens_per_sec(&self) -> f64 {
        let span = self.makespan_s();
        if span > 0.0 {
            self.generated_tokens() as f64 / span
        } else {
            0.0
        }
    }

    /// Fraction of completed requests whose TTFT met their SLO.
    pub fn slo_attainment(&self) -> f64 {
        let done: Vec<&RequestRecord> = self
            .records
            .iter()
            .filter(|r| r.status == RequestStatus::Completed)
            .collect();
        if done.is_empty() {
            return 1.0;
        }
        let met = done
            .iter()
            .filter(|r| r.ttft_ms().is_some_and(|t| t <= r.slo_ms))
            .count();
        met as f64 / done.len() as f64
    }

    /// Total CXL cold-page attention traffic across all requests.
    pub fn cold_read_bytes(&self) -> u64 {
        self.records.iter().map(|r| r.cold_read_bytes).sum()
    }

    pub fn max_queue_len(&self) -> usize {
        self.samples.iter().map(|s| s.queue_len).max().unwrap_or(0)
    }

    /// Peak KV bytes on a node across the whole run.
    pub fn peak_used(&self, node: usize) -> u64 {
        self.samples.iter().map(|s| s.used[node]).max().unwrap_or(0)
    }

    /// Time-weighted mean KV occupancy of a node.
    pub fn mean_used(&self, node: usize) -> f64 {
        if self.samples.len() < 2 {
            return self
                .samples
                .first()
                .map(|s| s.used[node] as f64)
                .unwrap_or(0.0);
        }
        let mut acc = 0.0;
        let mut span = 0.0;
        for w in self.samples.windows(2) {
            let dt = w[1].t_s - w[0].t_s;
            acc += w[0].used[node] as f64 * dt;
            span += dt;
        }
        if span > 0.0 {
            acc / span
        } else {
            self.samples[0].used[node] as f64
        }
    }

    /// Bit-exact FNV-1a digest of the whole result — per-request records,
    /// occupancy curve, pager counters and event counts. The determinism
    /// contract: reruns and different `--threads` settings must reproduce
    /// it exactly.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_str(&self.kv_policy);
        h.write_str(&self.admission);
        h.write_str(&self.topology);
        h.write_u64(self.node_caps.len() as u64);
        for c in &self.node_caps {
            h.write_u64(*c);
        }
        h.write_u64(self.dram_kv_budget);
        h.write_u64(self.records.len() as u64);
        for r in &self.records {
            r.fold(&mut h);
        }
        h.write_u64(self.samples.len() as u64);
        for s in &self.samples {
            h.write_f64(s.t_s);
            for u in &s.used {
                h.write_u64(*u);
            }
            h.write_u64(s.queue_len as u64);
            h.write_u64(s.running as u64);
        }
        h.write_u64(self.n_events);
        h.write_u64(self.n_steps);
        h.write_u64(self.kv.allocated_pages);
        h.write_u64(self.kv.freed_pages);
        h.write_u64(self.kv.evicted_pages);
        h.write_u64(self.kv.demoted_bytes);
        h.write_u64(self.kv.promoted_bytes);
        h.finish()
    }

    /// Machine-readable form (written by `cxlfine serve --json`):
    /// summary, per-node KV occupancy statistics, the full per-request
    /// record set and the occupancy curve, digest-self-certifying like
    /// `FleetResult::to_json`.
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        let nodes: Vec<Json> = self
            .node_names
            .iter()
            .enumerate()
            .map(|(i, name)| {
                jobj! {
                    "name" => name.as_str(),
                    "capacity" => self.node_caps[i],
                    "peak_kv" => self.peak_used(i),
                    "mean_kv" => self.mean_used(i),
                }
            })
            .collect();
        let requests: Vec<Json> = self.records.iter().map(RequestRecord::to_json).collect();
        let occupancy: Vec<Json> = self
            .samples
            .iter()
            .map(|s| {
                let used: Vec<Json> = s.used.iter().map(|&u| Json::from(u)).collect();
                jobj! {
                    "t_s" => s.t_s,
                    "used" => Json::Arr(used),
                    "queue_len" => s.queue_len,
                    "running" => s.running,
                }
            })
            .collect();
        jobj! {
            "kv_policy" => self.kv_policy.as_str(),
            "admission" => self.admission.as_str(),
            "topology" => self.topology.as_str(),
            "digest" => format!("{:016x}", self.digest()),
            "summary" => jobj! {
                "arrived" => self.arrived(),
                "completed" => self.completed(),
                "rejected" => self.rejected(),
                "shed" => self.shed(),
                "truncated" => self.truncated(),
                "unfinished" => self.unfinished(),
                "makespan_s" => self.makespan_s(),
                "sustained_req_per_s" => self.sustained_req_per_s(),
                "mean_ttft_ms" => opt(self.mean_ttft_ms()),
                "p99_ttft_ms" => opt(self.p99_ttft_ms()),
                "mean_tpot_ms" => opt(self.mean_tpot_ms()),
                "p99_tpot_ms" => opt(self.p99_tpot_ms()),
                "slo_attainment" => self.slo_attainment(),
                "generated_tokens" => self.generated_tokens(),
                "generated_tokens_per_sec" => self.generated_tokens_per_sec(),
                "cold_read_bytes" => self.cold_read_bytes(),
                "max_queue_len" => self.max_queue_len(),
                "dram_kv_budget" => self.dram_kv_budget,
                "kv_allocated_pages" => self.kv.allocated_pages,
                "kv_freed_pages" => self.kv.freed_pages,
                "kv_evicted_pages" => self.kv.evicted_pages,
                "kv_demoted_bytes" => self.kv.demoted_bytes,
                "kv_promoted_bytes" => self.kv.promoted_bytes,
                "n_events" => self.n_events,
                "n_steps" => self.n_steps,
            },
            "nodes" => Json::Arr(nodes),
            "requests" => Json::Arr(requests),
            "occupancy" => Json::Arr(occupancy),
        }
    }

    /// The serving summary (rendered by `cxlfine serve`).
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new(&["metric", "value"]).left(0);
        t.row(trow!["requests arrived", self.arrived()]);
        t.row(trow!["requests completed", self.completed()]);
        t.row(trow!["requests rejected", self.rejected()]);
        t.row(trow!["requests shed", self.shed()]);
        t.row(trow!["requests truncated", self.truncated()]);
        t.row(trow!["max queue length", self.max_queue_len()]);
        t.row(trow!["makespan", format!("{:.1}s", self.makespan_s())]);
        t.row(trow![
            "sustained throughput",
            format!("{:.3} req/s", self.sustained_req_per_s())
        ]);
        let ms = |v: Option<f64>| v.map(|x| format!("{x:.1}ms")).unwrap_or_else(|| "-".into());
        t.row(trow!["mean TTFT", ms(self.mean_ttft_ms())]);
        t.row(trow!["p99 TTFT", ms(self.p99_ttft_ms())]);
        t.row(trow!["mean TPOT", ms(self.mean_tpot_ms())]);
        t.row(trow!["p99 TPOT", ms(self.p99_tpot_ms())]);
        t.row(trow![
            "SLO attainment",
            format!("{:.1}%", 100.0 * self.slo_attainment())
        ]);
        t.row(trow![
            "decode throughput",
            format!("{:.0} tok/s", self.generated_tokens_per_sec())
        ]);
        t.row(trow![
            "KV demoted",
            fmt_bytes(self.kv.demoted_bytes)
        ]);
        t.row(trow![
            "KV promoted",
            fmt_bytes(self.kv.promoted_bytes)
        ]);
        t.row(trow![
            "cold KV reads",
            fmt_bytes(self.cold_read_bytes())
        ]);
        t.row(trow!["events processed", self.n_events]);
        t
    }

    /// Per-request rejection / shed reasons (rendered when any request
    /// carries one).
    pub fn reasons_table(&self) -> Option<Table> {
        let mut t = Table::new(&["request", "status", "reason"]).left(2);
        let mut any = false;
        for r in &self.records {
            if let Some(reason) = &r.reason {
                t.row(trow![r.id, r.status.name(), reason.clone()]);
                any = true;
            }
        }
        any.then_some(t)
    }

    /// Per-tier KV occupancy statistics (rendered by `cxlfine serve`).
    pub fn occupancy_table(&self) -> Table {
        let mut t = Table::new(&["node", "capacity", "peak KV", "peak %", "mean KV"]).left(0);
        for (i, name) in self.node_names.iter().enumerate() {
            let peak = self.peak_used(i);
            let cap = if i == 0 {
                self.dram_kv_budget.max(1)
            } else {
                self.node_caps[i]
            };
            t.row(trow![
                name.clone(),
                fmt_bytes(cap),
                fmt_bytes(peak),
                format!("{:.1}%", 100.0 * peak as f64 / cap.max(1) as f64),
                fmt_bytes(self.mean_used(i) as u64)
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::dev_tiny;

    fn record(id: u64, arrival: f64, finish: Option<f64>, out: u64) -> RequestRecord {
        RequestRecord {
            id,
            model: "tiny-2m".into(),
            prompt_tokens: 512,
            max_output_tokens: out as usize,
            slo_ms: 2000.0,
            arrival_s: arrival,
            start_s: finish.map(|_| arrival + 0.1),
            first_token_s: finish.map(|_| arrival + 0.5),
            finish_s: finish,
            output_tokens: if finish.is_some() { out } else { 0 },
            truncated: false,
            status: if finish.is_some() {
                RequestStatus::Completed
            } else {
                RequestStatus::Rejected
            },
            reason: finish
                .is_none()
                .then(|| "kv footprint exceeds tier capacity".to_string()),
            cold_read_bytes: if finish.is_some() { 1 << 20 } else { 0 },
        }
    }

    fn result() -> ServeResult {
        let topo = dev_tiny();
        let mut r = ServeResult::new("tiered:4", "fcfs", &topo);
        r.dram_kv_budget = 4 << 30;
        r.records = vec![
            record(0, 0.0, Some(10.0), 64),
            record(1, 2.0, Some(4.0), 2),
            record(2, 3.0, None, 64),
        ];
        r.samples = vec![
            OccupancySample { t_s: 0.0, used: vec![100, 0, 0], queue_len: 0, running: 1 },
            OccupancySample { t_s: 2.0, used: vec![300, 50, 0], queue_len: 1, running: 2 },
            OccupancySample { t_s: 10.0, used: vec![0, 0, 0], queue_len: 0, running: 0 },
        ];
        r.n_events = 7;
        r.n_steps = 4;
        r.kv = KvCounters {
            allocated_pages: 10,
            freed_pages: 10,
            evicted_pages: 0,
            demoted_bytes: 2 << 20,
            promoted_bytes: 1 << 20,
        };
        r
    }

    #[test]
    fn summary_statistics() {
        let r = result();
        assert_eq!(r.arrived(), 3);
        assert_eq!(r.completed(), 2);
        assert_eq!(r.rejected(), 1);
        assert_eq!(r.unfinished(), 0);
        assert_eq!(r.makespan_s(), 10.0);
        // TTFT is 500ms for both completions.
        assert!((r.mean_ttft_ms().unwrap() - 500.0).abs() < 1e-9);
        assert!((r.p99_ttft_ms().unwrap() - 500.0).abs() < 1e-9);
        // Request 0: (10 − 0.5)s over 63 inter-token gaps.
        let tpot0 = 9.5e3 / 63.0;
        // Request 1: (4 − 2.5)s over 1 gap = 1500ms.
        assert!((r.mean_tpot_ms().unwrap() - (tpot0 + 1500.0) / 2.0).abs() < 1e-9);
        assert!((r.sustained_req_per_s() - 0.2).abs() < 1e-12);
        assert_eq!(r.generated_tokens(), 66);
        assert_eq!(r.slo_attainment(), 1.0);
        assert_eq!(r.cold_read_bytes(), 2 << 20);
        assert_eq!(r.max_queue_len(), 1);
        assert_eq!(r.peak_used(0), 300);
        // time-weighted: 100·2 + 300·8 over 10s = 260
        assert!((r.mean_used(0) - 260.0).abs() < 1e-12);
        assert_eq!(r.kv.resident_pages(), 0);
    }

    #[test]
    fn digest_is_stable_and_sensitive() {
        let a = result();
        let b = result();
        assert_eq!(a.digest(), b.digest());
        let mut c = result();
        c.records[1].finish_s = Some(4.000001);
        assert_ne!(a.digest(), c.digest(), "a float wiggle must change it");
        let mut d = result();
        d.samples[1].used[1] = 51;
        assert_ne!(a.digest(), d.digest());
        let mut e = result();
        e.kv.demoted_bytes += 1;
        assert_ne!(a.digest(), e.digest(), "pager traffic is digest-material");
        let mut f = result();
        f.records[0].truncated = true;
        assert_ne!(a.digest(), f.digest());
        let mut g = result();
        g.kv_policy = "dram-only".into();
        assert_ne!(a.digest(), g.digest());
    }

    #[test]
    fn slo_misses_and_truncation_flow_into_the_summary() {
        let mut r = result();
        // Request 0 misses its SLO once TTFT > 2000ms.
        r.records[0].first_token_s = Some(2.5);
        assert!((r.slo_attainment() - 0.5).abs() < 1e-12);
        r.records[1].truncated = true;
        r.records[2].status = RequestStatus::Shed;
        r.records[2].reason = Some("projected TTFT exceeds SLO".into());
        assert_eq!(r.shed(), 1);
        assert_eq!(r.rejected(), 0);
        assert_eq!(r.truncated(), 1);
        let s = r.summary_table().render();
        assert!(s.contains("requests shed") && s.contains("SLO attainment"), "{s}");
        let reasons = r.reasons_table().expect("reasons present").render();
        assert!(reasons.contains("projected TTFT"), "{reasons}");
    }

    #[test]
    fn json_is_parseable_and_self_certifying() {
        let r = result();
        let text = r.to_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(
            parsed.path(&["digest"]).unwrap().as_str(),
            Some(format!("{:016x}", r.digest()).as_str())
        );
        assert_eq!(
            parsed.path(&["summary", "completed"]).unwrap().as_u64(),
            Some(2)
        );
        assert_eq!(
            parsed.path(&["summary", "kv_demoted_bytes"]).unwrap().as_u64(),
            Some(2 << 20)
        );
        let reqs = parsed.path(&["requests"]).unwrap().as_arr().unwrap();
        assert_eq!(reqs.len(), 3);
        assert_eq!(reqs[2].path(&["status"]).unwrap().as_str(), Some("rejected"));
        assert!(matches!(reqs[2].path(&["finish_s"]), Some(Json::Null)));
        let occ = parsed.path(&["occupancy"]).unwrap().as_arr().unwrap();
        assert_eq!(occ.len(), 3);
        // Tables render every tier.
        let o = r.occupancy_table().render();
        assert!(o.contains("dram") && o.contains("cxl1"), "{o}");
    }
}
