//! Topology presets: the paper's Table II platform in its two CXL
//! configurations, plus a small synthetic machine for tests/examples.
//!
//! Calibration sources (DESIGN.md §6):
//! * DRAM / CXL load-to-use latency midpoints of Fig. 4's ranges.
//! * PCIe Gen5 ×16: 64 GB/s per direction; ~85 % achievable by one stream.
//! * Contended CXL AIC (two concurrent GPU DMA streams): aggregate
//!   ~25 GiB/s (Fig. 6b) → contended_eff ≈ 0.42.
//! * CPU read-modify-write streams against a CXL AIC sustain far below
//!   link rate (CXL.mem round-trip limits per-core MLP): ~26 GB/s vs
//!   ~110 GB/s against local DRAM → the ~4× optimizer inflation of Fig. 5.
//! * Xeon 6780E: 144 E-cores, 108 MB LLC. H100 PCIe: 756 TFLOP/s bf16.

use super::*;
use crate::util::units::{GB, GIB, MIB};

/// Shared CPU description (Table II: 1× Intel Xeon 6780E).
fn xeon_6780e() -> CpuSpec {
    CpuSpec {
        name: "Intel Xeon 6780E".into(),
        cores: 144,
        llc_bytes: 108 * MIB,
        // Cache-resident vectorized Adam: calibrated so the large-N
        // DRAM-resident optimizer is ~25 % memory-stalled (Fig. 5 DRAM line
        // rises gently) and CXL reaches ~4× at ≥ 20 M elements.
        adam_compute_ns_per_elem: 0.26,
        optimizer_threads: 64,
    }
}

fn local_dram(capacity: u64) -> MemNodeSpec {
    MemNodeSpec {
        name: "dram".into(),
        kind: MemKind::LocalDram,
        capacity,
        latency_ns: 105.0,                    // Fig. 4: 80–140 ns
        peak_bw: 204.8 * GB as f64,           // 4 × DDR5-6400
        cpu_stream_bw: 110.0 * GB as f64,     // sustained RMW stream
        link: None,
    }
}

/// CXL AIC behind its own Gen5 ×16 link.
fn cxl_aic(name: &str, capacity: u64, link: LinkId) -> MemNodeSpec {
    MemNodeSpec {
        name: name.into(),
        kind: MemKind::CxlAic,
        capacity,
        latency_ns: 210.0,                // Fig. 4: 170–250 ns
        peak_bw: 64.0 * GB as f64,        // link-bound for DMA
        cpu_stream_bw: 26.0 * GB as f64,  // CXL.mem CPU loads/stores
        link: Some(link),
    }
}

fn cxl_link(name: &str) -> LinkSpec {
    LinkSpec {
        name: name.into(),
        per_dir_bw: 64.0 * GB as f64,
        single_stream_eff: 0.85,
        // Fig. 6b: two concurrent GPU DMA streams on one AIC collapse to
        // ~25 GiB/s aggregate: 64 GB/s × 0.42 ≈ 26.9 GB/s ≈ 25.0 GiB/s.
        contended_eff: 0.42,
    }
}

fn h100_pcie(idx: usize, link: LinkId) -> GpuSpec {
    GpuSpec {
        name: format!("H100-PCIe-{idx}"),
        bf16_flops: 756e12,
        mfu: 0.38,
        hbm_bytes: 80 * GIB,
        link,
    }
}

/// Table II, Config A: 512 GB DRAM + 1 × 512 GB AIC (CXA-8F2W), 2 × H100.
///
/// Links: 0,1 = GPUs; 2 = the AIC.
pub fn config_a() -> SystemTopology {
    let t = SystemTopology {
        name: "config-a (1x512GB AIC)".into(),
        cpu: xeon_6780e(),
        mem_nodes: vec![
            local_dram(512 * GIB),
            cxl_aic("cxl0 (CXA-8F2W)", 512 * GIB, LinkId(2)),
        ],
        links: vec![
            LinkSpec::pcie_gen5_x16("gpu0-link"),
            LinkSpec::pcie_gen5_x16("gpu1-link"),
            cxl_link("cxl0-link"),
        ],
        gpus: vec![h100_pcie(0, LinkId(0)), h100_pcie(1, LinkId(1))],
    };
    t.validate();
    t
}

/// Table II, Config B: 512 GB DRAM + 2 × 256 GB AICs (CXA-4F1W), 2 × H100.
///
/// Links: 0,1 = GPUs; 2,3 = the AICs.
pub fn config_b() -> SystemTopology {
    let t = SystemTopology {
        name: "config-b (2x256GB AIC)".into(),
        cpu: xeon_6780e(),
        mem_nodes: vec![
            local_dram(512 * GIB),
            cxl_aic("cxl0 (CXA-4F1W)", 256 * GIB, LinkId(2)),
            cxl_aic("cxl1 (CXA-4F1W)", 256 * GIB, LinkId(3)),
        ],
        links: vec![
            LinkSpec::pcie_gen5_x16("gpu0-link"),
            LinkSpec::pcie_gen5_x16("gpu1-link"),
            cxl_link("cxl0-link"),
            cxl_link("cxl1-link"),
        ],
        gpus: vec![h100_pcie(0, LinkId(0)), h100_pcie(1, LinkId(1))],
    };
    t.validate();
    t
}

/// The evaluation's constrained-host variant: the paper's "Naive CXL" and
/// "Our CXL" runs pair only **128 GiB of local DRAM** with the AIC(s)
/// (Sections V-B/V-C), while the baseline uses the full 512 GB. This helper
/// clamps DRAM capacity so policy runs see the same pressure.
pub fn with_dram_capacity(mut t: SystemTopology, dram_bytes: u64) -> SystemTopology {
    t.mem_nodes[0].capacity = dram_bytes;
    t.name = format!("{} dram={}", t.name, crate::util::units::fmt_bytes(dram_bytes));
    t.validate();
    t
}

/// Override every memory node's capacity at once — the fleet host's
/// "free view": admission plans are built against a clone of the host
/// topology whose capacities equal the *remaining* free bytes per node.
/// Deliberately not re-validated: a fully occupied node has zero
/// remaining capacity, which `validate` (rightly) rejects for real
/// machines but which the placement engines and allocator arithmetic
/// handle fine (a zero-capacity node simply never receives bytes).
pub fn with_node_capacities(mut t: SystemTopology, caps: &[u64]) -> SystemTopology {
    assert_eq!(caps.len(), t.mem_nodes.len(), "one capacity per node");
    for (node, cap) in t.mem_nodes.iter_mut().zip(caps) {
        node.capacity = *cap;
    }
    t
}

/// Scale one link's per-direction bandwidth by `factor` — a degraded
/// (throttled / retrained-at-lower-width) PCIe link. Any CXL node behind
/// the link has its DMA `peak_bw` scaled too (it is link-bound), while
/// `cpu_stream_bw` is left alone below the scaled link rate: CXL.mem CPU
/// streams are latency-limited, not link-limited, until the link drops
/// under them. Deliberately not re-validated (see `with_node_capacities`).
pub fn with_link_bw_factor(mut t: SystemTopology, link: LinkId, factor: f64) -> SystemTopology {
    assert!(link.0 < t.links.len(), "link {} out of range", link.0);
    assert!(factor > 0.0 && factor <= 1.0, "bw factor must be in (0, 1]");
    t.links[link.0].per_dir_bw *= factor;
    let link_rate = t.links[link.0].per_dir_bw;
    for node in t.mem_nodes.iter_mut() {
        if node.link == Some(link) {
            node.peak_bw = node.peak_bw.min(link_rate);
            node.cpu_stream_bw = node.cpu_stream_bw.min(link_rate);
        }
    }
    t
}

/// Take a CXL node offline (AIC hot-remove): capacity drops to zero so no
/// placement engine ever assigns it bytes. Node 0 (local DRAM) is rejected
/// — a host without DRAM is not a degraded machine, it is no machine.
/// Deliberately not re-validated: `validate` (rightly) refuses
/// zero-capacity nodes on real machines.
pub fn with_node_offline(mut t: SystemTopology, node: NodeId) -> SystemTopology {
    assert!(node.0 < t.mem_nodes.len(), "node {} out of range", node.0);
    assert!(
        t.mem_nodes[node.0].kind == MemKind::CxlAic,
        "only CXL AICs can go offline (node {} is {:?})",
        node.0,
        t.mem_nodes[node.0].kind
    );
    t.mem_nodes[node.0].capacity = 0;
    t
}

/// Shrink one node's capacity by `bytes` (ECC pressure / reserved-region
/// growth), saturating at zero. Deliberately not re-validated.
pub fn with_reduced_capacity(mut t: SystemTopology, node: NodeId, bytes: u64) -> SystemTopology {
    assert!(node.0 < t.mem_nodes.len(), "node {} out of range", node.0);
    t.mem_nodes[node.0].capacity = t.mem_nodes[node.0].capacity.saturating_sub(bytes);
    t
}

/// Add `n` extra GPUs (scalability studies beyond the paper's 2).
pub fn with_gpus(mut t: SystemTopology, n: usize) -> SystemTopology {
    let base_links = t.links.len();
    t.gpus.clear();
    // Re-number: keep AIC links, append GPU links at the end.
    for i in 0..n {
        t.links.push(LinkSpec::pcie_gen5_x16("gpu-link"));
        t.gpus.push(h100_pcie(i, LinkId(base_links + i)));
    }
    // Old GPU links 0/1 become unused; harmless but rebuild names for clarity.
    t.name = format!("{} gpus={n}", t.name);
    t.validate();
    t
}

/// Small machine for unit tests and the functional (PJRT) examples:
/// 8 GiB DRAM + two 4 GiB AICs + 2 modest GPUs. Same latency/contention
/// *shape* as Config A/B so tests exercise identical code paths fast.
pub fn dev_tiny() -> SystemTopology {
    let t = SystemTopology {
        name: "dev-tiny".into(),
        cpu: CpuSpec {
            name: "dev-cpu".into(),
            cores: 8,
            llc_bytes: 16 * MIB,
            adam_compute_ns_per_elem: 0.26,
            optimizer_threads: 8,
        },
        mem_nodes: vec![
            local_dram(8 * GIB),
            cxl_aic("cxl0", 4 * GIB, LinkId(2)),
            cxl_aic("cxl1", 4 * GIB, LinkId(3)),
        ],
        links: vec![
            LinkSpec::pcie_gen5_x16("gpu0-link"),
            LinkSpec::pcie_gen5_x16("gpu1-link"),
            cxl_link("cxl0-link"),
            cxl_link("cxl1-link"),
        ],
        gpus: vec![
            GpuSpec {
                name: "dev-gpu0".into(),
                bf16_flops: 50e12,
                mfu: 0.4,
                hbm_bytes: 8 * GIB,
                link: LinkId(0),
            },
            GpuSpec {
                name: "dev-gpu1".into(),
                bf16_flops: 50e12,
                mfu: 0.4,
                hbm_bytes: 8 * GIB,
                link: LinkId(1),
            },
        ],
    };
    t.validate();
    t
}

/// Look up a preset by CLI name.
pub fn by_name(name: &str) -> Option<SystemTopology> {
    match name {
        "config-a" | "a" => Some(config_a()),
        "config-b" | "b" => Some(config_b()),
        "dev-tiny" | "tiny" => Some(dev_tiny()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_resolves() {
        assert!(by_name("config-a").is_some());
        assert!(by_name("b").is_some());
        assert!(by_name("dev-tiny").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn with_dram_capacity_clamps() {
        let t = with_dram_capacity(config_a(), 128 * GIB);
        assert_eq!(t.dram().capacity, 128 * GIB);
        assert_eq!(t.node(t.cxl_nodes()[0]).capacity, 512 * GIB);
    }

    #[test]
    fn with_node_capacities_overrides_every_node_and_allows_zero() {
        let t = with_node_capacities(config_b(), &[10 * GIB, 0, 7]);
        assert_eq!(t.dram().capacity, 10 * GIB);
        assert_eq!(t.mem_nodes[1].capacity, 0);
        assert_eq!(t.mem_nodes[2].capacity, 7);
        assert_eq!(t.cxl_nodes().len(), 2, "node kinds unchanged");
    }

    #[test]
    fn with_link_bw_factor_scales_link_and_aic_peak() {
        let base = config_a();
        let t = with_link_bw_factor(base.clone(), LinkId(2), 0.5);
        assert_eq!(t.links[2].per_dir_bw, base.links[2].per_dir_bw * 0.5);
        // The AIC behind link 2 is link-bound: peak_bw clamps to the link.
        assert_eq!(t.mem_nodes[1].peak_bw, t.links[2].per_dir_bw);
        // cpu_stream_bw (26 GB/s) is already below 32 GB/s — untouched.
        assert_eq!(t.mem_nodes[1].cpu_stream_bw, base.mem_nodes[1].cpu_stream_bw);
        // GPU links unaffected.
        assert_eq!(t.links[0].per_dir_bw, base.links[0].per_dir_bw);
    }

    #[test]
    fn with_node_offline_zeroes_capacity_only() {
        let t = with_node_offline(config_b(), NodeId(1));
        assert_eq!(t.mem_nodes[1].capacity, 0);
        assert_eq!(t.mem_nodes[2].capacity, 256 * GIB);
        assert_eq!(t.cxl_nodes().len(), 2, "node kinds unchanged");
    }

    #[test]
    #[should_panic(expected = "only CXL AICs can go offline")]
    fn with_node_offline_rejects_dram() {
        let _ = with_node_offline(config_a(), NodeId(0));
    }

    #[test]
    fn with_reduced_capacity_saturates() {
        let t = with_reduced_capacity(config_a(), NodeId(1), 100 * GIB);
        assert_eq!(t.mem_nodes[1].capacity, 412 * GIB);
        let t = with_reduced_capacity(t, NodeId(1), u64::MAX);
        assert_eq!(t.mem_nodes[1].capacity, 0);
    }

    #[test]
    fn with_gpus_rewires_links() {
        let t = with_gpus(config_b(), 4);
        assert_eq!(t.gpus.len(), 4);
        t.validate(); // no link shared
    }

    #[test]
    fn cpu_stream_bw_ratio_drives_fig5() {
        // The DRAM/CXL sustained-RMW ratio is what produces the ~4×
        // optimizer inflation; keep it in a plausible band.
        let t = config_a();
        let ratio = t.dram().cpu_stream_bw / t.node(t.cxl_nodes()[0]).cpu_stream_bw;
        assert!((3.0..6.0).contains(&ratio), "ratio {ratio}");
    }
}
