//! Parameter groups: a named fp32 master buffer + Adam state + (logical)
//! placement. The functional trainer keeps one group per transformer block
//! plus one for the embedding so blocks can be streamed independently —
//! the same granularity the offload engine schedules transfers at.

use super::adam::{adam_step, AdamHp, AdamState};

/// One optimizer parameter group.
#[derive(Clone, Debug)]
pub struct ParamGroup {
    pub name: String,
    /// fp32 master parameters (flattened).
    pub master: Vec<f32>,
    pub state: AdamState,
}

impl ParamGroup {
    pub fn new(name: impl Into<String>, init: Vec<f32>) -> Self {
        let n = init.len();
        Self {
            name: name.into(),
            master: init,
            state: AdamState::new(n),
        }
    }

    pub fn len(&self) -> usize {
        self.master.len()
    }
    pub fn is_empty(&self) -> bool {
        self.master.is_empty()
    }

    /// Apply one Adam step with this group's gradients.
    pub fn step(&mut self, grads: &[f32], hp: &AdamHp, nthreads: usize) {
        adam_step(&mut self.master, grads, &mut self.state, hp, nthreads);
    }

    /// L2 norm of the master parameters (train-loop diagnostics).
    pub fn param_norm(&self) -> f64 {
        self.master
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_steps_and_norms() {
        let mut g = ParamGroup::new("block0", vec![1.0; 16]);
        assert_eq!(g.len(), 16);
        assert!((g.param_norm() - 4.0).abs() < 1e-9);
        let grads = vec![0.5f32; 16];
        g.step(&grads, &AdamHp::default(), 2);
        assert_eq!(g.state.step, 1);
        assert!(g.master.iter().all(|&x| x < 1.0), "params moved down-grad");
    }
}
