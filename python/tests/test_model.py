"""L2 model: per-block fwd/bwd correctness, RoPE properties, and the
streamed-vs-monolithic equivalence the Rust trainer relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.TinyConfig(layers=2, hidden=64, heads=4, vocab=256, ffn=96, batch=2, context=32)


def init_block(key, cfg):
    shapes = M.block_param_shapes(cfg)
    params = {}
    for name in M.BLOCK_PARAM_NAMES:
        key, sub = jax.random.split(key)
        if name.startswith("ln"):
            params[name] = jnp.ones(shapes[name], jnp.float32)
        else:
            params[name] = jax.random.normal(sub, shapes[name], jnp.float32) * 0.05
    return key, params


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    key, b0 = init_block(key, CFG)
    key, b1 = init_block(key, CFG)
    key, k1, k2, k3 = jax.random.split(key, 4)
    emb = jax.random.normal(k1, (CFG.vocab, CFG.hidden)) * 0.05
    lnf = jnp.ones((CFG.hidden,))
    ids = jax.random.randint(k2, (CFG.batch, CFG.context), 0, CFG.vocab)
    labels = jax.random.randint(k3, (CFG.batch, CFG.context), 0, CFG.vocab)
    return dict(blocks=[b0, b1], emb=emb, lnf=lnf, ids=ids, labels=labels)


def test_block_fwd_preserves_shape(setup):
    (x,) = M.embed_fwd(CFG, setup["ids"], setup["emb"])
    y = M.block_fwd(CFG, x, *[setup["blocks"][0][n] for n in M.BLOCK_PARAM_NAMES])
    assert y.shape == x.shape
    assert jnp.all(jnp.isfinite(y))


def test_block_bwd_matches_autodiff(setup):
    (x,) = M.embed_fwd(CFG, setup["ids"], setup["emb"])
    params = [setup["blocks"][0][n] for n in M.BLOCK_PARAM_NAMES]
    dy = jnp.ones_like(x) * 0.1
    grads = M.block_bwd(CFG, x, *params, dy)
    assert len(grads) == 1 + len(params)
    # against direct jax.grad of <block_fwd, dy>
    def scalar_fn(x, *p):
        return (M.block_fwd(CFG, x, *p) * dy).sum()
    want = jax.grad(scalar_fn, argnums=tuple(range(len(params) + 1)))(x, *params)
    for g, w in zip(grads, want):
        np.testing.assert_allclose(g, w, rtol=2e-4, atol=2e-4)


def test_head_loss_grads_match_autodiff(setup):
    (x,) = M.embed_fwd(CFG, setup["ids"], setup["emb"])
    loss, dx, dlnf, demb = M.head_loss(CFG, x, setup["lnf"], setup["emb"], setup["labels"])
    assert loss.shape == ()
    assert float(loss) > 0
    def f(x, lnf, emb):
        return M.head_loss(CFG, x, lnf, emb, setup["labels"])[0]
    wdx, wdlnf, wdemb = jax.grad(f, argnums=(0, 1, 2))(x, setup["lnf"], setup["emb"])
    np.testing.assert_allclose(dx, wdx, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dlnf, wdlnf, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(demb, wdemb, rtol=1e-5, atol=1e-6)


def test_embed_bwd_is_gather_transpose(setup):
    (x,) = M.embed_fwd(CFG, setup["ids"], setup["emb"])
    dx = jnp.ones_like(x)
    (demb,) = M.embed_bwd(CFG, setup["ids"], dx)
    want = jax.grad(lambda e: (M.embed_fwd(CFG, setup["ids"], e)[0] * dx).sum())(
        setup["emb"]
    )
    np.testing.assert_allclose(demb, want, rtol=1e-6, atol=1e-6)


def test_streamed_equals_monolithic(setup):
    """The property the Rust trainer depends on: running blocks one at a
    time from checkpoints gives the same loss/grads as the whole model."""
    loss_mono = M.full_model_loss(
        CFG, setup["ids"], setup["labels"], setup["emb"], setup["lnf"], setup["blocks"]
    )
    # streamed: embed → block-by-block with checkpoints → head
    (x,) = M.embed_fwd(CFG, setup["ids"], setup["emb"])
    ckpts = []
    for p in setup["blocks"]:
        ckpts.append(x)
        x = M.block_fwd(CFG, x, *[p[n] for n in M.BLOCK_PARAM_NAMES])
    loss_stream, dx, _, demb_head = M.head_loss(
        CFG, x, setup["lnf"], setup["emb"], setup["labels"]
    )
    np.testing.assert_allclose(loss_stream, loss_mono, rtol=1e-6)
    # streamed backward: reverse blocks from checkpoints, then embed_bwd;
    # full embedding gradient = gather-transpose part + tied-head part.
    for l in reversed(range(len(setup["blocks"]))):
        p = [setup["blocks"][l][n] for n in M.BLOCK_PARAM_NAMES]
        dx = M.block_bwd(CFG, ckpts[l], *p, dx)[0]
    (demb_gather,) = M.embed_bwd(CFG, setup["ids"], dx)
    demb_stream = demb_gather + demb_head
    want_demb = jax.grad(
        lambda e: M.full_model_loss(
            CFG, setup["ids"], setup["labels"], e, setup["lnf"], setup["blocks"]
        )
    )(setup["emb"])
    np.testing.assert_allclose(demb_stream, want_demb, rtol=3e-4, atol=3e-5)


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 32, 16))
    r = M.rope(x)
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(r, axis=-1), rtol=1e-5
    )


def test_rope_relative_property():
    # dot(rope(q)_i, rope(k)_j) depends only on (i - j) for single-freq pairs
    d = 8
    q = jnp.tile(jnp.array([1.0, 0.5, -0.3, 0.8, 0.1, -0.2, 0.7, 0.4]), (1, 1, 16, 1))
    k = q
    rq, rk = M.rope(q), M.rope(k)
    dots = jnp.einsum("bhqd,bhkd->bhqk", rq, rk)[0, 0]
    # compare dot(i, i+3) across i
    diag3 = jnp.array([dots[i, i + 3] for i in range(10)])
    np.testing.assert_allclose(diag3, diag3[0] * jnp.ones_like(diag3), rtol=1e-4)


def test_rmsnorm_scale_invariance():
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16))
    n1 = M.rmsnorm(x, jnp.ones(16))
    n2 = M.rmsnorm(x * 10.0, jnp.ones(16))
    np.testing.assert_allclose(n1, n2, rtol=1e-4, atol=1e-5)


def test_param_count_formula():
    assert CFG.n_params() == (
        2 * (2 * 64 + 4 * 64 * 64 + 3 * 64 * 96) + 256 * 64 + 64
    )
