//! Byte / time / rate unit helpers shared across the simulator and reports.

pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;
pub const TIB: u64 = 1 << 40;

/// Decimal (SI) units, used for link rates quoted in GB/s.
pub const KB: u64 = 1_000;
pub const MB: u64 = 1_000_000;
pub const GB: u64 = 1_000_000_000;

/// Format a byte count with binary units, e.g. `1.50 GiB`.
pub fn fmt_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    if bytes >= TIB {
        format!("{:.2} TiB", b / TIB as f64)
    } else if bytes >= GIB {
        format!("{:.2} GiB", b / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.2} MiB", b / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.2} KiB", b / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

/// Format a rate in bytes/second as GiB/s (the unit Fig. 6 uses).
pub fn fmt_rate(bytes_per_sec: f64) -> String {
    format!("{:.2} GiB/s", bytes_per_sec / GIB as f64)
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn fmt_secs(secs: f64) -> String {
    if !secs.is_finite() {
        return format!("{secs}");
    }
    let a = secs.abs();
    if a >= 1.0 {
        format!("{secs:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Parse strings like `512GiB`, `128 MiB`, `64GB`, `4096`, `2TiB` into bytes.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let s = s.trim();
    let split = s
        .find(|c: char| !c.is_ascii_digit() && c != '.' && c != '_')
        .unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let num: f64 = num
        .replace('_', "")
        .parse()
        .map_err(|e| format!("bad number in {s:?}: {e}"))?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1,
        "k" | "kib" => KIB,
        "m" | "mib" => MIB,
        "g" | "gib" => GIB,
        "t" | "tib" => TIB,
        "kb" => KB,
        "mb" => MB,
        "gb" => GB,
        "tb" => 1_000_000_000_000,
        other => return Err(format!("unknown byte unit {other:?} in {s:?}")),
    };
    Ok((num * mult as f64).round() as u64)
}

/// Parse counts like `32k`, `1m`, `20M`, `1e9` into u64 (used by CLI sweeps).
pub fn parse_count(s: &str) -> Result<u64, String> {
    let s = s.trim();
    if let Some(stripped) = s.strip_suffix(['k', 'K']) {
        return Ok((stripped
            .parse::<f64>()
            .map_err(|e| format!("bad count {s:?}: {e}"))?
            * 1e3) as u64);
    }
    if let Some(stripped) = s.strip_suffix(['m', 'M']) {
        return Ok((stripped
            .parse::<f64>()
            .map_err(|e| format!("bad count {s:?}: {e}"))?
            * 1e6) as u64);
    }
    if let Some(stripped) = s.strip_suffix(['b', 'B', 'g', 'G']) {
        return Ok((stripped
            .parse::<f64>()
            .map_err(|e| format!("bad count {s:?}: {e}"))?
            * 1e9) as u64);
    }
    s.parse::<f64>()
        .map(|f| f as u64)
        .map_err(|e| format!("bad count {s:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2 * KIB), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * MIB + MIB / 2), "3.50 MiB");
        assert_eq!(fmt_bytes(512 * GIB), "512.00 GiB");
        assert_eq!(fmt_bytes(2 * TIB), "2.00 TiB");
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(parse_bytes("512GiB").unwrap(), 512 * GIB);
        assert_eq!(parse_bytes("128 MiB").unwrap(), 128 * MIB);
        assert_eq!(parse_bytes("64GB").unwrap(), 64 * GB);
        assert_eq!(parse_bytes("4096").unwrap(), 4096);
        assert_eq!(parse_bytes("1.5k").unwrap(), 1536);
        assert!(parse_bytes("12xyz").is_err());
    }

    #[test]
    fn parse_counts() {
        assert_eq!(parse_count("32k").unwrap(), 32_000);
        assert_eq!(parse_count("20M").unwrap(), 20_000_000);
        assert_eq!(parse_count("1.5b").unwrap(), 1_500_000_000);
        assert_eq!(parse_count("777").unwrap(), 777);
    }

    #[test]
    fn fmt_secs_scales() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.002), "2.000 ms");
        assert_eq!(fmt_secs(3.4e-6), "3.400 µs");
        assert_eq!(fmt_secs(120e-9), "120.0 ns");
    }
}
