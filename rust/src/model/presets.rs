//! Model presets: the paper's two workloads (§V-A2) with their real
//! architectural dimensions, plus tiny configs for the functional (PJRT)
//! training path.

use super::ModelConfig;

/// Qwen2.5-7B (the paper's "7B" workload): 28 layers, H=3584, 28 heads /
/// 4 KV heads, FFN 18944, vocab 152k, untied head → 7.6B params.
pub fn qwen25_7b() -> ModelConfig {
    ModelConfig {
        name: "qwen2.5-7b".into(),
        layers: 28,
        hidden: 3584,
        heads: 28,
        kv_heads: 4,
        head_dim: 128,
        ffn_hidden: 18944,
        vocab: 152_064,
        tie_embeddings: false,
    }
}

/// Mistral NeMo 12B (the paper's "12B" workload): 40 layers, H=5120,
/// 32 heads / 8 KV heads, head_dim 128, FFN 14336, vocab 131k → 12.2B.
pub fn mistral_nemo_12b() -> ModelConfig {
    ModelConfig {
        name: "mistral-nemo-12b".into(),
        layers: 40,
        hidden: 5120,
        heads: 32,
        kv_heads: 8,
        head_dim: 128,
        ffn_hidden: 14336,
        vocab: 131_072,
        tie_embeddings: false,
    }
}

/// ~20M-parameter GPT for the real end-to-end training example
/// (CPU-PJRT-sized; same code path as the big models).
pub fn tiny_20m() -> ModelConfig {
    ModelConfig {
        name: "tiny-20m".into(),
        layers: 6,
        hidden: 384,
        heads: 6,
        kv_heads: 6,
        head_dim: 64,
        ffn_hidden: 1024,
        vocab: 4096,
        tie_embeddings: true,
    }
}

/// ~2M-parameter GPT for fast integration tests.
pub fn tiny_2m() -> ModelConfig {
    ModelConfig {
        name: "tiny-2m".into(),
        layers: 2,
        hidden: 128,
        heads: 4,
        kv_heads: 4,
        head_dim: 32,
        ffn_hidden: 384,
        vocab: 1024,
        tie_embeddings: true,
    }
}

/// Resolve a CLI name.
pub fn by_name(name: &str) -> Option<ModelConfig> {
    match name {
        "7b" | "qwen" | "qwen2.5-7b" => Some(qwen25_7b()),
        "12b" | "nemo" | "mistral-nemo-12b" => Some(mistral_nemo_12b()),
        "tiny" | "tiny-20m" => Some(tiny_20m()),
        "tiny-2m" => Some(tiny_2m()),
        _ => None,
    }
}
