//! §Schedule ablation: the engine × schedule matrix the schedule-graph IR
//! unlocked (ISSUE 3). For each fine-tuning scenario — full ZeRO-Offload,
//! gradient accumulation, LoRA, and the no-activation-offload ablation —
//! one iteration is simulated under the DRAM baseline, naive interleave,
//! and the CXL-aware policy, quantifying *which* traffic class each
//! placement decision actually prices:
//!
//! * `lora` collapses the optimizer working set → STEP becomes placement-
//!   insensitive (Fig. 5's left region) and only bulk streams remain;
//! * `grad-accum:K` multiplies bulk streams while STEP runs once → the
//!   opposite corner;
//! * `no-act-offload` deletes checkpoint round-trips → isolates activation
//!   traffic's share of the CXL sensitivity.
//!
//! Results land in `bench_out/schedule_ablation/` and — like
//! `sim_hotpath`'s `BENCH_sim.json` — in `BENCH_sched.json` (override:
//! `CXLFINE_BENCH_SCHED_OUT`), which the CI bench-smoke job uploads on
//! every push (`--smoke` preset) so the schedule-level perf trajectory is
//! recorded alongside the DES one.

use std::collections::BTreeMap;

use cxlfine::mem::{EngineRef, Policy};
use cxlfine::model::footprint::Workload;
use cxlfine::model::presets::qwen25_7b;
use cxlfine::offload::{schedules, simulate_iteration_report, MemoryPlan, PhaseReport, RunConfig};
use cxlfine::topology::presets::{config_a, with_dram_capacity};
use cxlfine::trow;
use cxlfine::util::bench::BenchReport;
use cxlfine::util::json::{Json, JsonObj};
use cxlfine::util::table::Table;
use cxlfine::util::units::GIB;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = BenchReport::new("schedule_ablation");
    let base_topo = config_a();
    let cxl_topo = with_dram_capacity(config_a(), 128 * GIB);
    let model = qwen25_7b();

    let sched_names: Vec<&str> = if smoke {
        vec!["zero-offload", "grad-accum:2", "lora", "no-act-offload"]
    } else {
        vec![
            "zero-offload",
            "grad-accum:2",
            "grad-accum:4",
            "lora",
            "lora:64",
            "no-act-offload",
        ]
    };
    let engines: Vec<EngineRef> = vec![
        Policy::DramOnly.into(),
        Policy::NaiveInterleave.into(),
        Policy::CxlAware { striping: false }.into(),
    ];
    let batches: Vec<usize> = if smoke { vec![4] } else { vec![1, 8, 16] };
    let context = 4096usize;

    // (schedule, engine, batch) → report
    let mut results: BTreeMap<(String, String, usize), PhaseReport> = BTreeMap::new();
    let mut json_scheds = Vec::new();

    for sched_name in &sched_names {
        let sched = schedules::by_name(sched_name).expect("registered schedule");
        let mut t = Table::new(&["engine", "batch", "iter s", "tok/s", "fwd s", "bwd s", "step s"])
            .left(0);
        let mut cells = Vec::new();
        for engine in &engines {
            let topo = if engine.is_baseline() {
                &base_topo
            } else {
                &cxl_topo
            };
            for &b in &batches {
                let cfg = RunConfig::new(
                    model.clone(),
                    Workload::new(1, b, context),
                    engine.clone(),
                )
                .with_schedule(sched.clone());
                let plan = MemoryPlan::build(topo, &cfg).expect("cell fits");
                let (rep, _) = simulate_iteration_report(topo, &cfg, &plan);
                let bd = rep.to_breakdown();
                t.row(trow![
                    engine.name(),
                    b,
                    format!("{:.3}", bd.iter_s),
                    format!("{:.0}", rep.tokens_per_sec()),
                    format!("{:.3}", bd.fwd_s),
                    format!("{:.3}", bd.bwd_s),
                    format!("{:.3}", bd.step_s)
                ]);
                let mut cell = JsonObj::new();
                cell.set("engine", engine.name());
                cell.set("batch", b);
                cell.set("context", context);
                cell.set("breakdown", bd.to_json());
                cell.set("tokens_per_sec", rep.tokens_per_sec());
                cells.push(Json::Obj(cell));
                results.insert((sched_name.to_string(), engine.name().to_string(), b), rep);
            }
        }
        // dots break bench_out filenames' readability; keep series simple
        let series = sched_name.replace(':', "_");
        report.section(&series, t, Json::Arr(cells.clone()));
        let mut js = JsonObj::new();
        js.set("schedule", *sched_name);
        js.set("cells", Json::Arr(cells));
        json_scheds.push(Json::Obj(js));
    }

    // ---- cross-schedule sanity gates ---------------------------------
    let get = |sched: &str, engine: &str, b: usize| {
        results
            .get(&(sched.to_string(), engine.to_string(), b))
            .unwrap_or_else(|| panic!("missing cell {sched}/{engine}/{b}"))
    };
    for engine in ["baseline-dram", "naive-cxl", "cxl-aware"] {
        for &b in &batches {
            let zo = get("zero-offload", engine, b).to_breakdown();
            let ga = get("grad-accum:2", engine, b);
            let lo = get("lora", engine, b).to_breakdown();
            let na = get("no-act-offload", engine, b).to_breakdown();
            assert!(
                ga.iter_s > zo.iter_s && ga.iter_s < 2.0 * zo.iter_s,
                "{engine}/b{b}: accum must amortize the step ({} vs {})",
                ga.iter_s,
                zo.iter_s
            );
            assert!(
                ga.tokens_per_sec() > zo.tokens_per_sec(),
                "{engine}/b{b}: accum must raise throughput"
            );
            assert!(
                lo.step_s < 0.15 * zo.step_s,
                "{engine}/b{b}: lora step {} vs full {}",
                lo.step_s,
                zo.step_s
            );
            assert!(
                na.iter_s <= zo.iter_s * 1.001,
                "{engine}/b{b}: dropping checkpoint traffic cannot slow the run"
            );
        }
    }
    // The headline: LoRA shrinks the naive-CXL penalty because STEP (the
    // phase naive placement hurts most, Fig. 7a) nearly vanishes.
    for &b in &batches {
        let full_pen = get("zero-offload", "naive-cxl", b).iter_s
            / get("zero-offload", "baseline-dram", b).iter_s;
        let lora_pen =
            get("lora", "naive-cxl", b).iter_s / get("lora", "baseline-dram", b).iter_s;
        println!(
            "b{b}: naive-CXL slowdown — full FT {full_pen:.3}x, lora {lora_pen:.3}x"
        );
        assert!(
            lora_pen < full_pen,
            "b{b}: lora must be less placement-sensitive ({lora_pen:.3} vs {full_pen:.3})"
        );
    }

    // ---- persist BENCH_sched.json ------------------------------------
    let mut root = JsonObj::new();
    root.set("bench", "schedule_ablation");
    root.set("smoke", smoke);
    root.set("model", model.name.as_str());
    root.set("schedules", Json::Arr(json_scheds));
    let out =
        std::env::var("CXLFINE_BENCH_SCHED_OUT").unwrap_or_else(|_| "BENCH_sched.json".into());
    let payload = Json::Obj(root).to_string_pretty();
    match std::fs::write(&out, &payload) {
        Ok(()) => println!("\n[schedule_ablation] wrote {out}"),
        Err(e) => eprintln!("warn: could not write {out}: {e}"),
    }
    report.finish();
}
