//! The CPU-offloading coordinator: the paper's Figure-1 workflow.
//!
//! * [`plan`] — Table-I region allocation under a placement policy,
//! * [`iteration`] — one simulated training iteration with full
//!   transfer/compute overlap over the fabric,
//! * [`metrics`] — phase breakdowns and throughput reports,
//! * [`sweep`] — (C, B) grid sweeps producing the Fig. 9/10 matrices.

pub mod iteration;
pub mod metrics;
pub mod plan;
pub mod sweep;

pub use iteration::{simulate_iteration, simulate_iteration_traced};
pub use metrics::PhaseBreakdown;
pub use plan::{MemoryPlan, PlanError, RunConfig};
pub use sweep::{sweep_grid, sweep_grid_with_threads, GridPoint, SweepResult};
