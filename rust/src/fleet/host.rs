//! The long-lived multi-job host: one [`NumaAllocator`] shared by every
//! resident job, plus GPU-slot accounting.
//!
//! Each admitted job is one committed region (its [`PlanReservation`]
//! shards, one per node) named `job-<id>`; completion releases it through
//! [`NumaAllocator::release_region`], restoring free space byte-identically
//! to the job never having run. Admission plans are built against a
//! *capacity view*: a clone of the host topology whose node capacities
//! equal the current free bytes, so the existing placement engines and
//! capacity arithmetic do all the work unchanged. [`FleetHost::free_view`]
//! is the one-shot form of that view; the simulator's probe keeps its own
//! scratch clone and rewrites only the capacities per attempt (same
//! semantics, no per-attempt deep clone).

use std::collections::BTreeMap;

use crate::mem::{AllocError, NumaAllocator, Placement, Policy, RegionId, RegionRequest, TensorClass};
use crate::offload::PlanReservation;
use crate::sim::memmodel::AccessMode;
use crate::topology::{presets as tpresets, SystemTopology};

pub struct FleetHost<'t> {
    topo: &'t SystemTopology,
    alloc: NumaAllocator<'t>,
    /// Committed reservation per resident job id.
    by_job: BTreeMap<u64, RegionId>,
    /// GPUs currently assigned to per-job reservations.
    gpus_in_use: usize,
}

impl<'t> FleetHost<'t> {
    pub fn new(topo: &'t SystemTopology) -> Self {
        Self {
            topo,
            // The engine is irrelevant: the host only `commit`s explicit
            // reservations computed by admission plans, never `alloc`s.
            alloc: NumaAllocator::new(topo, Policy::DramOnly),
            by_job: BTreeMap::new(),
            gpus_in_use: 0,
        }
    }

    pub fn topo(&self) -> &'t SystemTopology {
        self.topo
    }

    /// Free bytes per node, indexed by `NodeId.0`.
    pub fn free(&self) -> Vec<u64> {
        self.topo
            .all_nodes()
            .iter()
            .map(|&n| self.alloc.free_on(n))
            .collect()
    }

    /// Used bytes per node, indexed by `NodeId.0`.
    pub fn used(&self) -> Vec<u64> {
        self.topo
            .all_nodes()
            .iter()
            .map(|&n| self.alloc.used_on(n))
            .collect()
    }

    pub fn free_gpus(&self) -> usize {
        self.topo.gpus.len() - self.gpus_in_use
    }

    /// Clone of the host topology with capacities set to the current free
    /// bytes — the one-shot capacity view admission plans are built
    /// against (the simulator's probe maintains the same view
    /// incrementally in a scratch clone). Nodes may carry zero capacity,
    /// so the clone is deliberately not re-validated.
    pub fn free_view(&self) -> SystemTopology {
        tpresets::with_node_capacities(self.topo.clone(), &self.free())
    }

    pub fn n_resident(&self) -> usize {
        self.by_job.len()
    }

    /// Commit a job's reservation (memory shards + GPU slots) for its
    /// whole residency.
    pub fn reserve(
        &mut self,
        job_id: u64,
        reservation: &PlanReservation,
        gpus: usize,
    ) -> Result<(), AllocError> {
        assert!(
            !self.by_job.contains_key(&job_id),
            "job {job_id} is already resident"
        );
        assert!(
            gpus <= self.free_gpus(),
            "job {job_id} wants {gpus} GPUs, {} free",
            self.free_gpus()
        );
        let placement = Placement {
            parts: reservation.parts.clone(),
            mode: AccessMode::Partitioned,
        };
        let id = self.alloc.commit(
            RegionRequest::new(
                format!("job-{job_id}"),
                TensorClass::Activations,
                reservation.total_bytes(),
            ),
            placement,
        )?;
        self.by_job.insert(job_id, id);
        self.gpus_in_use += gpus;
        Ok(())
    }

    /// Release a completed job's reservation; free space afterwards is
    /// byte-identical to the job never having been resident.
    pub fn release(&mut self, job_id: u64, gpus: usize) -> bool {
        match self.by_job.remove(&job_id) {
            Some(rid) => {
                let released = self.alloc.release_region(rid).is_some();
                debug_assert!(released, "resident job must hold a live region");
                debug_assert!(self.gpus_in_use >= gpus, "GPU accounting underflow");
                self.gpus_in_use -= gpus;
                released
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::dev_tiny;
    use crate::topology::NodeId;
    use crate::util::units::GIB;

    fn res(parts: Vec<(NodeId, u64)>) -> PlanReservation {
        PlanReservation { parts }
    }

    #[test]
    fn reserve_release_round_trip_restores_free_and_gpus() {
        let topo = dev_tiny();
        let mut h = FleetHost::new(&topo);
        let before = h.free();
        assert_eq!(h.free_gpus(), 2);
        h.reserve(7, &res(vec![(NodeId(0), 2 * GIB), (NodeId(1), GIB)]), 1)
            .unwrap();
        assert_eq!(h.n_resident(), 1);
        assert_eq!(h.free_gpus(), 1);
        assert_eq!(h.free()[0], before[0] - 2 * GIB);
        assert_eq!(h.free()[1], before[1] - GIB);
        assert!(h.release(7, 1));
        assert_eq!(h.free(), before, "free space byte-identical after release");
        assert_eq!(h.free_gpus(), 2);
        assert!(!h.release(7, 1), "double release rejected");
    }

    #[test]
    fn free_view_tracks_occupancy_down_to_zero() {
        let topo = dev_tiny();
        let mut h = FleetHost::new(&topo);
        h.reserve(1, &res(vec![(NodeId(1), 4 * GIB)]), 0).unwrap();
        let view = h.free_view();
        assert_eq!(view.mem_nodes[1].capacity, 0, "cxl0 fully occupied");
        assert_eq!(view.mem_nodes[0].capacity, topo.mem_nodes[0].capacity);
        assert_eq!(view.gpus.len(), topo.gpus.len());
    }

    #[test]
    fn overcommit_is_rejected_and_leaves_state_unchanged() {
        let topo = dev_tiny(); // 8 GiB DRAM
        let mut h = FleetHost::new(&topo);
        let before = h.free();
        let err = h
            .reserve(3, &res(vec![(NodeId(0), 100 * GIB)]), 1)
            .unwrap_err();
        assert!(err.shortfall > 0);
        assert_eq!(h.free(), before);
        assert_eq!(h.n_resident(), 0);
        assert_eq!(h.free_gpus(), 2, "failed reserve must not leak GPU slots");
    }
}
