//! Stripe arithmetic: split a byte count across nodes by weight, capped by
//! per-node free capacity. Shared by the placement policies (§IV-B) and the
//! fabric's striped transfers.

use crate::topology::NodeId;

/// Split `bytes` across `nodes` proportionally to `weights`, respecting
/// per-node `free` capacity. Returns `(shards, unplaced)`: shards are
/// `(node, bytes)` with every node appearing at most once and zero-byte
/// shards omitted; `unplaced > 0` means capacity ran out.
///
/// The split is exact (shards sum to `bytes - unplaced`): fractional
/// entitlements are floored and the remainder distributed to the largest
/// fractional parts first (largest-remainder method), so results are
/// deterministic and balanced to within one byte before capacity clamping.
pub fn weighted_split(
    bytes: u64,
    nodes: &[NodeId],
    weights: &[f64],
    free: &[u64], // indexed by NodeId.0
) -> (Vec<(NodeId, u64)>, u64) {
    assert_eq!(nodes.len(), weights.len());
    assert!(weights.iter().all(|w| *w >= 0.0));
    let mut remaining = bytes;
    let mut shards: Vec<(NodeId, u64)> = Vec::new();
    // Iterate: allocate by weight among nodes that still have free space;
    // nodes that hit capacity drop out and their share is redistributed.
    let mut free_left: Vec<u64> = nodes.iter().map(|n| free[n.0]).collect();
    let mut acc: Vec<u64> = vec![0; nodes.len()];
    while remaining > 0 {
        let live: Vec<usize> = (0..nodes.len())
            .filter(|&i| free_left[i] > 0 && weights[i] > 0.0)
            .collect();
        if live.is_empty() {
            break;
        }
        let wsum: f64 = live.iter().map(|&i| weights[i]).sum();
        // entitlement per live node this round
        let mut round: Vec<(usize, u64, f64)> = Vec::with_capacity(live.len()); // (idx, floor, frac)
        let mut floored_total = 0u64;
        for &i in &live {
            let ent = remaining as f64 * weights[i] / wsum;
            // Clamp to `remaining`: above 2^53 bytes, `remaining as f64`
            // can round UP, making floor(ent) exceed what is left and
            // underflowing the `remaining -= grant` below.
            let fl = (ent.floor() as u64).min(free_left[i]).min(remaining);
            round.push((i, fl, ent - ent.floor()));
            floored_total += fl;
        }
        // Distribute the integer remainder by largest fraction (stable
        // order), strictly ONE byte per node — classic largest-remainder.
        // (An earlier `1 + leftover/len` batching could hand the
        // highest-fraction node far more than its entitlement whenever
        // capacity clamping inflated `leftover`; bulk redistribution now
        // happens only through the recomputed floors of the next round.)
        let mut leftover = remaining - floored_total.min(remaining);
        round.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap().then(a.0.cmp(&b.0)));
        for (i, fl, _) in round.iter_mut() {
            let extra = if leftover > 0 && *fl < free_left[*i] {
                leftover -= 1;
                1
            } else {
                0
            };
            // Clamp to what is actually left: above 2^53 bytes the f64
            // entitlements round, and the SUM of per-node floors can
            // exceed `remaining` even though each floor alone was clamped.
            let grant = (*fl + extra).min(remaining);
            acc[*i] += grant;
            free_left[*i] -= grant;
            remaining -= grant;
        }
        // If no progress was possible this round (all floors zero and no
        // leftover placed), push single bytes to the first live node to
        // guarantee termination.
        if round.iter().all(|(_, fl, _)| *fl == 0) && remaining > 0 {
            let mut progressed = false;
            for &i in &live {
                if free_left[i] > 0 {
                    let grant = remaining.min(1);
                    acc[i] += grant;
                    free_left[i] -= grant;
                    remaining -= grant;
                    progressed = true;
                    break;
                }
            }
            if !progressed {
                break;
            }
        }
    }
    for (i, node) in nodes.iter().enumerate() {
        if acc[i] > 0 {
            shards.push((*node, acc[i]));
        }
    }
    (shards, remaining)
}

/// Equal-weight split (naive interleave across nodes).
pub fn equal_split(bytes: u64, nodes: &[NodeId], free: &[u64]) -> (Vec<(NodeId, u64)>, u64) {
    let w = vec![1.0; nodes.len()];
    weighted_split(bytes, nodes, &w, free)
}

/// Sequential fill: pack into nodes in order, moving on when full.
pub fn sequential_fill(bytes: u64, nodes: &[NodeId], free: &[u64]) -> (Vec<(NodeId, u64)>, u64) {
    let mut remaining = bytes;
    let mut shards = Vec::new();
    for &n in nodes {
        if remaining == 0 {
            break;
        }
        let take = remaining.min(free[n.0]);
        if take > 0 {
            shards.push((n, take));
            remaining -= take;
        }
    }
    (shards, remaining)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: usize) -> Vec<NodeId> {
        (0..n).map(NodeId).collect()
    }

    #[test]
    fn equal_split_is_balanced() {
        let free = vec![u64::MAX / 4; 3];
        let (shards, unplaced) = equal_split(1_000_003, &nodes(3), &free);
        assert_eq!(unplaced, 0);
        let total: u64 = shards.iter().map(|(_, b)| b).sum();
        assert_eq!(total, 1_000_003);
        let min = shards.iter().map(|(_, b)| *b).min().unwrap();
        let max = shards.iter().map(|(_, b)| *b).max().unwrap();
        assert!(max - min <= 2, "imbalance {max}-{min}");
    }

    #[test]
    fn weighted_split_proportional() {
        let free = vec![u64::MAX / 4; 2];
        let (shards, unplaced) =
            weighted_split(1_000_000, &nodes(2), &[3.0, 1.0], &free);
        assert_eq!(unplaced, 0);
        assert_eq!(shards.len(), 2);
        let b0 = shards[0].1 as f64;
        let b1 = shards[1].1 as f64;
        assert!((b0 / b1 - 3.0).abs() < 0.01, "ratio {}", b0 / b1);
    }

    #[test]
    fn capacity_overflow_redistributes() {
        // node0 can only take 100; the rest flows to node1.
        let free = vec![100, 10_000];
        let (shards, unplaced) = equal_split(5_000, &nodes(2), &free);
        assert_eq!(unplaced, 0);
        assert_eq!(shards.iter().find(|(n, _)| n.0 == 0).unwrap().1, 100);
        assert_eq!(shards.iter().find(|(n, _)| n.0 == 1).unwrap().1, 4_900);
    }

    #[test]
    fn reports_unplaced_when_everything_full() {
        let free = vec![10, 20];
        let (shards, unplaced) = equal_split(100, &nodes(2), &free);
        let placed: u64 = shards.iter().map(|(_, b)| b).sum();
        assert_eq!(placed, 30);
        assert_eq!(unplaced, 70);
    }

    #[test]
    fn zero_weight_node_gets_nothing() {
        let free = vec![1000, 1000];
        let (shards, unplaced) = weighted_split(500, &nodes(2), &[0.0, 1.0], &free);
        assert_eq!(unplaced, 0);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].0, NodeId(1));
    }

    #[test]
    fn sequential_fill_order() {
        let free = vec![100, 100, 100];
        let (shards, unplaced) = sequential_fill(150, &nodes(3), &free);
        assert_eq!(unplaced, 0);
        assert_eq!(shards, vec![(NodeId(0), 100), (NodeId(1), 50)]);
    }

    #[test]
    fn zero_bytes_is_empty() {
        let free = vec![100];
        let (shards, unplaced) = equal_split(0, &nodes(1), &free);
        assert!(shards.is_empty());
        assert_eq!(unplaced, 0);
    }

    #[test]
    fn split_conserves_bytes_property() {
        use crate::util::proptest_lite::*;
        let gen = PairOf(
            U64Range { lo: 0, hi: 1 << 40 },
            VecOf {
                inner: U64Range { lo: 0, hi: 1 << 38 },
                min_len: 1,
                max_len: 5,
            },
        );
        forall("split-conserves", 42, 300, &gen, |(bytes, frees)| {
            let ns: Vec<NodeId> = (0..frees.len()).map(NodeId).collect();
            let (shards, unplaced) = equal_split(*bytes, &ns, frees);
            let placed: u64 = shards.iter().map(|(_, b)| b).sum();
            if placed + unplaced != *bytes {
                return Err(format!("placed {placed} + unplaced {unplaced} != {bytes}"));
            }
            for (n, b) in &shards {
                if *b > frees[n.0] {
                    return Err(format!("node {} over capacity", n.0));
                }
            }
            // at most one shard per node
            let mut seen = std::collections::HashSet::new();
            for (n, _) in &shards {
                if !seen.insert(n.0) {
                    return Err("duplicate shard".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn weighted_split_deterministic() {
        let free = vec![1 << 30; 4];
        let run = || weighted_split(123_456_789, &nodes(4), &[1.0, 2.0, 3.0, 4.0], &free);
        assert_eq!(run(), run());
    }

    #[test]
    fn conserves_beyond_f64_integer_precision() {
        // Above 2^53, `bytes as f64` rounds; the entitlement clamp must
        // keep grants within `remaining` (this used to underflow u64).
        for bytes in [(1u64 << 60) - 1, (1 << 53) + 1, u64::MAX / 4] {
            for n in [1usize, 3] {
                let ns = nodes(n);
                let free = vec![u64::MAX / 2; n];
                let (shards, unplaced) = weighted_split(bytes, &ns, &vec![1.0; n], &free);
                let placed: u64 = shards.iter().map(|(_, b)| b).sum();
                assert_eq!(placed + unplaced, bytes, "conservation at {bytes} over {n}");
                assert_eq!(unplaced, 0);
            }
        }
    }

    #[test]
    fn grants_never_exceed_entitlement_by_more_than_one_byte() {
        // With ample capacity the split completes in one round, so every
        // node must land within one byte of its exact fractional
        // entitlement — the defining property of the largest-remainder
        // method (the old batched grant violated this for the
        // highest-fraction node).
        use crate::util::proptest_lite::*;
        let gen = PairOf(
            U64Range { lo: 0, hi: 1 << 40 },
            VecOf {
                inner: U64Range { lo: 1, hi: 100 },
                min_len: 2,
                max_len: 6,
            },
        );
        forall("lr-entitlement", 5, 300, &gen, |(bytes, raw_weights)| {
            let n = raw_weights.len();
            let ns: Vec<NodeId> = (0..n).map(NodeId).collect();
            let ws: Vec<f64> = raw_weights.iter().map(|&w| w as f64).collect();
            let free = vec![u64::MAX / 8; n];
            let (shards, unplaced) = weighted_split(*bytes, &ns, &ws, &free);
            if unplaced != 0 {
                return Err(format!("unplaced {unplaced} with ample capacity"));
            }
            let wsum: f64 = ws.iter().sum();
            for (idx, w) in ws.iter().enumerate() {
                let got = shards
                    .iter()
                    .find(|(node, _)| node.0 == idx)
                    .map(|(_, b)| *b)
                    .unwrap_or(0) as f64;
                // f64 slack: entitlements of ~2^40-byte splits carry ~0.1 B
                // of rounding noise on top of the ±1 B remainder grant.
                let ent = *bytes as f64 * w / wsum;
                if got > ent + 1.5 || got < ent - 1.5 {
                    return Err(format!(
                        "node {idx}: got {got} vs entitlement {ent:.1} (weights {ws:?})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn weighted_split_proportional_under_tight_capacity() {
        // Node 0 has a tiny cap; its overflow must redistribute to the
        // remaining nodes in WEIGHT proportion (3:1 here), not drift toward
        // whichever node sorts first. A few bytes of largest-remainder
        // rounding per redistribution round is the only tolerated skew.
        use crate::util::proptest_lite::*;
        let gen = U64Range {
            lo: 10_000,
            hi: 50_000_000,
        };
        forall("tight-cap-proportional", 17, 200, &gen, |bytes| {
            let ns = nodes(3);
            let free = vec![1000, u64::MAX / 8, u64::MAX / 8];
            let (shards, unplaced) = weighted_split(*bytes, &ns, &[5.0, 3.0, 1.0], &free);
            if unplaced != 0 {
                return Err("unexpected unplaced".into());
            }
            let on = |i: usize| {
                shards
                    .iter()
                    .find(|(n, _)| n.0 == i)
                    .map(|(_, b)| *b)
                    .unwrap_or(0)
            };
            if on(0) != 1000 {
                return Err(format!("capped node got {} != 1000", on(0)));
            }
            let (b1, b2) = (on(1) as i64, on(2) as i64);
            if b1 + b2 + 1000 != *bytes as i64 {
                return Err("conservation broken".into());
            }
            // 3:1 within a few redistribution rounds of ±1-byte grants
            if (b1 - 3 * b2).abs() > 64 {
                return Err(format!("spill not 3:1 proportional: {b1} vs {b2}"));
            }
            Ok(())
        });
    }
}
