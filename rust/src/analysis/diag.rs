//! Diagnostic primitives for the static verifier: stable `P0xx` codes,
//! severities, anchors, and rustc-style rendering.
//!
//! A [`Diagnostic`] is one finding: a stable code (`P006`), a
//! [`Severity`], an [`Anchor`] naming the node / region / phase / job it
//! is about, and a one-line message carrying the offending values.
//! [`Diagnostics`] is the ordered collection a lint pass returns; emission
//! order is meaningful (the first `Error` is what legacy `validate`
//! callers see), so it is never sorted.

use crate::util::json::{Json, JsonObj};

/// How bad a finding is. Ordering is `Info < Warn < Error`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    /// Worth knowing, never fails anything (even under `--deny-warnings`).
    Info,
    /// Suspicious — fails `validate_strict` and `lint --deny-warnings`.
    Warn,
    /// Structurally wrong — fails `validate` and plan builds.
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warning",
            Severity::Error => "error",
        }
    }
}

impl std::fmt::Display for Severity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What a diagnostic is anchored to.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Anchor {
    /// A schedule node, by index (= dispatch priority) and span name.
    Node { index: usize, name: String },
    /// A plan region, by name.
    Region { name: String },
    /// A phase index (schedule phase or allocator timeline slot).
    Phase { index: usize },
    /// A fleet-trace job, by id.
    Job { id: u64 },
    /// The trace as a whole.
    Trace,
    /// No specific anchor (e.g. an empty schedule).
    General,
}

impl Anchor {
    /// Short location label, e.g. `node 12 (grad-offload b3)`; empty for
    /// [`Anchor::General`].
    pub fn label(&self) -> String {
        match self {
            Anchor::Node { index, name } => format!("node {index} ({name})"),
            Anchor::Region { name } => format!("region '{name}'"),
            Anchor::Phase { index } => format!("phase {index}"),
            Anchor::Job { id } => format!("job {id}"),
            Anchor::Trace => "trace".to_string(),
            Anchor::General => String::new(),
        }
    }
}

/// One finding from a lint pass.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Stable code, e.g. `"P006"`. Documented in DESIGN.md §12.
    pub code: &'static str,
    pub severity: Severity,
    pub anchor: Anchor,
    /// One line: what is wrong, with the offending values inline.
    pub message: String,
}

impl Diagnostic {
    /// rustc-style one-liner:
    /// `error[P006]: node 12 (grad-offload b3): has a Dma touch on a
    /// non-Transfer op`.
    pub fn render(&self) -> String {
        let label = self.anchor.label();
        if label.is_empty() {
            format!("{}[{}]: {}", self.severity, self.code, self.message)
        } else {
            format!(
                "{}[{}]: {}: {}",
                self.severity, self.code, label, self.message
            )
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.set("code", self.code);
        o.set("severity", self.severity.name());
        o.set("anchor", self.anchor.label());
        o.set("message", self.message.as_str());
        Json::Obj(o)
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

/// An ordered list of findings; what every `lint_*` entry point returns.
#[derive(Clone, Debug, Default)]
pub struct Diagnostics {
    items: Vec<Diagnostic>,
}

impl Diagnostics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(
        &mut self,
        code: &'static str,
        severity: Severity,
        anchor: Anchor,
        message: impl Into<String>,
    ) {
        self.items.push(Diagnostic {
            code,
            severity,
            anchor,
            message: message.into(),
        });
    }

    /// Append every finding of `other`, preserving order.
    pub fn extend(&mut self, other: Diagnostics) {
        self.items.extend(other.items);
    }

    pub fn iter(&self) -> std::slice::Iter<'_, Diagnostic> {
        self.items.iter()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn count(&self, severity: Severity) -> usize {
        self.items.iter().filter(|d| d.severity == severity).count()
    }

    pub fn has_errors(&self) -> bool {
        self.count(Severity::Error) > 0
    }

    pub fn has_warnings(&self) -> bool {
        self.count(Severity::Warn) > 0
    }

    /// First finding at severity `Error` (emission order).
    pub fn first_error(&self) -> Option<&Diagnostic> {
        self.first_at_least(Severity::Error)
    }

    /// First finding at or above `floor` (emission order).
    pub fn first_at_least(&self, floor: Severity) -> Option<&Diagnostic> {
        self.items.iter().find(|d| d.severity >= floor)
    }

    /// Highest severity present, if any findings exist.
    pub fn worst(&self) -> Option<Severity> {
        self.items.iter().map(|d| d.severity).max()
    }

    /// Does any finding carry this code?
    pub fn has_code(&self, code: &str) -> bool {
        self.items.iter().any(|d| d.code == code)
    }

    /// All codes present, in emission order (with duplicates).
    pub fn codes(&self) -> Vec<&'static str> {
        self.items.iter().map(|d| d.code).collect()
    }

    /// All findings rendered one per line.
    pub fn render(&self) -> String {
        self.items
            .iter()
            .map(|d| d.render())
            .collect::<Vec<_>>()
            .join("\n")
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.items.iter().map(|d| d.to_json()).collect())
    }
}

impl<'a> IntoIterator for &'a Diagnostics {
    type Item = &'a Diagnostic;
    type IntoIter = std::slice::Iter<'a, Diagnostic>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_orders_info_warn_error() {
        assert!(Severity::Info < Severity::Warn);
        assert!(Severity::Warn < Severity::Error);
    }

    #[test]
    fn renders_rustc_style() {
        let d = Diagnostic {
            code: "P006",
            severity: Severity::Error,
            anchor: Anchor::Node {
                index: 12,
                name: "grad-offload b3".into(),
            },
            message: "has a Dma touch on a non-Transfer op".into(),
        };
        assert_eq!(
            d.render(),
            "error[P006]: node 12 (grad-offload b3): has a Dma touch on a non-Transfer op"
        );
    }

    #[test]
    fn general_anchor_omits_location() {
        let d = Diagnostic {
            code: "P001",
            severity: Severity::Error,
            anchor: Anchor::General,
            message: "schedule has no nodes".into(),
        };
        assert_eq!(d.render(), "error[P001]: schedule has no nodes");
    }

    #[test]
    fn counts_and_first_error() {
        let mut ds = Diagnostics::new();
        ds.push("P013", Severity::Warn, Anchor::Phase { index: 1 }, "empty");
        ds.push("P018", Severity::Info, Anchor::Region { name: "x".into() }, "cold");
        assert!(!ds.has_errors());
        assert!(ds.has_warnings());
        assert_eq!(ds.worst(), Some(Severity::Warn));
        ds.push("P002", Severity::Error, Anchor::General, "bad phase");
        assert_eq!(ds.first_error().unwrap().code, "P002");
        assert_eq!(ds.count(Severity::Error), 1);
        assert!(ds.has_code("P013"));
        assert!(!ds.has_code("P999"));
    }

    #[test]
    fn json_shape() {
        let mut ds = Diagnostics::new();
        ds.push("P201", Severity::Error, Anchor::Trace, "digest mismatch");
        let j = ds.to_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        let o = arr[0].as_obj().unwrap();
        assert_eq!(o.get("code").and_then(|v| v.as_str()), Some("P201"));
        assert_eq!(o.get("severity").and_then(|v| v.as_str()), Some("error"));
    }
}
