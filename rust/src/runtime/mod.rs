//! PJRT runtime layer: artifact manifest + executable loading/execution.
//! See `python/compile/aot.py` for the producer side.

pub mod artifact;
pub mod engine;

pub use artifact::{Entry, Manifest, TensorSpec};
pub use engine::{Arg, HostTensor, HostTensorI32, Runtime};
