//! The serving layer: request-level inference over a CXL-tiered paged
//! KV cache — the complement to [`crate::fleet`]'s training fleet, and
//! the paper's capacity argument turned around: if CXL-attached memory
//! can hold a fine-tuning job's optimizer state, it can also hold the
//! *cold tail* of long-context KV caches, keeping only each sequence's
//! hot attention window in DRAM.
//!
//! * [`request`] — request specs, digest-signed replayable JSON traces,
//!   and the seeded generator with heavy-tailed prompt/output lengths
//!   (bounded-Pareto prompts, Zipf output ranks via [`crate::util::prng`]),
//! * [`kv`] — the paged KV cache: fixed [`kv::PAGE_TOKENS`]-token pages,
//!   a per-sequence hot window in DRAM, cold pages striped across CXL
//!   AICs through [`crate::mem::striping::weighted_split`], and the
//!   `dram-only` / `tiered[:H]` policy registry,
//! * [`sim`] — the continuous-batching event loop (an adapter over
//!   [`crate::simcore`] like `fleet::sim`), the `fcfs` / `slo-strict`
//!   admission registry, and the memoized per-(model, phase, batch
//!   bucket, context bucket) step-cost calibrator that prices steps with
//!   real `offload::executor` runs of the `prefill` / `decode` schedules,
//! * [`metrics`] — per-request records, TTFT/TPOT distributions,
//!   sustained throughput, per-tier KV occupancy curves, digests, JSON.
//!
//! Determinism is the same contract as the fleet: identical traces
//! produce bit-identical [`ServeResult::digest`]s across reruns and
//! thread counts.

pub mod kv;
pub mod metrics;
pub mod request;
pub mod sim;

pub use kv::{KvCounters, KvPager, KvPolicy, KvPolicyRef, PAGE_TOKENS};
pub use metrics::{RequestRecord, RequestStatus, ServeResult};
pub use request::{RequestGen, RequestSpec, RequestTrace};
pub use sim::{
    admission_by_name, admission_known_names, dram_kv_budget, simulate_serving, AdmitPolicy,
    AdmitRef, ServeCalibrator, ServeProbe,
};
