//! The multi-tenant discrete-event fleet simulator, an adapter over the
//! shared [`crate::simcore`] event core (DESIGN.md §14).
//!
//! Jobs arrive over simulated time (a [`simcore::EventQueue`] ordered by
//! [`simcore::EventKey`], dslab-style: completions before faults before
//! arrivals before re-queues at equal timestamps via the key's kind rank,
//! unique sequence numbers as the final tie-break, `f64::to_bits` as the
//! time component — exact for the non-negative times the fleet uses),
//! pass the configured admission policy, occupy DRAM/CXL capacity and GPU
//! slots on a [`FleetHost`] for their whole residency, and run
//! `iterations × iter_s` where `iter_s` comes from a [`Calibrator`]: one
//! *real* `offload::executor` run per distinct (configuration, engine,
//! degradation) triple, memoized, so fleets of hundreds of jobs cost
//! hundreds of plan builds but only a handful of executor runs.
//!
//! The port onto `simcore` kept every observable byte and moved the
//! per-event costs into memos (the frozen pre-port loop survives as
//! [`super::reference::ref_simulate_fleet_faulted`], the oracle that
//! `rust/tests/simcore_parity.rs` diffs against):
//!
//! * events drain in equal-timestamp cohorts (`EventQueue::pop_cohort`) —
//!   one queue operation per cohort; every push is strictly future
//!   (debug-asserted), so a popped cohort can never miss a same-time
//!   event;
//! * scheduling passes that provably admit nothing are elided: all three
//!   policies are greedy over monotone engines, so the end of any pass is
//!   a no-admission fixpoint that only a completion, fault, or newly
//!   queued job can break (the `dirty` flag below). Occupancy samples are
//!   still taken per event, so the sample stream — and therefore the
//!   digest — is byte-identical;
//! * the probe's topology view, plan builds, calibration prices, and
//!   failed probes are memoized in a [`ProbeCtx`] keyed by interned
//!   (config, engine) ids instead of formatted strings.
//!
//! Hardware faults ([`FaultTrace`]) are first-class events in the same
//! heap. Applying one folds it into a [`Degradation`], rebuilds the
//! degraded topology view that admission and calibration see from then
//! on, shrinks the host's effective capacities, and hands every resident
//! job the fault touched to the run's [`RecoveryPolicy`]
//! (`fail-stop` / `checkpoint-restart` / `evacuate` — see
//! [`simulate_fleet_faulted`] for the mechanics). With an empty fault
//! trace every added code path is a no-op and [`simulate_fleet`] is
//! bit-identical to the fault-free simulator under every recovery policy
//! (pinned by `zero_fault_run_is_bitwise_identical_across_recovery_policies`).
//!
//! Determinism contract: the event loop is serial and every tie is broken
//! by explicit keys; calibration cells are pure functions of (topology,
//! config, engine, degradation), so pre-warming them in parallel
//! (`--threads`) cannot change any value. Identical traces therefore
//! produce bit-identical [`FleetResult::digest`]s across reruns and
//! thread counts (pinned by `rust/tests/fleet_sim.rs` and
//! `rust/tests/fleet_faults.rs`).
//!
//! Rejection rule: a job is rejected *at arrival* iff the policy cannot
//! place it on an **empty** host (same engines, same accounting) — the
//! host being the machine *as degraded at that instant* — otherwise it
//! queues. The recorded rejection reason is the first engine's refusal.

use std::collections::{BTreeMap, BTreeSet};

use super::faults::{self, Degradation, FaultKind, FaultTrace, RecoveryAction, RecoveryRef};
use super::host::FleetHost;
use super::job::{FleetTrace, JobSpec, TraceGen};
use super::metrics::{FleetResult, JobRecord, JobStatus, OccupancySample};
use super::scheduler::{AdmissionProbe, PolicyRef, PLACEMENT_AWARE_ALTERNATIVES};
use crate::mem::engine;
use crate::model::presets as mpresets;
use crate::offload::{
    schedules, simulate_iteration, MemoryPlan, PlanReservation, RunConfig, RunProfiles,
};
use crate::simcore::{lanes, EventKey, EventQueue};
use crate::topology::SystemTopology;
use crate::util::memo::Memo;
use crate::util::units::fmt_bytes;

/// Calibrated price of one iteration of a (configuration, engine) pair,
/// measured on the empty host.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CalCost {
    pub iter_s: f64,
    pub tokens_per_iter: u64,
}

pub(crate) fn resolve_cfg(spec: &JobSpec, engine_name: &str) -> Option<RunConfig> {
    let model = mpresets::by_name(&spec.model)?;
    let eng = engine::by_name(engine_name)?;
    let schedule = schedules::by_name(&spec.schedule)?;
    Some(RunConfig::new(model, spec.workload(), eng).with_schedule(schedule))
}

/// Placement-independent per-region profiles of a job's configuration
/// (probe-based, so always computed against the real topology whose
/// capacities validate).
fn compute_profiles(topo: &SystemTopology, spec: &JobSpec) -> Option<RunProfiles> {
    if spec.gpus > topo.gpus.len() {
        return None;
    }
    let cfg = resolve_cfg(spec, "baseline-dram")?;
    MemoryPlan::profile_run(topo, &cfg).ok()
}

/// One real executor run on the empty host: the job's calibrated cost.
/// Falls back to a lifetime-aware plan for configurations only timeline
/// accounting can fit at all.
fn compute_cost(
    topo: &SystemTopology,
    spec: &JobSpec,
    engine_name: &str,
    profiles: Option<&RunProfiles>,
) -> Option<CalCost> {
    if spec.gpus > topo.gpus.len() {
        return None;
    }
    let cfg = resolve_cfg(spec, engine_name)?;
    let prof = profiles?;
    let plan = MemoryPlan::build_with_profiles(topo, &cfg, false, prof.clone())
        .or_else(|_| MemoryPlan::build_with_profiles(topo, &cfg, true, prof.clone()))
        .ok()?;
    let bd = simulate_iteration(topo, &cfg, &plan);
    Some(CalCost {
        iter_s: bd.iter_s,
        tokens_per_iter: bd.tokens,
    })
}

/// Memoized per-(configuration, engine, degradation) cost model and
/// per-configuration profile cache. Every value is a pure function of the
/// topology it was measured on, so cache warm-up order — including the
/// parallel pre-warm — cannot change results. Profiles are
/// placement-independent and always measured on the pristine topology;
/// costs are keyed by the [`Degradation::key`] of the machine they were
/// priced on (empty for pristine, so the zero-fault cache is unchanged).
///
/// Both layers are [`crate::util::memo::Memo`] tables — the same
/// value-pure cache implementation the sweep's
/// [`crate::offload::evalcache::EvalCtx`] builds on.
pub struct Calibrator<'t> {
    topo: &'t SystemTopology,
    profiles: Memo<String, Option<RunProfiles>>,
    costs: Memo<String, Option<CalCost>>,
}

impl<'t> Calibrator<'t> {
    pub fn new(topo: &'t SystemTopology) -> Self {
        Self {
            topo,
            profiles: Memo::new(),
            costs: Memo::new(),
        }
    }

    /// Cached measured profiles of the job's configuration (`None` when
    /// the model/schedule does not resolve or wants more GPUs than exist).
    pub fn profiles(&mut self, spec: &JobSpec) -> Option<RunProfiles> {
        let topo = self.topo;
        self.profiles
            .get_or_insert_with(spec.config_key(), || compute_profiles(topo, spec))
    }

    /// Cached calibrated cost of (configuration, engine) on the pristine
    /// host.
    pub fn cost(&mut self, spec: &JobSpec, engine_name: &str) -> Option<CalCost> {
        let topo = self.topo;
        self.cost_on(topo, "", spec, engine_name)
    }

    /// Cached calibrated cost of (configuration, engine) on `topo`, which
    /// must be the machine `deg_key` describes (the pristine topology for
    /// the empty key). Costs priced on differently degraded machines live
    /// in distinct cells and never collide.
    pub fn cost_on(
        &mut self,
        topo: &SystemTopology,
        deg_key: &str,
        spec: &JobSpec,
        engine_name: &str,
    ) -> Option<CalCost> {
        let key = format!("{}|{engine_name}|{deg_key}", spec.config_key());
        if let Some(v) = self.costs.get(&key) {
            return v;
        }
        let prof = self.profiles(spec);
        let v = compute_cost(topo, spec, engine_name, prof.as_ref());
        self.costs.insert(key, v);
        v
    }

    /// Pre-compute the distinct (configuration, requested-engine) cells of
    /// a trace across `threads` workers. Costs the placement-aware policy
    /// derives for substitute engines — and every cell on a degraded
    /// machine — still fill in lazily (serial).
    pub fn prewarm(&mut self, jobs: &[JobSpec], threads: usize) {
        let mut cells: BTreeMap<String, JobSpec> = BTreeMap::new();
        for j in jobs {
            cells
                .entry(format!("{}|{}", j.config_key(), j.engine))
                .or_insert_with(|| j.clone());
        }
        let cells: Vec<JobSpec> = cells.into_values().collect();
        let topo = self.topo;
        // Value-pure fan-out: results come back in item order whatever the
        // lane count, so the merge below is lane-count invariant.
        let results = lanes::par_indexed(cells.len(), threads, |i| {
            let spec = &cells[i];
            let prof = compute_profiles(topo, spec);
            let cost = compute_cost(topo, spec, &spec.engine, prof.as_ref());
            (prof, cost)
        });
        for (spec, (prof, cost)) in cells.iter().zip(results) {
            // Seeding is counter-neutral and never overwrites a value the
            // lazy path already cached.
            self.profiles.seed(spec.config_key(), prof);
            // Trailing '|' = the empty pristine degradation key.
            self.costs
                .seed(format!("{}|{}|", spec.config_key(), spec.engine), cost);
        }
    }
}

/// A recorded admission decision of one scheduling pass. The engine is an
/// interned id into the run's [`ProbeCtx`]; the caller materializes the
/// name only for the jobs that actually start.
struct ProbeAdmission {
    engine: u16,
    reservation: PlanReservation,
    cost: CalCost,
}

/// Cap on the plan/reservation memo: value-pure, so wholesale clearing
/// when full can only cost recomputation, never change a decision.
const PLAN_MEMO_CAP: usize = 1 << 14;

/// Interned engine names: the admission hot path compares `u16` ids where
/// the pre-port loop formatted `String` keys. Linear scan — the registry
/// plus the placement-aware alternates is a handful of names.
#[derive(Default)]
struct EngineInterner {
    names: Vec<String>,
}

impl EngineInterner {
    fn intern(&mut self, name: &str) -> u16 {
        if let Some(i) = self.names.iter().position(|n| n == name) {
            return i as u16;
        }
        assert!(self.names.len() < u16::MAX as usize, "engine interner full");
        self.names.push(name.to_string());
        (self.names.len() - 1) as u16
    }

    fn name(&self, id: u16) -> &str {
        &self.names[id as usize]
    }
}

/// Long-lived admission state shared by every scheduling pass of one run.
///
/// `blocked` memoizes failed probes by `(config, engine, accounting)`:
/// between two capacity-growing events, free capacity and free GPU slots
/// only *shrink* (admissions debit, arrivals change nothing), and every
/// registered engine is monotone in the free vector, so a failed probe
/// provably fails again until capacity is freed — the event loop clears
/// the set exactly then (completions, and every fault: restores grow
/// capacity back). Unlike the pre-port string key there is no degradation
/// component: the set is cleared at every fault, so an entry never
/// outlives the degradation state it was observed under. This turns the
/// O(queue × engines) plan rebuilds a long blocked queue would pay at
/// every arrival into set lookups, without changing a single admission
/// decision.
struct ProbeCtx {
    /// Persistent scratch clone of the (possibly degraded) host topology,
    /// rebuilt only when a fault lands; only its `mem_nodes[..].capacity`
    /// fields are rewritten (to the working free bytes) before each plan
    /// build, so probes cost capacity writes, not per-event deep clones.
    view: SystemTopology,
    engines: EngineInterner,
    blocked: BTreeSet<(u32, u16, bool)>,
    /// Plan/reservation memo. `MemoryPlan::build_with_profiles` is a pure
    /// function of (config, engine, accounting, degradation, exact free
    /// vector), so a hit replays the reservation — or the byte-identical
    /// refusal string — without building anything. Bounded by
    /// [`PLAN_MEMO_CAP`]: a [`Memo`] clears itself wholesale when full,
    /// the same shared implementation the sweep's `EvalCtx` uses.
    #[allow(clippy::type_complexity)]
    plans: Memo<(u32, u16, bool, u32, Vec<u64>), Result<PlanReservation, String>>,
    /// Calibrated price per (config, engine, degradation epoch): spares
    /// the per-call string key the calibrator itself would format.
    costs: Memo<(u32, u16, u32), Option<CalCost>>,
    /// Bumped at every fault. Epoch-keyed memo entries from a *restored*
    /// equivalent degradation state recompute rather than hit — the
    /// functions are pure, so the recomputed values cannot differ.
    deg_epoch: u32,
}

impl ProbeCtx {
    fn new(topo: &SystemTopology) -> Self {
        ProbeCtx {
            view: topo.clone(),
            engines: EngineInterner::default(),
            blocked: BTreeSet::new(),
            plans: Memo::with_cap(PLAN_MEMO_CAP),
            costs: Memo::new(),
            deg_epoch: 0,
        }
    }
}

/// The simulator's [`AdmissionProbe`]: a working free view (memory + GPU
/// slots) that `MemoryPlan` builds — or their memoized reservations — are
/// checked against and debited from as the policy picks jobs. `base` is
/// the (possibly degraded) machine itself, kept un-rewritten for
/// calibration.
struct Probe<'a, 't> {
    ctx: &'a mut ProbeCtx,
    base: &'a SystemTopology,
    deg_key: &'a str,
    free: Vec<u64>,
    free_gpus: usize,
    queue: Vec<&'a JobSpec>,
    /// Interned config id per queued job (parallel to `queue`).
    queue_cfg: Vec<u32>,
    cal: &'a mut Calibrator<'t>,
    admissions: Vec<Option<ProbeAdmission>>,
    /// First refusal reason per queued job (feeds `JobRecord::reason`).
    reasons: Vec<Option<String>>,
}

impl<'a, 't> Probe<'a, 't> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        topo: &'a SystemTopology,
        free: Vec<u64>,
        free_gpus: usize,
        queue: Vec<&'a JobSpec>,
        queue_cfg: Vec<u32>,
        cal: &'a mut Calibrator<'t>,
        ctx: &'a mut ProbeCtx,
        deg_key: &'a str,
    ) -> Self {
        let n = queue.len();
        debug_assert_eq!(n, queue_cfg.len());
        Self {
            ctx,
            base: topo,
            deg_key,
            free,
            free_gpus,
            queue,
            queue_cfg,
            cal,
            admissions: (0..n).map(|_| None).collect(),
            reasons: (0..n).map(|_| None).collect(),
        }
    }

    /// Record the first refusal reason for job `idx` (later candidates'
    /// refusals are noise once one engine has explained itself).
    fn note(&mut self, idx: usize, msg: String) {
        if self.reasons[idx].is_none() {
            self.reasons[idx] = Some(msg);
        }
    }
}

impl AdmissionProbe for Probe<'_, '_> {
    fn queue_len(&self) -> usize {
        self.queue.len()
    }

    fn job(&self, idx: usize) -> &JobSpec {
        self.queue[idx]
    }

    fn try_admit(&mut self, idx: usize, engine_name: Option<&str>, lifetime: bool) -> bool {
        if self.admissions[idx].is_some() {
            return false;
        }
        let spec = self.queue[idx];
        let cfg_id = self.queue_cfg[idx];
        let eng_id = self.ctx.engines.intern(engine_name.unwrap_or(&spec.engine));
        let probe_key = (cfg_id, eng_id, lifetime);
        if self.ctx.blocked.contains(&probe_key) {
            return false;
        }
        if spec.gpus > self.free_gpus {
            self.ctx.blocked.insert(probe_key);
            let msg = format!("wants {} GPUs, {} free", spec.gpus, self.free_gpus);
            self.note(idx, msg);
            return false;
        }
        let epoch = self.ctx.deg_epoch;
        let plan_key = (cfg_id, eng_id, lifetime, epoch, self.free.clone());
        let outcome = if let Some(v) = self.ctx.plans.get(&plan_key) {
            v
        } else {
            let engine = self.ctx.engines.name(eng_id).to_string();
            let v = match self.cal.profiles(spec).zip(resolve_cfg(spec, &engine)) {
                None => Err(format!(
                    "{engine}: model/schedule/engine does not resolve or cannot be profiled"
                )),
                Some((profiles, cfg)) => {
                    // Plan against the working free view: capacities =
                    // what is left.
                    for (node, cap) in self.ctx.view.mem_nodes.iter_mut().zip(&self.free) {
                        node.capacity = *cap;
                    }
                    match MemoryPlan::build_with_profiles(&self.ctx.view, &cfg, lifetime, profiles)
                    {
                        Ok(p) => Ok(p.reservation()),
                        Err(e) => Err(format!("{engine}: {e}")),
                    }
                }
            };
            // The memo enforces PLAN_MEMO_CAP itself (clear-when-full).
            self.ctx.plans.insert(plan_key, v.clone());
            v
        };
        let reservation = match outcome {
            Ok(r) => r,
            Err(msg) => {
                self.ctx.blocked.insert(probe_key);
                self.note(idx, msg);
                return false;
            }
        };
        // Price only engines that actually admit: the calibration cell is
        // a real executor run, wasted on candidates whose plan fails.
        let cost_key = (cfg_id, eng_id, self.ctx.deg_epoch);
        let cost = if let Some(c) = self.ctx.costs.get(&cost_key) {
            c
        } else {
            let engine = self.ctx.engines.name(eng_id).to_string();
            let c = self.cal.cost_on(self.base, self.deg_key, spec, &engine);
            self.ctx.costs.insert(cost_key, c);
            c
        };
        let Some(cost) = cost else {
            self.ctx.blocked.insert(probe_key);
            let msg = format!("{}: calibration failed", self.ctx.engines.name(eng_id));
            self.note(idx, msg);
            return false;
        };
        for (n, b) in &reservation.parts {
            debug_assert!(self.free[n.0] >= *b, "probe view over-promised");
            self.free[n.0] -= *b;
        }
        self.free_gpus -= spec.gpus;
        self.admissions[idx] = Some(ProbeAdmission {
            engine: eng_id,
            reservation,
            cost,
        });
        true
    }
}

/// Can the policy place this job on an EMPTY host? (The reject-at-arrival
/// feasibility check — runs the real policy against a single-job queue
/// with full capacity of the machine *as currently degraded*, so
/// fifo/backfill test the requested engine under static accounting and
/// placement-aware tests its whole engine menu under lifetime
/// accounting.) Returns `None` when the job is placeable and the first
/// refusal reason otherwise.
fn feasible_on_empty(
    topo: &SystemTopology,
    spec: &JobSpec,
    cfg_id: u32,
    policy: &PolicyRef,
    cal: &mut Calibrator<'_>,
    ctx: &mut ProbeCtx,
    deg_key: &str,
) -> Option<String> {
    let free: Vec<u64> = topo.mem_nodes.iter().map(|n| n.capacity).collect();
    // A throwaway blocked-set (pre-port semantics): failures observed at
    // *current* capacity do not apply to the empty-host hypothetical, and
    // vice versa. The value-pure plan/cost memos stay shared — the
    // empty-host free vector is just another key.
    let saved = std::mem::take(&mut ctx.blocked);
    let mut probe = Probe::new(
        topo,
        free,
        topo.gpus.len(),
        vec![spec],
        vec![cfg_id],
        cal,
        ctx,
        deg_key,
    );
    policy.schedule(&mut probe);
    let verdict = if probe.admissions[0].is_some() {
        None
    } else {
        Some(probe.reasons[0].clone().unwrap_or_else(|| {
            "no registered engine can place the job on an empty host".to_string()
        }))
    };
    drop(probe);
    ctx.blocked = saved;
    verdict
}

const EV_COMPLETE: u8 = 0;
const EV_FAULT: u8 = 1;
const EV_ARRIVE: u8 = 2;
const EV_REQUEUE: u8 = 3;

/// "This job has no live completion event" sentinel for `completion_seq`.
const NO_COMPLETION: u64 = u64::MAX;

/// Mutable per-job lifecycle state; the immutable [`JobSpec`] stays in the
/// trace (the event loop reads it by reference, never clones it).
struct JobState {
    status: JobStatus,
    engine_used: Option<String>,
    start_s: Option<f64>,
    finish_s: Option<f64>,
    iter_s: Option<f64>,
    reason: Option<String>,
    /// Iterations safely behind the last checkpoint (survive a restart).
    durable_iters: u64,
    /// Iterations of the in-flight run segment (remaining at admission).
    run_iters: u64,
    /// Scheduled finish time of the in-flight run segment.
    pending_finish_s: f64,
    interruptions: u32,
    migrations: u32,
    recovery_s: f64,
    lost_tokens: u64,
    /// Iterations actually executed (useful + lost), across all segments.
    processed_iters: u64,
}

impl JobState {
    fn fresh() -> Self {
        JobState {
            status: JobStatus::Queued,
            engine_used: None,
            start_s: None,
            finish_s: None,
            iter_s: None,
            reason: None,
            durable_iters: 0,
            run_iters: 0,
            pending_finish_s: 0.0,
            interruptions: 0,
            migrations: 0,
            recovery_s: 0.0,
            lost_tokens: 0,
            processed_iters: 0,
        }
    }
}

/// Aggregate bandwidth available for evacuating regions off a faulted
/// node: the sum of the single-flow link capacities of every *online*
/// CXL AIC (DRAM-bound moves ride those same links), with the DRAM
/// stream bandwidth as the floor when every AIC is gone.
pub(crate) fn migration_bandwidth(topo: &SystemTopology) -> f64 {
    topo.migration_bandwidth()
}

/// Human-readable fault description for job records and CLI summaries.
pub(crate) fn describe_fault(topo: &SystemTopology, kind: &FaultKind) -> String {
    match kind {
        FaultKind::LinkDegrade { link, bw_factor } => format!(
            "link {} degraded to {:.0}% bandwidth",
            topo.links[*link].name,
            bw_factor * 100.0
        ),
        FaultKind::NodeOffline { node } => {
            format!("node {} went offline", topo.mem_nodes[*node].name)
        }
        FaultKind::NodeRestore { node } => {
            format!("node {} restored", topo.mem_nodes[*node].name)
        }
        FaultKind::CapacitySqueeze { node, bytes } => format!(
            "node {} squeezed by {}",
            topo.mem_nodes[*node].name,
            fmt_bytes(*bytes)
        ),
    }
}

/// Run a whole trace under one policy on a fault-free machine. `threads`
/// only parallelizes the calibration pre-warm — the event loop itself is
/// serial and the result digest is independent of the worker count.
pub fn simulate_fleet(
    topo: &SystemTopology,
    trace: &FleetTrace,
    policy: &PolicyRef,
    threads: usize,
) -> FleetResult {
    let recovery = faults::by_name("fail-stop").expect("registered");
    simulate_fleet_faulted(topo, trace, policy, &FaultTrace::empty(), &recovery, threads)
}

/// Run a whole trace under one policy while injecting `faults`, resolving
/// every hit resident job through `recovery`.
///
/// Recovery mechanics (the policy is pure choice, this is the machinery):
///
/// * **fail-stop** — the job dies where it stands: regions and GPUs are
///   released, all processed work is lost.
/// * **checkpoint-restart** — progress rolls back to the last multiple of
///   [`faults::CHECKPOINT_INTERVAL_ITERS`]; the job releases everything
///   and re-queues after an exponential backoff
///   ([`faults::BACKOFF_BASE_S`] `· 2^(hit-1)`), failing outright after
///   [`faults::MAX_RETRIES`] interruptions. Re-admission re-plans (and
///   may re-price) on the then-current machine; only the iterations past
///   the checkpoint are re-run.
/// * **evacuate** — the job's regions are re-planned against the degraded
///   host's *free* view (its own bytes released first; requested engine,
///   then the placement-aware alternates, static then lifetime
///   accounting) and migrated at the cost of `bytes-moved / remaining
///   aggregate link bandwidth`, which delays its completion; GPUs stay
///   held and no progress is lost. When nothing fits, it falls back to
///   checkpoint-restart. The per-iteration price stays locked at
///   admission — a link degrade slows *future* admissions' calibration,
///   not jobs already running (documented simplification).
pub fn simulate_fleet_faulted(
    topo: &SystemTopology,
    trace: &FleetTrace,
    policy: &PolicyRef,
    faults: &FaultTrace,
    recovery: &RecoveryRef,
    threads: usize,
) -> FleetResult {
    let mut ids = BTreeSet::new();
    for j in &trace.jobs {
        assert!(ids.insert(j.id), "duplicate job id {}", j.id);
        assert!(
            j.arrival_s.is_finite() && j.arrival_s >= 0.0,
            "job {}: arrival must be a non-negative finite time",
            j.id
        );
        assert!(j.iterations >= 1, "job {}: needs at least one iteration", j.id);
        assert!(
            j.gpus >= 1 && j.batch >= 1 && j.context >= 1,
            "job {}: workload dimensions must be positive",
            j.id
        );
    }
    faults
        .validate(topo)
        .unwrap_or_else(|e| panic!("invalid fault trace: {e}"));
    let id_to_idx: BTreeMap<u64, usize> =
        trace.jobs.iter().enumerate().map(|(i, j)| (j.id, i)).collect();
    let mut cal = Calibrator::new(topo);
    cal.prewarm(&trace.jobs, threads);
    let mut host = FleetHost::new(topo);
    let mut jobs: Vec<JobState> = trace.jobs.iter().map(|_| JobState::fresh()).collect();

    // Interned config ids, in first-appearance order over the trace: the
    // hot admission path compares these instead of formatted string keys.
    let mut cfg_cells: BTreeMap<String, u32> = BTreeMap::new();
    let cfg_ids: Vec<u32> = trace
        .jobs
        .iter()
        .map(|j| {
            let next = cfg_cells.len() as u32;
            *cfg_cells.entry(j.config_key()).or_insert(next)
        })
        .collect();
    drop(cfg_cells);

    // Event key: `time_bits · kind · seq` ([`EventKey`]; the payload is a
    // job index except for EV_FAULT events, where it indexes
    // `faults.events`). At one timestamp completions sort before faults
    // (a job that finishes at t is done) and faults before arrivals (a
    // job arriving at t sees the post-fault machine); `seq` makes every
    // key unique. `EventKey::new` folds a hand-written `-0.0` time into
    // `+0.0` — its sign-bit pattern would otherwise sort after every
    // positive time.
    let mut events: EventQueue<usize> = EventQueue::new();
    for (i, s) in trace.jobs.iter().enumerate() {
        events.push(EventKey::new(s.arrival_s, EV_ARRIVE, i as u64), i);
    }
    // Fault, completion and re-queue events continue the unique-sequence
    // space after arrivals (zero faults ⇒ the sequence allocation is
    // byte-identical to the fault-free simulator's).
    let mut seq: u64 = trace.jobs.len() as u64;
    for (fi, ev) in faults.events.iter().enumerate() {
        events.push(EventKey::new(ev.t_s, EV_FAULT, seq), fi);
        seq += 1;
    }

    // The live completion event per job: a fault that kills, restarts or
    // migrates a running job cannot remove its queued completion from the
    // heap, so it bumps this sequence instead and the stale pop is skipped.
    let mut completion_seq: Vec<u64> = vec![NO_COMPLETION; trace.jobs.len()];

    let mut deg = Degradation::pristine(topo);
    let mut deg_key = String::new();
    // The degraded machine, rebuilt at each fault; `None` ⇒ pristine (use
    // `topo` itself — keeps the zero-fault path free of clones).
    let mut dtopo: Option<SystemTopology> = None;

    let mut queue: Vec<usize> = Vec::new();
    let mut samples: Vec<OccupancySample> = Vec::new();
    // Arrival-feasibility memo keyed (config id, requested-engine id,
    // degradation epoch): `None` = feasible, `Some(reason)` = reject.
    let mut feasible: BTreeMap<(u32, u16, u32), Option<String>> = BTreeMap::new();
    // Blocked-probe set, plan/cost memos, and the persistent topology
    // view (see [`ProbeCtx`]); completions and faults (restores!) grow
    // capacity, so they clear the blocked set.
    let mut ctx = ProbeCtx::new(topo);
    let mut n_events: u64 = 0;
    let mut running: usize = 0;
    // The no-admission-fixpoint flag: set by every event that could let a
    // queued job start (freed capacity, a fault's clears and restores, a
    // newly queued job); while clear, a scheduling pass provably admits
    // nothing and is elided. Rejected arrivals touch nothing the policies
    // read, so they leave it clear.
    let mut dirty = false;

    // Drain equal-timestamp cohorts whole. Every push below is strictly
    // future (debug-asserted), so no event belonging to the popped cohort
    // can appear after the pop; within the cohort events apply in key
    // order, and samples/passes stay per-event — the observable stream is
    // exactly the one-pop-at-a-time loop's.
    let mut cohort: Vec<(EventKey, usize)> = Vec::new();
    let mut cohort_pos = 0usize;
    loop {
        if cohort_pos == cohort.len() {
            if !events.pop_cohort(&mut cohort) {
                break;
            }
            cohort_pos = 0;
        }
        let (key, ji) = cohort[cohort_pos];
        cohort_pos += 1;
        let kind = key.kind();
        // A cancelled (stale) completion: its job was killed, restarted or
        // migrated by a fault after this event was scheduled.
        if kind == EV_COMPLETE && completion_seq[ji] != key.seq() {
            continue;
        }
        let now = key.time();
        n_events += 1;
        match kind {
            EV_COMPLETE => {
                let spec = &trace.jobs[ji];
                host.release(spec.id, spec.gpus)
                    .unwrap_or_else(|e| panic!("completion of job {}: {e}", spec.id));
                completion_seq[ji] = NO_COMPLETION;
                jobs[ji].processed_iters += jobs[ji].run_iters;
                jobs[ji].status = JobStatus::Completed;
                jobs[ji].finish_s = Some(now);
                running -= 1;
                ctx.blocked.clear();
                dirty = true;
            }
            EV_FAULT => {
                let ev = &faults.events[ji];
                deg.apply(&ev.kind);
                deg_key = deg.key();
                dtopo = if deg.is_pristine() {
                    None
                } else {
                    Some(deg.degraded_topo(topo))
                };
                let eff = deg.effective_caps(topo);
                for (i, cap) in eff.iter().enumerate() {
                    host.set_capacity(i, *cap);
                }
                // New degradation state: epoch-keyed memo entries go
                // stale, the blocked set resets, and the persistent probe
                // view is re-cloned from the degraded machine.
                ctx.deg_epoch += 1;
                ctx.view = dtopo.as_ref().unwrap_or(topo).clone();
                ctx.blocked.clear();
                dirty = true;
                let desc = describe_fault(topo, &ev.kind);

                // Victims: residents whose bytes the fault touched, with
                // the byte count that must move or die.
                let victims: Vec<(usize, u64)> = match &ev.kind {
                    FaultKind::NodeOffline { node } => host
                        .residents_on(*node)
                        .into_iter()
                        .map(|(id, bytes)| (id_to_idx[&id], bytes))
                        .collect(),
                    FaultKind::CapacitySqueeze { node, .. } => {
                        let used = host.used()[*node];
                        if used > eff[*node] {
                            // Evict the largest residents first (fewest
                            // victims), job id as the deterministic tie.
                            let mut residents = host.residents_on(*node);
                            residents.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                            let mut overshoot = used - eff[*node];
                            let mut v = Vec::new();
                            for (id, bytes) in residents {
                                if overshoot == 0 {
                                    break;
                                }
                                v.push((id_to_idx[&id], bytes));
                                overshoot = overshoot.saturating_sub(bytes);
                            }
                            v
                        } else {
                            Vec::new()
                        }
                    }
                    // Bandwidth loss and hot-add displace no bytes.
                    FaultKind::LinkDegrade { .. } | FaultKind::NodeRestore { .. } => Vec::new(),
                };

                // Release every victim's memory before re-planning any of
                // them: an evacuation may reuse the room a co-victim frees.
                for &(vji, _) in &victims {
                    host.release_memory(trace.jobs[vji].id)
                        .unwrap_or_else(|e| panic!("fault victim: {e}"));
                }
                let cur = dtopo.as_ref().unwrap_or(topo);
                for (vji, bytes_hit) in victims {
                    let spec = &trace.jobs[vji];
                    let tpi = spec.workload().tokens_per_iter();
                    let st = &mut jobs[vji];
                    let iter_s = st.iter_s.expect("victim was running");
                    let remaining =
                        ((st.pending_finish_s - now) / iter_s).ceil().max(0.0) as u64;
                    let run_done = st.run_iters.saturating_sub(remaining);
                    st.interruptions += 1;
                    let hit = st.interruptions;
                    let action = recovery.decide(spec, hit);
                    let mut eff_action = action;
                    if action == RecoveryAction::Evacuate {
                        // Re-plan against the degraded free view (the
                        // victim's own bytes are already released).
                        let free = host.free();
                        let mut view = cur.clone();
                        for (node, cap) in view.mem_nodes.iter_mut().zip(&free) {
                            node.capacity = *cap;
                        }
                        let mut candidates: Vec<String> = vec![st
                            .engine_used
                            .clone()
                            .unwrap_or_else(|| spec.engine.clone())];
                        for alt in PLACEMENT_AWARE_ALTERNATIVES {
                            if !candidates.iter().any(|c| c == alt) {
                                candidates.push(alt.to_string());
                            }
                        }
                        let mut placed: Option<(String, PlanReservation)> = None;
                        'search: for engine_name in &candidates {
                            let Some((profiles, cfg)) =
                                cal.profiles(spec).zip(resolve_cfg(spec, engine_name))
                            else {
                                continue;
                            };
                            for lifetime in [false, true] {
                                if let Ok(plan) = MemoryPlan::build_with_profiles(
                                    &view,
                                    &cfg,
                                    lifetime,
                                    profiles.clone(),
                                ) {
                                    placed = Some((engine_name.clone(), plan.reservation()));
                                    break 'search;
                                }
                            }
                        }
                        if let Some((engine_name, resv)) = placed {
                            host.reserve_memory(spec.id, &resv)
                                .expect("plan was built against the free view");
                            let migrate_s = bytes_hit as f64 / migration_bandwidth(cur);
                            st.pending_finish_s += migrate_s;
                            // Strictly future: a victim is running, so its
                            // pending finish is past `now` (a completion
                            // at exactly `now` sorts before the fault and
                            // already removed it from residency).
                            debug_assert!(st.pending_finish_s > now);
                            events.push(EventKey::new(st.pending_finish_s, EV_COMPLETE, seq), vji);
                            completion_seq[vji] = seq;
                            seq += 1;
                            st.status = JobStatus::Migrated;
                            st.migrations += 1;
                            st.recovery_s += migrate_s;
                            st.engine_used = Some(engine_name);
                            // GPUs stay held; no progress is lost (the
                            // delayed completion credits the full segment).
                            continue;
                        }
                        eff_action = RecoveryAction::CheckpointRestart;
                    }
                    // Kill or restart: the run segment ends here.
                    st.processed_iters += run_done;
                    host.release_gpus(spec.gpus);
                    running -= 1;
                    completion_seq[vji] = NO_COMPLETION;
                    if eff_action == RecoveryAction::CheckpointRestart
                        && hit <= faults::MAX_RETRIES
                    {
                        let total_done = st.durable_iters + run_done;
                        let ckpt = (total_done / faults::CHECKPOINT_INTERVAL_ITERS)
                            * faults::CHECKPOINT_INTERVAL_ITERS;
                        st.lost_tokens += (total_done - ckpt) * tpi;
                        st.durable_iters = ckpt;
                        st.status = JobStatus::Interrupted;
                        let backoff = faults::BACKOFF_BASE_S * 2f64.powi(hit as i32 - 1);
                        debug_assert!(backoff > 0.0);
                        events.push(EventKey::new(now + backoff, EV_REQUEUE, seq), vji);
                        seq += 1;
                    } else {
                        st.status = JobStatus::Failed;
                        st.finish_s = Some(now);
                        // Nothing completed: every processed iteration is
                        // sunk work.
                        st.lost_tokens = st.processed_iters * tpi;
                        st.reason = Some(if action == RecoveryAction::FailStop {
                            format!("fail-stop: {desc}")
                        } else {
                            format!("retries exhausted after {desc}")
                        });
                    }
                }
            }
            EV_ARRIVE => {
                // Reject at arrival iff the policy cannot place the job
                // even on an empty host (as currently degraded); otherwise
                // it queues.
                let spec = &trace.jobs[ji];
                let cur = dtopo.as_ref().unwrap_or(topo);
                let eng = ctx.engines.intern(&spec.engine);
                let fkey = (cfg_ids[ji], eng, ctx.deg_epoch);
                let verdict = match feasible.get(&fkey) {
                    Some(v) => v.clone(),
                    None => {
                        let v = feasible_on_empty(
                            cur,
                            spec,
                            cfg_ids[ji],
                            policy,
                            &mut cal,
                            &mut ctx,
                            &deg_key,
                        );
                        feasible.insert(fkey, v.clone());
                        v
                    }
                };
                match verdict {
                    None => {
                        queue.push(ji);
                        dirty = true;
                    }
                    Some(reason) => {
                        jobs[ji].status = JobStatus::Rejected;
                        jobs[ji].reason = Some(reason);
                    }
                }
            }
            EV_REQUEUE => {
                // The backoff after an interruption elapsed: back in line.
                jobs[ji].status = JobStatus::Queued;
                queue.push(ji);
                dirty = true;
            }
            _ => unreachable!("unknown event kind {kind}"),
        }

        // Scheduling pass: hand the policy the queued specs by reference.
        // Elided when the state is still a no-admission fixpoint (see
        // `dirty` above) — the frozen loop runs it unconditionally and the
        // parity suite shows the elision is invisible.
        if dirty && !queue.is_empty() {
            let cur = dtopo.as_ref().unwrap_or(topo);
            let snapshot: Vec<&JobSpec> = queue.iter().map(|&i| &trace.jobs[i]).collect();
            let snapshot_cfg: Vec<u32> = queue.iter().map(|&i| cfg_ids[i]).collect();
            let admissions = {
                let mut probe = Probe::new(
                    cur,
                    host.free(),
                    host.free_gpus(),
                    snapshot,
                    snapshot_cfg,
                    &mut cal,
                    &mut ctx,
                    &deg_key,
                );
                policy.schedule(&mut probe);
                probe.admissions
            };
            let mut started: Vec<usize> = Vec::new();
            for (qpos, adm) in admissions.into_iter().enumerate() {
                let Some(adm) = adm else { continue };
                let ji = queue[qpos];
                let spec = &trace.jobs[ji];
                host.reserve(spec.id, &adm.reservation, spec.gpus)
                    .expect("probe debited the identical free view");
                // Only the iterations past the durable checkpoint re-run.
                let remaining = spec.iterations as u64 - jobs[ji].durable_iters;
                let finish = now + adm.cost.iter_s * remaining as f64;
                debug_assert!(finish > now, "calibrated iteration time must be positive");
                jobs[ji].status = JobStatus::Running;
                jobs[ji].engine_used = Some(ctx.engines.name(adm.engine).to_string());
                if jobs[ji].start_s.is_none() {
                    jobs[ji].start_s = Some(now);
                }
                jobs[ji].iter_s = Some(adm.cost.iter_s);
                jobs[ji].run_iters = remaining;
                jobs[ji].pending_finish_s = finish;
                events.push(EventKey::new(finish, EV_COMPLETE, seq), ji);
                completion_seq[ji] = seq;
                seq += 1;
                running += 1;
                started.push(qpos);
            }
            for &qpos in started.iter().rev() {
                queue.remove(qpos);
            }
        }
        dirty = false;
        samples.push(OccupancySample {
            t_s: now,
            used: host.used(),
            queue_len: queue.len(),
            running,
        });
    }
    assert!(running == 0, "fleet failed to drain: {running} still running");
    if !queue.is_empty() {
        // Only a degraded machine can strand queued jobs (the fault-free
        // loop re-schedules at every completion until everything starts).
        assert!(
            !faults.events.is_empty(),
            "fleet failed to drain with no faults: {} queued",
            queue.len()
        );
        for ji in queue {
            let spec = &trace.jobs[ji];
            let tpi = spec.workload().tokens_per_iter();
            jobs[ji].status = JobStatus::Failed;
            jobs[ji].reason =
                Some("starved on the degraded host after the trace drained".to_string());
            jobs[ji].lost_tokens = jobs[ji].processed_iters * tpi;
        }
    }

    let mut result = FleetResult::new(policy.name(), topo);
    result.recovery = recovery.name().to_string();
    result.n_events = n_events;
    result.n_faults = faults.events.len() as u64;
    result.samples = samples;
    result.records = trace
        .jobs
        .iter()
        .zip(jobs)
        .map(|(spec, j)| {
            let tpi = spec.workload().tokens_per_iter();
            JobRecord {
                id: spec.id,
                model: spec.model.clone(),
                gpus: spec.gpus,
                batch: spec.batch,
                context: spec.context,
                schedule: spec.schedule.clone(),
                engine_requested: spec.engine.clone(),
                engine_used: j.engine_used,
                iterations: spec.iterations,
                arrival_s: spec.arrival_s,
                start_s: j.start_s,
                finish_s: j.finish_s,
                iter_s: j.iter_s,
                total_tokens: spec.total_tokens(),
                status: j.status,
                reason: j.reason,
                interruptions: j.interruptions,
                migrations: j.migrations,
                recovery_s: j.recovery_s,
                lost_tokens: j.lost_tokens,
                processed_tokens: j.processed_iters * tpi,
            }
        })
        .collect();
    result
}

/// The pinned evaluation trace: `n_mixed` jobs from [`TraceGen::mixed`]
/// plus `n_xl` "XL" jobs at the first batch rung (context 32768) whose
/// *static* footprint overflows the host but whose per-phase peak fits —
/// the cells only a lifetime-aware admission policy can serve. Returns
/// the mixed trace unchanged when the host has no such rung (ample DRAM);
/// callers that depend on the XL cell assert on `jobs.len()`.
pub fn mixed_trace_with_xl(
    topo: &SystemTopology,
    seed: u64,
    n_mixed: usize,
    n_xl: usize,
) -> FleetTrace {
    let mut tg = TraceGen::mixed(seed, n_mixed);
    // Lighter than the default mix: enough idle capacity that the XL jobs
    // mostly run in windows the static policies would leave empty.
    tg.mean_interarrival_s = 240.0;
    let mut trace = tg.generate();
    if n_xl == 0 {
        return trace;
    }
    let xl_engine = "cxl-aware+striping";
    let context = 32768usize;
    let model = mpresets::by_name("7b").expect("preset");
    let mut xl_batch = None;
    for rung in 1..=40usize {
        let batch = rung * 8;
        let cfg = RunConfig::new(
            model.clone(),
            crate::model::footprint::Workload::new(1, batch, context),
            engine::by_name(xl_engine).expect("registered"),
        );
        // Static fit is monotone in batch (only activations grow), so the
        // first failing rung is THE static/lifetime boundary candidate.
        if !MemoryPlan::fits(topo, &cfg) {
            if MemoryPlan::fits_lifetime_aware(topo, &cfg) {
                xl_batch = Some(batch);
            }
            break;
        }
    }
    let Some(batch) = xl_batch else {
        return trace;
    };
    let span = trace.jobs.last().map(|j| j.arrival_s).unwrap_or(0.0);
    let base_id = trace.jobs.len() as u64;
    for k in 0..n_xl {
        trace.jobs.push(JobSpec {
            id: base_id + k as u64,
            arrival_s: span * (k as f64 + 1.0) / (n_xl as f64 + 1.0),
            model: "7b".into(),
            gpus: 1,
            batch,
            context,
            schedule: "zero-offload".into(),
            engine: xl_engine.into(),
            iterations: 1,
        });
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::scheduler;
    use crate::topology::presets::dev_tiny;
    use crate::util::units::MIB;

    fn job(id: u64, arrival: f64, batch: usize, context: usize) -> JobSpec {
        JobSpec {
            id,
            arrival_s: arrival,
            model: "tiny-2m".into(),
            gpus: 1,
            batch,
            context,
            schedule: "zero-offload".into(),
            engine: "cxl-aware+striping".into(),
            iterations: 2,
        }
    }

    /// dev-tiny shrunk so tiny-2m jobs actually contend for memory.
    fn tight_topo() -> SystemTopology {
        let mut t = dev_tiny();
        t.mem_nodes[0].capacity = 48 * MIB;
        t.mem_nodes[1].capacity = 16 * MIB;
        t.mem_nodes[2].capacity = 16 * MIB;
        t.validate();
        t
    }

    #[test]
    fn single_job_runs_to_completion() {
        let topo = dev_tiny();
        let trace = FleetTrace {
            seed: 0,
            jobs: vec![job(0, 1.0, 2, 256)],
        };
        let policy = scheduler::by_name("fifo").unwrap();
        let res = simulate_fleet(&topo, &trace, &policy, 1);
        assert_eq!(res.completed(), 1);
        assert_eq!(res.rejected(), 0);
        assert_eq!(res.n_events, 2, "one arrival + one completion");
        let r = &res.records[0];
        assert_eq!(r.start_s, Some(1.0), "empty host admits on arrival");
        let iter_s = r.iter_s.unwrap();
        assert!(iter_s > 0.0);
        assert!((r.finish_s.unwrap() - (1.0 + 2.0 * iter_s)).abs() < 1e-9);
        assert_eq!(r.engine_used.as_deref(), Some("cxl-aware+striping"));
        // occupancy returns to zero at the final sample
        let last = res.samples.last().unwrap();
        assert!(last.used.iter().all(|&u| u == 0));
    }

    #[test]
    fn gpu_slots_serialize_a_two_gpu_host() {
        // Three 1-GPU jobs arriving together on a 2-GPU host: two start at
        // once, the third waits for the first completion.
        let topo = dev_tiny();
        let trace = FleetTrace {
            seed: 0,
            jobs: vec![job(0, 0.0, 1, 256), job(1, 0.0, 1, 256), job(2, 0.0, 1, 256)],
        };
        let policy = scheduler::by_name("fifo").unwrap();
        let res = simulate_fleet(&topo, &trace, &policy, 1);
        assert_eq!(res.completed(), 3);
        let starts: Vec<f64> = res.records.iter().map(|r| r.start_s.unwrap()).collect();
        assert_eq!(starts[0], 0.0);
        assert_eq!(starts[1], 0.0);
        assert!(starts[2] > 0.0, "third job must wait for a GPU slot");
        assert_eq!(res.max_queue_len(), 1);
    }

    #[test]
    fn infeasible_jobs_are_rejected_at_arrival() {
        let topo = tight_topo();
        // context 65536 × batch 8 tiny-2m activation checkpoints alone
        // (512·B·C bytes) overflow the whole 80 MiB machine under any
        // accounting; the small job is untouched.
        let trace = FleetTrace {
            seed: 0,
            jobs: vec![job(0, 0.0, 8, 65536), job(1, 1.0, 1, 256)],
        };
        for policy in scheduler::registry() {
            let res = simulate_fleet(&topo, &trace, &policy, 1);
            assert_eq!(res.rejected(), 1, "{}", policy.name());
            assert_eq!(res.completed(), 1, "{}", policy.name());
            assert_eq!(
                res.records[0].status,
                JobStatus::Rejected,
                "{}: the XL job is the rejected one",
                policy.name()
            );
            assert!(res.records[0].start_s.is_none());
            // Satellite: the rejection carries its reason into the record.
            let reason = res.records[0].reason.as_deref().unwrap_or_default();
            assert!(!reason.is_empty(), "{}: rejection must say why", policy.name());
            assert!(res.records[1].reason.is_none(), "{}", policy.name());
        }
    }

    #[test]
    fn backfill_starts_small_jobs_a_blocked_fifo_head_delays() {
        // GPU-slot head-of-line blocking on a 2-GPU host, all arrivals at
        // t=0 (same-time events process in id order): job 0 takes one GPU,
        // job 1 wants both and blocks, job 2 wants the remaining one.
        // Fifo's blocked head also delays job 2; backfill lets it jump.
        let topo = dev_tiny();
        let mut j1 = job(1, 0.0, 1, 256);
        j1.gpus = 2;
        let trace = FleetTrace {
            seed: 0,
            jobs: vec![job(0, 0.0, 1, 256), j1, job(2, 0.0, 1, 256)],
        };
        let fifo = scheduler::by_name("fifo").unwrap();
        let backfill = scheduler::by_name("backfill").unwrap();
        let rf = simulate_fleet(&topo, &trace, &fifo, 1);
        let rb = simulate_fleet(&topo, &trace, &backfill, 1);
        assert_eq!(rf.completed(), 3);
        assert_eq!(rb.completed(), 3);
        let start = |r: &FleetResult, id: usize| r.records[id].start_s.unwrap();
        // Under fifo, job 2 starts only after the blocked 2-GPU head ran.
        assert!(start(&rf, 1) > 0.0, "head must wait for job 0's GPU");
        assert!(start(&rf, 2) >= start(&rf, 1));
        // Backfill starts job 2 immediately, jumping the blocked head.
        assert_eq!(start(&rb, 2), 0.0, "backfill must jump the blocked head");
        assert!(
            start(&rb, 2) < start(&rb, 1),
            "small job first: {} vs {}",
            start(&rb, 2),
            start(&rb, 1)
        );
    }

    #[test]
    fn calibrator_memoizes_costs_and_profiles() {
        let topo = dev_tiny();
        let mut cal = Calibrator::new(&topo);
        let a = job(0, 0.0, 2, 256);
        let c1 = cal.cost(&a, "cxl-aware+striping").unwrap();
        let c2 = cal.cost(&a, "cxl-aware+striping").unwrap();
        assert_eq!(c1, c2);
        assert_eq!(cal.costs.len(), 1, "one (config, engine) cell");
        assert_eq!(cal.profiles.len(), 1);
        // same config, second engine → one more cost cell, no new profile
        cal.cost(&a, "baseline-dram").unwrap();
        assert_eq!(cal.costs.len(), 2);
        assert_eq!(cal.profiles.len(), 1);
        assert!(cal.cost(&a, "no-such-engine").is_none());
        // pre-warm is value-identical to the lazy path
        let mut warm = Calibrator::new(&topo);
        warm.prewarm(&[a.clone()], 4);
        assert_eq!(warm.cost(&a, &a.engine), cal.cost(&a, &a.engine));
    }

    #[test]
    fn calibrator_keys_costs_by_degradation_state() {
        // A cost priced on a degraded machine lives in its own cell and
        // never shadows (or is shadowed by) the pristine price.
        let topo = dev_tiny();
        let mut cal = Calibrator::new(&topo);
        let a = job(0, 0.0, 2, 4096);
        let pristine = cal.cost(&a, "cxl-aware+striping").unwrap();
        let mut deg = Degradation::pristine(&topo);
        deg.apply(&FaultKind::LinkDegrade {
            link: 2,
            bw_factor: 0.25,
        });
        let dt = deg.degraded_topo(&topo);
        let degraded = cal
            .cost_on(&dt, &deg.key(), &a, "cxl-aware+striping")
            .unwrap();
        assert_eq!(cal.costs.len(), 2, "distinct cells per degradation");
        // The pristine cell is untouched by the degraded measurement.
        assert_eq!(cal.cost(&a, "cxl-aware+striping").unwrap(), pristine);
        assert!(
            degraded.iter_s >= pristine.iter_s,
            "a slower link cannot make an iteration faster: {} vs {}",
            degraded.iter_s,
            pristine.iter_s
        );
    }

    #[test]
    fn zero_fault_run_is_bitwise_identical_across_recovery_policies() {
        // The acceptance bar for the fault machinery: with an empty fault
        // trace, every recovery policy (and thread count) produces the
        // exact digest of the fault-free simulator.
        let topo = tight_topo();
        let trace = FleetTrace {
            seed: 0,
            jobs: vec![job(0, 0.0, 2, 256), job(1, 5.0, 2, 512), job(2, 9.0, 1, 256)],
        };
        let policy = scheduler::by_name("backfill").unwrap();
        let base = simulate_fleet(&topo, &trace, &policy, 1);
        assert_eq!(base.completed(), 3);
        let empty = FaultTrace::empty();
        for recovery in faults::registry() {
            for threads in [1, 4] {
                let res =
                    simulate_fleet_faulted(&topo, &trace, &policy, &empty, &recovery, threads);
                assert_eq!(
                    res.digest(),
                    base.digest(),
                    "{} × {threads} threads must be a bitwise no-op",
                    recovery.name()
                );
                assert_eq!(res.recovery, recovery.name());
                assert_eq!(res.n_faults, 0);
            }
        }
    }

    #[test]
    fn recovery_policies_resolve_a_hot_remove_differently() {
        // One memory-hungry job whose activations spill onto the CXL
        // nodes; the derived pinned faults degrade its link, hot-remove
        // cxl0 mid-run, and restore it later. fail-stop kills the job,
        // checkpoint-restart loses progress but finishes, evacuate
        // migrates (or at worst restarts) and finishes no later.
        let topo = tight_topo();
        let mut spec = job(0, 0.0, 8, 10240);
        spec.iterations = 4;
        let trace = FleetTrace {
            seed: 0,
            jobs: vec![spec],
        };
        let policy = scheduler::by_name("placement-aware").unwrap();
        let baseline = simulate_fleet(&topo, &trace, &policy, 1);
        assert_eq!(baseline.completed(), 1);
        let faults_trace = faults::pinned_faults_from_baseline(&topo, &baseline);
        faults_trace.validate(&topo).unwrap();

        let run = |name: &str| {
            let recovery = faults::by_name(name).unwrap();
            simulate_fleet_faulted(&topo, &trace, &policy, &faults_trace, &recovery, 1)
        };
        let fs = run("fail-stop");
        assert_eq!(fs.completed(), 0, "fail-stop kills the only job");
        assert_eq!(fs.failed(), 1);
        assert_eq!(fs.records[0].status, JobStatus::Failed);
        let reason = fs.records[0].reason.as_deref().unwrap();
        assert!(reason.starts_with("fail-stop:"), "{reason}");
        assert!(fs.records[0].lost_tokens > 0, "killed mid-run work is lost");
        assert_eq!(fs.useful_tokens(), 0);

        let cr = run("checkpoint-restart");
        assert_eq!(cr.completed(), 1, "the restarted job finishes");
        assert!(cr.interruptions() >= 1);
        let cr_finish = cr.records[0].finish_s.unwrap();
        assert!(
            cr_finish > baseline.records[0].finish_s.unwrap(),
            "backoff + rework must delay completion"
        );

        let ev = run("evacuate");
        assert_eq!(ev.completed(), 1, "the evacuated job finishes");
        assert!(ev.interruptions() >= 1);
        let ev_finish = ev.records[0].finish_s.unwrap();
        assert!(
            ev_finish <= cr_finish,
            "migration never loses to restart-with-backoff: {ev_finish} vs {cr_finish}"
        );
        assert!(
            ev.goodput_tokens_per_sec() >= cr.goodput_tokens_per_sec(),
            "evacuate goodput {} < checkpoint-restart {}",
            ev.goodput_tokens_per_sec(),
            cr.goodput_tokens_per_sec()
        );
        assert!(
            ev.goodput_tokens_per_sec() > fs.goodput_tokens_per_sec(),
            "evacuate must strictly beat fail-stop on goodput"
        );
        // Reruns are bit-reproducible fault-for-fault.
        assert_eq!(run("evacuate").digest(), ev.digest());
        assert_eq!(run("fail-stop").digest(), fs.digest());
    }

    #[test]
    fn a_squeeze_below_occupancy_evicts_the_largest_resident() {
        // Squeeze DRAM below what the resident job holds there: the job is
        // a victim even though the node stays online.
        let topo = tight_topo();
        let mut spec = job(0, 0.0, 8, 10240);
        spec.iterations = 4;
        let trace = FleetTrace {
            seed: 0,
            jobs: vec![spec],
        };
        let policy = scheduler::by_name("placement-aware").unwrap();
        let baseline = simulate_fleet(&topo, &trace, &policy, 1);
        let mid = baseline.records[0].finish_s.unwrap() * 0.5;
        // Squeezing DRAM down to 1 MiB guarantees occupancy > capacity.
        let squeeze = FaultTrace {
            seed: 0,
            events: vec![faults::FaultEvent {
                t_s: mid,
                kind: FaultKind::CapacitySqueeze {
                    node: 0,
                    bytes: 47 * MIB,
                },
            }],
        };
        squeeze.validate(&topo).unwrap();
        let recovery = faults::by_name("fail-stop").unwrap();
        let res = simulate_fleet_faulted(&topo, &trace, &policy, &squeeze, &recovery, 1);
        assert_eq!(res.failed(), 1, "the squeezed-out job dies under fail-stop");
        assert!(res.records[0].reason.as_deref().unwrap().contains("squeezed"));
        // Occupancy respects the squeezed capacity in every later sample.
        for s in res.samples.iter().filter(|s| s.t_s >= mid) {
            assert!(s.used[0] <= MIB, "sample at {} overshoots", s.t_s);
        }
    }
}
