//! Inference schedules: the serving layer's two phases as schedule DAGs.
//!
//! * [`Prefill`] — one forward pass over the whole prompt: per block,
//!   stream the bf16 parameters in (prefetch window exactly like the
//!   Fig. 1 forward), run the block kernel, and write the block's KV
//!   pairs back to the host KV pool. No backward, no optimizer.
//! * [`Decode`] — one autoregressive step for a batch of sequences whose
//!   KV length is `workload.context`: per block, stream the parameters
//!   in, read the block's accumulated KV from the host pool, run the
//!   single-token kernel (projection/MLP work for one token plus the
//!   attention reads over the whole context), and append the new token's
//!   KV.
//!
//! Both builders carry honest [`RegionTouch::Dma`] annotations on every
//! transfer — KV traffic rides the plan's per-GPU activation region (the
//! host-side streaming pool) and parameter streams ride `params16` — so
//! `AccessProfile`/lifetime accounting and the P009 honesty lint see
//! exactly the bytes the executor will move. The serving simulator
//! calibrates its per-(configuration, phase) step costs by pricing these
//! schedules through `offload::simulate_iteration`, the same machinery
//! the fleet calibrator uses for training jobs.

use super::super::plan::{MemoryPlan, RunConfig};
use super::super::schedule::{FlopsTerm, Op, OpNode, Schedule};
use super::zero_offload::IterQuantities;
use super::ScheduleBuilder;
use crate::model::flops;
use crate::model::ModelConfig;
use crate::sim::fabric::Dir;
use crate::topology::{GpuId, SystemTopology};

/// KV-cache bytes one token adds per transformer block: K and V vectors
/// (2 tensors) in bf16 (2 bytes) across every KV head.
pub fn kv_bytes_per_token_block(m: &ModelConfig) -> f64 {
    2.0 * 2.0 * (m.kv_heads as f64) * (m.head_dim as f64)
}

/// KV-cache bytes one token occupies across the whole model — what the
/// serving pager sizes its pages from.
pub fn kv_bytes_per_token(m: &ModelConfig) -> u64 {
    (kv_bytes_per_token_block(m) * m.layers as f64) as u64
}

/// The prompt pass: forward-only parameter streaming + per-block KV
/// writeback for `workload.context` prompt tokens.
pub struct Prefill;

impl ScheduleBuilder for Prefill {
    fn name(&self) -> &str {
        "prefill"
    }

    fn build(&self, _topo: &SystemTopology, cfg: &RunConfig, plan: &MemoryPlan<'_>) -> Schedule {
        let q = IterQuantities::compute(cfg, plan);
        let (b, c) = (cfg.workload.batch, cfg.workload.context);
        let kv_block_bytes = (b * c) as f64 * kv_bytes_per_token_block(&cfg.model);
        let p16 = plan.params16_fractions();

        let mut s = Schedule::new(cfg.workload.tokens_per_iter());
        let prefill = s.phase("prefill");
        for g in 0..cfg.workload.n_gpus {
            let acts = plan.activation_fractions(GpuId(g));
            let h2d = format!("gpu{g}/h2d");
            let d2h = format!("gpu{g}/d2h");
            let compute = format!("gpu{g}/compute");
            let mut load = vec![None; q.layers];
            let mut fwd = vec![None; q.layers];
            for l in 0..q.depth.min(q.layers) {
                load[l] = Some(s.push(OpNode {
                    op: Op::Transfer {
                        gpu: GpuId(g),
                        stripes: p16.clone(),
                        dir: Dir::HostToGpu,
                        bytes: q.param_block_bytes,
                    },
                    deps: vec![],
                    name: format!("param-load b{l}"),
                    lane: h2d.clone(),
                    phase: prefill,
                    ends_phase: false,
                    touches: vec![crate::offload::RegionTouch::Dma(plan.params16)],
                }));
            }
            for l in 0..q.layers {
                let mut deps = vec![load[l].expect("prefetch covered every block")];
                if l > 0 {
                    deps.push(fwd[l - 1].unwrap());
                }
                let mut work = vec![FlopsTerm::new(q.f_fwd_block)];
                if l == 0 || l == q.layers - 1 {
                    // embedding on the first block, LM head on the last
                    work.push(FlopsTerm::scaled(q.f_head, 0.5));
                }
                let fc = s.push(OpNode {
                    op: Op::Compute {
                        gpu: GpuId(g),
                        work,
                    },
                    deps,
                    name: format!("prefill b{l}"),
                    lane: compute.clone(),
                    phase: prefill,
                    ends_phase: false,
                    touches: vec![],
                });
                fwd[l] = Some(fc);
                s.push(OpNode {
                    op: Op::Transfer {
                        gpu: GpuId(g),
                        stripes: acts.clone(),
                        dir: Dir::GpuToHost,
                        bytes: kv_block_bytes,
                    },
                    deps: vec![fc],
                    name: format!("kv-writeback b{l}"),
                    lane: d2h.clone(),
                    phase: prefill,
                    // The last block's writeback closes the (only) phase.
                    ends_phase: g == cfg.workload.n_gpus - 1 && l == q.layers - 1,
                    touches: vec![crate::offload::RegionTouch::Dma(plan.activations[g])],
                });
                let nxt = l + q.depth;
                if nxt < q.layers {
                    load[nxt] = Some(s.push(OpNode {
                        op: Op::Transfer {
                            gpu: GpuId(g),
                            stripes: p16.clone(),
                            dir: Dir::HostToGpu,
                            bytes: q.param_block_bytes,
                        },
                        deps: vec![fc],
                        name: format!("param-load b{nxt}"),
                        lane: h2d.clone(),
                        phase: prefill,
                        ends_phase: false,
                        touches: vec![crate::offload::RegionTouch::Dma(plan.params16)],
                    }));
                }
            }
        }
        s
    }
}

/// One autoregressive decode step: `workload.context` is the sequences'
/// current KV length, `workload.batch` the number of sequences per GPU.
/// Emits one new token per sequence.
pub struct Decode;

impl ScheduleBuilder for Decode {
    fn name(&self) -> &str {
        "decode"
    }

    fn build(&self, _topo: &SystemTopology, cfg: &RunConfig, plan: &MemoryPlan<'_>) -> Schedule {
        let q = IterQuantities::compute(cfg, plan);
        let m = &cfg.model;
        let (b, c) = (cfg.workload.batch, cfg.workload.context);
        let kv_read_bytes = (b * c) as f64 * kv_bytes_per_token_block(m);
        let kv_append_bytes = b as f64 * kv_bytes_per_token_block(m);
        // Single-token block work: projections/MLP for one token, plus the
        // attention reads over the whole context (QKᵀ and attn·V, 2·2
        // FLOPs per context element per attended dimension).
        let f_token = flops::block_fwd_flops(m, b, 1);
        let f_attn = 4.0 * (b * c) as f64 * (m.heads * m.head_dim) as f64;
        let p16 = plan.params16_fractions();

        let mut s = Schedule::new((cfg.workload.n_gpus * b) as u64);
        let decode = s.phase("decode");
        for g in 0..cfg.workload.n_gpus {
            let acts = plan.activation_fractions(GpuId(g));
            let h2d = format!("gpu{g}/h2d");
            let d2h = format!("gpu{g}/d2h");
            let compute = format!("gpu{g}/compute");
            let mut load = vec![None; q.layers];
            let mut kv_read = vec![None; q.layers];
            let mut dec = vec![None; q.layers];
            let mut issue = |s: &mut Schedule, l: usize, dep: Option<crate::offload::OpId>| {
                let deps: Vec<_> = dep.into_iter().collect();
                (
                    s.push(OpNode {
                        op: Op::Transfer {
                            gpu: GpuId(g),
                            stripes: p16.clone(),
                            dir: Dir::HostToGpu,
                            bytes: q.param_block_bytes,
                        },
                        deps: deps.clone(),
                        name: format!("param-load b{l}"),
                        lane: h2d.clone(),
                        phase: decode,
                        ends_phase: false,
                        touches: vec![crate::offload::RegionTouch::Dma(plan.params16)],
                    }),
                    s.push(OpNode {
                        op: Op::Transfer {
                            gpu: GpuId(g),
                            stripes: acts.clone(),
                            dir: Dir::HostToGpu,
                            bytes: kv_read_bytes,
                        },
                        deps,
                        name: format!("kv-read b{l}"),
                        lane: h2d.clone(),
                        phase: decode,
                        ends_phase: false,
                        touches: vec![crate::offload::RegionTouch::Dma(plan.activations[g])],
                    }),
                )
            };
            for l in 0..q.depth.min(q.layers) {
                let (p, k) = issue(&mut s, l, None);
                load[l] = Some(p);
                kv_read[l] = Some(k);
            }
            for l in 0..q.layers {
                let mut deps = vec![
                    load[l].expect("prefetch covered every block"),
                    kv_read[l].unwrap(),
                ];
                if l > 0 {
                    deps.push(dec[l - 1].unwrap());
                }
                let mut work = vec![FlopsTerm::new(f_token), FlopsTerm::new(f_attn)];
                if l == q.layers - 1 {
                    work.push(FlopsTerm::new(flops::head_fwd_flops(m, b, 1)));
                }
                let dc = s.push(OpNode {
                    op: Op::Compute {
                        gpu: GpuId(g),
                        work,
                    },
                    deps,
                    name: format!("decode b{l}"),
                    lane: compute.clone(),
                    phase: decode,
                    ends_phase: false,
                    touches: vec![],
                });
                dec[l] = Some(dc);
                s.push(OpNode {
                    op: Op::Transfer {
                        gpu: GpuId(g),
                        stripes: acts.clone(),
                        dir: Dir::GpuToHost,
                        bytes: kv_append_bytes,
                    },
                    deps: vec![dc],
                    name: format!("kv-append b{l}"),
                    lane: d2h.clone(),
                    phase: decode,
                    ends_phase: g == cfg.workload.n_gpus - 1 && l == q.layers - 1,
                    touches: vec![crate::offload::RegionTouch::Dma(plan.activations[g])],
                });
                let nxt = l + q.depth;
                if nxt < q.layers {
                    let (p, k) = issue(&mut s, nxt, Some(dc));
                    load[nxt] = Some(p);
                    kv_read[nxt] = Some(k);
                }
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Policy;
    use crate::model::footprint::Workload;
    use crate::model::presets::tiny_2m;
    use crate::topology::presets::dev_tiny;

    #[test]
    fn prefill_builds_a_strict_clean_forward_only_dag() {
        let topo = dev_tiny();
        let cfg = RunConfig::new(tiny_2m(), Workload::new(2, 2, 256), Policy::DramOnly);
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let s = Prefill.build(&topo, &cfg, &plan);
        s.validate_strict(&topo).unwrap();
        // per GPU: L loads + L kernels + L writebacks, nothing else
        let l = cfg.model.layers;
        assert_eq!(s.len(), 2 * 3 * l);
        assert_eq!(s.phases, vec!["prefill"]);
        assert!(s.nodes.last().unwrap().ends_phase);
        assert_eq!(s.tokens, cfg.workload.tokens_per_iter());
    }

    #[test]
    fn decode_builds_a_strict_clean_single_token_dag() {
        let topo = dev_tiny();
        let cfg = RunConfig::new(tiny_2m(), Workload::new(2, 4, 512), Policy::DramOnly);
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let s = Decode.build(&topo, &cfg, &plan);
        s.validate_strict(&topo).unwrap();
        // per GPU: L loads + L kv-reads + L kernels + L kv-appends
        let l = cfg.model.layers;
        assert_eq!(s.len(), 2 * 4 * l);
        assert_eq!(s.phases, vec!["decode"]);
        // one new token per sequence
        assert_eq!(s.tokens, 2 * 4);
        // KV read grows with context, append does not
        let reads: Vec<f64> = s
            .nodes
            .iter()
            .filter(|n| n.name.starts_with("kv-read"))
            .map(|n| match &n.op {
                crate::offload::Op::Transfer { bytes, .. } => *bytes,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(reads.len(), 2 * l);
        let per_block = kv_bytes_per_token_block(&cfg.model);
        assert!((reads[0] - 4.0 * 512.0 * per_block).abs() < 1e-9);
    }

    #[test]
    fn kv_sizing_matches_the_model_shape() {
        let m = tiny_2m();
        // 2 tensors × 2 bytes × kv_heads × head_dim per block
        assert_eq!(
            kv_bytes_per_token_block(&m),
            (4 * m.kv_heads * m.head_dim) as f64
        );
        assert_eq!(
            kv_bytes_per_token(&m),
            (4 * m.kv_heads * m.head_dim * m.layers) as u64
        );
    }
}
