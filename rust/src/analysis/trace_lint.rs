//! Fleet-trace lints: integrity (digest), well-formedness (job fields,
//! duplicate ids), registry resolution (models / schedules / engines),
//! and arrival-order hygiene.
//!
//! Operates on parsed JSON rather than a [`FleetTrace`] so it can keep
//! going where `FleetTrace::from_json` must abort: one malformed job
//! becomes one P205 diagnostic and the remaining jobs are still checked.

use super::diag::{Anchor, Diagnostics, Severity};
use crate::fleet::{FleetTrace, JobSpec};
use crate::util::json::Json;

/// Lint a fleet trace as parsed JSON. See DESIGN.md §12 for the catalog.
pub fn lint_trace(j: &Json) -> Diagnostics {
    let mut ds = Diagnostics::new();
    let Some(obj) = j.as_obj() else {
        ds.push(
            "P205",
            Severity::Error,
            Anchor::Trace,
            "trace is not a JSON object",
        );
        return ds;
    };
    // Canonical traces carry the seed as a decimal string (u64 survives
    // round-tripping); a plain number is tolerated like `from_json` does.
    let seed = match obj.get("seed") {
        Some(Json::Str(s)) => s.parse::<u64>().ok(),
        Some(v) => v.as_u64(),
        None => None,
    };
    if seed.is_none() {
        ds.push(
            "P205",
            Severity::Error,
            Anchor::Trace,
            "trace is missing a u64 'seed'",
        );
    }
    let Some(jobs_json) = obj.get("jobs").and_then(|v| v.as_arr()) else {
        ds.push(
            "P205",
            Severity::Error,
            Anchor::Trace,
            "trace is missing a 'jobs' array",
        );
        return ds;
    };
    let mut jobs: Vec<JobSpec> = Vec::new();
    let mut all_parsed = true;
    for (idx, jj) in jobs_json.iter().enumerate() {
        match JobSpec::from_json(jj) {
            Ok(job) => {
                for issue in job.registry_issues() {
                    ds.push("P204", Severity::Error, Anchor::Job { id: job.id }, issue);
                }
                jobs.push(job);
            }
            Err(e) => {
                all_parsed = false;
                ds.push(
                    "P205",
                    Severity::Error,
                    Anchor::Trace,
                    format!("jobs[{idx}]: {e}"),
                );
            }
        }
    }
    let mut seen_ids = std::collections::BTreeSet::new();
    for job in &jobs {
        if !seen_ids.insert(job.id) {
            ds.push(
                "P202",
                Severity::Error,
                Anchor::Job { id: job.id },
                "duplicate job id",
            );
        }
    }
    // Arrival order: the fleet host replays jobs in listed order, so an
    // out-of-order arrival is legal (and exercised by the XL generator)
    // but usually means the trace was edited by hand.
    for w in jobs.windows(2) {
        if w[1].arrival_s < w[0].arrival_s {
            ds.push(
                "P203",
                Severity::Warn,
                Anchor::Job { id: w[1].id },
                format!(
                    "arrives at {:.3}s, before preceding job {} at {:.3}s \
                     (arrivals are not sorted)",
                    w[1].arrival_s, w[0].id, w[0].arrival_s
                ),
            );
        }
    }
    match obj.get("digest").and_then(|v| v.as_str()) {
        Some(want) => {
            // Recomputing requires every job to have parsed; P205 already
            // covers the trace when one did not.
            if let (Some(seed), true) = (seed, all_parsed) {
                let got = format!("{:016x}", FleetTrace { seed, jobs }.digest());
                if got != want {
                    ds.push(
                        "P201",
                        Severity::Error,
                        Anchor::Trace,
                        format!("digest mismatch: file says {want}, contents hash to {got}"),
                    );
                }
            }
        }
        None => ds.push(
            "P206",
            Severity::Info,
            Anchor::Trace,
            "trace carries no digest — integrity cannot be verified",
        ),
    }
    ds
}
