//! Deterministic pseudo-random number generation.
//!
//! No `rand` crate is available in the offline vendor set, so this module
//! provides the two generators the rest of the crate needs:
//!
//! * [`SplitMix64`] — stateless-ish stream used for seeding,
//! * [`Xoshiro256pp`] — the general-purpose generator (xoshiro256++ 1.0,
//!   Blackman & Vigna, public domain reference implementation).
//!
//! Everything in the crate that consumes randomness takes an explicit
//! generator so simulations, tests and property checks are reproducible
//! from a single `u64` seed.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` using Lemire's rejection method.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        // Lemire multiply-shift with rejection to remove modulo bias.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Exponentially distributed sample with the given `mean`, via the
    /// inverse-CDF transform `-mean · ln(1 − U)` — the inter-arrival time
    /// of a Poisson process with rate `1/mean`. One uniform draw per call,
    /// so traces built from this are reproducible from the seed alone.
    pub fn exp_mean(&mut self, mean: f64) -> f64 {
        assert!(mean > 0.0 && mean.is_finite(), "exp_mean needs mean > 0");
        // 1 − U ∈ (0, 1], so ln never sees 0 and the sample is finite.
        -mean * (1.0 - self.next_f64()).ln()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// `true` with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public splitmix64.c.
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn deterministic_across_clones() {
        let mut a = Xoshiro256pp::seeded(42);
        let mut b = a.clone();
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn unit_interval_bounds() {
        let mut rng = Xoshiro256pp::seeded(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Xoshiro256pp::seeded(9);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            // expectation 10_000, allow ±6%
            assert!((9_400..=10_600).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn below_never_exceeds_bound() {
        let mut rng = Xoshiro256pp::seeded(11);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(rng.below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut rng = Xoshiro256pp::seeded(13);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..1000 {
            match rng.range_u64(3, 5) {
                3 => saw_lo = true,
                5 => saw_hi = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn exp_mean_moments_and_support() {
        let mut rng = Xoshiro256pp::seeded(29);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.exp_mean(3.0);
            assert!(x >= 0.0 && x.is_finite(), "sample {x}");
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn exp_mean_is_deterministic() {
        let mut a = Xoshiro256pp::seeded(31);
        let mut b = Xoshiro256pp::seeded(31);
        for _ in 0..100 {
            assert_eq!(a.exp_mean(7.0).to_bits(), b.exp_mean(7.0).to_bits());
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256pp::seeded(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256pp::seeded(23);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
