//! `pallas lint` — a static verifier over the three IRs the system
//! already has: schedule graphs ([`crate::offload::Schedule`]), memory
//! plans ([`crate::offload::MemoryPlan`]), and fleet traces
//! ([`crate::fleet::FleetTrace`]).
//!
//! The paper's placement results stand on *honest accounting*: every
//! lifetime, stripe fraction, and admission decision downstream of a
//! schedule is derived from its `touches` annotations, so a dishonest or
//! incomplete annotation silently corrupts placement long before the
//! executor's runtime ledger could notice. This module moves those checks
//! to registration time: each `lint_*` entry point walks its IR and
//! returns [`Diagnostics`] — rustc-style findings with stable `P0xx`
//! codes, `Error`/`Warn`/`Info` severities, and node/region/phase/job
//! anchors — instead of scattered panics.
//!
//! Code space (full catalog in DESIGN.md §12):
//!
//! | Range | Layer | Entry point |
//! |---|---|---|
//! | `P001`–`P018` | schedule graph | [`lint_schedule`] |
//! | `P101`–`P105` | plan / allocator | [`lint_plan`], [`lint_commit`] |
//! | `P201`–`P206` | fleet trace | [`lint_trace`] |
//! | `P207`–`P209` | fault trace | [`lint_fault_trace`] |
//! | `P210`–`P212` | request trace | [`lint_request_trace`] |
//!
//! Integration: `Schedule::validate` renders the first `Error` (same
//! strings as the legacy checks), `Schedule::validate_strict` also fails
//! on warnings, `MemoryPlan` builds lint the probe schedule against the
//! probe plan, and the CLI `lint` subcommand (CI: `lint --all
//! --deny-warnings`) sweeps every registered schedule × preset.

pub mod diag;
mod plan_lint;
mod schedule_lint;
mod trace_lint;

pub use diag::{Anchor, Diagnostic, Diagnostics, Severity};
pub use plan_lint::{lint_commit, lint_plan};
pub(crate) use schedule_lint::lint_schedule_adjacency;
pub use schedule_lint::{lint_schedule, RegionInfo, ScheduleLintContext};
pub use trace_lint::{lint_fault_trace, lint_request_trace, lint_trace};
