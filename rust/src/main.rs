//! `cxlfine` — leader entrypoint.
//!
//! The coordinator binary: placement planning, iteration simulation,
//! figure sweeps, and the functional PJRT training loop. See `--help`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(cxlfine::cli::run(args));
}
