//! Fig. 3: 12B model, 4K context, 2 GPUs — throughput and memory vs batch
//! size (1 … 48).
//!
//! Paper shape: throughput improves with batch until GPU-utilization
//! saturation; memory grows linearly with batch.

use cxlfine::mem::Policy;
use cxlfine::model::footprint::{Footprint, Workload};
use cxlfine::model::presets::mistral_nemo_12b;
use cxlfine::offload::{simulate_iteration, MemoryPlan, RunConfig};
use cxlfine::topology::presets::config_a;
use cxlfine::trow;
use cxlfine::util::bench::{points_json, BenchReport};
use cxlfine::util::table::Table;
use cxlfine::util::units::GIB;

fn main() {
    let mut report = BenchReport::new("fig3_batch_scaling");
    let topo = config_a();
    let model = mistral_nemo_12b();
    let mut t = Table::new(&["batch", "cpu_mem_gib", "tokens_per_sec", "gain_vs_prev"]);
    let (mut xs, mut mem, mut tps) = (Vec::new(), Vec::new(), Vec::new());
    let mut prev = 0.0f64;
    for b in [1usize, 2, 4, 8, 16, 24, 32, 48] {
        let w = Workload::new(2, b, 4096);
        let f = Footprint::compute(&model, &w);
        let cfg = RunConfig::new(model.clone(), w, Policy::CxlAware { striping: false });
        let plan = MemoryPlan::build(&topo, &cfg).expect("plan fits");
        let bd = simulate_iteration(&topo, &cfg, &plan);
        let rate = bd.tokens_per_sec();
        t.row(trow![
            b,
            format!("{:.1}", f.total() as f64 / GIB as f64),
            format!("{rate:.0}"),
            if prev > 0.0 {
                format!("{:.2}x", rate / prev)
            } else {
                "-".into()
            }
        ]);
        xs.push(b as f64);
        mem.push(f.total() as f64 / GIB as f64);
        tps.push(rate);
        prev = rate;
    }
    // paper shape: big early gains, saturating tail
    let early_gain = tps[1] / tps[0];
    let late_gain = tps[7] / tps[6];
    assert!(early_gain > 1.3, "batch 1→2 should pay off: {early_gain}");
    assert!(late_gain < early_gain, "gains must diminish");
    // memory linear in batch
    let slope1 = (mem[7] - mem[6]) / 16.0;
    let slope2 = (mem[4] - mem[3]) / 8.0;
    assert!((slope1 / slope2 - 1.0).abs() < 0.05, "memory not linear in B");
    report.section(
        "throughput_and_mem_vs_batch",
        t,
        points_json(&xs, &[("cpu_mem_gib", &mem), ("tokens_per_sec", &tps)]),
    );
    report.finish();
}
