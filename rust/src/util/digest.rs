//! FNV-1a 64-bit digests for golden-trace locking.
//!
//! The simulator's determinism contract (DESIGN.md §7) is enforced by
//! comparing *digests* of full event/span sequences: floating-point
//! timestamps are folded in via their IEEE-754 bit patterns, so two runs
//! match iff they are bit-identical — a tolerance-free lock that survives
//! refactors only when the arithmetic is genuinely unchanged.
//!
//! FNV-1a is used because the goal is a stable, dependency-free fingerprint
//! of a deterministic byte stream, not collision resistance against an
//! adversary.

/// Incremental FNV-1a (64-bit).
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Fnv64 {
    pub fn new() -> Self {
        Self(FNV_OFFSET)
    }

    #[inline]
    pub fn write(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
        self
    }

    #[inline]
    pub fn write_u64(&mut self, v: u64) -> &mut Self {
        self.write(&v.to_le_bytes())
    }

    /// Fold in an `f64` by bit pattern (bit-identity, not tolerance).
    #[inline]
    pub fn write_f64(&mut self, v: f64) -> &mut Self {
        self.write_u64(v.to_bits())
    }

    /// Length-prefixed string write, so `("ab","c")` ≠ `("a","bc")`.
    #[inline]
    pub fn write_str(&mut self, s: &str) -> &mut Self {
        self.write_u64(s.len() as u64);
        self.write(s.as_bytes())
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // FNV-1a 64 reference values
        assert_eq!(Fnv64::new().finish(), 0xcbf29ce484222325);
        assert_eq!(Fnv64::new().write(b"a").finish(), 0xaf63dc4c8601ec8c);
        assert_eq!(Fnv64::new().write(b"foobar").finish(), 0x85944171f73967e8);
    }

    #[test]
    fn f64_folds_bit_pattern() {
        let mut a = Fnv64::new();
        a.write_f64(0.1 + 0.2);
        let mut b = Fnv64::new();
        b.write_f64(0.3);
        // 0.1+0.2 != 0.3 bitwise — the digest must see the difference
        assert_ne!(a.finish(), b.finish());
        let mut c = Fnv64::new();
        c.write_f64(0.1 + 0.2);
        assert_eq!(a.finish(), c.finish());
    }

    #[test]
    fn string_length_prefix_disambiguates() {
        let mut a = Fnv64::new();
        a.write_str("ab").write_str("c");
        let mut b = Fnv64::new();
        b.write_str("a").write_str("bc");
        assert_ne!(a.finish(), b.finish());
    }
}
