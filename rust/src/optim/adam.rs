//! Real vectorized, multithreaded CPU Adam — the optimizer the coordinator
//! executes after every iteration (ZeRO-Offload runs exactly this update on
//! the host; DeepSpeed's version is OpenMP + AVX, ours is chunked fan-out
//! over the persistent [`Pool`] + an inner loop the compiler
//! auto-vectorizes).
//!
//! [`adam_step`] submits its chunks to the process-wide worker pool rather
//! than spawning fresh OS threads per step: at small N (≤1M elements) the
//! update body is a few hundred µs, so `nthreads` × ~10–30 µs of spawn cost
//! was a measurable per-step tax. The old spawning path is kept as
//! [`adam_step_spawning`] — `benches/adam_hotpath.rs` reports the small-N
//! per-step overhead of both so the win stays measured, and the unit tests
//! pin the two paths (and the serial oracle) bitwise against each other.
//!
//! The update, per element:
//! ```text
//! m ← β₁·m + (1-β₁)·g           v ← β₂·v + (1-β₂)·g²
//! m̂ = m / (1-β₁ᵗ)               v̂ = v / (1-β₂ᵗ)
//! p ← p − lr·( m̂ / (√v̂ + ε) + λ·p )
//! ```

use crate::util::threadpool::{default_threads, Pool, ScopedTask};

/// Adam hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct AdamHp {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Decoupled weight decay (AdamW-style); 0 disables.
    pub weight_decay: f32,
}

impl Default for AdamHp {
    fn default() -> Self {
        Self {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
        }
    }
}

/// Optimizer state for one parameter group (fp32 master copy lives with it).
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// Completed steps (bias correction uses step+1 during the call).
    pub step: u64,
}

impl AdamState {
    pub fn new(n: usize) -> Self {
        Self {
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.m.len()
    }
    pub fn is_empty(&self) -> bool {
        self.m.is_empty()
    }
}

/// Single-threaded reference update over a slice range (also the oracle the
/// parallel path is tested against).
pub fn adam_update_serial(
    params: &mut [f32],
    grads: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    hp: &AdamHp,
    step: u64, // 1-based
) {
    assert_eq!(params.len(), grads.len());
    assert_eq!(params.len(), m.len());
    assert_eq!(params.len(), v.len());
    let bc1 = 1.0 - hp.beta1.powi(step as i32);
    let bc2 = 1.0 - hp.beta2.powi(step as i32);
    let inv_bc1 = 1.0 / bc1;
    let inv_bc2 = 1.0 / bc2;
    for i in 0..params.len() {
        let g = grads[i];
        let mi = hp.beta1 * m[i] + (1.0 - hp.beta1) * g;
        let vi = hp.beta2 * v[i] + (1.0 - hp.beta2) * g * g;
        m[i] = mi;
        v[i] = vi;
        let mhat = mi * inv_bc1;
        let vhat = vi * inv_bc2;
        params[i] -= hp.lr * (mhat / (vhat.sqrt() + hp.eps) + hp.weight_decay * params[i]);
    }
}

/// The optimized hot path over one chunk.
///
/// §Perf note (EXPERIMENTS.md): an earlier manually-unrolled-by-8 variant
/// was 20 % SLOWER than this plain zipped loop under
/// `-C target-cpu=native` — the sub-slice reborrows blocked LLVM's
/// vectorizer, while the iterator form below compiles to clean packed
/// AVX (vsqrtps + vdivps) with no bounds checks. Measure before unrolling.
#[inline]
pub fn adam_update_chunk(
    params: &mut [f32],
    grads: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    hp: &AdamHp,
    inv_bc1: f32,
    inv_bc2: f32,
) {
    let n = params.len();
    assert!(grads.len() == n && m.len() == n && v.len() == n);
    let lr = hp.lr;
    let b1 = hp.beta1;
    let ob1 = 1.0 - hp.beta1;
    let b2 = hp.beta2;
    let ob2 = 1.0 - hp.beta2;
    let eps = hp.eps;
    let wd = hp.weight_decay;
    for (((p, &g), mi), vi) in params
        .iter_mut()
        .zip(grads.iter())
        .zip(m.iter_mut())
        .zip(v.iter_mut())
    {
        let mn = b1 * *mi + ob1 * g;
        let vn = b2 * *vi + ob2 * g * g;
        *mi = mn;
        *vi = vn;
        let mhat = mn * inv_bc1;
        let vhat = vn * inv_bc2;
        *p -= lr * (mhat / (vhat.sqrt() + eps) + wd * *p);
    }
}

/// Shared prologue of both step paths: validates lengths, advances the
/// step counter, derives the bias-correction reciprocals, clamps the
/// worker count — and completes the update inline (returning `None`) for
/// the empty and single-threaded cases. Keeping this in one place is what
/// keeps [`adam_step`] and [`adam_step_spawning`] bitwise interchangeable
/// (`pool_path_matches_spawning_path_exactly`): only the fan-out mechanism
/// differs between them.
fn step_prologue(
    params: &mut [f32],
    grads: &[f32],
    state: &mut AdamState,
    hp: &AdamHp,
    nthreads: usize,
) -> Option<(f32, f32, usize)> {
    assert_eq!(params.len(), grads.len(), "param/grad length mismatch");
    assert_eq!(params.len(), state.len(), "param/state length mismatch");
    state.step += 1;
    let step = state.step;
    let bc1 = 1.0 - hp.beta1.powi(step as i32);
    let bc2 = 1.0 - hp.beta2.powi(step as i32);
    let inv_bc1 = 1.0 / bc1;
    let inv_bc2 = 1.0 / bc2;
    let n = params.len();
    if n == 0 {
        return None;
    }
    let nthreads = nthreads.max(1).min(n);
    if nthreads == 1 {
        adam_update_chunk(params, grads, &mut state.m, &mut state.v, hp, inv_bc1, inv_bc2);
        return None;
    }
    Some((inv_bc1, inv_bc2, nthreads))
}

/// Parallel Adam step: advances `state.step`, updates `params` in place.
///
/// Chunks fan out over the persistent [`Pool`] (see module docs); the
/// chunk math is element-local, so the result is bitwise identical to the
/// serial oracle regardless of worker count or execution order.
pub fn adam_step(
    params: &mut [f32],
    grads: &[f32],
    state: &mut AdamState,
    hp: &AdamHp,
    nthreads: usize,
) {
    let Some((inv_bc1, inv_bc2, nthreads)) = step_prologue(params, grads, state, hp, nthreads)
    else {
        return;
    };
    // Split all four slices identically and fan the chunks out to the pool.
    let n = params.len();
    let hp = *hp;
    let base = n / nthreads;
    let extra = n % nthreads;
    let mut tasks: Vec<ScopedTask<'_>> = Vec::with_capacity(nthreads);
    let mut p_rest = params;
    let mut g_rest = grads;
    let mut m_rest = state.m.as_mut_slice();
    let mut v_rest = state.v.as_mut_slice();
    for t in 0..nthreads {
        let len = base + usize::from(t < extra);
        let (p, pr) = p_rest.split_at_mut(len);
        let (g, gr) = g_rest.split_at(len);
        let (m, mr) = m_rest.split_at_mut(len);
        let (v, vr) = v_rest.split_at_mut(len);
        p_rest = pr;
        g_rest = gr;
        m_rest = mr;
        v_rest = vr;
        tasks.push(Box::new(move || {
            adam_update_chunk(p, g, m, v, &hp, inv_bc1, inv_bc2);
        }));
    }
    Pool::global().run_scoped(tasks);
}

/// The pre-pool `adam_step`: identical chunking, but spawning fresh scoped
/// OS threads on every call. Kept as the measured baseline for the pool
/// (`benches/adam_hotpath.rs` small-N section); results are bitwise
/// identical to [`adam_step`].
pub fn adam_step_spawning(
    params: &mut [f32],
    grads: &[f32],
    state: &mut AdamState,
    hp: &AdamHp,
    nthreads: usize,
) {
    let Some((inv_bc1, inv_bc2, nthreads)) = step_prologue(params, grads, state, hp, nthreads)
    else {
        return;
    };
    let n = params.len();
    let base = n / nthreads;
    let extra = n % nthreads;
    std::thread::scope(|scope| {
        let mut p_rest = params;
        let mut g_rest = grads;
        let mut m_rest = state.m.as_mut_slice();
        let mut v_rest = state.v.as_mut_slice();
        for t in 0..nthreads {
            let len = base + usize::from(t < extra);
            let (p, pr) = p_rest.split_at_mut(len);
            let (g, gr) = g_rest.split_at(len);
            let (m, mr) = m_rest.split_at_mut(len);
            let (v, vr) = v_rest.split_at_mut(len);
            p_rest = pr;
            g_rest = gr;
            m_rest = mr;
            v_rest = vr;
            scope.spawn(move || {
                adam_update_chunk(p, g, m, v, hp, inv_bc1, inv_bc2);
            });
        }
    });
}

/// Convenience wrapper with the default thread count.
pub fn adam_step_auto(params: &mut [f32], grads: &[f32], state: &mut AdamState, hp: &AdamHp) {
    adam_step(params, grads, state, hp, default_threads());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256pp;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seeded(seed);
        (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn parallel_matches_serial_exactly() {
        let n = 10_007;
        let hp = AdamHp {
            weight_decay: 0.01,
            ..Default::default()
        };
        let grads = randv(n, 1);
        let mut p1 = randv(n, 2);
        let mut p2 = p1.clone();
        let mut s1 = AdamState::new(n);
        let mut s2 = AdamState::new(n);
        for step in 1..=3 {
            adam_update_serial(&mut p1, &grads, &mut s1.m, &mut s1.v, &hp, step);
            s1.step = step;
            adam_step(&mut p2, &grads, &mut s2, &hp, 8);
        }
        // chunked math is element-local → bitwise identical
        assert_eq!(p1, p2);
        assert_eq!(s1.m, s2.m);
        assert_eq!(s1.v, s2.v);
    }

    #[test]
    fn pool_path_matches_spawning_path_exactly() {
        // adam_step (persistent pool) and adam_step_spawning (per-call
        // scoped threads) must be interchangeable bit-for-bit.
        let n = 40_009;
        let hp = AdamHp {
            weight_decay: 0.003,
            ..Default::default()
        };
        let grads = randv(n, 11);
        let mut p1 = randv(n, 12);
        let mut p2 = p1.clone();
        let mut s1 = AdamState::new(n);
        let mut s2 = AdamState::new(n);
        for _ in 0..4 {
            adam_step(&mut p1, &grads, &mut s1, &hp, 8);
            adam_step_spawning(&mut p2, &grads, &mut s2, &hp, 8);
        }
        assert_eq!(p1, p2);
        assert_eq!(s1.m, s2.m);
        assert_eq!(s1.v, s2.v);
        assert_eq!(s1.step, s2.step);
    }

    #[test]
    fn pool_path_handles_more_chunks_than_workers() {
        // nthreads far above the pool's worker count just queues chunks.
        let n = 10_007;
        let grads = randv(n, 21);
        let mut p1 = randv(n, 22);
        let mut p2 = p1.clone();
        let mut s1 = AdamState::new(n);
        let mut s2 = AdamState::new(n);
        adam_step(&mut p1, &grads, &mut s1, &AdamHp::default(), 64);
        adam_update_serial(&mut p2, &grads, &mut s2.m, &mut s2.v, &AdamHp::default(), 1);
        assert_eq!(p1, p2);
    }

    #[test]
    fn descends_a_quadratic() {
        // minimize f(p) = Σ (p - 3)²; gradient = 2(p-3)
        let n = 256;
        let hp = AdamHp {
            lr: 0.05,
            ..Default::default()
        };
        let mut p = vec![0.0f32; n];
        let mut st = AdamState::new(n);
        for _ in 0..500 {
            let g: Vec<f32> = p.iter().map(|x| 2.0 * (x - 3.0)).collect();
            adam_step(&mut p, &g, &mut st, &hp, 4);
        }
        for &x in &p {
            assert!((x - 3.0).abs() < 0.05, "param {x} did not converge");
        }
    }

    #[test]
    fn bias_correction_first_step() {
        // After one step with constant gradient g, Adam moves by ≈ lr·sign(g)
        // (bias correction makes m̂ = g, v̂ = g²).
        let hp = AdamHp::default();
        let mut p = vec![1.0f32; 4];
        let g = vec![0.5f32; 4];
        let mut st = AdamState::new(4);
        adam_step(&mut p, &g, &mut st, &hp, 1);
        for &x in &p {
            assert!(
                (x - (1.0 - hp.lr)).abs() < 1e-4,
                "first step should be ≈ -lr: {x}"
            );
        }
    }

    #[test]
    fn weight_decay_shrinks_params_with_zero_grad() {
        let hp = AdamHp {
            lr: 0.1,
            weight_decay: 0.5,
            ..Default::default()
        };
        let mut p = vec![2.0f32; 8];
        let g = vec![0.0f32; 8];
        let mut st = AdamState::new(8);
        adam_step(&mut p, &g, &mut st, &hp, 2);
        for &x in &p {
            assert!((x - (2.0 - 0.1 * 0.5 * 2.0)).abs() < 1e-5);
        }
    }

    #[test]
    fn step_counter_advances() {
        let mut st = AdamState::new(4);
        let mut p = vec![0.0f32; 4];
        let g = vec![1.0f32; 4];
        adam_step(&mut p, &g, &mut st, &AdamHp::default(), 2);
        adam_step(&mut p, &g, &mut st, &AdamHp::default(), 2);
        assert_eq!(st.step, 2);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut st = AdamState::new(0);
        let mut p: Vec<f32> = vec![];
        adam_step(&mut p, &[], &mut st, &AdamHp::default(), 8);
        let mut st3 = AdamState::new(3);
        let mut p3 = vec![1.0f32; 3];
        adam_step(&mut p3, &[0.1, 0.2, 0.3], &mut st3, &AdamHp::default(), 64);
        assert!(p3.iter().all(|x| x.is_finite()));
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        let mut st = AdamState::new(4);
        let mut p = vec![0.0f32; 4];
        adam_step(&mut p, &[1.0; 3], &mut st, &AdamHp::default(), 1);
    }
}
