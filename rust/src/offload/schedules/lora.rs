//! LoRA fine-tuning: the base model is frozen, only rank-R adapter
//! matrices train. The forward/backward streaming structure is unchanged
//! (base parameters still stream block-by-block, checkpoints still
//! round-trip), but the gradient offloads and the CPU optimizer shrink by
//! orders of magnitude.
//!
//! This is the schedule that stresses the *latency-critical-in-DRAM* side
//! of the paper's allocator: with the Adam working set far below the LLC
//! knee (Fig. 5's left region), even a naive CXL placement barely hurts
//! STEP — the remaining sensitivity is all bulk transfer traffic. Compare
//! against `zero-offload` (full fine-tuning, STEP-dominated inflation) in
//! `benches/schedule_ablation.rs`.

use super::super::plan::{MemoryPlan, RunConfig};
use super::super::schedule::{Op, OpNode, Schedule};
use super::zero_offload::{build_fig1_passes, cpu_step_touches, Fig1Shape};
use super::ScheduleBuilder;
use crate::topology::SystemTopology;

/// Default adapter rank when the registry name carries no `:R` parameter.
pub const DEFAULT_RANK: usize = 16;

pub struct Lora {
    rank: usize,
    name: String,
}

impl Lora {
    pub fn new(rank: usize) -> Self {
        assert!(rank >= 1);
        Self {
            rank,
            name: format!("lora:{rank}"),
        }
    }

    /// Trainable adapter elements per block: A (h×r) + B (r×h) pairs on
    /// the attention q and v projections — the standard LoRA target set.
    fn adapter_elems_per_block(&self, cfg: &RunConfig) -> u64 {
        4 * self.rank as u64 * cfg.model.hidden as u64
    }
}

impl ScheduleBuilder for Lora {
    fn name(&self) -> &str {
        &self.name
    }

    fn build(&self, _topo: &SystemTopology, cfg: &RunConfig, plan: &MemoryPlan<'_>) -> Schedule {
        let adapter_per_block = self.adapter_elems_per_block(cfg);
        let adapter_total = adapter_per_block * cfg.model.layers as u64;

        // Frozen base → only bf16 adapter grads leave the GPU per block.
        let (mut s, all_grads, step) = build_fig1_passes(
            cfg,
            plan,
            &Fig1Shape {
                grad_block_bytes: Some(2.0 * adapter_per_block as f64),
                ..Fig1Shape::default()
            },
        );
        // Tiny optimizer: Adam over the adapters only, casting only the
        // adapter copies. The placement layouts still come from the plan,
        // so a policy that interleaved the optimizer regions onto CXL is
        // charged accordingly — it just barely matters below the LLC knee.
        s.push(OpNode {
            op: Op::CpuStep {
                adam_elements: adapter_total,
                adam_layout: plan.opt_layout(),
                streams: vec![
                    (4.0 * adapter_total as f64, plan.region_layout(plan.master)),
                    (2.0 * adapter_total as f64, plan.region_layout(plan.params16)),
                ],
            },
            deps: all_grads,
            name: "optimizer step".into(),
            lane: "cpu/step".into(),
            phase: step,
            ends_phase: true,
            touches: cpu_step_touches(plan),
        });
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Policy;
    use crate::model::footprint::Workload;
    use crate::model::presets::tiny_2m;
    use crate::offload::executor::execute;
    use crate::offload::schedules::zero_offload::ZeroOffload;
    use crate::topology::presets::dev_tiny;

    #[test]
    fn lora_step_is_orders_of_magnitude_cheaper() {
        let topo = dev_tiny();
        let cfg = RunConfig::new(tiny_2m(), Workload::new(1, 2, 256), Policy::DramOnly);
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let zo = execute(&topo, &ZeroOffload.build(&topo, &cfg, &plan))
            .report
            .to_breakdown();
        let lo = execute(&topo, &Lora::new(8).build(&topo, &cfg, &plan))
            .report
            .to_breakdown();
        assert!(
            lo.step_s < zo.step_s * 0.5,
            "adapter-only step must be far cheaper: {} vs {}",
            lo.step_s,
            zo.step_s
        );
        // compute and activation traffic unchanged → fwd identical
        assert_eq!(lo.fwd_s.to_bits(), zo.fwd_s.to_bits());
        assert!(lo.iter_s < zo.iter_s);
    }

    #[test]
    fn adapter_count_scales_with_rank() {
        let topo = dev_tiny();
        let cfg = RunConfig::new(tiny_2m(), Workload::new(1, 2, 256), Policy::DramOnly);
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let s8 = Lora::new(8).build(&topo, &cfg, &plan);
        let s64 = Lora::new(64).build(&topo, &cfg, &plan);
        s8.validate(&topo).unwrap();
        let step_elems = |s: &Schedule| {
            s.nodes
                .iter()
                .find_map(|n| match &n.op {
                    Op::CpuStep { adam_elements, .. } => Some(*adam_elements),
                    _ => None,
                })
                .unwrap()
        };
        assert_eq!(step_elems(&s64), 8 * step_elems(&s8));
    }
}
