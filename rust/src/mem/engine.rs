//! The pluggable placement layer: [`PlacementEngine`] + a name registry.
//!
//! The paper evaluates exactly three policies, and the original code froze
//! them into the [`Policy`] enum — every new placement strategy meant
//! touching every `match` arm. This module inverts that: placement is an
//! object-safe trait, the legacy enum variants are engines (byte-identical
//! plans, see the golden-parity tests below), and new strategies plug in by
//! implementing the trait and registering a name. The allocator, plan
//! builder, iteration simulator, grid sweep, CLI and benches all consume
//! [`EngineRef`]s, never the enum.
//!
//! One genuinely new engine ships here: [`AdaptiveSpill`], which re-weights
//! spill/stripe shares by each node's `cpu_stream_bw` **and** its remaining
//! free-capacity fraction — a nearly-full AIC absorbs proportionally less,
//! so repeated allocations degrade gracefully instead of wedging one card
//! (the MemAscend-style spill-ordering idea on top of Fig. 8c's
//! bandwidth-proportional split).

use std::sync::Arc;

use super::policy::Policy;
use super::profile::AccessProfile;
use super::region::{Placement, RegionRequest};
use super::striping;
use crate::sim::memmodel::AccessMode;
use crate::topology::{NodeId, SystemTopology};

/// An object-safe placement strategy.
///
/// Implementations must be pure functions of `(topo, req, free)` — the
/// allocator commits the returned placement and owns all bookkeeping, so
/// engines never see their own history except through `free`.
pub trait PlacementEngine: Send + Sync {
    /// Registry / CLI name, e.g. `"cxl-aware+striping"`.
    fn name(&self) -> &str;

    /// Compute the placement for `req` given per-node free bytes (indexed
    /// by `NodeId.0`). `Err(shortfall)` when the region cannot be placed.
    fn place(
        &self,
        topo: &SystemTopology,
        req: &RegionRequest,
        free: &[u64],
    ) -> Result<Placement, u64>;

    /// Context-carrying placement: the region's measured
    /// [`AccessProfile`] (when the plan computed one) rides along with the
    /// request. The default ignores the profile and delegates to
    /// [`PlacementEngine::place`], so every legacy engine is byte-identical
    /// through this path — the allocator routes *all* allocations here.
    fn place_profiled(
        &self,
        topo: &SystemTopology,
        req: &RegionRequest,
        profile: Option<&AccessProfile>,
        free: &[u64],
    ) -> Result<Placement, u64> {
        let _ = profile;
        self.place(topo, req, free)
    }

    /// Does this engine consume [`AccessProfile`]s? The plan builder only
    /// pays for the profiling pass (probe plan + schedule walk) when an
    /// engine asks for it or lifetime accounting needs the windows.
    fn uses_profiles(&self) -> bool {
        false
    }

    /// Baseline engines run against the all-DRAM host in grid sweeps
    /// (the paper's "DRAM-only" comparison column).
    fn is_baseline(&self) -> bool {
        false
    }
}

/// Shared handle to an engine — what every layer above `mem` threads around.
pub type EngineRef = Arc<dyn PlacementEngine>;

/// The legacy policies are engines; plans are byte-identical by delegation.
impl PlacementEngine for Policy {
    fn name(&self) -> &str {
        Policy::name(*self)
    }

    fn place(
        &self,
        topo: &SystemTopology,
        req: &RegionRequest,
        free: &[u64],
    ) -> Result<Placement, u64> {
        Policy::place(*self, topo, req, free)
    }

    fn is_baseline(&self) -> bool {
        matches!(self, Policy::DramOnly)
    }
}

impl From<Policy> for EngineRef {
    fn from(p: Policy) -> Self {
        Arc::new(p)
    }
}

/// Adaptive bandwidth-weighted spill (§IV-B, extended).
///
/// Like `cxl-aware+striping`, latency-critical data fills DRAM first; but
/// both the optimizer-spill partition and the latency-tolerant stripes are
/// weighted by `cpu_stream_bw × free_fraction` per CXL node instead of by
/// bandwidth alone. Static bandwidth weighting keeps hammering a card that
/// is already nearly full (its weight never drops), forcing later regions
/// into capacity-clamped, unbalanced splits; folding in the remaining free
/// fraction spreads pressure so every allocation in a long sequence stays
/// close to bandwidth-proportional.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdaptiveSpill;

impl AdaptiveSpill {
    pub const NAME: &'static str = "adaptive-spill";

    fn weights(topo: &SystemTopology, nodes: &[NodeId], free: &[u64]) -> Vec<f64> {
        nodes
            .iter()
            .map(|&n| {
                let spec = topo.node(n);
                let cap = spec.capacity as f64;
                let free_frac = if cap > 0.0 { free[n.0] as f64 / cap } else { 0.0 };
                spec.cpu_stream_bw * free_frac
            })
            .collect()
    }
}

impl PlacementEngine for AdaptiveSpill {
    fn name(&self) -> &str {
        Self::NAME
    }

    fn place(
        &self,
        topo: &SystemTopology,
        req: &RegionRequest,
        free: &[u64],
    ) -> Result<Placement, u64> {
        if req.bytes == 0 {
            return Ok(Placement {
                parts: vec![],
                mode: AccessMode::Partitioned,
            });
        }
        let dram = NodeId(0);
        let cxl = topo.cxl_nodes();
        if req.class.latency_critical() {
            // DRAM first; spill across AICs weighted by bw × free fraction.
            if free[0] >= req.bytes {
                return Ok(Placement::single(dram, req.bytes));
            }
            let dram_take = free[0];
            let rest = req.bytes - dram_take;
            if cxl.is_empty() {
                return Err(rest);
            }
            let weights = Self::weights(topo, &cxl, free);
            let (mut parts, unplaced) = striping::weighted_split(rest, &cxl, &weights, free);
            if unplaced > 0 {
                return Err(unplaced);
            }
            if dram_take > 0 {
                parts.insert(0, (dram, dram_take));
            }
            Ok(Placement {
                parts,
                mode: AccessMode::Partitioned,
            })
        } else {
            // Latency-tolerant → adaptive stripes over CXL, overflow to DRAM.
            let (mut parts, unplaced) = if cxl.is_empty() {
                striping::sequential_fill(req.bytes, &[dram], free)
            } else {
                let weights = Self::weights(topo, &cxl, free);
                striping::weighted_split(req.bytes, &cxl, &weights, free)
            };
            let mut rest = unplaced;
            if rest > 0 && !cxl.is_empty() {
                let take = rest.min(free[0]);
                if take > 0 {
                    parts.push((dram, take));
                    rest -= take;
                }
            }
            if rest > 0 {
                return Err(rest);
            }
            Ok(Placement {
                parts,
                mode: AccessMode::Partitioned,
            })
        }
    }
}

impl From<AdaptiveSpill> for EngineRef {
    fn from(e: AdaptiveSpill) -> Self {
        Arc::new(e)
    }
}

/// The paper's §IV allocator, driven by *measured* traffic instead of the
/// `TensorClass` taxonomy.
///
/// Placement is a function of each region's [`AccessProfile`]:
///
/// * **Hot** profiles (any CPU RMW element traffic — the optimizer's
///   read-modify-write inner loop) are latency-critical: DRAM first, and
///   any spill is partitioned across the AICs weighted by
///   `cpu_stream_bw × free-fraction`, so spilled optimizer shards land on
///   the coldest (least-occupied) cards first.
/// * **Cold** profiles (DMA-only traffic) are bandwidth-bound: striped
///   across the AICs proportionally to each card's *DMA* bandwidth
///   (`peak_bw`, the link rate — not the much lower CPU-stream rate),
///   overflowing to DRAM only when every AIC is full.
///
/// Evict-by-coldness, statically: a one-shot planner cannot evict after
/// commit, so the rule appears as admission order — the plan requests the
/// hottest regions (highest [`AccessProfile::heat`]) first, which is
/// exactly the state an evicting allocator converges to: whenever DRAM is
/// contended, the bytes that end up on CXL are the coldest ones.
///
/// Without a profile (a region the schedule never touches, or a caller on
/// the plain `place` path) it falls back to the class taxonomy via
/// `cxl-aware+striping` — the measured and declared notions of
/// latency-criticality coincide on every Table-I region, which is what
/// keeps the fallback honest.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProfileAware;

impl ProfileAware {
    pub const NAME: &'static str = "profile-aware";

    /// Coldness-ranked spill weights: stream bandwidth × free fraction.
    fn spill_weights(topo: &SystemTopology, nodes: &[NodeId], free: &[u64]) -> Vec<f64> {
        nodes
            .iter()
            .map(|&n| {
                let spec = topo.node(n);
                let cap = spec.capacity as f64;
                let free_frac = if cap > 0.0 { free[n.0] as f64 / cap } else { 0.0 };
                spec.cpu_stream_bw * free_frac
            })
            .collect()
    }

    /// DMA-bandwidth stripe weights (the link rate each AIC can sustain).
    fn dma_weights(topo: &SystemTopology, nodes: &[NodeId]) -> Vec<f64> {
        nodes.iter().map(|&n| topo.node(n).peak_bw).collect()
    }
}

impl PlacementEngine for ProfileAware {
    fn name(&self) -> &str {
        Self::NAME
    }

    fn uses_profiles(&self) -> bool {
        true
    }

    /// Profile-less fallback: the class taxonomy (§IV-A/B).
    fn place(
        &self,
        topo: &SystemTopology,
        req: &RegionRequest,
        free: &[u64],
    ) -> Result<Placement, u64> {
        Policy::CxlAware { striping: true }.place(topo, req, free)
    }

    fn place_profiled(
        &self,
        topo: &SystemTopology,
        req: &RegionRequest,
        profile: Option<&AccessProfile>,
        free: &[u64],
    ) -> Result<Placement, u64> {
        let Some(p) = profile else {
            return self.place(topo, req, free);
        };
        if req.bytes == 0 {
            return Ok(Placement {
                parts: vec![],
                mode: AccessMode::Partitioned,
            });
        }
        let dram = NodeId(0);
        let cxl = topo.cxl_nodes();
        if p.latency_critical() {
            // Measured RMW traffic → pin in DRAM; spill coldness-ranked.
            if free[0] >= req.bytes {
                return Ok(Placement::single(dram, req.bytes));
            }
            let dram_take = free[0];
            let rest = req.bytes - dram_take;
            if cxl.is_empty() {
                return Err(rest);
            }
            let weights = Self::spill_weights(topo, &cxl, free);
            let (mut parts, unplaced) = striping::weighted_split(rest, &cxl, &weights, free);
            if unplaced > 0 {
                return Err(unplaced);
            }
            if dram_take > 0 {
                parts.insert(0, (dram, dram_take));
            }
            Ok(Placement {
                parts,
                mode: AccessMode::Partitioned,
            })
        } else {
            // DMA-bound (or untouched) → stripe by link bandwidth,
            // overflow to DRAM last.
            let (mut parts, unplaced) = if cxl.is_empty() {
                striping::sequential_fill(req.bytes, &[dram], free)
            } else {
                let weights = Self::dma_weights(topo, &cxl);
                striping::weighted_split(req.bytes, &cxl, &weights, free)
            };
            let mut rest = unplaced;
            if rest > 0 && !cxl.is_empty() {
                let take = rest.min(free[0]);
                if take > 0 {
                    parts.push((dram, take));
                    rest -= take;
                }
            }
            if rest > 0 {
                return Err(rest);
            }
            Ok(Placement {
                parts,
                mode: AccessMode::Partitioned,
            })
        }
    }
}

impl From<ProfileAware> for EngineRef {
    fn from(e: ProfileAware) -> Self {
        Arc::new(e)
    }
}

/// Canonical names of every registered engine (CLI help text).
pub fn known_names() -> Vec<&'static str> {
    vec![
        "baseline-dram",
        "naive-cxl",
        "cxl-aware",
        "cxl-aware+striping",
        AdaptiveSpill::NAME,
        ProfileAware::NAME,
    ]
}

/// Resolve an engine by name (accepts every legacy `Policy::by_name` alias
/// plus the adaptive engine's aliases). This is what the CLI uses, so new
/// engines become selectable by registering here — no enum edits anywhere.
pub fn by_name(name: &str) -> Option<EngineRef> {
    if let Some(p) = Policy::by_name(name) {
        return Some(p.into());
    }
    match name {
        AdaptiveSpill::NAME | "adaptive" | "bw-adaptive" => Some(AdaptiveSpill.into()),
        ProfileAware::NAME | "profiled" | "paper-iv" => Some(ProfileAware.into()),
        _ => None,
    }
}

/// One instance of every registered engine, in canonical order.
pub fn registry() -> Vec<EngineRef> {
    known_names()
        .into_iter()
        .map(|n| by_name(n).expect("known name resolves"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::region::TensorClass;
    use crate::topology::presets::{config_a, config_b, with_dram_capacity};
    use crate::topology::GpuId;
    use crate::util::units::GIB;

    fn free_of(topo: &SystemTopology) -> Vec<u64> {
        topo.mem_nodes.iter().map(|n| n.capacity).collect()
    }

    #[test]
    fn registry_resolves_every_known_name() {
        for name in known_names() {
            let e = by_name(name).unwrap_or_else(|| panic!("{name} must resolve"));
            assert_eq!(e.name(), name, "canonical name must round-trip");
        }
        assert!(by_name("??").is_none());
        assert_eq!(registry().len(), known_names().len());
    }

    #[test]
    fn adaptive_aliases_resolve() {
        for alias in ["adaptive-spill", "adaptive", "bw-adaptive"] {
            assert_eq!(by_name(alias).unwrap().name(), AdaptiveSpill::NAME);
        }
    }

    #[test]
    fn profile_aware_aliases_resolve() {
        for alias in ["profile-aware", "profiled", "paper-iv"] {
            assert_eq!(by_name(alias).unwrap().name(), ProfileAware::NAME);
        }
    }

    #[test]
    fn only_profile_aware_uses_profiles() {
        for e in registry() {
            assert_eq!(
                e.uses_profiles(),
                e.name() == ProfileAware::NAME,
                "{}",
                e.name()
            );
        }
    }

    fn hot_profile() -> AccessProfile {
        AccessProfile {
            h2d_bytes: 0.0,
            d2h_bytes: 0.0,
            cpu_rmw_elements: 1_000_000,
            cpu_stream_bytes: 4e6,
            touches: 1,
            lifetime: crate::mem::Lifetime::spanning(2, 2),
        }
    }

    fn cold_profile() -> AccessProfile {
        AccessProfile {
            h2d_bytes: 1e9,
            d2h_bytes: 1e9,
            cpu_rmw_elements: 0,
            cpu_stream_bytes: 0.0,
            touches: 64,
            lifetime: crate::mem::Lifetime::spanning(0, 1),
        }
    }

    /// `place_profiled`'s default path must be byte-identical to `place`
    /// for every registered engine — and for the legacy engines the
    /// profile must be ignored entirely (they keep the trait default).
    #[test]
    fn place_profiled_parity_for_all_registered_engines() {
        let topos = [config_a(), config_b(), with_dram_capacity(config_b(), 16 * GIB)];
        for topo in &topos {
            for engine in registry() {
                for class in TensorClass::all() {
                    for bytes in [0u64, 1, GIB - 1, 10 * GIB, 300 * GIB] {
                        let req = RegionRequest::new("r", class, bytes);
                        let mut tight = free_of(topo);
                        for f in tight.iter_mut() {
                            *f /= 3;
                        }
                        for free in [free_of(topo), tight] {
                            let direct = engine.place(topo, &req, &free);
                            let profiled_none =
                                engine.place_profiled(topo, &req, None, &free);
                            assert_eq!(
                                direct, profiled_none,
                                "{}: place_profiled(None) must delegate to place \
                                 ({class:?}, {bytes}B)",
                                engine.name()
                            );
                            if !engine.uses_profiles() {
                                for prof in [hot_profile(), cold_profile()] {
                                    let with_prof = engine
                                        .place_profiled(topo, &req, Some(&prof), &free);
                                    assert_eq!(
                                        direct,
                                        with_prof,
                                        "{}: legacy engine must ignore profiles",
                                        engine.name()
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn profile_aware_pins_hot_profiles_in_dram() {
        // The class says "latency-tolerant" but the measured traffic says
        // RMW → the profile wins and the region is pinned in DRAM.
        let topo = config_a();
        let free = free_of(&topo);
        let req = RegionRequest::new("x", TensorClass::Activations, 40 * GIB);
        let p = ProfileAware
            .place_profiled(&topo, &req, Some(&hot_profile()), &free)
            .unwrap();
        assert_eq!(p.parts, vec![(NodeId(0), 40 * GIB)]);
    }

    #[test]
    fn profile_aware_stripes_cold_profiles_by_dma_bandwidth() {
        // The class says "latency-critical" but the measured traffic is
        // DMA-only → striped across the AICs (equal link bw → equal halves),
        // DRAM untouched.
        let topo = config_b();
        let free = free_of(&topo);
        let req = RegionRequest::new("x", TensorClass::OptimizerStates, 64 * GIB);
        let p = ProfileAware
            .place_profiled(&topo, &req, Some(&cold_profile()), &free)
            .unwrap();
        assert_eq!(p.bytes_on(NodeId(1)), 32 * GIB);
        assert_eq!(p.bytes_on(NodeId(2)), 32 * GIB);
        assert!(!p.touches(NodeId(0)));
    }

    #[test]
    fn profile_aware_spills_hot_data_to_coldest_aic_first() {
        // DRAM full; cxl0 75 % occupied, cxl1 empty → the spill weights
        // (stream bw × free fraction) send 4× more to cxl1.
        let topo = with_dram_capacity(config_b(), GIB);
        let mut free = free_of(&topo);
        free[0] = 0;
        free[1] = 64 * GIB;
        free[2] = 256 * GIB;
        let req = RegionRequest::new("o", TensorClass::OptimizerStates, 50 * GIB);
        let p = ProfileAware
            .place_profiled(&topo, &req, Some(&hot_profile()), &free)
            .unwrap();
        let on1 = p.bytes_on(NodeId(1)) as i64;
        let on2 = p.bytes_on(NodeId(2)) as i64;
        assert!((on1 - (10 * GIB) as i64).abs() <= 8, "cxl0 share {on1}");
        assert!((on2 - (40 * GIB) as i64).abs() <= 8, "cxl1 share {on2}");
        assert_eq!(p.total_bytes(), 50 * GIB);
    }

    #[test]
    fn profile_aware_cold_overflows_to_dram_and_reports_shortfall() {
        let topo = config_a();
        let mut free = free_of(&topo);
        free[1] = GIB;
        let req = RegionRequest::new("a", TensorClass::Activations, 3 * GIB);
        let p = ProfileAware
            .place_profiled(&topo, &req, Some(&cold_profile()), &free)
            .unwrap();
        assert_eq!(p.bytes_on(NodeId(1)), GIB);
        assert_eq!(p.bytes_on(NodeId(0)), 2 * GIB);

        let tiny = vec![GIB, GIB];
        let err = ProfileAware
            .place_profiled(&topo, &req, Some(&cold_profile()), &tiny)
            .unwrap_err();
        assert_eq!(err, GIB);
    }

    #[test]
    fn profile_aware_fallback_matches_cxl_aware_striping() {
        let topo = config_b();
        let free = free_of(&topo);
        for class in TensorClass::all() {
            for bytes in [1u64, GIB, 100 * GIB] {
                let req = RegionRequest::new("r", class, bytes);
                assert_eq!(
                    ProfileAware.place(&topo, &req, &free),
                    Policy::CxlAware { striping: true }.place(&topo, &req, &free),
                    "{class:?} {bytes}B"
                );
            }
        }
    }

    #[test]
    fn profile_aware_conserves_bytes_property() {
        use crate::util::proptest_lite::*;
        let topo = config_b();
        let gen = PairOf(
            U64Range {
                lo: 1,
                hi: 900 * GIB,
            },
            UsizeRange { lo: 0, hi: 1 },
        );
        forall("profile-aware-conserves", 23, 200, &gen, |(bytes, hot)| {
            let prof = if *hot == 1 { hot_profile() } else { cold_profile() };
            let free = free_of(&topo);
            let req = RegionRequest::new("r", TensorClass::Activations, *bytes);
            match ProfileAware.place_profiled(&topo, &req, Some(&prof), &free) {
                Ok(p) => {
                    if p.total_bytes() != *bytes {
                        return Err(format!("placed {} of {bytes}", p.total_bytes()));
                    }
                    for (n, b) in &p.parts {
                        if *b > free[n.0] {
                            return Err(format!("node {} over cap", n.0));
                        }
                    }
                    p.validate(*bytes);
                    Ok(())
                }
                Err(0) => Err("zero shortfall".into()),
                Err(_) => Ok(()),
            }
        });
    }

    #[test]
    fn only_dram_only_is_baseline() {
        for e in registry() {
            assert_eq!(e.is_baseline(), e.name() == "baseline-dram", "{}", e.name());
        }
    }

    /// Golden parity: the three legacy policies must produce byte-identical
    /// placements whether called through the enum or through the registry.
    #[test]
    fn legacy_policies_golden_parity_through_trait() {
        let topos = [
            config_a(),
            config_b(),
            with_dram_capacity(config_a(), 16 * GIB),
            with_dram_capacity(config_b(), 16 * GIB),
        ];
        let policies = [
            Policy::DramOnly,
            Policy::NaiveInterleave,
            Policy::CxlAware { striping: false },
            Policy::CxlAware { striping: true },
        ];
        for topo in &topos {
            for policy in policies {
                let engine = by_name(PlacementEngine::name(&policy)).expect("registered");
                for class in TensorClass::all() {
                    for bytes in [0u64, 1, GIB - 1, 10 * GIB, 300 * GIB, 2000 * GIB] {
                        for gpu in [None, Some(GpuId(0)), Some(GpuId(1))] {
                            let mut req = RegionRequest::new("r", class, bytes);
                            if let Some(g) = gpu {
                                req = req.for_gpu(g);
                            }
                            // full and degraded free vectors
                            let mut frees = vec![free_of(topo)];
                            let mut tight = free_of(topo);
                            for f in tight.iter_mut() {
                                *f /= 7;
                            }
                            frees.push(tight);
                            for free in &frees {
                                let via_enum = policy.place(topo, &req, free);
                                let via_trait = engine.place(topo, &req, free);
                                assert_eq!(
                                    via_enum, via_trait,
                                    "parity broken: {} {class:?} {bytes}B gpu={gpu:?}",
                                    Policy::name(policy)
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn adaptive_pins_fitting_optimizer_data_to_dram() {
        let topo = config_a();
        let free = free_of(&topo);
        let req = RegionRequest::new("o", TensorClass::OptimizerStates, 40 * GIB);
        let p = AdaptiveSpill.place(&topo, &req, &free).unwrap();
        assert_eq!(p.parts, vec![(NodeId(0), 40 * GIB)]);
    }

    #[test]
    fn adaptive_spill_weights_by_bandwidth_and_free_capacity() {
        // Config B: two AICs with equal cpu_stream_bw; make cxl0 75 % full.
        // weights ∝ bw × free_frac → 0.25 : 1.0 → the 50 GiB spill splits
        // 10 GiB : 40 GiB instead of the static policy's 25 : 25.
        let topo = with_dram_capacity(config_b(), GIB);
        let mut free = free_of(&topo);
        free[0] = 0; // DRAM exhausted → everything spills
        free[1] = 64 * GIB; // cxl0: 64 of 256 GiB free
        free[2] = 256 * GIB; // cxl1: empty
        let req = RegionRequest::new("o", TensorClass::OptimizerStates, 50 * GIB);
        let p = AdaptiveSpill.place(&topo, &req, &free).unwrap();
        assert_eq!(p.mode, AccessMode::Partitioned);
        assert_eq!(p.bytes_on(NodeId(0)), 0);
        let on1 = p.bytes_on(NodeId(1)) as i64;
        let on2 = p.bytes_on(NodeId(2)) as i64;
        assert!((on1 - (10 * GIB) as i64).abs() <= 8, "cxl0 share {on1}");
        assert!((on2 - (40 * GIB) as i64).abs() <= 8, "cxl1 share {on2}");
        assert_eq!(p.total_bytes(), 50 * GIB);
    }

    #[test]
    fn adaptive_matches_static_stripe_on_fresh_nodes() {
        // With both AICs empty the free fractions are equal, so the adaptive
        // weights reduce to plain bandwidth weights: equal halves here.
        let topo = config_b();
        let free = free_of(&topo);
        let req = RegionRequest::new("a", TensorClass::Activations, 64 * GIB);
        let p = AdaptiveSpill.place(&topo, &req, &free).unwrap();
        assert_eq!(p.bytes_on(NodeId(1)), 32 * GIB);
        assert_eq!(p.bytes_on(NodeId(2)), 32 * GIB);
        assert!(!p.touches(NodeId(0)));
    }

    #[test]
    fn adaptive_overflows_transfer_data_to_dram() {
        let topo = config_a();
        let mut free = free_of(&topo);
        free[1] = GIB;
        let req = RegionRequest::new("a", TensorClass::Activations, 3 * GIB);
        let p = AdaptiveSpill.place(&topo, &req, &free).unwrap();
        assert_eq!(p.bytes_on(NodeId(1)), GIB);
        assert_eq!(p.bytes_on(NodeId(0)), 2 * GIB);
    }

    #[test]
    fn adaptive_reports_shortfall() {
        let topo = config_a();
        let free = vec![GIB, GIB];
        let req = RegionRequest::new("o", TensorClass::OptimizerStates, 10 * GIB);
        let err = AdaptiveSpill.place(&topo, &req, &free).unwrap_err();
        assert_eq!(err, 8 * GIB);
    }

    #[test]
    fn adaptive_conserves_bytes_property() {
        use crate::util::proptest_lite::*;
        let topo = config_b();
        let gen = PairOf(
            U64Range {
                lo: 1,
                hi: 900 * GIB,
            },
            UsizeRange { lo: 0, hi: 5 },
        );
        forall("adaptive-conserves", 19, 200, &gen, |(bytes, class_idx)| {
            let class = TensorClass::all()[*class_idx % 6];
            let free = free_of(&topo);
            let req = RegionRequest::new("r", class, *bytes);
            match AdaptiveSpill.place(&topo, &req, &free) {
                Ok(p) => {
                    if p.total_bytes() != *bytes {
                        return Err(format!("placed {} of {bytes}", p.total_bytes()));
                    }
                    for (n, b) in &p.parts {
                        if *b > free[n.0] {
                            return Err(format!("node {} over cap", n.0));
                        }
                    }
                    p.validate(*bytes);
                    Ok(())
                }
                Err(0) => Err("zero shortfall".into()),
                Err(_) => Ok(()),
            }
        });
    }
}
