//! Stripe arithmetic: split a byte count across nodes by weight, capped by
//! per-node free capacity. Shared by the placement policies (§IV-B) and the
//! fabric's striped transfers.

use crate::topology::NodeId;

/// Split `bytes` across `nodes` proportionally to `weights`, respecting
/// per-node `free` capacity. Returns `(shards, unplaced)`: shards are
/// `(node, bytes)` with every node appearing at most once and zero-byte
/// shards omitted; `unplaced > 0` means capacity ran out.
///
/// The split is exact (shards sum to `bytes - unplaced`): fractional
/// entitlements are floored and the remainder distributed to the largest
/// fractional parts first (largest-remainder method), so results are
/// deterministic and balanced to within one byte before capacity clamping.
pub fn weighted_split(
    bytes: u64,
    nodes: &[NodeId],
    weights: &[f64],
    free: &[u64], // indexed by NodeId.0
) -> (Vec<(NodeId, u64)>, u64) {
    assert_eq!(nodes.len(), weights.len());
    assert!(weights.iter().all(|w| *w >= 0.0));
    let mut remaining = bytes;
    let mut shards: Vec<(NodeId, u64)> = Vec::new();
    // Iterate: allocate by weight among nodes that still have free space;
    // nodes that hit capacity drop out and their share is redistributed.
    let mut free_left: Vec<u64> = nodes.iter().map(|n| free[n.0]).collect();
    let mut acc: Vec<u64> = vec![0; nodes.len()];
    while remaining > 0 {
        let live: Vec<usize> = (0..nodes.len())
            .filter(|&i| free_left[i] > 0 && weights[i] > 0.0)
            .collect();
        if live.is_empty() {
            break;
        }
        let wsum: f64 = live.iter().map(|&i| weights[i]).sum();
        // entitlement per live node this round
        let mut round: Vec<(usize, u64, f64)> = Vec::with_capacity(live.len()); // (idx, floor, frac)
        let mut floored_total = 0u64;
        for &i in &live {
            let ent = remaining as f64 * weights[i] / wsum;
            let fl = (ent.floor() as u64).min(free_left[i]);
            round.push((i, fl, ent - ent.floor()));
            floored_total += fl;
        }
        // distribute the integer remainder by largest fraction (stable order)
        let mut leftover = remaining - floored_total.min(remaining);
        round.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap().then(a.0.cmp(&b.0)));
        let spread = round_len_guard(round.len());
        for (i, fl, _) in round.iter_mut() {
            let extra = if leftover > 0 && *fl < free_left[*i] {
                let e = std::cmp::min(leftover, free_left[*i] - *fl);
                // one byte at a time is exact but slow; grant the min of
                // leftover and capacity — later rounds rebalance.
                let e = e.min(1 + leftover / spread); // keep it spread
                leftover -= e;
                e
            } else {
                0
            };
            let grant = *fl + extra;
            acc[*i] += grant;
            free_left[*i] -= grant;
            remaining -= grant;
        }
        // If no progress was possible this round (all floors zero and no
        // leftover placed), push single bytes to the first live node to
        // guarantee termination.
        if round.iter().all(|(_, fl, _)| *fl == 0) && remaining > 0 {
            let mut progressed = false;
            for &i in &live {
                if free_left[i] > 0 {
                    let grant = remaining.min(1);
                    acc[i] += grant;
                    free_left[i] -= grant;
                    remaining -= grant;
                    progressed = true;
                    break;
                }
            }
            if !progressed {
                break;
            }
        }
    }
    for (i, node) in nodes.iter().enumerate() {
        if acc[i] > 0 {
            shards.push((*node, acc[i]));
        }
    }
    (shards, remaining)
}

#[allow(dead_code)]
fn round_len(r: &[(usize, u64, f64)]) -> usize {
    r.len()
}
fn round_len_guard(n: usize) -> u64 {
    n.max(1) as u64
}

/// Equal-weight split (naive interleave across nodes).
pub fn equal_split(bytes: u64, nodes: &[NodeId], free: &[u64]) -> (Vec<(NodeId, u64)>, u64) {
    let w = vec![1.0; nodes.len()];
    weighted_split(bytes, nodes, &w, free)
}

/// Sequential fill: pack into nodes in order, moving on when full.
pub fn sequential_fill(bytes: u64, nodes: &[NodeId], free: &[u64]) -> (Vec<(NodeId, u64)>, u64) {
    let mut remaining = bytes;
    let mut shards = Vec::new();
    for &n in nodes {
        if remaining == 0 {
            break;
        }
        let take = remaining.min(free[n.0]);
        if take > 0 {
            shards.push((n, take));
            remaining -= take;
        }
    }
    (shards, remaining)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: usize) -> Vec<NodeId> {
        (0..n).map(NodeId).collect()
    }

    #[test]
    fn equal_split_is_balanced() {
        let free = vec![u64::MAX / 4; 3];
        let (shards, unplaced) = equal_split(1_000_003, &nodes(3), &free);
        assert_eq!(unplaced, 0);
        let total: u64 = shards.iter().map(|(_, b)| b).sum();
        assert_eq!(total, 1_000_003);
        let min = shards.iter().map(|(_, b)| *b).min().unwrap();
        let max = shards.iter().map(|(_, b)| *b).max().unwrap();
        assert!(max - min <= 2, "imbalance {max}-{min}");
    }

    #[test]
    fn weighted_split_proportional() {
        let free = vec![u64::MAX / 4; 2];
        let (shards, unplaced) =
            weighted_split(1_000_000, &nodes(2), &[3.0, 1.0], &free);
        assert_eq!(unplaced, 0);
        assert_eq!(shards.len(), 2);
        let b0 = shards[0].1 as f64;
        let b1 = shards[1].1 as f64;
        assert!((b0 / b1 - 3.0).abs() < 0.01, "ratio {}", b0 / b1);
    }

    #[test]
    fn capacity_overflow_redistributes() {
        // node0 can only take 100; the rest flows to node1.
        let free = vec![100, 10_000];
        let (shards, unplaced) = equal_split(5_000, &nodes(2), &free);
        assert_eq!(unplaced, 0);
        assert_eq!(shards.iter().find(|(n, _)| n.0 == 0).unwrap().1, 100);
        assert_eq!(shards.iter().find(|(n, _)| n.0 == 1).unwrap().1, 4_900);
    }

    #[test]
    fn reports_unplaced_when_everything_full() {
        let free = vec![10, 20];
        let (shards, unplaced) = equal_split(100, &nodes(2), &free);
        let placed: u64 = shards.iter().map(|(_, b)| b).sum();
        assert_eq!(placed, 30);
        assert_eq!(unplaced, 70);
    }

    #[test]
    fn zero_weight_node_gets_nothing() {
        let free = vec![1000, 1000];
        let (shards, unplaced) = weighted_split(500, &nodes(2), &[0.0, 1.0], &free);
        assert_eq!(unplaced, 0);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].0, NodeId(1));
    }

    #[test]
    fn sequential_fill_order() {
        let free = vec![100, 100, 100];
        let (shards, unplaced) = sequential_fill(150, &nodes(3), &free);
        assert_eq!(unplaced, 0);
        assert_eq!(shards, vec![(NodeId(0), 100), (NodeId(1), 50)]);
    }

    #[test]
    fn zero_bytes_is_empty() {
        let free = vec![100];
        let (shards, unplaced) = equal_split(0, &nodes(1), &free);
        assert!(shards.is_empty());
        assert_eq!(unplaced, 0);
    }

    #[test]
    fn split_conserves_bytes_property() {
        use crate::util::proptest_lite::*;
        let gen = PairOf(
            U64Range { lo: 0, hi: 1 << 40 },
            VecOf {
                inner: U64Range { lo: 0, hi: 1 << 38 },
                min_len: 1,
                max_len: 5,
            },
        );
        forall("split-conserves", 42, 300, &gen, |(bytes, frees)| {
            let ns: Vec<NodeId> = (0..frees.len()).map(NodeId).collect();
            let (shards, unplaced) = equal_split(*bytes, &ns, frees);
            let placed: u64 = shards.iter().map(|(_, b)| b).sum();
            if placed + unplaced != *bytes {
                return Err(format!("placed {placed} + unplaced {unplaced} != {bytes}"));
            }
            for (n, b) in &shards {
                if *b > frees[n.0] {
                    return Err(format!("node {} over capacity", n.0));
                }
            }
            // at most one shard per node
            let mut seen = std::collections::HashSet::new();
            for (n, _) in &shards {
                if !seen.insert(n.0) {
                    return Err("duplicate shard".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn weighted_split_deterministic() {
        let free = vec![1 << 30; 4];
        let run = || weighted_split(123_456_789, &nodes(4), &[1.0, 2.0, 3.0, 4.0], &free);
        assert_eq!(run(), run());
    }
}
