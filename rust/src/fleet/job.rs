//! Fleet jobs and arrival traces.
//!
//! A [`JobSpec`] names one fine-tuning job by *configuration* — model
//! preset × workload shape × schedule × requested placement engine ×
//! iteration count — plus its arrival time. Everything is stored as
//! registry names (resolved at simulation time through
//! `model::presets::by_name`, `offload::schedules::by_name` and
//! `mem::engine::by_name`), so traces serialize to plain JSON and replay
//! bit-identically on any host.
//!
//! [`TraceGen`] is the seeded synthetic workload generator: Poisson-ish
//! arrivals via the inverse-CDF exponential sampler on the crate PRNG
//! ([`Xoshiro256pp::exp_mean`]) and a job-mix sampled over model presets ×
//! context lengths × batches × schedules. One PRNG stream, one fixed
//! sampling order per job — the same seed always yields a byte-identical
//! trace (pinned below), and [`FleetTrace::to_json`] embeds a digest so a
//! replayed file is self-certifying.

use crate::jobj;
use crate::model::footprint::Workload;
use crate::util::digest::Fnv64;
use crate::util::json::Json;
use crate::util::prng::Xoshiro256pp;

/// One fine-tuning job of the fleet.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    pub id: u64,
    /// Arrival time on the shared host, seconds from trace start.
    pub arrival_s: f64,
    /// Model preset name (`model::presets::by_name`).
    pub model: String,
    pub gpus: usize,
    pub batch: usize,
    pub context: usize,
    /// Schedule registry name (`offload::schedules::by_name`).
    pub schedule: String,
    /// Requested placement engine (`mem::engine::by_name`); the
    /// placement-aware policy may substitute a different one.
    pub engine: String,
    /// Training iterations the job runs once admitted.
    pub iterations: u32,
}

impl JobSpec {
    pub fn workload(&self) -> Workload {
        Workload::new(self.gpus, self.batch, self.context)
    }

    /// Tokens the job processes over its whole life.
    pub fn total_tokens(&self) -> u64 {
        self.workload().tokens_per_iter() * self.iterations as u64
    }

    /// Memoization key of the job's *configuration* — the identity fields
    /// that determine profiles and calibrated cost (id/arrival excluded).
    pub fn config_key(&self) -> String {
        format!(
            "{}|{}|{}|{}|{}",
            self.model, self.gpus, self.batch, self.context, self.schedule
        )
    }

    pub fn to_json(&self) -> Json {
        jobj! {
            "id" => self.id,
            "arrival_s" => self.arrival_s,
            "model" => self.model.as_str(),
            "gpus" => self.gpus,
            "batch" => self.batch,
            "context" => self.context,
            "schedule" => self.schedule.as_str(),
            "engine" => self.engine.as_str(),
            "iterations" => self.iterations as u64,
        }
    }

    pub fn from_json(j: &Json) -> Result<JobSpec, String> {
        let num = |key: &str| {
            j.path(&[key])
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("job missing numeric {key:?}"))
        };
        let text = |key: &str| {
            j.path(&[key])
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("job missing string {key:?}"))
        };
        let iterations = num("iterations")?;
        if !(1..=u32::MAX as u64).contains(&iterations) {
            return Err(format!("job iterations {iterations} out of range (1..=u32::MAX)"));
        }
        let spec = JobSpec {
            id: num("id")?,
            arrival_s: j
                .path(&["arrival_s"])
                .and_then(Json::as_f64)
                .ok_or_else(|| "job missing arrival_s".to_string())?,
            model: text("model")?,
            gpus: num("gpus")? as usize,
            batch: num("batch")? as usize,
            context: num("context")? as usize,
            schedule: text("schedule")?,
            engine: text("engine")?,
            iterations: iterations as u32,
        };
        if !(spec.arrival_s.is_finite() && spec.arrival_s >= 0.0) {
            return Err(format!(
                "job {}: arrival_s must be a non-negative finite time",
                spec.id
            ));
        }
        if spec.gpus < 1 || spec.batch < 1 || spec.context < 1 {
            return Err(format!("job {}: workload dimensions must be positive", spec.id));
        }
        Ok(spec)
    }

    /// Registry resolution: which of the job's model / schedule / engine
    /// names fail to resolve. Empty for a simulatable job. The static
    /// verifier reports each entry as a P204 diagnostic; the fleet host
    /// would otherwise only discover the dangling name at admission time.
    pub fn registry_issues(&self) -> Vec<String> {
        let mut out = Vec::new();
        if crate::model::presets::by_name(&self.model).is_none() {
            out.push(format!("names unregistered model preset {:?}", self.model));
        }
        if crate::offload::schedules::by_name(&self.schedule).is_none() {
            out.push(format!("names unregistered schedule {:?}", self.schedule));
        }
        if crate::mem::engine::by_name(&self.engine).is_none() {
            out.push(format!("names unregistered engine {:?}", self.engine));
        }
        out
    }

    fn fold(&self, h: &mut Fnv64) {
        h.write_u64(self.id);
        h.write_f64(self.arrival_s);
        h.write_str(&self.model);
        h.write_u64(self.gpus as u64);
        h.write_u64(self.batch as u64);
        h.write_u64(self.context as u64);
        h.write_str(&self.schedule);
        h.write_str(&self.engine);
        h.write_u64(self.iterations as u64);
    }
}

/// A replayable arrival trace: the generator seed (0 for hand-built
/// traces) plus every job. The generator emits jobs in arrival order, but
/// the simulator orders events by time itself, so appended out-of-order
/// jobs (e.g. [`crate::fleet::sim::mixed_trace_with_xl`]'s XL cells) are
/// fine.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetTrace {
    pub seed: u64,
    pub jobs: Vec<JobSpec>,
}

impl FleetTrace {
    /// Bit-exact FNV-1a fingerprint of the whole trace (float fields by
    /// IEEE-754 pattern): two traces match iff they are byte-identical.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_u64(self.seed);
        h.write_u64(self.jobs.len() as u64);
        for j in &self.jobs {
            j.fold(&mut h);
        }
        h.finish()
    }

    /// Machine-readable trace (what `cxlfine fleet --trace` writes and
    /// replays), digest-embedded so files are self-certifying. The seed is
    /// written as a decimal *string*: JSON numbers ride an f64 here, which
    /// would silently round seeds above 2^53 and break the digest on
    /// replay of the tool's own output.
    pub fn to_json(&self) -> Json {
        let jobs: Vec<Json> = self.jobs.iter().map(JobSpec::to_json).collect();
        jobj! {
            "seed" => self.seed.to_string(),
            "digest" => format!("{:016x}", self.digest()),
            "jobs" => Json::Arr(jobs),
        }
    }

    /// Parse a trace, verifying the embedded digest when present and
    /// rejecting duplicate job ids (replays would double-reserve).
    /// Accepts the seed as either a decimal string (what [`to_json`]
    /// writes) or a plain number (hand-written files).
    pub fn from_json(j: &Json) -> Result<FleetTrace, String> {
        let seed_field = j
            .path(&["seed"])
            .ok_or_else(|| "trace missing seed".to_string())?;
        let seed = match seed_field {
            Json::Str(s) => s
                .parse::<u64>()
                .map_err(|e| format!("trace seed {s:?}: {e}"))?,
            other => other
                .as_u64()
                .ok_or_else(|| "trace seed must be a u64".to_string())?,
        };
        let raw = j
            .path(&["jobs"])
            .and_then(Json::as_arr)
            .ok_or_else(|| "trace missing jobs array".to_string())?;
        let jobs = raw
            .iter()
            .map(JobSpec::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let mut ids = std::collections::BTreeSet::new();
        for job in &jobs {
            if !ids.insert(job.id) {
                return Err(format!("trace has duplicate job id {}", job.id));
            }
        }
        let trace = FleetTrace { seed, jobs };
        if let Some(want) = j.path(&["digest"]).and_then(Json::as_str) {
            let got = format!("{:016x}", trace.digest());
            if want != got {
                return Err(format!(
                    "trace digest mismatch: file says {want}, contents hash to {got}"
                ));
            }
        }
        Ok(trace)
    }
}

/// Seeded synthetic workload generator.
///
/// Arrivals are a Poisson process (exponential inter-arrivals with mean
/// `mean_interarrival_s`, inverse-CDF on [`Xoshiro256pp`]); each job's
/// configuration is sampled uniformly from the mix vectors. Sampling order
/// per job is fixed (inter-arrival, model, batch, context, schedule,
/// engine, iterations), so a seed pins the whole trace bitwise.
#[derive(Clone, Debug)]
pub struct TraceGen {
    pub seed: u64,
    pub n_jobs: usize,
    pub mean_interarrival_s: f64,
    pub gpus: usize,
    pub models: Vec<String>,
    pub contexts: Vec<usize>,
    pub batches: Vec<usize>,
    pub schedules: Vec<String>,
    pub engines: Vec<String>,
    /// Inclusive iteration-count range.
    pub min_iterations: u32,
    pub max_iterations: u32,
}

impl TraceGen {
    /// The default mixed-context fleet: 7B jobs across the paper's context
    /// ladder, full fine-tuning and LoRA, striped CXL-aware placement.
    pub fn mixed(seed: u64, n_jobs: usize) -> Self {
        Self {
            seed,
            n_jobs,
            mean_interarrival_s: 120.0,
            gpus: 1,
            models: vec!["7b".into()],
            contexts: vec![4096, 8192, 16384, 32768],
            batches: vec![1, 4, 8, 16],
            schedules: vec!["zero-offload".into(), "lora:16".into()],
            engines: vec!["cxl-aware+striping".into()],
            min_iterations: 2,
            max_iterations: 8,
        }
    }

    /// Heavy-tailed variant of [`Self::generate`] sharing the serving
    /// layer's samplers: context is drawn by Zipf *rank* over the mix
    /// ladder (rank 1 = the shortest context dominates, the long-context
    /// cells are the tail) and the iteration count is bounded-Pareto over
    /// the `[min_iterations, max_iterations]` range — the production
    /// fine-tuning mix where most jobs are short and a fat tail runs long.
    /// Same fixed per-job sampling order discipline: inter-arrival,
    /// model, batch, context rank, schedule, engine, iterations.
    pub fn generate_heavy(&self) -> FleetTrace {
        assert!(
            !self.models.is_empty()
                && !self.contexts.is_empty()
                && !self.batches.is_empty()
                && !self.schedules.is_empty()
                && !self.engines.is_empty(),
            "every mix dimension needs at least one entry"
        );
        assert!(self.min_iterations >= 1 && self.min_iterations <= self.max_iterations);
        let mut rng = Xoshiro256pp::seeded(self.seed);
        let mut t = 0.0;
        let mut jobs = Vec::with_capacity(self.n_jobs);
        for id in 0..self.n_jobs {
            t += rng.exp_mean(self.mean_interarrival_s);
            let model = rng.choice(&self.models).clone();
            let batch = *rng.choice(&self.batches);
            let rank = rng.zipf(self.contexts.len() as u64, 1.1) as usize - 1;
            let schedule = rng.choice(&self.schedules).clone();
            let engine = rng.choice(&self.engines).clone();
            let iterations = rng
                .bounded_pareto(self.min_iterations as f64, self.max_iterations as f64, 1.2)
                .round() as u32;
            jobs.push(JobSpec {
                id: id as u64,
                arrival_s: t,
                model,
                gpus: self.gpus,
                batch,
                context: self.contexts[rank],
                schedule,
                engine,
                iterations: iterations.clamp(self.min_iterations, self.max_iterations),
            });
        }
        FleetTrace {
            seed: self.seed,
            jobs,
        }
    }

    pub fn generate(&self) -> FleetTrace {
        assert!(
            !self.models.is_empty()
                && !self.contexts.is_empty()
                && !self.batches.is_empty()
                && !self.schedules.is_empty()
                && !self.engines.is_empty(),
            "every mix dimension needs at least one entry"
        );
        assert!(self.min_iterations >= 1 && self.min_iterations <= self.max_iterations);
        let mut rng = Xoshiro256pp::seeded(self.seed);
        let mut t = 0.0;
        let mut jobs = Vec::with_capacity(self.n_jobs);
        for id in 0..self.n_jobs {
            t += rng.exp_mean(self.mean_interarrival_s);
            jobs.push(JobSpec {
                id: id as u64,
                arrival_s: t,
                model: rng.choice(&self.models).clone(),
                gpus: self.gpus,
                batch: *rng.choice(&self.batches),
                context: *rng.choice(&self.contexts),
                schedule: rng.choice(&self.schedules).clone(),
                engine: rng.choice(&self.engines).clone(),
                iterations: rng
                    .range_u64(self.min_iterations as u64, self.max_iterations as u64)
                    as u32,
            });
        }
        FleetTrace {
            seed: self.seed,
            jobs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_yields_byte_identical_traces() {
        let a = TraceGen::mixed(77, 40).generate();
        let b = TraceGen::mixed(77, 40).generate();
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "serialized traces must match byte-for-byte"
        );
        let c = TraceGen::mixed(78, 40).generate();
        assert_ne!(a.digest(), c.digest(), "a different seed must diverge");
    }

    #[test]
    fn arrivals_are_sorted_and_mix_is_sampled() {
        let t = TraceGen::mixed(5, 200).generate();
        assert_eq!(t.jobs.len(), 200);
        for w in t.jobs.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s, "arrivals must ascend");
        }
        for j in &t.jobs {
            assert!(j.arrival_s.is_finite() && j.arrival_s > 0.0);
            assert!((2..=8).contains(&j.iterations));
        }
        let contexts: std::collections::BTreeSet<usize> =
            t.jobs.iter().map(|j| j.context).collect();
        assert!(contexts.len() >= 3, "200 draws must hit most of the ladder");
        let schedules: std::collections::BTreeSet<&str> =
            t.jobs.iter().map(|j| j.schedule.as_str()).collect();
        assert_eq!(schedules.len(), 2);
    }

    #[test]
    fn heavy_trace_is_deterministic_and_skews_short() {
        let a = TraceGen::mixed(91, 300).generate_heavy();
        let b = TraceGen::mixed(91, 300).generate_heavy();
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        for j in &a.jobs {
            assert!((2..=8).contains(&j.iterations));
            assert!(j.registry_issues().is_empty());
        }
        // Zipf rank 1 = the shortest context must dominate the mix.
        let short = a.jobs.iter().filter(|j| j.context == 4096).count();
        let longest = a.jobs.iter().filter(|j| j.context == 32768).count();
        assert!(
            short > a.jobs.len() / 3 && short > longest,
            "heavy tail must skew short: {short} short vs {longest} longest of {}",
            a.jobs.len()
        );
        // And it is a different mix than the uniform generator.
        assert_ne!(a.digest(), TraceGen::mixed(91, 300).generate().digest());
    }

    #[test]
    fn trace_json_round_trips_and_verifies_digest() {
        let t = TraceGen::mixed(11, 17).generate();
        let text = t.to_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        let back = FleetTrace::from_json(&parsed).unwrap();
        assert_eq!(t, back, "round trip must preserve every field bitwise");
        // A tampered trace must be rejected by the digest check.
        let mut t2 = t.clone();
        t2.jobs[0].context += 1;
        let mut tampered = t2.to_json();
        // keep t2's jobs but the ORIGINAL digest → mismatch
        if let Json::Obj(o) = &mut tampered {
            o.set("digest", format!("{:016x}", t.digest()));
        }
        let err = FleetTrace::from_json(&tampered).unwrap_err();
        assert!(err.contains("digest mismatch"), "{err}");
    }

    #[test]
    fn registry_issues_flags_each_dangling_name() {
        // Every generated job must be simulatable as-is.
        let t = TraceGen::mixed(3, 25).generate();
        for j in &t.jobs {
            let issues = j.registry_issues();
            assert!(issues.is_empty(), "job {} dangles: {issues:?}", j.id);
        }
        let mut bad = t.jobs[0].clone();
        bad.model = "no-such-model".into();
        bad.schedule = "no-such-sched".into();
        bad.engine = "no-such-engine".into();
        let issues = bad.registry_issues();
        assert_eq!(issues.len(), 3, "{issues:?}");
        assert!(issues[0].contains("model preset") && issues[0].contains("no-such-model"));
        assert!(issues[1].contains("schedule") && issues[1].contains("no-such-sched"));
        assert!(issues[2].contains("engine") && issues[2].contains("no-such-engine"));
    }

    #[test]
    fn huge_seeds_and_bad_jobs_survive_or_fail_parsing_cleanly() {
        // Seeds above 2^53 must round-trip exactly (stringified seed).
        let mut t = TraceGen::mixed(1, 3).generate();
        t.seed = (1u64 << 53) + 3;
        let back = FleetTrace::from_json(&Json::parse(&t.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(back.seed, (1u64 << 53) + 3);
        assert_eq!(t, back);
        // A numeric seed (hand-written file) still parses.
        let hand = Json::parse(r#"{"seed": 7, "jobs": []}"#).unwrap();
        assert_eq!(FleetTrace::from_json(&hand).unwrap().seed, 7);
        // Malformed jobs are clean errors, not panics downstream.
        let zero_iter = Json::parse(
            r#"{"seed": 1, "jobs": [{"id": 0, "arrival_s": 0.0, "model": "7b",
                "gpus": 1, "batch": 1, "context": 256, "schedule": "zero-offload",
                "engine": "cxl-aware", "iterations": 0}]}"#,
        )
        .unwrap();
        let err = FleetTrace::from_json(&zero_iter).unwrap_err();
        assert!(err.contains("iterations"), "{err}");
        // Duplicate ids are rejected even without a digest.
        let mut dup = TraceGen::mixed(1, 2).generate();
        dup.jobs[1].id = dup.jobs[0].id;
        let mut json = dup.to_json();
        if let Json::Obj(o) = &mut json {
            o.set("digest", Json::Null); // strip certification
        }
        // digest now Null → as_str None → skipped; duplicate check must fire
        let err = FleetTrace::from_json(&json).unwrap_err();
        assert!(err.contains("duplicate job id"), "{err}");
    }

    #[test]
    fn mean_interarrival_is_respected() {
        let mut g = TraceGen::mixed(13, 2000);
        g.mean_interarrival_s = 10.0;
        let t = g.generate();
        let last = t.jobs.last().unwrap().arrival_s;
        let mean = last / 2000.0;
        assert!((mean - 10.0).abs() < 1.0, "empirical mean {mean}");
    }
}
