//! Fig. 9: training-throughput comparison on the single-AIC platform
//! (Config A): Baseline (DRAM-only) vs Naive CXL vs CXL-aware allocation,
//! across context lengths and batch sizes.
//!
//! Paper bands (normalized to baseline = 100%):
//!   (a) 7B, 1 GPU:  naive 76–94%, ours 97–99%
//!   (b) 12B, 1 GPU: naive 72–93%, ours 88–96%  (DRAM pressure → PGO spill)
//!   (c) 7B+12B, 2 GPUs: naive 84–94%, ours 86–99% (residual contention)
//!
//! We assert the *shape*: ordering baseline ≥ ours ≥ naive everywhere, and
//! the band positions within generous tolerances.

use cxlfine::mem::{EngineRef, Policy};
use cxlfine::model::presets::{mistral_nemo_12b, qwen25_7b};
use cxlfine::offload::sweep_grid;
use cxlfine::topology::presets::{config_a, with_dram_capacity};
use cxlfine::trow;
use cxlfine::util::bench::BenchReport;
use cxlfine::util::json::{Json, JsonObj};
use cxlfine::util::table::Table;
use cxlfine::util::units::GIB;

const CONTEXTS: &[usize] = &[4096, 8192, 16384, 32768];
const BATCHES: &[usize] = &[1, 4, 16, 32];

fn panel(
    report: &mut BenchReport,
    name: &str,
    model: cxlfine::model::ModelConfig,
    gpus: usize,
) -> (f64, f64, f64, f64) {
    let base_topo = config_a();
    let cxl_topo = with_dram_capacity(config_a(), 128 * GIB);
    let policies: Vec<EngineRef> = vec![
        Policy::DramOnly.into(),
        Policy::NaiveInterleave.into(),
        Policy::CxlAware { striping: false }.into(),
    ];
    let res = sweep_grid(
        &base_topo, &cxl_topo, &model, gpus, CONTEXTS, BATCHES, &policies,
    );
    let mut t = Table::new(&["context", "batch", "baseline tok/s", "naive %", "ours %"]);
    let mut arr = Vec::new();
    for p in &res.points {
        let base_tps = p.runs[0]
            .as_ref()
            .map(|b| b.tokens_per_sec())
            .unwrap_or(f64::NAN);
        let naive = res.normalized(p, 1, 0);
        let ours = res.normalized(p, 2, 0);
        let pct = |v: Option<f64>| {
            v.map(|r| format!("{:.1}", 100.0 * r))
                .unwrap_or_else(|| "OOM".into())
        };
        t.row(trow![
            p.context,
            p.batch,
            if base_tps.is_nan() { "OOM".into() } else { format!("{base_tps:.0}") },
            pct(naive),
            pct(ours)
        ]);
        let mut o = JsonObj::new();
        o.set("context", p.context);
        o.set("batch", p.batch);
        o.set("baseline_tps", if base_tps.is_nan() { Json::Null } else { base_tps.into() });
        o.set("naive_rel", naive.map(Json::from).unwrap_or(Json::Null));
        o.set("ours_rel", ours.map(Json::from).unwrap_or(Json::Null));
        arr.push(Json::Obj(o));
    }
    // ordering invariant on every comparable cell
    for p in &res.points {
        if let (Some(n), Some(o)) = (res.normalized(p, 1, 0), res.normalized(p, 2, 0)) {
            assert!(
                o >= n - 1e-9,
                "{name}: ours ({o:.3}) must beat naive ({n:.3}) at C={} B={}",
                p.context,
                p.batch
            );
            assert!(o <= 1.02, "{name}: ours cannot beat baseline on one AIC: {o:.3}");
        }
    }
    let (nlo, nhi) = res.normalized_range(1, 0).expect("naive range");
    let (olo, ohi) = res.normalized_range(2, 0).expect("ours range");
    println!(
        "{name}: naive {:.0}%–{:.0}% | ours {:.0}%–{:.0}% of baseline",
        nlo * 100.0,
        nhi * 100.0,
        olo * 100.0,
        ohi * 100.0
    );
    report.section(name, t, Json::Arr(arr));
    (nlo, nhi, olo, ohi)
}

fn main() {
    let mut report = BenchReport::new("fig9_single_aic");

    // NOTE on tolerances: the paper's bar groups sample a subset of the
    // (C, B) plane; our full cross-product includes harder transfer-bound
    // corners (e.g. B=1 at 4K, where parameter streaming dominates), so
    // the naive band is wider here than the quoted 76–94%. The assertions
    // below pin the SHAPE: naive always loses, CXL-aware recovers most of
    // the gap, and its ceiling touches the baseline.

    // (a) 7B, single GPU — paper: naive 76–94%, ours 97–99%
    let (nlo, nhi, olo, ohi) = panel(&mut report, "a_7b_1gpu", qwen25_7b(), 1);
    assert!(nhi < 1.0, "naive must never reach baseline: {nhi:.2}");
    assert!(olo > nlo + 0.10, "ours floor must clear naive floor: {olo:.2} vs {nlo:.2}");
    assert!(ohi > 0.97, "ours ceiling must touch baseline: {ohi:.2}");

    // (b) 12B, single GPU — paper: naive 72–93%, ours 88–96% (PGO spill)
    let (nlo, nhi, olo, _ohi) = panel(&mut report, "b_12b_1gpu", mistral_nemo_12b(), 1);
    assert!(nhi < 1.0, "12B naive ceiling: {nhi:.2}");
    assert!(olo > nlo + 0.10, "12B ours floor vs naive: {olo:.2} vs {nlo:.2}");
    assert!(olo > 0.75, "12B ours floor: {olo:.2}");

    // (c) both models, dual GPU — paper: naive 84–94%, ours 86–99%
    // (residual single-AIC contention caps the recovery)
    let (nlo7, _, olo7, ohi7) = panel(&mut report, "c_7b_2gpu", qwen25_7b(), 2);
    let (nlo12, _, olo12, _) = panel(&mut report, "c_12b_2gpu", mistral_nemo_12b(), 2);
    assert!(olo7 >= nlo7 && olo12 >= nlo12, "dual-GPU ordering");
    assert!(ohi7 > 0.95, "7B dual-GPU ours ceiling: {ohi7:.2}");
    assert!(
        olo7 < 0.97 || olo12 < 0.97,
        "single-AIC dual-GPU should NOT fully recover (that's striping's job): {olo7:.2}/{olo12:.2}"
    );

    report.finish();
}
