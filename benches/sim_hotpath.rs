//! §Perf microbench: the DES hot path — events/sec of the slab/heap
//! [`FlowSim`] vs the frozen pre-refactor HashMap engine
//! ([`RefFlowSim`]), at 1e3–1e6 concurrent flows over contended paths and
//! under timer-heavy mixes (DESIGN.md §7–§8).
//!
//! Also records the CPU Adam effective bandwidth so one file tracks both
//! coordinator hot paths. Results are written to `BENCH_sim.json` (path
//! override: `CXLFINE_BENCH_SIM_OUT`) — the CI bench-smoke job runs this
//! with `--smoke` so the perf trajectory is recorded on every push.
//!
//! Acceptance bar (ISSUE 2): ≥3× events/sec over the baseline at ≥1e5
//! flows — asserted in the full (non-smoke) run.

use cxlfine::optim::{adam_step, AdamHp, AdamState};
use cxlfine::sim::flow::{CapacityModel, FlowSim, ResourceId};
use cxlfine::sim::memmodel::ADAM_BYTES_PER_ELEM;
use cxlfine::sim::reference::RefFlowSim;
use cxlfine::trow;
use cxlfine::util::bench::{points_json, BenchReport};
use cxlfine::util::json::{Json, JsonObj};
use cxlfine::util::prng::Xoshiro256pp;
use cxlfine::util::table::Table;
use cxlfine::util::threadpool::default_threads;

const GB: f64 = 1e9;

/// The operations a scenario needs from either engine.
trait DesBench {
    fn add_resource(&mut self, name: &str, model: CapacityModel) -> ResourceId;
    fn start_flow(&mut self, path: &[ResourceId], bytes: f64, setup: f64, tag: u64);
    fn add_timer(&mut self, delay: f64, tag: u64);
    fn step(&mut self) -> bool;
}

impl DesBench for FlowSim {
    fn add_resource(&mut self, name: &str, model: CapacityModel) -> ResourceId {
        FlowSim::add_resource(self, name, model)
    }
    fn start_flow(&mut self, path: &[ResourceId], bytes: f64, setup: f64, tag: u64) {
        FlowSim::start_flow(self, path, bytes, setup, tag);
    }
    fn add_timer(&mut self, delay: f64, tag: u64) {
        FlowSim::add_timer(self, delay, tag);
    }
    fn step(&mut self) -> bool {
        FlowSim::next_event(self).is_some()
    }
}

impl DesBench for RefFlowSim {
    fn add_resource(&mut self, name: &str, model: CapacityModel) -> ResourceId {
        RefFlowSim::add_resource(self, name, model)
    }
    fn start_flow(&mut self, path: &[ResourceId], bytes: f64, setup: f64, tag: u64) {
        RefFlowSim::start_flow(self, path, bytes, setup, tag);
    }
    fn add_timer(&mut self, delay: f64, tag: u64) {
        RefFlowSim::add_timer(self, delay, tag);
    }
    fn step(&mut self) -> bool {
        RefFlowSim::next_event(self).is_some()
    }
}

/// Pre-generated workload so both engines replay the identical call
/// sequence: (path-resource-indices, bytes, setup, tag) per flow.
struct Scenario {
    flows: Vec<([usize; 2], f64, f64, u64)>,
    timers: Vec<(f64, u64)>,
}

/// The config-B-shaped resource set: 2 DRAM controllers, 2 contended AIC
/// links, 4 GPU links.
fn add_resources<S: DesBench>(sim: &mut S) -> Vec<ResourceId> {
    let mut r = vec![
        sim.add_resource("dram0", CapacityModel::Fixed(204.0 * GB)),
        sim.add_resource("dram1", CapacityModel::Fixed(204.0 * GB)),
    ];
    for i in 0..2 {
        r.push(sim.add_resource(
            &format!("aic{i}"),
            CapacityModel::Contended {
                single: 54.0 * GB,
                contended: 26.0 * GB,
            },
        ));
    }
    for i in 0..4 {
        r.push(sim.add_resource(&format!("gpu{i}"), CapacityModel::Fixed(54.0 * GB)));
    }
    r
}

/// Contended mix: every flow is a [host-side, gpu-side] path, ~half of the
/// host sides on the collapsing AIC links, 25 % with DMA setup latency, one
/// timer per 8 flows.
fn contended_scenario(n_flows: usize, seed: u64) -> Scenario {
    let mut rng = Xoshiro256pp::seeded(seed);
    let mut flows = Vec::with_capacity(n_flows);
    for tag in 0..n_flows as u64 {
        let host = rng.range_usize(0, 3); // dram0, dram1, aic0, aic1
        let gpu = 4 + rng.range_usize(0, 3);
        let bytes = rng.range_f64(1e6, 1e9);
        let setup = if rng.below(4) == 0 {
            rng.range_f64(10e-6, 1e-3)
        } else {
            0.0
        };
        flows.push(([host, gpu], bytes, setup, tag));
    }
    let timers = (0..n_flows / 8)
        .map(|i| (rng.range_f64(1e-4, 5e-2), 1_000_000 + i as u64))
        .collect();
    Scenario { flows, timers }
}

/// Timer-heavy mix: a static population of long-lived flows plus a dense
/// timer train — the pure event-queue/drain path (rates stay clean).
fn timer_scenario(n_flows: usize, n_timers: usize, seed: u64) -> Scenario {
    let mut rng = Xoshiro256pp::seeded(seed);
    let mut flows = Vec::with_capacity(n_flows);
    for tag in 0..n_flows as u64 {
        let host = rng.range_usize(0, 1); // DRAM only: no collapse solves
        let gpu = 4 + rng.range_usize(0, 3);
        // enormous transfers → no completion lands during the timer train
        flows.push(([host, gpu], 1e15, 0.0, tag));
    }
    let timers = (0..n_timers)
        .map(|i| (1e-6 * (i as f64 + 1.0), 1_000_000 + i as u64))
        .collect();
    Scenario { flows, timers }
}

/// Apply the scenario, then time `k_events` deliveries. Returns events/sec.
fn run_events<S: DesBench>(sim: &mut S, sc: &Scenario, k_events: usize) -> f64 {
    let rids = add_resources(sim);
    for (path, bytes, setup, tag) in &sc.flows {
        sim.start_flow(&[rids[path[0]], rids[path[1]]], *bytes, *setup, *tag);
    }
    for (delay, tag) in &sc.timers {
        sim.add_timer(*delay, *tag);
    }
    let t0 = std::time::Instant::now();
    let mut delivered = 0usize;
    while delivered < k_events {
        if !sim.step() {
            break;
        }
        delivered += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    assert!(delivered > 0, "scenario produced no events");
    delivered as f64 / dt
}

fn speedup_row(label: &str, n: usize, new_eps: f64, ref_eps: f64, t: &mut Table) -> f64 {
    let speedup = new_eps / ref_eps;
    t.row(trow![
        label,
        n,
        format!("{:.0}", new_eps),
        format!("{:.0}", ref_eps),
        format!("{:.2}x", speedup)
    ]);
    speedup
}

fn adam_gbps(n: usize, iters: usize) -> (f64, f64) {
    let threads = default_threads();
    let mut p = vec![1.0f32; n];
    let g: Vec<f32> = (0..n).map(|i| (i as f32 % 7.0) * 0.01).collect();
    let mut st = AdamState::new(n);
    let hp = AdamHp::default();
    adam_step(&mut p, &g, &mut st, &hp, threads); // warm (also warms the pool)
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        adam_step(&mut p, &g, &mut st, &hp, threads);
    }
    let per_step = t0.elapsed().as_secs_f64() / iters as f64;
    let eps = n as f64 / per_step;
    (eps * ADAM_BYTES_PER_ELEM / 1e9, per_step)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = BenchReport::new("sim_hotpath");
    let mut json_root = JsonObj::new();
    json_root.set("smoke", smoke);

    // ---- contended mix: events/sec vs flow count, both engines -------
    // (flows, k_events, run_reference)
    let grid: &[(usize, usize, bool)] = if smoke {
        &[(2_000, 400, true)]
    } else {
        &[
            (1_000, 2_000, true),
            (10_000, 800, true),
            (100_000, 120, true),
            (1_000_000, 60, false), // baseline would take minutes here
        ]
    };
    let mut t = Table::new(&["mix", "flows", "events/s", "ref events/s", "speedup"]).left(0);
    let (mut xs, mut new_rates, mut ref_rates) = (vec![], vec![], vec![]);
    let mut json_cells = Vec::new();
    let mut speedup_at_1e5 = None;
    for &(n, k, with_ref) in grid {
        let sc = contended_scenario(n, 42);
        let new_eps = run_events(&mut FlowSim::new(), &sc, k);
        let ref_eps = if with_ref {
            run_events(&mut RefFlowSim::new(), &sc, k)
        } else {
            f64::NAN
        };
        let mut cell = JsonObj::new();
        cell.set("flows", n);
        cell.set("events_per_sec", new_eps);
        if with_ref {
            let s = speedup_row("contended", n, new_eps, ref_eps, &mut t);
            cell.set("ref_events_per_sec", ref_eps);
            cell.set("speedup", s);
            if n >= 100_000 {
                speedup_at_1e5 = Some(s);
            }
            ref_rates.push(ref_eps);
        } else {
            t.row(trow![
                "contended",
                n,
                format!("{:.0}", new_eps),
                "-".to_string(),
                "-".to_string()
            ]);
            // no measurement — NaN keeps the persisted series honest
            // (matches the table's "-" rendering)
            ref_rates.push(f64::NAN);
        }
        xs.push(n as f64);
        new_rates.push(new_eps);
        json_cells.push(Json::Obj(cell));
    }
    report.section(
        "contended_mix",
        t,
        points_json(&xs, &[("events_per_s", &new_rates), ("ref_events_per_s", &ref_rates)]),
    );
    json_root.set("contended", Json::Arr(json_cells));

    // ---- timer-heavy mix ---------------------------------------------
    let (n_flows, n_timers, k) = if smoke {
        (2_000, 600, 500)
    } else {
        (20_000, 3_000, 1_500)
    };
    let sc = timer_scenario(n_flows, n_timers, 7);
    let new_eps = run_events(&mut FlowSim::new(), &sc, k.min(n_timers));
    let ref_eps = run_events(&mut RefFlowSim::new(), &sc, k.min(n_timers));
    let mut t2 = Table::new(&["mix", "flows", "events/s", "ref events/s", "speedup"]).left(0);
    let timer_speedup = speedup_row("timer-heavy", n_flows, new_eps, ref_eps, &mut t2);
    report.section(
        "timer_mix",
        t2,
        points_json(
            &[n_flows as f64],
            &[("events_per_s", &[new_eps]), ("ref_events_per_s", &[ref_eps])],
        ),
    );
    let mut tm = JsonObj::new();
    tm.set("flows", n_flows);
    tm.set("timers", n_timers);
    tm.set("events_per_sec", new_eps);
    tm.set("ref_events_per_sec", ref_eps);
    tm.set("speedup", timer_speedup);
    json_root.set("timer_mix", tm);

    // ---- CPU Adam bandwidth (the other coordinator hot path) ---------
    let (adam_n, adam_iters) = if smoke { (2_000_000, 3) } else { (50_000_000, 3) };
    let (gbps, per_step) = adam_gbps(adam_n, adam_iters);
    let mut t3 = Table::new(&["elements", "GB/s moved", "s/step"]);
    t3.row(trow![
        adam_n,
        format!("{gbps:.1}"),
        format!("{per_step:.4}")
    ]);
    report.section(
        "adam_bandwidth",
        t3,
        points_json(&[adam_n as f64], &[("gbps", &[gbps])]),
    );
    let mut aj = JsonObj::new();
    aj.set("elements", adam_n);
    aj.set("gbps", gbps);
    aj.set("sec_per_step", per_step);
    json_root.set("adam", aj);

    // ---- persist BENCH_sim.json --------------------------------------
    let out = std::env::var("CXLFINE_BENCH_SIM_OUT").unwrap_or_else(|_| "BENCH_sim.json".into());
    let payload = Json::Obj(json_root).to_string_pretty();
    match std::fs::write(&out, &payload) {
        Ok(()) => println!("\n[sim_hotpath] wrote {out}"),
        Err(e) => eprintln!("warn: could not write {out}: {e}"),
    }
    report.finish();

    // ---- acceptance gate (full run only) -----------------------------
    if !smoke {
        let s = speedup_at_1e5.expect("full run measures the 1e5 cell");
        assert!(
            s >= 3.0,
            "slab/heap DES must be ≥3x the HashMap baseline at 1e5 flows, got {s:.2}x"
        );
    }
}
