//! Capacity planner: for each (model, context, batch) cell, which memory
//! configuration can hold the run at all — and what throughput does each
//! policy deliver? This is the planning tool the paper's §II-B motivates:
//! "system memory capacity determines the feasible model size and maximum
//! context length".
//!
//! ```bash
//! cargo run --release --example capacity_planner
//! ```

use cxlfine::mem::{engine, EngineRef, Policy};
use cxlfine::model::footprint::{Footprint, Workload};
use cxlfine::model::presets::{mistral_nemo_12b, qwen25_7b};
use cxlfine::offload::{simulate_iteration, MemoryPlan, RunConfig};
use cxlfine::topology::presets::{config_b, with_dram_capacity};
use cxlfine::util::table::Table;
use cxlfine::util::units::{fmt_bytes, GIB};
use cxlfine::trow;

/// Throughput of one (host, workload, engine) cell, or "-" when it OOMs.
fn cell(
    host: &cxlfine::topology::SystemTopology,
    model: &cxlfine::model::ModelConfig,
    w: Workload,
    eng: &EngineRef,
) -> (bool, String) {
    let cfg = RunConfig::new(model.clone(), w, eng.clone());
    match MemoryPlan::build(host, &cfg) {
        Ok(plan) => {
            let b = simulate_iteration(host, &cfg, &plan);
            (true, format!("{:.0}", b.tokens_per_sec()))
        }
        Err(_) => (false, "-".to_string()),
    }
}

fn main() {
    // a modest host: 128 GiB DRAM... but with 2×256 GiB CXL AICs available
    let dram_only_host = with_dram_capacity(config_b(), 128 * GIB);
    let cxl_host = with_dram_capacity(config_b(), 128 * GIB);

    // Every CXL column resolves through the engine registry — adding a new
    // placement strategy makes it a one-line addition here.
    let striped = engine::by_name("cxl-aware+striping").expect("registered");
    let adaptive = engine::by_name("adaptive-spill").expect("registered");

    let mut t = Table::new(&[
        "model", "C", "B", "needed", "128GiB DRAM", "+CXL (striped)", "striped tok/s", "adaptive tok/s",
    ])
    .left(0);

    for model in [qwen25_7b(), mistral_nemo_12b()] {
        for &context in &[4096usize, 16384, 32768] {
            for &batch in &[1usize, 16] {
                let w = Workload::new(2, batch, context);
                let f = Footprint::compute(&model, &w);
                let dram_cfg = RunConfig::new(model.clone(), w, Policy::DramOnly);
                let dram_fits = MemoryPlan::fits(&dram_only_host, &dram_cfg);
                let (striped_fits, striped_tps) = cell(&cxl_host, &model, w, &striped);
                // per-engine fit shows up as "-" in its own tok/s column
                let (_adaptive_fits, adaptive_tps) = cell(&cxl_host, &model, w, &adaptive);
                t.row(trow![
                    model.name,
                    context,
                    batch,
                    fmt_bytes(f.total()),
                    if dram_fits { "fits" } else { "OOM" },
                    if striped_fits { "fits" } else { "OOM" },
                    striped_tps,
                    adaptive_tps
                ]);
            }
        }
    }
    println!("capacity planning on a 128 GiB-DRAM host, 2 GPUs, ±2×256 GiB CXL AICs\n");
    print!("{}", t.render());
    println!("\n→ every cell the bare host OOMs on, CXL + striping makes feasible —");
    println!("  the capacity argument of §II-B, with throughput attached.");
    println!("  (engines resolved by name: {})", engine::known_names().join(", "));
}
