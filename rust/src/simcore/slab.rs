//! Slab entity store with free-list recycling (DESIGN.md §14).
//!
//! Generalizes the `FlowSlot` slab PR 2 built inside `sim::flow`: entities
//! live in a dense `Vec<T>` addressed by `u32` slot index; released slots
//! are recycled LIFO through a free list, so a steady-state simulation
//! allocates nothing per entity. Slot indices are *reused*; any stable
//! identity (flow ids, job ids) is the caller's field inside `T` — the slab
//! deliberately does not version its slots, matching the engines' existing
//! contract that a released index is never dereferenced again.

use std::ops::{Index, IndexMut};

/// Dense `u32`-indexed entity store with LIFO slot recycling.
#[derive(Clone, Debug, Default)]
pub struct Slab<T> {
    entries: Vec<T>,
    free: Vec<u32>,
}

impl<T> Slab<T> {
    pub fn new() -> Self {
        Slab {
            entries: Vec::new(),
            free: Vec::new(),
        }
    }

    /// Store `value`, reusing the most recently released slot if any.
    pub fn insert(&mut self, value: T) -> u32 {
        match self.free.pop() {
            Some(i) => {
                self.entries[i as usize] = value;
                i
            }
            None => {
                assert!(self.entries.len() < u32::MAX as usize, "slab full");
                self.entries.push(value);
                (self.entries.len() - 1) as u32
            }
        }
    }

    /// Mark a slot free for reuse. The value stays in place until the slot
    /// is overwritten by a later [`Slab::insert`]; the caller promises not
    /// to dereference the index again (and not to double-release).
    pub fn release(&mut self, index: u32) {
        debug_assert!((index as usize) < self.entries.len(), "release of unknown slot");
        self.free.push(index);
    }

    /// Live (non-released) entries.
    pub fn len(&self) -> usize {
        self.entries.len() - self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total slots ever allocated (live + free) — the index-space bound
    /// callers size per-slot side tables (e.g. rate buffers) against.
    pub fn slot_count(&self) -> usize {
        self.entries.len()
    }

    /// The raw backing storage, including released slots. Per-slot passes
    /// that walk an external live-index list (the engines' id-sorted
    /// `active` vectors) borrow this to stay cache-linear.
    pub fn entries(&self) -> &[T] {
        &self.entries
    }

    /// Drop every entry and forget every free slot, retaining the backing
    /// capacity. After `clear` the slab is observationally identical to
    /// [`Slab::new`] — the arena-reuse contract `FlowSim::reset` builds on.
    pub fn clear(&mut self) {
        self.entries.clear();
        self.free.clear();
    }
}

impl<T> Index<usize> for Slab<T> {
    type Output = T;
    #[inline]
    fn index(&self, i: usize) -> &T {
        &self.entries[i]
    }
}

impl<T> IndexMut<usize> for Slab<T> {
    #[inline]
    fn index_mut(&mut self, i: usize) -> &mut T {
        &mut self.entries[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inserts_release_and_recycle_lifo() {
        let mut s: Slab<&str> = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        assert_eq!((a, b), (0, 1));
        assert_eq!(s.len(), 2);
        assert_eq!(s.slot_count(), 2);
        s.release(a);
        assert_eq!(s.len(), 1);
        assert_eq!(s.slot_count(), 2, "released slots stay allocated");
        let c = s.insert("c");
        assert_eq!(c, a, "most recently released slot is reused first");
        assert_eq!(s[c as usize], "c");
        assert_eq!(s[b as usize], "b");
        assert_eq!(s.slot_count(), 2, "no growth while the free list feeds inserts");
    }

    #[test]
    fn index_mut_writes_in_place() {
        let mut s: Slab<u64> = Slab::new();
        let i = s.insert(5);
        s[i as usize] += 10;
        assert_eq!(s[i as usize], 15);
        assert_eq!(s.entries(), &[15]);
    }

    #[test]
    fn clear_is_observationally_fresh() {
        let mut s: Slab<u32> = Slab::new();
        let a = s.insert(1);
        s.insert(2);
        s.release(a);
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.slot_count(), 0);
        // Fresh allocation order: index 0 first, no recycled free list.
        assert_eq!(s.insert(9), 0);
        assert_eq!(s.insert(10), 1);
    }

    #[test]
    fn empty_slab_reports_empty() {
        let mut s: Slab<u8> = Slab::default();
        assert!(s.is_empty());
        let i = s.insert(1);
        assert!(!s.is_empty());
        s.release(i);
        assert!(s.is_empty());
        assert_eq!(s.slot_count(), 1);
    }
}
