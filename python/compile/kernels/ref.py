"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package must
match its oracle to float32 tolerance across the hypothesis shape/dtype
sweep in ``python/tests/``. They are also used as the (recomputing)
backward implementations inside the kernels' ``custom_vjp`` rules.
"""

import jax.numpy as jnp


def attention(q, k, v, *, causal=True):
    """Naive scaled-dot-product attention.

    Args:
      q, k, v: ``[bh, seq, head_dim]`` (batch*heads folded together).
      causal: apply a causal mask.

    Returns:
      ``[bh, seq, head_dim]`` attention output (same dtype as q).
    """
    seq = q.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((seq, seq), bool))
        s = jnp.where(mask[None, :, :], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def linear_cross_entropy(x, emb, labels):
    """Materialized linear + softmax cross-entropy.

    Args:
      x: ``[tokens, hidden]`` final hidden states (already normed).
      emb: ``[vocab, hidden]`` tied LM-head weights.
      labels: ``[tokens]`` int32 target ids.

    Returns:
      scalar mean cross-entropy (f32).
    """
    logits = x.astype(jnp.float32) @ emb.astype(jnp.float32).T  # [T, V]
    m = logits.max(-1)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m[:, None]), -1)) + m
    label_logit = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - label_logit)


def lse_and_label_logit(x, emb, labels):
    """The two per-row streaming statistics the fused-CE kernel produces."""
    logits = x.astype(jnp.float32) @ emb.astype(jnp.float32).T
    m = logits.max(-1)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m[:, None]), -1)) + m
    label_logit = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse, label_logit
