//! Serving bench: the CXL-tiered paged KV cache vs a DRAM-only cache on
//! the pinned long-context request trace (every prompt overflows the
//! DRAM KV budget but fits DRAM+CXL), plus a heavy-tailed mixed workload
//! for the latency/occupancy profile.
//!
//! Gates (enforced in CI via `--smoke`):
//! * the tiered cache sustains strictly more req/s than `dram-only` on
//!   the pinned trace while meeting every TTFT SLO (p99 ≤ SLO),
//! * bit-identical result digests across reruns (the determinism
//!   contract extends to serving).
//!
//! Results land in `bench_out/serve_kv/` and in `BENCH_serve.json`
//! (override: `CXLFINE_BENCH_SERVE_OUT`), which the CI bench-smoke job
//! uploads on every push so the serving trajectory is recorded alongside
//! the fleet ones.

use std::time::Instant;

use cxlfine::model::presets as mpresets;
use cxlfine::offload::schedules::inference::kv_bytes_per_token;
use cxlfine::serve::{
    admission_by_name, dram_kv_budget, kv, simulate_serving, RequestGen, RequestSpec,
    RequestTrace, ServeResult, PAGE_TOKENS,
};
use cxlfine::topology::presets::{dev_tiny, with_dram_capacity};
use cxlfine::topology::SystemTopology;
use cxlfine::trow;
use cxlfine::util::bench::BenchReport;
use cxlfine::util::json::{Json, JsonObj};
use cxlfine::util::table::Table;
use cxlfine::util::units::{fmt_bytes, MIB};

const SLO_MS: f64 = 3_600_000.0;

/// Every prompt lands in the capacity gap: bigger than the DRAM KV
/// budget, far below DRAM+CXL (same arithmetic as `rust/tests/serve_sim.rs`).
fn gap_trace(topo: &SystemTopology, n: usize) -> RequestTrace {
    let budget = dram_kv_budget(topo, "tiny-2m");
    let m = mpresets::by_name("tiny-2m").unwrap();
    let page = PAGE_TOKENS as u64 * kv_bytes_per_token(&m);
    let prompt = ((budget / page) as usize + 8) * PAGE_TOKENS;
    RequestTrace {
        seed: 0,
        requests: (0..n)
            .map(|i| RequestSpec {
                id: i as u64,
                arrival_s: i as f64,
                model: "tiny-2m".into(),
                prompt_tokens: prompt,
                max_output_tokens: 8,
                slo_ms: SLO_MS,
            })
            .collect(),
    }
}

fn run(topo: &SystemTopology, trace: &RequestTrace, kv_name: &str, threads: usize) -> ServeResult {
    simulate_serving(
        topo,
        trace,
        &kv::by_name(kv_name).unwrap(),
        &admission_by_name("fcfs").unwrap(),
        8,
        threads,
    )
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = BenchReport::new("serve_kv");
    let topo = with_dram_capacity(dev_tiny(), 48 * MIB);
    let threads = cxlfine::util::threadpool::default_threads();
    let n = if smoke { 8 } else { 16 };
    let pinned = gap_trace(&topo, n);
    let mut mixed = RequestGen::mixed(77, if smoke { 16 } else { 48 }, "tiny-2m");
    mixed.slo_ms = SLO_MS;
    let mixed = mixed.generate();
    println!(
        "pinned gap trace: {} requests of {} prompt tokens (digest {:016x}) on {}",
        pinned.requests.len(),
        pinned.requests[0].prompt_tokens,
        pinned.digest(),
        topo.name
    );

    let policies = ["dram-only", "tiered:2", "tiered:4"];
    let mut raws = Vec::new();
    let mut by_name = Vec::new();
    for (label, trace) in [("pinned_gap", &pinned), ("mixed", &mixed)] {
        let mut t = Table::new(&[
            "kv policy",
            "wall",
            "completed",
            "rejected",
            "req/s",
            "p99 ttft ms",
            "p99 tpot ms",
            "cold reads",
            "demoted",
        ])
        .left(0);
        for kv_name in policies {
            let t0 = Instant::now();
            let res = run(&topo, trace, kv_name, threads);
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            t.row(trow![
                kv_name,
                format!("{wall:.2}s"),
                res.completed(),
                res.rejected(),
                format!("{:.3}", res.sustained_req_per_s()),
                res.p99_ttft_ms().map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into()),
                res.p99_tpot_ms().map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into()),
                fmt_bytes(res.cold_read_bytes()),
                fmt_bytes(res.kv.demoted_bytes)
            ]);
            let mut cell = JsonObj::new();
            cell.set("trace", label);
            cell.set("kv_policy", kv_name);
            cell.set("wall_s", wall);
            cell.set("completed", res.completed());
            cell.set("rejected", res.rejected());
            cell.set("truncated", res.truncated());
            cell.set("sustained_req_per_s", res.sustained_req_per_s());
            match res.p99_ttft_ms() {
                Some(v) => cell.set("p99_ttft_ms", v),
                None => cell.set("p99_ttft_ms", Json::Null),
            }
            cell.set("slo_attainment", res.slo_attainment());
            cell.set("cold_read_bytes", res.cold_read_bytes());
            cell.set("demoted_bytes", res.kv.demoted_bytes);
            cell.set("digest", format!("{:016x}", res.digest()));
            raws.push(Json::Obj(cell));
            by_name.push((format!("{label}/{kv_name}"), res));
        }
        report.section(label, t, Json::Null);
    }
    let get = |name: &str| {
        by_name
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, r)| r)
            .expect("swept policy ran")
    };

    // Gate 1: on the pinned gap trace the tiered cache strictly beats
    // dram-only on sustained req/s, with every TTFT SLO met.
    let (dram, tiered) = (get("pinned_gap/dram-only"), get("pinned_gap/tiered:4"));
    assert_eq!(dram.completed(), 0, "dram-only must reject the whole gap");
    assert_eq!(tiered.completed(), n, "tiered must complete the whole gap");
    assert!(
        tiered.sustained_req_per_s() > dram.sustained_req_per_s(),
        "the strict req/s beat: {:.3} vs {:.3}",
        tiered.sustained_req_per_s(),
        dram.sustained_req_per_s()
    );
    let p99 = tiered.p99_ttft_ms().expect("tiered completed requests");
    assert!(p99 <= SLO_MS, "tiered p99 TTFT {p99}ms blew the {SLO_MS}ms SLO");
    assert_eq!(tiered.slo_attainment(), 1.0);

    // Gate 2: determinism — a single-threaded rerun is bit-identical.
    let rerun = run(&topo, &pinned, "tiered:4", 1);
    assert_eq!(rerun.digest(), tiered.digest(), "serving rerun must be bit-identical");

    let mut root = JsonObj::new();
    root.set("bench", "serve_kv");
    root.set("smoke", smoke);
    root.set("pinned_digest", format!("{:016x}", pinned.digest()));
    root.set("mixed_digest", format!("{:016x}", mixed.digest()));
    root.set("tiered_req_per_s", tiered.sustained_req_per_s());
    root.set("dram_only_req_per_s", dram.sustained_req_per_s());
    root.set("cells", Json::Arr(raws));
    let out =
        std::env::var("CXLFINE_BENCH_SERVE_OUT").unwrap_or_else(|_| "BENCH_serve.json".into());
    let payload = Json::Obj(root).to_string_pretty();
    match std::fs::write(&out, &payload) {
        Ok(()) => println!("\n[serve_kv] wrote {out}"),
        Err(e) => eprintln!("warn: could not write {out}: {e}"),
    }
    report.finish();
}
