//! The NUMA-aware allocator: tracks per-node capacity, commits placements
//! computed by a [`PlacementEngine`], and reports utilization. This is the
//! library's stand-in for `libnuma`/`numactl` in the real system — plus the
//! paper's CXL-aware logic layered on top.
//!
//! Capacity is accounted on a **timeline**: each node carries one usage
//! counter per schedule phase, and a region with a scoped
//! [`Lifetime`] occupies only the phases of its liveness window. The fit
//! check is therefore *per-phase peak* occupancy, not the static sum —
//! activations dead during the optimizer step no longer count against the
//! step-phase peak, which lets configurations fit that static accounting
//! rejects as OOM. The default single-phase allocator
//! ([`NumaAllocator::new`]) degenerates to exactly the legacy static
//! arithmetic: one phase, every region eternal, `free = capacity − Σ
//! committed` — byte-identical to the pre-timeline code.

use std::collections::BTreeMap;

use super::engine::{EngineRef, PlacementEngine};
use super::profile::AccessProfile;
use super::region::{Lifetime, Placement, Region, RegionId, RegionRequest};
use crate::topology::{NodeId, SystemTopology};
use crate::util::units::fmt_bytes;

/// Per-node view of an allocation failure.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeShortfall {
    pub node: NodeId,
    /// Bytes available on the node during the request's liveness window.
    pub free: u64,
    /// Bytes the request tried to put there (the whole region when the
    /// engine refused to place, the node's shard when commit overflowed).
    pub requested: u64,
    /// Missing bytes on this node.
    pub shortfall: u64,
}

/// Allocation failure, with the per-node breakdown the satellite asks for.
#[derive(Debug, Clone, PartialEq)]
pub struct AllocError {
    pub request: String,
    pub bytes: u64,
    pub shortfall: u64,
    /// `(node, free, requested, shortfall)` breakdown at failure time.
    pub nodes: Vec<NodeShortfall>,
    /// Phase at which peak occupancy was exceeded (timeline accounting;
    /// `None` when the engine itself refused the placement).
    pub phase: Option<usize>,
    /// Placement-integrity failure (lint code P101/P105): the engine
    /// returned a malformed placement rather than a capacity shortfall.
    pub detail: Option<String>,
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "cannot place {} ({}): short {}",
            self.request,
            fmt_bytes(self.bytes),
            fmt_bytes(self.shortfall)
        )?;
        if let Some(ph) = self.phase {
            write!(f, " at phase {ph} peak")?;
        }
        // Nodes with zero shortfall had room for the whole request — the
        // engine declined them for placement-rule reasons, not capacity —
        // so only truly-short nodes make the diagnostic line.
        for n in self.nodes.iter().filter(|n| n.shortfall > 0) {
            write!(
                f,
                "; node{} free {} < requested {} (short {})",
                n.node.0,
                fmt_bytes(n.free),
                fmt_bytes(n.requested),
                fmt_bytes(n.shortfall)
            )?;
        }
        if let Some(d) = &self.detail {
            write!(f, "; {d}")?;
        }
        Ok(())
    }
}
impl std::error::Error for AllocError {}

/// Per-node capacity tracker + region table.
pub struct NumaAllocator<'t> {
    topo: &'t SystemTopology,
    engine: EngineRef,
    /// `used[node][phase]` — committed bytes live on the node during the
    /// phase. Single-phase allocators reproduce static accounting.
    used: Vec<Vec<u64>>,
    n_phases: usize,
    regions: BTreeMap<usize, Region>,
    next_id: usize,
}

impl<'t> NumaAllocator<'t> {
    /// Static accounting: one phase, every region live for the whole run.
    pub fn new(topo: &'t SystemTopology, engine: impl Into<EngineRef>) -> Self {
        Self::with_phases(topo, engine, 1)
    }

    /// Timeline accounting over `n_phases` schedule phases: regions with a
    /// [`Lifetime`] occupy only their window, and the fit check is the
    /// per-phase peak.
    pub fn with_phases(
        topo: &'t SystemTopology,
        engine: impl Into<EngineRef>,
        n_phases: usize,
    ) -> Self {
        let n_phases = n_phases.max(1);
        Self {
            topo,
            engine: engine.into(),
            used: topo.mem_nodes.iter().map(|_| vec![0; n_phases]).collect(),
            n_phases,
            regions: BTreeMap::new(),
            next_id: 0,
        }
    }

    /// The placement engine this allocator routes requests through.
    pub fn engine(&self) -> &dyn PlacementEngine {
        self.engine.as_ref()
    }

    pub fn topo(&self) -> &SystemTopology {
        self.topo
    }

    /// Number of timeline phases (1 = static accounting).
    pub fn n_phases(&self) -> usize {
        self.n_phases
    }

    /// A request's effective phase window, clamped to the timeline.
    fn window(&self, lifetime: Option<Lifetime>) -> (usize, usize) {
        let last = self.n_phases - 1;
        match lifetime {
            Some(l) => ((l.birth_phase as usize).min(last), (l.death_phase as usize).min(last)),
            None => (0, last),
        }
    }

    /// Peak committed bytes on a node across all phases.
    fn peak_used(&self, node: usize) -> u64 {
        self.used[node].iter().copied().max().unwrap_or(0)
    }

    /// Free bytes on a node (against its peak-phase occupancy).
    pub fn free_on(&self, node: NodeId) -> u64 {
        self.topo.node(node).capacity - self.peak_used(node.0)
    }

    /// Used bytes on a node (peak across phases).
    pub fn used_on(&self, node: NodeId) -> u64 {
        self.peak_used(node.0)
    }

    /// Committed bytes on a node during one phase.
    pub fn used_on_at(&self, node: NodeId, phase: usize) -> u64 {
        self.used[node.0][phase.min(self.n_phases - 1)]
    }

    /// Free bytes per node during `[lo, hi]` — what a request with that
    /// liveness window can actually claim.
    fn window_free(&self, lo: usize, hi: usize) -> Vec<u64> {
        self.topo
            .mem_nodes
            .iter()
            .enumerate()
            .map(|(n, spec)| {
                let peak = self.used[n][lo..=hi].iter().copied().max().unwrap_or(0);
                spec.capacity - peak
            })
            .collect()
    }

    /// Place and commit a region (no profile context: legacy engines see
    /// exactly the pre-refactor inputs).
    pub fn alloc(&mut self, req: RegionRequest) -> Result<RegionId, AllocError> {
        self.alloc_profiled(req, None)
    }

    /// Place and commit a region, handing the engine its measured
    /// [`AccessProfile`] when one exists. Every allocation — profiled or
    /// not — routes through [`PlacementEngine::place_profiled`]; the
    /// default implementation delegates to `place`, so legacy engines stay
    /// byte-identical.
    pub fn alloc_profiled(
        &mut self,
        req: RegionRequest,
        profile: Option<&AccessProfile>,
    ) -> Result<RegionId, AllocError> {
        let (lo, hi) = self.window(req.lifetime);
        let free = self.window_free(lo, hi);
        let placement = self
            .engine
            .place_profiled(self.topo, &req, profile, &free)
            .map_err(|shortfall| AllocError {
                request: req.name.clone(),
                bytes: req.bytes,
                shortfall,
                nodes: free
                    .iter()
                    .enumerate()
                    .map(|(n, &f)| NodeShortfall {
                        node: NodeId(n),
                        free: f,
                        requested: req.bytes,
                        shortfall: req.bytes.saturating_sub(f),
                    })
                    .collect(),
                phase: None,
                detail: None,
            })?;
        // Placement integrity (lint P101/P105) as an error, not a panic:
        // a buggy engine should fail the one allocation, not the process.
        if let Err(msg) = placement.check(req.bytes) {
            return Err(AllocError {
                request: req.name.clone(),
                bytes: req.bytes,
                shortfall: 0,
                nodes: Vec::new(),
                phase: None,
                detail: Some(format!("engine returned a malformed placement: {msg}")),
            });
        }
        self.commit(req, placement)
    }

    /// Commit an explicitly computed placement (used by tests and by the
    /// engine when it needs policy-independent staging buffers).
    pub fn commit(
        &mut self,
        req: RegionRequest,
        placement: Placement,
    ) -> Result<RegionId, AllocError> {
        let (lo, hi) = self.window(req.lifetime);
        for (n, b) in &placement.parts {
            for ph in lo..=hi {
                let cap = self.topo.node(*n).capacity;
                let free = cap - self.used[n.0][ph];
                if *b > free {
                    return Err(AllocError {
                        request: req.name.clone(),
                        bytes: req.bytes,
                        shortfall: *b - free,
                        nodes: vec![NodeShortfall {
                            node: *n,
                            free,
                            requested: *b,
                            shortfall: *b - free,
                        }],
                        phase: Some(ph),
                        detail: None,
                    });
                }
            }
        }
        for (n, b) in &placement.parts {
            for ph in lo..=hi {
                self.used[n.0][ph] += *b;
            }
        }
        let id = RegionId(self.next_id);
        self.next_id += 1;
        let lifetime = req
            .lifetime
            .map(|_| Lifetime::spanning(lo as u32, hi as u32));
        self.regions.insert(
            id.0,
            Region {
                id,
                name: req.name,
                class: req.class,
                bytes: req.bytes,
                gpu: req.gpu,
                placement,
                lifetime,
            },
        );
        Ok(id)
    }

    /// Release a region, returning its bytes to the nodes (across every
    /// phase of its committed window).
    pub fn release(&mut self, id: RegionId) -> bool {
        self.release_region(id).is_some()
    }

    /// [`NumaAllocator::release`] returning the released [`Region`] — the
    /// explicit public path long-lived owners (the fleet host) use to free
    /// a completed job's reservation without rebuilding the allocator.
    /// Free space afterwards is byte-identical to never having allocated
    /// the region (pinned by `release_restores_free_byte_identically`).
    pub fn release_region(&mut self, id: RegionId) -> Option<Region> {
        let r = self.regions.remove(&id.0)?;
        let (lo, hi) = self.window(r.lifetime);
        for (n, b) in &r.placement.parts {
            for ph in lo..=hi {
                debug_assert!(self.used[n.0][ph] >= *b, "release underflow");
                self.used[n.0][ph] -= *b;
            }
        }
        Some(r)
    }

    /// [`NumaAllocator::release_region`] with a structured error instead
    /// of a silently ignorable `None`: callers that *know* the region must
    /// be live (the fleet host releasing a resident job) route through
    /// this, so a double release names the dead id instead of corrupting
    /// capacity accounting downstream.
    pub fn release_strict(&mut self, id: RegionId) -> Result<Region, String> {
        self.release_region(id)
            .ok_or_else(|| format!("release of unknown region id {}", id.0))
    }

    /// Per-phase (early) release of a region's committed tail: give back
    /// the phases `[from, death]` of its window and shrink the lifetime to
    /// end at `from − 1` — how a long-lived host retires e.g. activation
    /// occupancy the moment the backward pass ends instead of at region
    /// death. Releasing at or before the birth phase releases the whole
    /// region; releasing past the death phase is a no-op. Eternal regions
    /// (no lifetime) span the full timeline and become scoped when
    /// truncated. Returns `false` only for unknown ids.
    pub fn release_phases_from(&mut self, id: RegionId, from: usize) -> bool {
        let (lifetime, parts) = match self.regions.get(&id.0) {
            Some(r) => (r.lifetime, r.placement.parts.clone()),
            None => return false,
        };
        let (lo, hi) = self.window(lifetime);
        if from <= lo {
            return self.release_region(id).is_some();
        }
        if from > hi {
            return true;
        }
        for (n, b) in &parts {
            for ph in from..=hi {
                debug_assert!(self.used[n.0][ph] >= *b, "release underflow");
                self.used[n.0][ph] -= *b;
            }
        }
        let r = self.regions.get_mut(&id.0).expect("presence checked above");
        r.lifetime = Some(Lifetime::spanning(lo as u32, (from - 1) as u32));
        true
    }

    pub fn region(&self, id: RegionId) -> Option<&Region> {
        self.regions.get(&id.0)
    }

    /// Regions in ascending [`RegionId`] order (a `BTreeMap` underneath,
    /// so reports and digests over the table are stable across runs).
    pub fn regions(&self) -> impl Iterator<Item = &Region> {
        self.regions.values()
    }

    pub fn n_regions(&self) -> usize {
        self.regions.len()
    }

    /// Total bytes allocated across all nodes (peak-phase view).
    pub fn total_used(&self) -> u64 {
        self.topo
            .all_nodes()
            .iter()
            .map(|&n| self.used_on(n))
            .sum()
    }

    /// Utilization table (for reports / `cxlfine plan`).
    pub fn describe(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(s, "allocator ({}):", self.engine.name());
        for n in self.topo.all_nodes() {
            let spec = self.topo.node(n);
            let used = self.used_on(n);
            let _ = write!(
                s,
                "  {}: {} / {} used ({:.1}%)",
                spec.name,
                fmt_bytes(used),
                fmt_bytes(spec.capacity),
                100.0 * used as f64 / spec.capacity as f64
            );
            if self.n_phases > 1 {
                let peaks: Vec<String> = (0..self.n_phases)
                    .map(|ph| fmt_bytes(self.used[n.0][ph]))
                    .collect();
                let _ = write!(s, " — per-phase [{}]", peaks.join(", "));
            }
            let _ = writeln!(s);
        }
        for r in self.regions.values() {
            let parts: Vec<String> = r
                .placement
                .parts
                .iter()
                .map(|(n, b)| format!("{}={}", self.topo.node(*n).name, fmt_bytes(*b)))
                .collect();
            let _ = write!(
                s,
                "  region {} [{}] {}: {}",
                r.name,
                r.class.name(),
                fmt_bytes(r.bytes),
                parts.join(" + ")
            );
            if let Some(l) = r.lifetime {
                let _ = write!(s, " live {l}");
            }
            let _ = writeln!(s);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::region::TensorClass;
    use crate::mem::Policy;
    use crate::topology::presets::{config_a, dev_tiny};
    use crate::util::units::GIB;

    #[test]
    fn alloc_release_roundtrip() {
        let topo = config_a();
        let mut a = NumaAllocator::new(&topo, Policy::DramOnly);
        let before = a.free_on(NodeId(0));
        let id = a
            .alloc(RegionRequest::new("p", TensorClass::MasterParams, 4 * GIB))
            .unwrap();
        assert_eq!(a.free_on(NodeId(0)), before - 4 * GIB);
        assert_eq!(a.region(id).unwrap().bytes, 4 * GIB);
        assert!(a.release(id));
        assert_eq!(a.free_on(NodeId(0)), before);
        assert!(!a.release(id), "double free must be rejected");
    }

    #[test]
    fn oom_error_carries_shortfall() {
        let topo = dev_tiny(); // 8 GiB DRAM
        let mut a = NumaAllocator::new(&topo, Policy::DramOnly);
        let err = a
            .alloc(RegionRequest::new("big", TensorClass::MasterParams, 100 * GIB))
            .unwrap_err();
        assert_eq!(err.shortfall, 92 * GIB);
        assert!(err.to_string().contains("short"));
    }

    #[test]
    fn oom_error_breaks_down_per_node() {
        let topo = dev_tiny(); // 8 GiB DRAM + 2 × 4 GiB CXL
        let mut a = NumaAllocator::new(&topo, Policy::CxlAware { striping: true });
        let err = a
            .alloc(RegionRequest::new("big", TensorClass::MasterParams, 100 * GIB))
            .unwrap_err();
        assert_eq!(err.nodes.len(), 3, "one entry per node");
        assert_eq!(err.nodes[0].node, NodeId(0));
        assert_eq!(err.nodes[0].free, 8 * GIB);
        assert_eq!(err.nodes[0].requested, 100 * GIB);
        assert_eq!(err.nodes[0].shortfall, 92 * GIB);
        assert_eq!(err.phase, None, "engine refusal carries no phase");
        let msg = err.to_string();
        assert!(msg.contains("node0") && msg.contains("node2"), "{msg}");
    }

    #[test]
    fn commit_overflow_reports_node_and_phase() {
        let topo = dev_tiny();
        let mut a = NumaAllocator::with_phases(&topo, Policy::DramOnly, 3);
        // phase 1 already holds 6 GiB
        a.commit(
            RegionRequest::new("r0", TensorClass::Activations, 6 * GIB)
                .with_lifetime(Lifetime::spanning(1, 1)),
            Placement::single(NodeId(0), 6 * GIB),
        )
        .unwrap();
        // 4 GiB across phases 0..=1 overflows at phase 1 only
        let err = a
            .commit(
                RegionRequest::new("r1", TensorClass::Activations, 4 * GIB)
                    .with_lifetime(Lifetime::spanning(0, 1)),
                Placement::single(NodeId(0), 4 * GIB),
            )
            .unwrap_err();
        assert_eq!(err.phase, Some(1));
        assert_eq!(err.nodes.len(), 1);
        assert_eq!(err.nodes[0].free, 2 * GIB);
        assert_eq!(err.nodes[0].shortfall, 2 * GIB);
        assert!(err.to_string().contains("phase 1"), "{err}");
    }

    #[test]
    fn malformed_engine_placement_is_an_error_not_a_panic() {
        struct BadEngine;
        impl crate::mem::PlacementEngine for BadEngine {
            fn name(&self) -> &str {
                "bad-test-engine"
            }
            fn place(
                &self,
                _topo: &crate::topology::SystemTopology,
                req: &RegionRequest,
                _free: &[u64],
            ) -> Result<Placement, u64> {
                // One byte more than the region: an integrity violation
                // that used to panic inside alloc_profiled.
                Ok(Placement::single(NodeId(0), req.bytes + 1))
            }
        }
        let topo = dev_tiny();
        let engine: crate::mem::EngineRef = std::sync::Arc::new(BadEngine);
        let mut a = NumaAllocator::new(&topo, engine);
        let err = a
            .alloc(RegionRequest::new("bad", TensorClass::Activations, 1000))
            .unwrap_err();
        assert!(err.detail.is_some(), "integrity failures carry a detail");
        assert!(err.to_string().contains("bytes mismatch"), "{err}");
    }

    #[test]
    fn sequential_allocs_respect_capacity() {
        let topo = dev_tiny();
        let mut a = NumaAllocator::new(&topo, Policy::CxlAware { striping: true });
        // fill CXL (4+4 GiB) with activations, then overflow to DRAM
        let mut ids = Vec::new();
        for i in 0..5 {
            let id = a
                .alloc(RegionRequest::new(
                    format!("act{i}"),
                    TensorClass::Activations,
                    2 * GIB,
                ))
                .unwrap();
            ids.push(id);
        }
        // 10 GiB of activations: 8 on CXL, 2 on DRAM
        let on_cxl: u64 = ids
            .iter()
            .map(|&id| {
                let r = a.region(id).unwrap();
                r.placement.bytes_on(NodeId(1)) + r.placement.bytes_on(NodeId(2))
            })
            .sum();
        assert_eq!(on_cxl, 8 * GIB);
        assert_eq!(a.total_used(), 10 * GIB);
    }

    #[test]
    fn used_plus_free_is_capacity_invariant() {
        use crate::util::proptest_lite::*;
        let topo = dev_tiny();
        let gen = VecOf {
            inner: PairOf(
                U64Range {
                    lo: 1,
                    hi: 3 * GIB,
                },
                UsizeRange { lo: 0, hi: 11 },
            ),
            min_len: 1,
            max_len: 12,
        };
        forall("used+free=cap", 21, 60, &gen, |ops| {
            let mut a = NumaAllocator::new(&topo, Policy::CxlAware { striping: true });
            let mut live = Vec::new();
            for (bytes, sel) in ops {
                let class = TensorClass::all()[sel % 6];
                if sel % 2 == 0 || live.is_empty() {
                    if let Ok(id) = a.alloc(RegionRequest::new("r", class, *bytes)) {
                        live.push(id);
                    }
                } else {
                    let id = live.remove(sel % live.len());
                    a.release(id);
                }
                // invariant: per-node used + free == capacity
                for n in a.topo().all_nodes() {
                    let cap = a.topo().node(n).capacity;
                    if a.free_on(n) + a.used_on(n) != cap {
                        return Err(format!("node {} accounting broken", n.0));
                    }
                }
                // invariant: sum of region placements == total used
                let sum: u64 = a.regions().map(|r| r.placement.total_bytes()).sum();
                if sum != a.total_used() {
                    return Err("region sum != used".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn regions_iterate_in_id_order() {
        let topo = config_a();
        let mut a = NumaAllocator::new(&topo, Policy::DramOnly);
        for i in 0..16 {
            a.alloc(RegionRequest::new(
                format!("r{i}"),
                TensorClass::Activations,
                GIB,
            ))
            .unwrap();
        }
        let ids: Vec<usize> = a.regions().map(|r| r.id.0).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted, "region table must iterate by ascending id");
    }

    #[test]
    fn describe_lists_regions() {
        let topo = config_a();
        let mut a = NumaAllocator::new(&topo, Policy::CxlAware { striping: false });
        a.alloc(RegionRequest::new("opt", TensorClass::OptimizerStates, GIB))
            .unwrap();
        let d = a.describe();
        assert!(d.contains("opt"));
        assert!(d.contains("optimizer-states-fp32"));
    }

    #[test]
    fn describe_shows_lifetimes_and_phase_peaks() {
        let topo = dev_tiny();
        let mut a = NumaAllocator::with_phases(&topo, Policy::DramOnly, 3);
        a.alloc(
            RegionRequest::new("acts", TensorClass::Activations, GIB)
                .with_lifetime(Lifetime::spanning(0, 1)),
        )
        .unwrap();
        let d = a.describe();
        assert!(d.contains("live [0..1]"), "{d}");
        assert!(d.contains("per-phase"), "{d}");
    }

    /// The fleet-host satellite: after releasing a region, every phase of
    /// every node must be byte-identical to an allocator where the region
    /// was never allocated at all. Neighbouring regions are committed with
    /// explicit placements so their shards cannot shift with the victim's
    /// presence.
    #[test]
    fn release_restores_free_byte_identically() {
        let topo = dev_tiny();
        let build = |with_victim: bool| {
            let mut a = NumaAllocator::with_phases(&topo, Policy::DramOnly, 3);
            a.commit(
                RegionRequest::new("keep-a", TensorClass::MasterParams, GIB)
                    .with_lifetime(Lifetime::spanning(0, 2)),
                Placement::single(NodeId(0), GIB),
            )
            .unwrap();
            let victim = if with_victim {
                Some(
                    a.commit(
                        RegionRequest::new("victim", TensorClass::Activations, 2 * GIB),
                        Placement {
                            parts: vec![(NodeId(0), GIB), (NodeId(1), GIB)],
                            mode: crate::sim::memmodel::AccessMode::Partitioned,
                        },
                    )
                    .unwrap(),
                )
            } else {
                None
            };
            a.commit(
                RegionRequest::new("keep-b", TensorClass::Activations, GIB)
                    .with_lifetime(Lifetime::spanning(1, 1)),
                Placement::single(NodeId(2), GIB),
            )
            .unwrap();
            (a, victim)
        };
        let (mut with, victim) = build(true);
        let released = with.release_region(victim.unwrap()).expect("live region");
        assert_eq!(released.name, "victim");
        assert_eq!(released.bytes, 2 * GIB);
        let (without, _) = build(false);
        for n in topo.all_nodes() {
            for ph in 0..3 {
                assert_eq!(
                    with.used_on_at(n, ph),
                    without.used_on_at(n, ph),
                    "node {} phase {ph} differs from never-allocated",
                    n.0
                );
            }
            assert_eq!(with.free_on(n), without.free_on(n));
        }
        assert!(with.release_region(released.id).is_none(), "double release");
        let err = with.release_strict(released.id).unwrap_err();
        assert!(err.contains("unknown region id"), "{err}");
    }

    #[test]
    fn release_phases_from_truncates_the_tail_only() {
        let topo = dev_tiny();
        let mut a = NumaAllocator::with_phases(&topo, Policy::DramOnly, 4);
        let id = a
            .alloc(
                RegionRequest::new("acts", TensorClass::Activations, 2 * GIB)
                    .with_lifetime(Lifetime::spanning(0, 2)),
            )
            .unwrap();
        assert!(a.release_phases_from(id, 1));
        assert_eq!(a.used_on_at(NodeId(0), 0), 2 * GIB, "head phase keeps bytes");
        assert_eq!(a.used_on_at(NodeId(0), 1), 0);
        assert_eq!(a.used_on_at(NodeId(0), 2), 0);
        assert_eq!(a.region(id).unwrap().lifetime, Some(Lifetime::spanning(0, 0)));
        // past-death truncation is a no-op, not an error
        assert!(a.release_phases_from(id, 3));
        assert_eq!(a.used_on_at(NodeId(0), 0), 2 * GIB);
        // truncating at (or before) birth releases the whole region
        assert!(a.release_phases_from(id, 0));
        assert!(a.region(id).is_none());
        for ph in 0..4 {
            assert_eq!(a.used_on_at(NodeId(0), ph), 0, "phase {ph}");
        }
        assert!(!a.release_phases_from(id, 0), "unknown id must be rejected");
    }

    #[test]
    fn release_phases_from_scopes_eternal_regions() {
        let topo = dev_tiny();
        let mut a = NumaAllocator::with_phases(&topo, Policy::DramOnly, 3);
        let id = a
            .alloc(RegionRequest::new("p", TensorClass::MasterParams, GIB))
            .unwrap();
        assert!(a.release_phases_from(id, 2));
        assert_eq!(a.region(id).unwrap().lifetime, Some(Lifetime::spanning(0, 1)));
        assert_eq!(a.used_on_at(NodeId(0), 1), GIB);
        assert_eq!(a.used_on_at(NodeId(0), 2), 0);
        // the shrunk window is what a subsequent full release gives back
        assert!(a.release(id));
        for ph in 0..3 {
            assert_eq!(a.used_on_at(NodeId(0), ph), 0);
        }
    }

    // ------------------------------------------------------------------
    // Timeline (lifetime) accounting.
    // ------------------------------------------------------------------

    #[test]
    fn disjoint_lifetimes_share_capacity() {
        let topo = dev_tiny(); // 8 GiB DRAM
        let mut a = NumaAllocator::with_phases(&topo, Policy::DramOnly, 3);
        // 6 GiB live in phases 0..1 + 6 GiB live in phase 2 → static sum
        // (12 GiB) exceeds DRAM, but the per-phase peak (6 GiB) fits.
        let acts = a
            .alloc(
                RegionRequest::new("acts", TensorClass::Activations, 6 * GIB)
                    .with_lifetime(Lifetime::spanning(0, 1)),
            )
            .unwrap();
        let opt = a
            .alloc(
                RegionRequest::new("opt", TensorClass::OptimizerStates, 6 * GIB)
                    .with_lifetime(Lifetime::spanning(2, 2)),
            )
            .unwrap();
        assert_eq!(a.used_on_at(NodeId(0), 0), 6 * GIB);
        assert_eq!(a.used_on_at(NodeId(0), 1), 6 * GIB);
        assert_eq!(a.used_on_at(NodeId(0), 2), 6 * GIB);
        assert_eq!(a.used_on(NodeId(0)), 6 * GIB, "peak, not sum");
        // an eternal region must fit against the peak in EVERY phase
        let err = a
            .alloc(RegionRequest::new("x", TensorClass::MasterParams, 3 * GIB))
            .unwrap_err();
        assert_eq!(err.shortfall, GIB);
        a.release(acts);
        a.release(opt);
        assert_eq!(a.free_on(NodeId(0)), 8 * GIB);
    }

    #[test]
    fn static_allocator_ignores_windows_gracefully() {
        // In single-phase mode a scoped lifetime clamps to phase 0 and the
        // arithmetic is the legacy static sum.
        let topo = dev_tiny();
        let mut a = NumaAllocator::new(&topo, Policy::DramOnly);
        a.alloc(
            RegionRequest::new("a", TensorClass::Activations, 5 * GIB)
                .with_lifetime(Lifetime::spanning(0, 1)),
        )
        .unwrap();
        let err = a
            .alloc(
                RegionRequest::new("b", TensorClass::OptimizerStates, 5 * GIB)
                    .with_lifetime(Lifetime::spanning(2, 2)),
            )
            .unwrap_err();
        assert_eq!(err.shortfall, 2 * GIB, "static mode must still sum");
    }

    #[test]
    fn prop_release_restores_every_phase_exactly() {
        use crate::util::proptest_lite::*;
        let topo = dev_tiny();
        let gen = VecOf {
            inner: PairOf(
                U64Range { lo: 1, hi: GIB },
                PairOf(UsizeRange { lo: 0, hi: 3 }, UsizeRange { lo: 0, hi: 3 }),
            ),
            min_len: 1,
            max_len: 10,
        };
        forall("lifetime-release-restores", 33, 80, &gen, |ops| {
            let mut a = NumaAllocator::with_phases(&topo, Policy::DramOnly, 4);
            let mut ids = Vec::new();
            for (bytes, (p1, p2)) in ops {
                let (lo, hi) = (*p1.min(p2) as u32, *p1.max(p2) as u32);
                let req = RegionRequest::new("r", TensorClass::Activations, *bytes)
                    .with_lifetime(Lifetime::spanning(lo, hi));
                if let Ok(id) = a.alloc(req) {
                    ids.push(id);
                }
            }
            for id in ids.drain(..) {
                if !a.release(id) {
                    return Err("live region failed to release".into());
                }
                if a.release(id) {
                    return Err("double-release accepted".into());
                }
            }
            for n in a.topo().all_nodes() {
                for ph in 0..a.n_phases() {
                    if a.used_on_at(n, ph) != 0 {
                        return Err(format!("node {} phase {ph} not restored", n.0));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_phase_peak_never_exceeds_static_sum() {
        use crate::util::proptest_lite::*;
        let topo = dev_tiny();
        let gen = VecOf {
            inner: PairOf(
                U64Range { lo: 1, hi: GIB },
                PairOf(UsizeRange { lo: 0, hi: 4 }, UsizeRange { lo: 0, hi: 4 }),
            ),
            min_len: 1,
            max_len: 12,
        };
        forall("peak<=static-sum", 35, 80, &gen, |ops| {
            let mut a = NumaAllocator::with_phases(&topo, Policy::CxlAware { striping: true }, 5);
            let mut static_sum = vec![0u64; a.topo().all_nodes().len()];
            for (bytes, (p1, p2)) in ops {
                let (lo, hi) = (*p1.min(p2) as u32, *p1.max(p2) as u32);
                let req = RegionRequest::new("r", TensorClass::Activations, *bytes)
                    .with_lifetime(Lifetime::spanning(lo, hi));
                if let Ok(id) = a.alloc(req) {
                    for (n, b) in &a.region(id).unwrap().placement.parts {
                        static_sum[n.0] += *b;
                    }
                }
                for n in a.topo().all_nodes() {
                    if a.used_on(n) > static_sum[n.0] {
                        return Err(format!(
                            "node {} peak {} exceeds static sum {}",
                            n.0,
                            a.used_on(n),
                            static_sum[n.0]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_phase_peak_fit_implies_commit_succeeds() {
        use crate::util::proptest_lite::*;
        let topo = dev_tiny(); // DRAM capacity 8 GiB
        let cap = topo.node(NodeId(0)).capacity;
        let gen = VecOf {
            inner: PairOf(
                U64Range { lo: 1, hi: 2 * GIB },
                PairOf(UsizeRange { lo: 0, hi: 3 }, UsizeRange { lo: 0, hi: 3 }),
            ),
            min_len: 1,
            max_len: 10,
        };
        forall("peak-fit=>commit", 37, 80, &gen, |ops| {
            // Predict per-phase occupancy by hand; the allocator must agree
            // on every commit verdict.
            let mut a = NumaAllocator::with_phases(&topo, Policy::DramOnly, 4);
            let mut predicted = vec![0u64; 4];
            for (bytes, (p1, p2)) in ops {
                let (lo, hi) = (*p1.min(p2), *p1.max(p2));
                let fits = (lo..=hi).all(|ph| predicted[ph] + bytes <= cap);
                let res = a.commit(
                    RegionRequest::new("r", TensorClass::Activations, *bytes)
                        .with_lifetime(Lifetime::spanning(lo as u32, hi as u32)),
                    Placement::single(NodeId(0), *bytes),
                );
                match (fits, &res) {
                    (true, Err(e)) => {
                        return Err(format!("phase-peak fits but commit failed: {e}"))
                    }
                    (false, Ok(_)) => return Err("overfull commit accepted".into()),
                    _ => {}
                }
                if res.is_ok() {
                    for ph in lo..=hi {
                        predicted[ph] += bytes;
                    }
                }
                for (ph, want) in predicted.iter().enumerate() {
                    if a.used_on_at(NodeId(0), ph) != *want {
                        return Err(format!("phase {ph} occupancy diverged"));
                    }
                }
            }
            Ok(())
        });
    }
}
