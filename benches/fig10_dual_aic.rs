//! Fig. 10: training throughput on the dual-AIC platform (Config B):
//! Baseline vs Naive CXL vs CXL-aware + Multi-AIC Striping.
//!
//! Paper bands: naive loses 2–11%; ours recovers to ~99–101% of the
//! DRAM-only baseline — the striping result that motivates §IV-B.

use cxlfine::mem::{EngineRef, Policy};
use cxlfine::model::presets::{mistral_nemo_12b, qwen25_7b};
use cxlfine::offload::sweep_grid;
use cxlfine::topology::presets::{config_b, with_dram_capacity};
use cxlfine::trow;
use cxlfine::util::bench::BenchReport;
use cxlfine::util::json::{Json, JsonObj};
use cxlfine::util::table::Table;
use cxlfine::util::units::GIB;

const CONTEXTS: &[usize] = &[4096, 8192, 16384, 32768];
const BATCHES: &[usize] = &[1, 4, 16, 32];

fn panel(
    report: &mut BenchReport,
    name: &str,
    model: cxlfine::model::ModelConfig,
    gpus: usize,
) -> (f64, f64) {
    let base_topo = config_b();
    let cxl_topo = with_dram_capacity(config_b(), 128 * GIB);
    let policies: Vec<EngineRef> = vec![
        Policy::DramOnly.into(),
        Policy::NaiveInterleave.into(),
        Policy::CxlAware { striping: true }.into(),
    ];
    let res = sweep_grid(&base_topo, &cxl_topo, &model, gpus, CONTEXTS, BATCHES, &policies);
    let mut t = Table::new(&["context", "batch", "baseline tok/s", "naive %", "ours+striping %"]);
    let mut arr = Vec::new();
    for p in &res.points {
        let base_tps = p.runs[0].as_ref().map(|b| b.tokens_per_sec());
        let naive = res.normalized(p, 1, 0);
        let ours = res.normalized(p, 2, 0);
        let pct = |v: Option<f64>| {
            v.map(|r| format!("{:.1}", 100.0 * r)).unwrap_or_else(|| "OOM".into())
        };
        t.row(trow![
            p.context,
            p.batch,
            base_tps.map(|v| format!("{v:.0}")).unwrap_or_else(|| "OOM".into()),
            pct(naive),
            pct(ours)
        ]);
        let mut o = JsonObj::new();
        o.set("context", p.context);
        o.set("batch", p.batch);
        o.set("naive_rel", naive.map(Json::from).unwrap_or(Json::Null));
        o.set("ours_rel", ours.map(Json::from).unwrap_or(Json::Null));
        arr.push(Json::Obj(o));
        if let (Some(n), Some(o)) = (naive, ours) {
            assert!(o >= n, "{name}: striping must beat naive at C={} B={}", p.context, p.batch);
        }
    }
    let (olo, ohi) = res.normalized_range(2, 0).expect("ours range");
    let (nlo, nhi) = res.normalized_range(1, 0).expect("naive range");
    println!(
        "{name}: naive {:.0}%–{:.0}% | ours+striping {:.0}%–{:.0}%",
        nlo * 100.0,
        nhi * 100.0,
        olo * 100.0,
        ohi * 100.0
    );
    report.section(name, t, Json::Arr(arr));
    (olo, ohi)
}

fn main() {
    let mut report = BenchReport::new("fig10_dual_aic");

    // (a) 12B, 1 GPU — paper: ours 100–101%
    let (olo, _) = panel(&mut report, "a_12b_1gpu", mistral_nemo_12b(), 1);
    assert!(olo > 0.93, "12B 1-GPU striped floor {olo:.3} (paper ~1.00)");

    // (b) 7B, 2 GPUs — paper: ours ≥ 99%
    let (olo, _) = panel(&mut report, "b_7b_2gpu", qwen25_7b(), 2);
    assert!(olo > 0.93, "7B 2-GPU striped floor {olo:.3}");

    // (c) 12B, 2 GPUs — paper: ours ≥ 99%
    let (olo, _) = panel(&mut report, "c_12b_2gpu", mistral_nemo_12b(), 2);
    assert!(olo > 0.90, "12B 2-GPU striped floor {olo:.3}");

    println!("dual-AIC striping recovers near-baseline throughput (Fig. 10 shape holds)");
    report.finish();
}
