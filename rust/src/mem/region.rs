//! Memory regions and the paper's data taxonomy.
//!
//! Table I's system-memory components, tagged with the property that drives
//! placement (§IV-A): latency-critical data is touched by the CPU optimizer
//! inner loop; latency-tolerant data only rides DMA engines to/from GPUs.

use crate::sim::memmodel::AccessMode;
use crate::topology::{GpuId, NodeId};

/// The offloaded data classes of Table I.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TensorClass {
    /// fp32 master parameters (optimizer input/output). Latency-critical.
    MasterParams,
    /// fp32 gradients accumulated for the optimizer. Latency-critical.
    Gradients32,
    /// fp32 Adam moments (m, v). Latency-critical.
    OptimizerStates,
    /// bf16 parameter copies streamed to GPUs each step. Latency-tolerant.
    Params16,
    /// bf16 gradients offloaded from GPUs each step. Latency-tolerant.
    Grads16,
    /// bf16 checkpointed activations (per GPU). Latency-tolerant, the
    /// capacity driver for long contexts.
    Activations,
}

impl TensorClass {
    /// Is this class read/written by the CPU optimizer inner loop?
    /// (§III-A: such data suffers the CXL latency penalty.)
    pub fn latency_critical(self) -> bool {
        matches!(
            self,
            TensorClass::MasterParams | TensorClass::Gradients32 | TensorClass::OptimizerStates
        )
    }

    /// Is this class only moved by DMA to/from GPUs? (§III-B: such data is
    /// bandwidth-bound and tolerates CXL placement.)
    pub fn gpu_transfer(self) -> bool {
        !self.latency_critical()
    }

    pub fn name(self) -> &'static str {
        match self {
            TensorClass::MasterParams => "master-params-fp32",
            TensorClass::Gradients32 => "grads-fp32",
            TensorClass::OptimizerStates => "optimizer-states-fp32",
            TensorClass::Params16 => "params-bf16",
            TensorClass::Grads16 => "grads-bf16",
            TensorClass::Activations => "activations-bf16",
        }
    }

    pub fn all() -> [TensorClass; 6] {
        [
            TensorClass::MasterParams,
            TensorClass::Gradients32,
            TensorClass::OptimizerStates,
            TensorClass::Params16,
            TensorClass::Grads16,
            TensorClass::Activations,
        ]
    }
}

/// Where a region's bytes physically live.
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    /// (node, bytes) shards; bytes sum to the region size.
    pub parts: Vec<(NodeId, u64)>,
    /// How the shards are accessed by CPU threads (drives STEP timing).
    pub mode: AccessMode,
}

impl Placement {
    pub fn single(node: NodeId, bytes: u64) -> Self {
        Self {
            parts: vec![(node, bytes)],
            mode: AccessMode::Partitioned,
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.parts.iter().map(|(_, b)| *b).sum()
    }

    pub fn bytes_on(&self, node: NodeId) -> u64 {
        self.parts
            .iter()
            .filter(|(n, _)| *n == node)
            .map(|(_, b)| *b)
            .sum()
    }

    /// Fractions per node (for fabric striped transfers / STEP layout).
    pub fn fractions(&self) -> Vec<(NodeId, f64)> {
        let total = self.total_bytes() as f64;
        assert!(total > 0.0, "fractions of an empty placement");
        self.parts
            .iter()
            .map(|(n, b)| (*n, *b as f64 / total))
            .collect()
    }

    /// True if any byte lives on one of `nodes`.
    pub fn touches(&self, node: NodeId) -> bool {
        self.parts.iter().any(|(n, b)| *n == node && *b > 0)
    }

    /// Integrity check: parts must sum to the region size exactly and
    /// name each node at most once (the allocator merges shards). The
    /// non-panicking form — the allocator routes failures through
    /// `AllocError`, the plan linter reports them as P101/P105.
    pub fn check(&self, expected_bytes: u64) -> Result<(), String> {
        let total = self.total_bytes();
        if total != expected_bytes {
            return Err(format!(
                "placement bytes mismatch: parts sum to {total}, region is {expected_bytes}"
            ));
        }
        let mut seen = std::collections::HashSet::new();
        for (n, _) in &self.parts {
            if !seen.insert(n.0) {
                return Err(format!("duplicate node {} in placement", n.0));
            }
        }
        Ok(())
    }

    /// Panicking form of [`Placement::check`], for engine-internal
    /// invariants where a violation is a programming error.
    pub fn validate(&self, expected_bytes: u64) {
        if let Err(e) = self.check(expected_bytes) {
            panic!("{e}");
        }
    }
}

/// The phases of a schedule during which a region's bytes must be
/// resident — its liveness window, in [`crate::offload::Schedule`] phase
/// indices (inclusive on both ends).
///
/// Derived by [`crate::mem::profile::profile_schedule`] from the ops that
/// actually touch the region. A region with no lifetime (the static
/// default) is treated as live for the whole run; a scoped lifetime lets
/// the allocator's timeline accounting overlay it with regions whose
/// windows do not intersect (activations dead during the optimizer step
/// no longer count against the step-phase peak). Contents of a dead
/// region are assumed demotable (MemAscend-style swap space), not lost.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Lifetime {
    /// First phase (inclusive) in which the region is touched.
    pub birth_phase: u32,
    /// Last phase (inclusive) in which the region is touched.
    pub death_phase: u32,
}

impl Lifetime {
    pub fn spanning(birth_phase: u32, death_phase: u32) -> Self {
        assert!(
            birth_phase <= death_phase,
            "lifetime dies ({death_phase}) before it is born ({birth_phase})"
        );
        Self {
            birth_phase,
            death_phase,
        }
    }

    /// Grow the window to cover `phase`.
    pub fn cover(&mut self, phase: u32) {
        self.birth_phase = self.birth_phase.min(phase);
        self.death_phase = self.death_phase.max(phase);
    }

    /// Is the region live during `phase`?
    pub fn contains(&self, phase: u32) -> bool {
        self.birth_phase <= phase && phase <= self.death_phase
    }

    /// Do two windows share at least one phase?
    pub fn overlaps(&self, other: &Lifetime) -> bool {
        self.birth_phase <= other.death_phase && other.birth_phase <= self.death_phase
    }

    /// Number of phases covered.
    pub fn span(&self) -> u32 {
        self.death_phase - self.birth_phase + 1
    }
}

impl std::fmt::Display for Lifetime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.birth_phase == self.death_phase {
            write!(f, "[{}]", self.birth_phase)
        } else {
            write!(f, "[{}..{}]", self.birth_phase, self.death_phase)
        }
    }
}

/// A named allocation request.
#[derive(Clone, Debug)]
pub struct RegionRequest {
    pub name: String,
    pub class: TensorClass,
    pub bytes: u64,
    /// Owning GPU for per-GPU data (activation checkpoints, bf16 staging);
    /// lets policies give each GPU an AIC affinity when not striping.
    pub gpu: Option<GpuId>,
    /// Liveness window for the allocator's timeline accounting; `None`
    /// (the static default) means live for the whole run.
    pub lifetime: Option<Lifetime>,
}

impl RegionRequest {
    pub fn new(name: impl Into<String>, class: TensorClass, bytes: u64) -> Self {
        Self {
            name: name.into(),
            class,
            bytes,
            gpu: None,
            lifetime: None,
        }
    }

    pub fn for_gpu(mut self, gpu: GpuId) -> Self {
        self.gpu = Some(gpu);
        self
    }

    pub fn with_lifetime(mut self, lifetime: Lifetime) -> Self {
        self.lifetime = Some(lifetime);
        self
    }
}

/// Identifier of a committed region.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RegionId(pub usize);

/// A committed region.
#[derive(Clone, Debug)]
pub struct Region {
    pub id: RegionId,
    pub name: String,
    pub class: TensorClass,
    pub bytes: u64,
    pub gpu: Option<GpuId>,
    pub placement: Placement,
    /// Liveness window the region was committed under (`None` = whole run).
    pub lifetime: Option<Lifetime>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_taxonomy_matches_fig8a() {
        // fp32 P, G, O → DRAM side; bf16 P, G and activations → CXL side.
        assert!(TensorClass::MasterParams.latency_critical());
        assert!(TensorClass::Gradients32.latency_critical());
        assert!(TensorClass::OptimizerStates.latency_critical());
        assert!(TensorClass::Params16.gpu_transfer());
        assert!(TensorClass::Grads16.gpu_transfer());
        assert!(TensorClass::Activations.gpu_transfer());
    }

    #[test]
    fn classes_partition() {
        for c in TensorClass::all() {
            assert!(c.latency_critical() != c.gpu_transfer());
        }
    }

    #[test]
    fn placement_accounting() {
        let p = Placement {
            parts: vec![(NodeId(0), 600), (NodeId(1), 400)],
            mode: AccessMode::Partitioned,
        };
        p.validate(1000);
        assert_eq!(p.total_bytes(), 1000);
        assert_eq!(p.bytes_on(NodeId(1)), 400);
        assert!(p.touches(NodeId(0)));
        assert!(!p.touches(NodeId(2)));
        let f = p.fractions();
        assert!((f[0].1 - 0.6).abs() < 1e-12);
        assert!((f[1].1 - 0.4).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "bytes mismatch")]
    fn validate_rejects_wrong_total() {
        Placement::single(NodeId(0), 10).validate(11);
    }

    #[test]
    fn lifetime_window_arithmetic() {
        let mut l = Lifetime::spanning(1, 1);
        assert!(l.contains(1) && !l.contains(0) && !l.contains(2));
        l.cover(3);
        l.cover(0);
        assert_eq!(l, Lifetime::spanning(0, 3));
        assert_eq!(l.span(), 4);
        assert!(l.overlaps(&Lifetime::spanning(3, 9)));
        assert!(!Lifetime::spanning(0, 1).overlaps(&Lifetime::spanning(2, 2)));
        assert_eq!(Lifetime::spanning(2, 2).to_string(), "[2]");
        assert_eq!(Lifetime::spanning(0, 2).to_string(), "[0..2]");
    }

    #[test]
    #[should_panic(expected = "before it is born")]
    fn lifetime_rejects_inverted_window() {
        Lifetime::spanning(3, 1);
    }

    #[test]
    fn request_builder_carries_lifetime() {
        let r = RegionRequest::new("r", TensorClass::Activations, 10)
            .with_lifetime(Lifetime::spanning(0, 1));
        assert_eq!(r.lifetime, Some(Lifetime::spanning(0, 1)));
        assert_eq!(RegionRequest::new("r", TensorClass::Activations, 10).lifetime, None);
    }

    #[test]
    #[should_panic(expected = "duplicate node")]
    fn validate_rejects_duplicates() {
        let p = Placement {
            parts: vec![(NodeId(0), 5), (NodeId(0), 5)],
            mode: AccessMode::Partitioned,
        };
        p.validate(10);
    }
}
