//! Functional training path: real numerics through the PJRT runtime with
//! the Figure-1 offload workflow (streamed blocks, host checkpoint arena,
//! Rust CPU Adam).

pub mod data;
pub mod loop_;
pub mod state;

pub use data::CorpusGen;
pub use loop_::{batch_shape, StepLog, Trainer, TrainerCfg};
pub use state::{BlockParams, TrainState};
