//! Hand-rolled substrates for crates that are unavailable in the offline
//! vendor set (`rand`, `serde_json`, `clap`, `rayon`, `criterion`,
//! `proptest`). Everything downstream in the crate builds on these.

pub mod bench;
pub mod cli;
pub mod digest;
pub mod json;
pub mod logging;
pub mod memo;
pub mod prng;
pub mod proptest_lite;
pub mod stats;
pub mod table;
pub mod threadpool;
pub mod units;
