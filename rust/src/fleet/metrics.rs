//! Fleet-level metrics: per-job records, occupancy curves, summary
//! statistics, bitwise digests and the digest-self-certifying JSON form
//! (the fleet analogue of `SweepResult::to_json`).

use crate::jobj;
use crate::topology::SystemTopology;
use crate::trow;
use crate::util::digest::Fnv64;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::util::units::fmt_bytes;

/// Lifecycle state of a job. `Queued`/`Running` are transient, as are the
/// fault-recovery states `Interrupted` (rolled back to its checkpoint,
/// waiting out its re-admission backoff) and `Migrated` (running with its
/// regions evacuated to surviving nodes — it completes like any running
/// job). A finished simulation leaves only `Completed`, `Rejected` and
/// `Failed` (asserted by the fleet invariant tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Completed,
    Rejected,
    /// Killed by a fault (fail-stop, retries exhausted, or starved on the
    /// degraded host after the trace drained).
    Failed,
    /// Hit by a fault, rolled back, waiting to re-enter the queue.
    Interrupted,
    /// Running after a live evacuation of its regions.
    Migrated,
}

impl JobStatus {
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Completed => "completed",
            JobStatus::Rejected => "rejected",
            JobStatus::Failed => "failed",
            JobStatus::Interrupted => "interrupted",
            JobStatus::Migrated => "migrated",
        }
    }

    fn code(self) -> u64 {
        match self {
            JobStatus::Queued => 0,
            JobStatus::Running => 1,
            JobStatus::Completed => 2,
            JobStatus::Rejected => 3,
            JobStatus::Failed => 4,
            JobStatus::Interrupted => 5,
            JobStatus::Migrated => 6,
        }
    }
}

/// Everything the simulator knows about one job at the end of the run.
#[derive(Clone, Debug, PartialEq)]
pub struct JobRecord {
    pub id: u64,
    pub model: String,
    pub gpus: usize,
    pub batch: usize,
    pub context: usize,
    pub schedule: String,
    pub engine_requested: String,
    /// Engine the job actually ran under (policies may substitute).
    pub engine_used: Option<String>,
    pub iterations: u32,
    pub arrival_s: f64,
    pub start_s: Option<f64>,
    pub finish_s: Option<f64>,
    /// Calibrated per-iteration time the job was priced at.
    pub iter_s: Option<f64>,
    /// Tokens over the job's whole life (counted when completed).
    pub total_tokens: u64,
    pub status: JobStatus,
    /// Why the job was rejected or failed: the structured
    /// `AllocError`/`PlanError` detail from admission, or the fault that
    /// killed it. `None` for clean lifecycles.
    pub reason: Option<String>,
    /// Fault hits that interrupted the job (rollbacks + evacuations).
    pub interruptions: u32,
    /// Successful live evacuations of the job's regions.
    pub migrations: u32,
    /// Simulated seconds spent migrating the job's regions.
    pub recovery_s: f64,
    /// Tokens of work thrown away: progress rolled back to a checkpoint
    /// (recomputed later) or dead work of a killed job.
    pub lost_tokens: u64,
    /// Tokens the job actually processed, including recomputed and dead
    /// work (≥ `total_tokens` contribution for a completed job).
    pub processed_tokens: u64,
}

impl JobRecord {
    /// Job completion time (finish − arrival), the fleet's headline
    /// latency metric; `None` unless completed.
    pub fn jct_s(&self) -> Option<f64> {
        Some(self.finish_s? - self.arrival_s)
    }

    fn fold(&self, h: &mut Fnv64) {
        h.write_u64(self.id);
        h.write_str(&self.model);
        h.write_u64(self.gpus as u64);
        h.write_u64(self.batch as u64);
        h.write_u64(self.context as u64);
        h.write_str(&self.schedule);
        h.write_str(&self.engine_requested);
        h.write_str(self.engine_used.as_deref().unwrap_or(""));
        h.write_u64(self.iterations as u64);
        h.write_f64(self.arrival_s);
        for opt in [self.start_s, self.finish_s, self.iter_s] {
            match opt {
                Some(v) => {
                    h.write_u64(1);
                    h.write_f64(v);
                }
                None => {
                    h.write_u64(0);
                }
            }
        }
        h.write_u64(self.total_tokens);
        h.write_u64(self.status.code());
        match &self.reason {
            Some(r) => {
                h.write_u64(1);
                h.write_str(r);
            }
            None => {
                h.write_u64(0);
            }
        }
        h.write_u64(self.interruptions as u64);
        h.write_u64(self.migrations as u64);
        h.write_f64(self.recovery_s);
        h.write_u64(self.lost_tokens);
        h.write_u64(self.processed_tokens);
    }

    fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        jobj! {
            "id" => self.id,
            "model" => self.model.as_str(),
            "gpus" => self.gpus,
            "batch" => self.batch,
            "context" => self.context,
            "schedule" => self.schedule.as_str(),
            "engine_requested" => self.engine_requested.as_str(),
            "engine_used" => self.engine_used.as_deref().map(Json::from).unwrap_or(Json::Null),
            "iterations" => self.iterations as u64,
            "arrival_s" => self.arrival_s,
            "start_s" => opt(self.start_s),
            "finish_s" => opt(self.finish_s),
            "iter_s" => opt(self.iter_s),
            "total_tokens" => self.total_tokens,
            "status" => self.status.name(),
            "reason" => self.reason.as_deref().map(Json::from).unwrap_or(Json::Null),
            "interruptions" => self.interruptions as u64,
            "migrations" => self.migrations as u64,
            "recovery_s" => self.recovery_s,
            "lost_tokens" => self.lost_tokens,
            "processed_tokens" => self.processed_tokens,
        }
    }
}

/// One point of the occupancy curve, sampled after every processed event.
#[derive(Clone, Debug, PartialEq)]
pub struct OccupancySample {
    pub t_s: f64,
    /// Used bytes per node, indexed by `NodeId.0`.
    pub used: Vec<u64>,
    pub queue_len: usize,
    pub running: usize,
}

/// The complete outcome of one fleet simulation.
#[derive(Clone, Debug)]
pub struct FleetResult {
    pub policy: String,
    pub topology: String,
    pub node_names: Vec<String>,
    pub node_caps: Vec<u64>,
    pub records: Vec<JobRecord>,
    pub samples: Vec<OccupancySample>,
    /// Discrete events processed (arrivals + completions + faults +
    /// re-queues).
    pub n_events: u64,
    /// Recovery policy the run used. JSON-only — deliberately excluded
    /// from the digest so a zero-fault run is bit-identical under every
    /// recovery policy (the zero-fault path is a bitwise no-op).
    pub recovery: String,
    /// Fault events applied during the run (folded into the digest).
    pub n_faults: u64,
}

impl FleetResult {
    pub fn new(policy: &str, topo: &SystemTopology) -> Self {
        Self {
            policy: policy.to_string(),
            topology: topo.name.clone(),
            node_names: topo.mem_nodes.iter().map(|n| n.name.clone()).collect(),
            node_caps: topo.mem_nodes.iter().map(|n| n.capacity).collect(),
            records: Vec::new(),
            samples: Vec::new(),
            n_events: 0,
            recovery: String::new(),
            n_faults: 0,
        }
    }

    pub fn arrived(&self) -> usize {
        self.records.len()
    }

    fn count(&self, s: JobStatus) -> usize {
        self.records.iter().filter(|r| r.status == s).count()
    }

    pub fn completed(&self) -> usize {
        self.count(JobStatus::Completed)
    }

    pub fn rejected(&self) -> usize {
        self.count(JobStatus::Rejected)
    }

    /// Jobs killed by a fault (or starved after the trace drained).
    pub fn failed(&self) -> usize {
        self.count(JobStatus::Failed)
    }

    /// Jobs still in a transient state when the event heap drained (0 for
    /// a finished simulation — pinned by the invariant tests).
    pub fn unfinished(&self) -> usize {
        self.count(JobStatus::Queued)
            + self.count(JobStatus::Running)
            + self.count(JobStatus::Interrupted)
            + self.count(JobStatus::Migrated)
    }

    /// Admitted = every job that got to run (completed + still running,
    /// migrated jobs included).
    pub fn admitted(&self) -> usize {
        self.completed() + self.count(JobStatus::Running) + self.count(JobStatus::Migrated)
    }

    /// Total fault interruptions across all jobs.
    pub fn interruptions(&self) -> u64 {
        self.records.iter().map(|r| r.interruptions as u64).sum()
    }

    /// Total successful evacuations across all jobs.
    pub fn migrations(&self) -> u64 {
        self.records.iter().map(|r| r.migrations as u64).sum()
    }

    /// Total simulated seconds spent migrating regions.
    pub fn recovery_s(&self) -> f64 {
        self.records.iter().map(|r| r.recovery_s).sum()
    }

    /// Simulated-clock end of the fleet: the last completion time.
    pub fn makespan_s(&self) -> f64 {
        self.records
            .iter()
            .filter_map(|r| r.finish_s)
            .fold(0.0, f64::max)
    }

    /// Completion times (finish − arrival) of all completed jobs. Failed
    /// jobs carry a `finish_s` (their kill time) but are not completions.
    pub fn jcts_s(&self) -> Vec<f64> {
        self.records
            .iter()
            .filter(|r| r.status == JobStatus::Completed)
            .filter_map(JobRecord::jct_s)
            .collect()
    }

    pub fn mean_jct_s(&self) -> Option<f64> {
        let xs = self.jcts_s();
        (!xs.is_empty()).then(|| xs.iter().sum::<f64>() / xs.len() as f64)
    }

    pub fn p99_jct_s(&self) -> Option<f64> {
        let mut xs = self.jcts_s();
        if xs.is_empty() {
            return None;
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((xs.len() - 1) as f64 * 0.99).round() as usize;
        Some(xs[idx])
    }

    /// Tokens completed by the whole fleet per simulated second.
    pub fn aggregate_tokens_per_sec(&self) -> f64 {
        let tokens: u64 = self
            .records
            .iter()
            .filter(|r| r.status == JobStatus::Completed)
            .map(|r| r.total_tokens)
            .sum();
        let span = self.makespan_s();
        if span > 0.0 {
            tokens as f64 / span
        } else {
            0.0
        }
    }

    /// Tokens of *useful* work: completed jobs' nominal tokens, every
    /// iteration counted exactly once no matter how often it was recomputed.
    pub fn useful_tokens(&self) -> u64 {
        self.records
            .iter()
            .filter(|r| r.status == JobStatus::Completed)
            .map(|r| r.total_tokens)
            .sum()
    }

    /// Tokens actually processed fleet-wide, recomputed and dead work
    /// included.
    pub fn processed_tokens(&self) -> u64 {
        self.records.iter().map(|r| r.processed_tokens).sum()
    }

    /// Tokens thrown away to rollbacks and kills.
    pub fn lost_tokens(&self) -> u64 {
        self.records.iter().map(|r| r.lost_tokens).sum()
    }

    /// Goodput: useful tokens per simulated second. Recomputed work and
    /// dead work of failed jobs contribute nothing — under faults this is
    /// the honest fleet throughput, and without faults it coincides with
    /// [`Self::aggregate_tokens_per_sec`].
    pub fn goodput_tokens_per_sec(&self) -> f64 {
        let span = self.makespan_s();
        if span > 0.0 {
            self.useful_tokens() as f64 / span
        } else {
            0.0
        }
    }

    /// Fraction of processed tokens that was wasted (recomputed or dead).
    pub fn waste_frac(&self) -> f64 {
        let processed = self.processed_tokens();
        if processed == 0 {
            return 0.0;
        }
        1.0 - self.useful_tokens().min(processed) as f64 / processed as f64
    }

    pub fn max_queue_len(&self) -> usize {
        self.samples.iter().map(|s| s.queue_len).max().unwrap_or(0)
    }

    /// Peak committed bytes on a node across the whole run.
    pub fn peak_used(&self, node: usize) -> u64 {
        self.samples.iter().map(|s| s.used[node]).max().unwrap_or(0)
    }

    /// Time-weighted mean occupancy of a node (each sample holds until the
    /// next event).
    pub fn mean_used(&self, node: usize) -> f64 {
        if self.samples.len() < 2 {
            return self.samples.first().map(|s| s.used[node] as f64).unwrap_or(0.0);
        }
        let mut acc = 0.0;
        let mut span = 0.0;
        for w in self.samples.windows(2) {
            let dt = w[1].t_s - w[0].t_s;
            acc += w[0].used[node] as f64 * dt;
            span += dt;
        }
        if span > 0.0 {
            acc / span
        } else {
            self.samples[0].used[node] as f64
        }
    }

    /// Bit-exact FNV-1a digest of the whole result — per-job records,
    /// occupancy curve and event count. The determinism contract: reruns
    /// and different `--threads` settings must reproduce it exactly.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_str(&self.policy);
        h.write_str(&self.topology);
        h.write_u64(self.node_caps.len() as u64);
        for c in &self.node_caps {
            h.write_u64(*c);
        }
        h.write_u64(self.records.len() as u64);
        for r in &self.records {
            r.fold(&mut h);
        }
        h.write_u64(self.samples.len() as u64);
        for s in &self.samples {
            h.write_f64(s.t_s);
            for u in &s.used {
                h.write_u64(*u);
            }
            h.write_u64(s.queue_len as u64);
            h.write_u64(s.running as u64);
        }
        h.write_u64(self.n_events);
        h.write_u64(self.n_faults);
        h.finish()
    }

    /// Machine-readable form (written by `cxlfine fleet --json`): summary,
    /// per-node occupancy statistics, the full per-job record set and the
    /// occupancy curve, digest-self-certifying like `SweepResult::to_json`.
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        let nodes: Vec<Json> = self
            .node_names
            .iter()
            .enumerate()
            .map(|(i, name)| {
                jobj! {
                    "name" => name.as_str(),
                    "capacity" => self.node_caps[i],
                    "peak_used" => self.peak_used(i),
                    "mean_used" => self.mean_used(i),
                }
            })
            .collect();
        let jobs: Vec<Json> = self.records.iter().map(JobRecord::to_json).collect();
        let occupancy: Vec<Json> = self
            .samples
            .iter()
            .map(|s| {
                let used: Vec<Json> = s.used.iter().map(|&u| Json::from(u)).collect();
                jobj! {
                    "t_s" => s.t_s,
                    "used" => Json::Arr(used),
                    "queue_len" => s.queue_len,
                    "running" => s.running,
                }
            })
            .collect();
        jobj! {
            "policy" => self.policy.as_str(),
            "topology" => self.topology.as_str(),
            "recovery" => self.recovery.as_str(),
            "digest" => format!("{:016x}", self.digest()),
            "summary" => jobj! {
                "arrived" => self.arrived(),
                "completed" => self.completed(),
                "rejected" => self.rejected(),
                "failed" => self.failed(),
                "unfinished" => self.unfinished(),
                "makespan_s" => self.makespan_s(),
                "mean_jct_s" => opt(self.mean_jct_s()),
                "p99_jct_s" => opt(self.p99_jct_s()),
                "aggregate_tokens_per_sec" => self.aggregate_tokens_per_sec(),
                "goodput_tokens_per_sec" => self.goodput_tokens_per_sec(),
                "useful_tokens" => self.useful_tokens(),
                "processed_tokens" => self.processed_tokens(),
                "lost_tokens" => self.lost_tokens(),
                "waste_frac" => self.waste_frac(),
                "interruptions" => self.interruptions(),
                "migrations" => self.migrations(),
                "recovery_s" => self.recovery_s(),
                "max_queue_len" => self.max_queue_len(),
                "n_events" => self.n_events,
                "n_faults" => self.n_faults,
            },
            "nodes" => Json::Arr(nodes),
            "jobs" => Json::Arr(jobs),
            "occupancy" => Json::Arr(occupancy),
        }
    }

    /// The fleet summary (rendered by `cxlfine fleet`).
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new(&["metric", "value"]).left(0);
        t.row(trow!["jobs arrived", self.arrived()]);
        t.row(trow!["jobs completed", self.completed()]);
        t.row(trow!["jobs rejected", self.rejected()]);
        t.row(trow!["jobs failed", self.failed()]);
        t.row(trow!["max queue length", self.max_queue_len()]);
        t.row(trow!["makespan", format!("{:.1}s", self.makespan_s())]);
        t.row(trow![
            "mean JCT",
            self.mean_jct_s()
                .map(|v| format!("{v:.1}s"))
                .unwrap_or_else(|| "-".into())
        ]);
        t.row(trow![
            "p99 JCT",
            self.p99_jct_s()
                .map(|v| format!("{v:.1}s"))
                .unwrap_or_else(|| "-".into())
        ]);
        t.row(trow![
            "aggregate throughput",
            format!("{:.0} tok/s", self.aggregate_tokens_per_sec())
        ]);
        t.row(trow![
            "goodput",
            format!("{:.0} tok/s", self.goodput_tokens_per_sec())
        ]);
        if self.n_faults > 0 {
            t.row(trow!["faults applied", self.n_faults]);
            t.row(trow!["interruptions", self.interruptions()]);
            t.row(trow!["migrations", self.migrations()]);
            t.row(trow![
                "migration time",
                format!("{:.1}s", self.recovery_s())
            ]);
            t.row(trow!["lost work", format!("{} tok", self.lost_tokens())]);
            t.row(trow![
                "waste",
                format!("{:.1}%", 100.0 * self.waste_frac())
            ]);
        }
        t.row(trow!["events processed", self.n_events]);
        t
    }

    /// Per-job rejection / failure reasons (rendered by `cxlfine fleet`
    /// when any job carries one).
    pub fn reasons_table(&self) -> Option<Table> {
        let mut t = Table::new(&["job", "status", "reason"]).left(2);
        let mut any = false;
        for r in &self.records {
            if let Some(reason) = &r.reason {
                t.row(trow![r.id, r.status.name(), reason.clone()]);
                any = true;
            }
        }
        any.then_some(t)
    }

    /// Per-node occupancy statistics (rendered by `cxlfine fleet`).
    pub fn occupancy_table(&self) -> Table {
        let mut t = Table::new(&["node", "capacity", "peak used", "peak %", "mean used"]).left(0);
        for (i, name) in self.node_names.iter().enumerate() {
            let peak = self.peak_used(i);
            let cap = self.node_caps[i];
            t.row(trow![
                name.clone(),
                fmt_bytes(cap),
                fmt_bytes(peak),
                format!("{:.1}%", 100.0 * peak as f64 / cap.max(1) as f64),
                fmt_bytes(self.mean_used(i) as u64)
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::dev_tiny;

    fn record(id: u64, arrival: f64, finish: Option<f64>, tokens: u64) -> JobRecord {
        JobRecord {
            id,
            model: "tiny-2m".into(),
            gpus: 1,
            batch: 2,
            context: 256,
            schedule: "zero-offload".into(),
            engine_requested: "cxl-aware".into(),
            engine_used: finish.map(|_| "cxl-aware".to_string()),
            iterations: 2,
            arrival_s: arrival,
            start_s: finish.map(|f| f - 1.0),
            finish_s: finish,
            iter_s: finish.map(|_| 0.5),
            total_tokens: tokens,
            status: if finish.is_some() {
                JobStatus::Completed
            } else {
                JobStatus::Rejected
            },
            reason: finish.is_none().then(|| "cannot place params16".to_string()),
            interruptions: 0,
            migrations: 0,
            recovery_s: 0.0,
            lost_tokens: 0,
            processed_tokens: if finish.is_some() { tokens } else { 0 },
        }
    }

    fn result() -> FleetResult {
        let topo = dev_tiny();
        let mut r = FleetResult::new("fifo", &topo);
        r.records = vec![
            record(0, 0.0, Some(10.0), 1000),
            record(1, 2.0, Some(4.0), 500),
            record(2, 3.0, None, 700),
        ];
        r.samples = vec![
            OccupancySample { t_s: 0.0, used: vec![100, 0, 0], queue_len: 0, running: 1 },
            OccupancySample { t_s: 2.0, used: vec![300, 50, 0], queue_len: 1, running: 2 },
            OccupancySample { t_s: 10.0, used: vec![0, 0, 0], queue_len: 0, running: 0 },
        ];
        r.n_events = 5;
        r
    }

    #[test]
    fn summary_statistics() {
        let r = result();
        assert_eq!(r.arrived(), 3);
        assert_eq!(r.completed(), 2);
        assert_eq!(r.rejected(), 1);
        assert_eq!(r.unfinished(), 0);
        assert_eq!(r.makespan_s(), 10.0);
        // JCTs: 10−0 = 10, 4−2 = 2 → mean 6, p99 = max
        assert!((r.mean_jct_s().unwrap() - 6.0).abs() < 1e-12);
        assert!((r.p99_jct_s().unwrap() - 10.0).abs() < 1e-12);
        // only completed tokens count: (1000 + 500) / 10
        assert!((r.aggregate_tokens_per_sec() - 150.0).abs() < 1e-12);
        // no faults → goodput coincides with aggregate throughput
        assert_eq!(r.failed(), 0);
        assert!((r.goodput_tokens_per_sec() - 150.0).abs() < 1e-12);
        assert_eq!(r.useful_tokens(), 1500);
        assert_eq!(r.processed_tokens(), 1500);
        assert_eq!(r.waste_frac(), 0.0);
        assert_eq!(r.max_queue_len(), 1);
        assert_eq!(r.peak_used(0), 300);
        // time-weighted: 100·2 + 300·8 over 10s = 260
        assert!((r.mean_used(0) - 260.0).abs() < 1e-12);
    }

    #[test]
    fn digest_is_stable_and_sensitive() {
        let a = result();
        let b = result();
        assert_eq!(a.digest(), b.digest());
        let mut c = result();
        c.records[1].finish_s = Some(4.000001);
        assert_ne!(a.digest(), c.digest(), "a float wiggle must change it");
        let mut d = result();
        d.samples[1].queue_len = 2;
        assert_ne!(a.digest(), d.digest());
        // Recovery accounting is digest-material…
        let mut e = result();
        e.records[0].lost_tokens = 1;
        assert_ne!(a.digest(), e.digest());
        let mut f = result();
        f.records[0].reason = Some("x".into());
        assert_ne!(a.digest(), f.digest());
        let mut g = result();
        g.n_faults = 1;
        assert_ne!(a.digest(), g.digest());
        // …but the recovery-policy *name* is not: a zero-fault run must be
        // bit-identical under every recovery policy.
        let mut h = result();
        h.recovery = "evacuate".into();
        assert_eq!(a.digest(), h.digest());
    }

    #[test]
    fn fault_accounting_flows_into_summary_and_goodput() {
        let mut r = result();
        r.recovery = "checkpoint-restart".into();
        r.n_faults = 2;
        // Job 1 was interrupted once and recomputed 250 tokens.
        r.records[1].interruptions = 1;
        r.records[1].lost_tokens = 250;
        r.records[1].processed_tokens = 750;
        // Job 2 becomes a fault kill instead of a rejection.
        r.records[2].status = JobStatus::Failed;
        r.records[2].finish_s = Some(6.0);
        r.records[2].reason = Some("node cxl0 went offline".into());
        r.records[2].lost_tokens = 300;
        r.records[2].processed_tokens = 300;

        assert_eq!(r.failed(), 1);
        assert_eq!(r.rejected(), 0);
        assert_eq!(r.unfinished(), 0);
        assert_eq!(r.interruptions(), 1);
        assert_eq!(r.lost_tokens(), 550);
        assert_eq!(r.useful_tokens(), 1500);
        assert_eq!(r.processed_tokens(), 2050);
        assert!((r.waste_frac() - (1.0 - 1500.0 / 2050.0)).abs() < 1e-12);
        // The failed job's finish time is not a JCT.
        assert_eq!(r.jcts_s().len(), 2);
        // Reasons surface in the table and JSON.
        let reasons = r.reasons_table().expect("two reasons present").render();
        assert!(reasons.contains("went offline"), "{reasons}");
        let s = r.summary_table().render();
        assert!(s.contains("jobs failed") && s.contains("waste"), "{s}");
        let j = r.to_json().to_string_pretty();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.path(&["summary", "failed"]).unwrap().as_u64(), Some(1));
        assert_eq!(parsed.path(&["recovery"]).unwrap().as_str(), Some("checkpoint-restart"));
        let jobs = parsed.path(&["jobs"]).unwrap().as_arr().unwrap();
        assert_eq!(jobs[2].path(&["status"]).unwrap().as_str(), Some("failed"));
        assert_eq!(
            jobs[2].path(&["reason"]).unwrap().as_str(),
            Some("node cxl0 went offline")
        );
        // A clean result has no reasons table.
        let mut clean = result();
        for rec in &mut clean.records {
            rec.reason = None;
        }
        assert!(clean.reasons_table().is_none());
    }

    #[test]
    fn json_is_parseable_and_self_certifying() {
        let r = result();
        let text = r.to_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(
            parsed.path(&["digest"]).unwrap().as_str(),
            Some(format!("{:016x}", r.digest()).as_str())
        );
        assert_eq!(
            parsed.path(&["summary", "completed"]).unwrap().as_u64(),
            Some(2)
        );
        let jobs = parsed.path(&["jobs"]).unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[2].path(&["status"]).unwrap().as_str(), Some("rejected"));
        assert!(matches!(jobs[2].path(&["finish_s"]), Some(Json::Null)));
        let occ = parsed.path(&["occupancy"]).unwrap().as_arr().unwrap();
        assert_eq!(occ.len(), 3);
        assert_eq!(occ[1].path(&["queue_len"]).unwrap().as_u64(), Some(1));
    }

    #[test]
    fn tables_render_every_node_and_metric() {
        let r = result();
        let s = r.summary_table().render();
        assert!(s.contains("aggregate throughput"), "{s}");
        let o = r.occupancy_table().render();
        assert!(o.contains("dram") && o.contains("cxl1"), "{o}");
    }
}
