//! Memory plan: allocate every Table-I region for a fine-tuning run under a
//! chosen placement policy. The plan is what the iteration simulator and
//! the functional trainer both consume — placement decisions are made once,
//! here, exactly like the real system pins its arenas at startup.

use super::schedules::{self, ScheduleRef};
use crate::mem::{EngineRef, NumaAllocator, RegionId, RegionRequest, TensorClass};
use crate::model::footprint::{Footprint, Workload};
use crate::model::ModelConfig;
use crate::sim::memmodel::{AccessMode, OptLayout};
use crate::topology::{GpuId, NodeId, SystemTopology};

/// Everything needed to run (or simulate) one fine-tuning configuration.
/// Placement goes through a pluggable [`crate::mem::PlacementEngine`];
/// `RunConfig::new` accepts anything convertible (a legacy
/// [`crate::mem::Policy`], [`crate::mem::AdaptiveSpill`], or an existing
/// [`EngineRef`]). The iteration *schedule* is pluggable the same way: a
/// [`ScheduleRef`] resolved from the `offload::schedules` registry
/// (default: the paper's `zero-offload` workflow).
#[derive(Clone)]
pub struct RunConfig {
    pub model: ModelConfig,
    pub workload: Workload,
    pub engine: EngineRef,
    /// Blocks of parameters prefetched ahead of compute (ZeRO-Offload
    /// overlaps the next block's H2D copy with the current block's kernel).
    pub prefetch_depth: usize,
    /// The fine-tuning scenario simulated for this run.
    pub schedule: ScheduleRef,
}

impl RunConfig {
    pub fn new(model: ModelConfig, workload: Workload, engine: impl Into<EngineRef>) -> Self {
        Self {
            model,
            workload,
            engine: engine.into(),
            prefetch_depth: 2,
            schedule: schedules::zero_offload(),
        }
    }

    /// Builder-style schedule override.
    pub fn with_schedule(mut self, schedule: ScheduleRef) -> Self {
        self.schedule = schedule;
        self
    }
}

impl std::fmt::Debug for RunConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RunConfig")
            .field("model", &self.model.name)
            .field("workload", &self.workload)
            .field("engine", &self.engine.name())
            .field("prefetch_depth", &self.prefetch_depth)
            .field("schedule", &self.schedule.name())
            .finish()
    }
}

/// The committed regions of one run.
pub struct MemoryPlan<'t> {
    pub alloc: NumaAllocator<'t>,
    pub footprint: Footprint,
    pub master: RegionId,
    pub grads32: RegionId,
    pub optstates: RegionId,
    pub params16: RegionId,
    pub grads16: RegionId,
    /// One checkpointed-activation region per GPU.
    pub activations: Vec<RegionId>,
}

/// Why a plan could not be built.
#[derive(Debug, Clone)]
pub struct PlanError {
    pub message: String,
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}
impl std::error::Error for PlanError {}

impl<'t> MemoryPlan<'t> {
    /// Allocate all regions. Latency-critical regions are requested first
    /// so the CXL-aware policy reserves DRAM for them before bulk data
    /// arrives (the real allocator pins arenas in the same order).
    pub fn build(
        topo: &'t SystemTopology,
        cfg: &RunConfig,
    ) -> Result<MemoryPlan<'t>, PlanError> {
        let f = Footprint::compute(&cfg.model, &cfg.workload);
        let mut alloc = NumaAllocator::new(topo, cfg.engine.clone());
        let mut get = |req: RegionRequest| {
            alloc.alloc(req).map_err(|e| PlanError {
                message: format!("{} (policy {})", e, cfg.engine.name()),
            })
        };
        let master = get(RegionRequest::new(
            "master-params",
            TensorClass::MasterParams,
            f.params_fp32,
        ))?;
        let grads32 = get(RegionRequest::new(
            "grads-fp32",
            TensorClass::Gradients32,
            f.grads_fp32,
        ))?;
        let optstates = get(RegionRequest::new(
            "optimizer-states",
            TensorClass::OptimizerStates,
            f.optimizer_fp32,
        ))?;
        let params16 = get(RegionRequest::new(
            "params-bf16",
            TensorClass::Params16,
            f.params_bf16,
        ))?;
        let grads16 = get(RegionRequest::new(
            "grads-bf16",
            TensorClass::Grads16,
            f.grads_bf16,
        ))?;
        let mut activations = Vec::with_capacity(cfg.workload.n_gpus);
        for g in 0..cfg.workload.n_gpus {
            activations.push(get(RegionRequest::new(
                format!("activations-gpu{g}"),
                TensorClass::Activations,
                f.activations_per_gpu(&cfg.workload),
            )
            .for_gpu(GpuId(g)))?);
        }
        Ok(MemoryPlan {
            alloc,
            footprint: f,
            master,
            grads32,
            optstates,
            params16,
            grads16,
            activations,
        })
    }

    /// Does this configuration fit at all (used by capacity sweeps)?
    pub fn fits(topo: &SystemTopology, cfg: &RunConfig) -> bool {
        MemoryPlan::build(topo, cfg).is_ok()
    }

    /// Merged placement of the optimizer's working set (fp32 P, G, O) as an
    /// [`OptLayout`] for the STEP timing model.
    pub fn opt_layout(&self) -> OptLayout {
        let regions = [self.master, self.grads32, self.optstates];
        let mut per_node: std::collections::BTreeMap<usize, u64> = Default::default();
        let mut mode = AccessMode::Partitioned;
        for id in regions {
            let r = self.alloc.region(id).expect("plan region");
            if r.placement.mode == AccessMode::Interleaved {
                mode = AccessMode::Interleaved;
            }
            for (n, b) in &r.placement.parts {
                *per_node.entry(n.0).or_insert(0) += *b;
            }
        }
        let total: u64 = per_node.values().sum();
        OptLayout {
            parts: per_node
                .into_iter()
                .map(|(n, b)| (NodeId(n), b as f64 / total as f64))
                .collect(),
            mode,
        }
    }

    /// Generic stream layout of a single region (for cast/copy timing).
    pub fn region_layout(&self, id: RegionId) -> OptLayout {
        let r = self.alloc.region(id).expect("plan region");
        OptLayout {
            parts: r.placement.fractions(),
            mode: r.placement.mode,
        }
    }

    /// Host-side node fractions a GPU's parameter stream reads from.
    pub fn params16_fractions(&self) -> Vec<(NodeId, f64)> {
        self.alloc
            .region(self.params16)
            .unwrap()
            .placement
            .fractions()
    }

    /// Host-side node fractions a GPU's gradient offload writes to.
    pub fn grads16_fractions(&self) -> Vec<(NodeId, f64)> {
        self.alloc
            .region(self.grads16)
            .unwrap()
            .placement
            .fractions()
    }

    /// Host-side node fractions of one GPU's activation checkpoints.
    pub fn activation_fractions(&self, gpu: GpuId) -> Vec<(NodeId, f64)> {
        self.alloc
            .region(self.activations[gpu.0])
            .unwrap()
            .placement
            .fractions()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Policy;
    use crate::model::presets::{mistral_nemo_12b, qwen25_7b, tiny_2m};
    use crate::topology::presets::{config_a, config_b, dev_tiny, with_dram_capacity};
    use crate::util::units::GIB;

    #[test]
    fn baseline_plan_all_in_dram() {
        let topo = config_a();
        let cfg = RunConfig::new(qwen25_7b(), Workload::new(1, 8, 4096), Policy::DramOnly);
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        assert_eq!(plan.alloc.used_on(NodeId(1)), 0);
        let layout = plan.opt_layout();
        assert_eq!(layout.parts, vec![(NodeId(0), 1.0)]);
    }

    #[test]
    fn paper_constrained_dram_forces_cxl_use() {
        // §V-B: 128 GiB DRAM + 512 GiB AIC. 7.6B model: fp32 PGO = 121.7 GiB
        // fits DRAM; bf16 P/G + activations land on CXL.
        let topo = with_dram_capacity(config_a(), 128 * GIB);
        let cfg = RunConfig::new(
            qwen25_7b(),
            Workload::new(1, 8, 4096),
            Policy::CxlAware { striping: false },
        );
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let layout = plan.opt_layout();
        assert_eq!(layout.parts, vec![(NodeId(0), 1.0)], "PGO stays in DRAM");
        for (_, frac) in plan.params16_fractions() {
            assert!(frac > 0.0);
        }
        let p16 = plan.params16_fractions();
        assert!(p16.iter().all(|(n, _)| n.0 != 0), "bf16 params on CXL");
    }

    #[test]
    fn naive_plan_puts_optimizer_data_on_cxl() {
        let topo = with_dram_capacity(config_a(), 128 * GIB);
        let cfg = RunConfig::new(
            qwen25_7b(),
            Workload::new(1, 8, 4096),
            Policy::NaiveInterleave,
        );
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let layout = plan.opt_layout();
        assert_eq!(layout.mode, AccessMode::Interleaved);
        assert!(
            layout.parts.iter().any(|(n, f)| n.0 == 1 && *f > 0.3),
            "naive interleave must put a large PGO share on CXL: {layout:?}"
        );
    }

    #[test]
    fn dram_only_larger_than_capacity_fails() {
        // 12B @ 32K context × 2 GPUs × batch 16 overflows 512 GB DRAM → the
        // motivation for CXL (Fig. 2/3).
        let topo = config_a();
        let cfg = RunConfig::new(
            mistral_nemo_12b(),
            Workload::new(2, 16, 32768),
            Policy::DramOnly,
        );
        assert!(!MemoryPlan::fits(&topo, &cfg));
        // ...but the CXL-aware plan fits using the AIC.
        let cfg2 = RunConfig {
            engine: Policy::CxlAware { striping: false }.into(),
            ..cfg
        };
        assert!(MemoryPlan::fits(&topo, &cfg2));
    }

    #[test]
    fn striping_spreads_activations_over_both_aics() {
        let topo = config_b();
        let cfg = RunConfig::new(
            mistral_nemo_12b(),
            Workload::new(2, 16, 4096),
            Policy::CxlAware { striping: true },
        );
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        for g in 0..2 {
            let fr = plan.activation_fractions(GpuId(g));
            assert_eq!(fr.len(), 2, "gpu{g} activations should stripe: {fr:?}");
            for (_, f) in fr {
                assert!((f - 0.5).abs() < 0.01);
            }
        }
    }

    #[test]
    fn affinity_mode_separates_gpus() {
        let topo = config_b();
        let cfg = RunConfig::new(
            qwen25_7b(),
            Workload::new(2, 8, 4096),
            Policy::CxlAware { striping: false },
        );
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let f0 = plan.activation_fractions(GpuId(0));
        let f1 = plan.activation_fractions(GpuId(1));
        assert_ne!(f0[0].0, f1[0].0, "per-GPU AIC affinity expected");
    }

    #[test]
    fn spilled_optimizer_layout_is_partitioned() {
        // dev_tiny has 8 GiB DRAM; a 2M model with huge batch won't spill,
        // so shrink DRAM instead: 12B fp32 PGO = 195 GiB > 128 GiB DRAM.
        let topo = with_dram_capacity(config_b(), 128 * GIB);
        let cfg = RunConfig::new(
            mistral_nemo_12b(),
            Workload::new(1, 1, 512),
            Policy::CxlAware { striping: true },
        );
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let layout = plan.opt_layout();
        assert_eq!(layout.mode, AccessMode::Partitioned);
        assert!(layout.parts.len() >= 2, "spill expected: {layout:?}");
        let dram_frac = layout
            .parts
            .iter()
            .find(|(n, _)| n.0 == 0)
            .map(|(_, f)| *f)
            .unwrap_or(0.0);
        assert!(dram_frac > 0.5, "most PGO still in DRAM: {dram_frac}");
    }

    #[test]
    fn tiny_plan_on_dev_machine() {
        let topo = dev_tiny();
        for policy in [
            Policy::DramOnly,
            Policy::NaiveInterleave,
            Policy::CxlAware { striping: false },
            Policy::CxlAware { striping: true },
        ] {
            let cfg = RunConfig::new(tiny_2m(), Workload::new(2, 4, 512), policy);
            let plan = MemoryPlan::build(&topo, &cfg).unwrap();
            assert_eq!(plan.activations.len(), 2);
            let total_expected = plan.footprint.total();
            assert_eq!(plan.alloc.total_used(), total_expected);
        }
    }
}
