//! Ablation: Fig. 8c — striping *spilled optimizer state* across
//! DRAM + multiple AICs vs naive alternatives.
//!
//! When fp32 P/G/O exceed local DRAM, the spill's placement decides STEP
//! time: sequential fill (everything-extra on one AIC), naive interleave,
//! or bandwidth-proportional partitioning (ours). The proportional split
//! should track max(shard_time) ≈ the DRAM-only time.

use cxlfine::sim::memmodel::{AccessMode, OptLayout, OptimizerMemModel};
use cxlfine::topology::presets::config_b;
use cxlfine::topology::NodeId;
use cxlfine::trow;
use cxlfine::util::bench::{points_json, BenchReport};
use cxlfine::util::table::Table;

fn main() {
    let mut report = BenchReport::new("ablation_spill_striping");
    let topo = config_b();
    let mm = OptimizerMemModel::new(&topo);
    let nodes = [NodeId(0), NodeId(1), NodeId(2)];
    let elements: u64 = 12_000_000_000 / 16; // a 12B model's PGO working set

    // spill fraction sweep: how much of PGO falls off DRAM
    let mut t = Table::new(&[
        "dram_fraction",
        "seq-fill (s)",
        "interleave (s)",
        "proportional (s)",
        "prop vs dram-only",
    ]);
    let dram_only = mm.step_time(elements, &OptLayout::dram_only());
    let (mut xs, mut seqv, mut intv, mut propv) = (vec![], vec![], vec![], vec![]);
    for dram_frac in [0.9f64, 0.8, 0.7, 0.6, 0.5] {
        let spill = 1.0 - dram_frac;
        // sequential: all spill on AIC 0
        let seq = OptLayout {
            parts: vec![
                (nodes[0], dram_frac),
                (nodes[1], spill),
            ],
            mode: AccessMode::Partitioned,
        };
        // interleave across all three (page round-robin over the spill +
        // dram mix — the numactl default behaviour)
        let inter = OptLayout::interleave(&nodes);
        // bandwidth-proportional split of the WHOLE set (ours, Fig. 8c)
        let prop = OptLayout::striped_proportional(&topo, &nodes);
        let ts = mm.step_time(elements, &seq);
        let ti = mm.step_time(elements, &inter);
        let tp = mm.step_time(elements, &prop);
        t.row(trow![
            format!("{dram_frac:.1}"),
            format!("{ts:.3}"),
            format!("{ti:.3}"),
            format!("{tp:.3}"),
            format!("{:.2}x", tp / dram_only)
        ]);
        xs.push(dram_frac);
        seqv.push(ts);
        intv.push(ti);
        propv.push(tp);
    }
    // ours never loses to either alternative and stays at the DRAM roofline
    for i in 0..xs.len() {
        assert!(propv[i] <= seqv[i] + 1e-9, "prop must beat seq-fill");
        assert!(propv[i] <= intv[i] + 1e-9, "prop must beat interleave");
    }
    let worst = propv.iter().cloned().fold(0.0, f64::max);
    assert!(
        worst <= dram_only * 1.01,
        "proportional striping should hold the DRAM-only time: {worst} vs {dram_only}"
    );
    println!(
        "proportional spill striping holds STEP at {:.3}s (dram-only {:.3}s)",
        worst, dram_only
    );
    report.section(
        "step_time_vs_spill",
        t,
        points_json(
            &xs,
            &[("seq_fill_s", &seqv), ("interleave_s", &intv), ("proportional_s", &propv)],
        ),
    );
    report.finish();
}
