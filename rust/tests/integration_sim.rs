//! Cross-module integration tests: topology → plan → simulation → metrics,
//! plus failure injection (OOM paths, malformed manifests, workload/topology
//! mismatches) and whole-pipeline invariants.

use cxlfine::mem::{Policy, RegionRequest, TensorClass};
use cxlfine::model::footprint::{Footprint, Workload};
use cxlfine::model::presets::{mistral_nemo_12b, qwen25_7b, tiny_2m};
use cxlfine::offload::{simulate_iteration, simulate_iteration_traced, MemoryPlan, RunConfig};
use cxlfine::runtime::Manifest;
use cxlfine::topology::presets::{config_a, config_b, dev_tiny, with_dram_capacity};
use cxlfine::topology::NodeId;
use cxlfine::util::units::GIB;

#[test]
fn full_pipeline_all_policies_all_presets() {
    // every (preset, policy) combination must plan + simulate cleanly for
    // a workload that fits
    for topo in [config_a(), config_b(), dev_tiny()] {
        let model = if topo.name.starts_with("dev") {
            tiny_2m()
        } else {
            qwen25_7b()
        };
        for policy in [
            Policy::DramOnly,
            Policy::NaiveInterleave,
            Policy::CxlAware { striping: false },
            Policy::CxlAware { striping: true },
        ] {
            let w = Workload::new(2, 2, 512);
            let cfg = RunConfig::new(model.clone(), w, policy);
            let plan = MemoryPlan::build(&topo, &cfg)
                .unwrap_or_else(|e| panic!("{} {:?}: {e}", topo.name, policy));
            let b = simulate_iteration(&topo, &cfg, &plan);
            assert!(b.iter_s.is_finite() && b.iter_s > 0.0);
            assert!(b.fwd_s > 0.0 && b.bwd_s > 0.0 && b.step_s > 0.0);
        }
    }
}

#[test]
fn trace_covers_every_scheduled_operation() {
    let topo = config_a();
    let cfg = RunConfig::new(qwen25_7b(), Workload::new(2, 4, 4096), Policy::DramOnly);
    let plan = MemoryPlan::build(&topo, &cfg).unwrap();
    let (b, trace) = simulate_iteration_traced(&topo, &cfg, &plan);
    let l = cfg.model.layers;
    // per GPU: L param loads + L fwd + L ckpt offloads + L param reloads
    //          + L ckpt loads + L bwd + L grad offloads = 7L spans, + STEP
    assert_eq!(trace.spans().len(), 2 * 7 * l + 1, "span count");
    // no span exceeds the iteration window
    for s in trace.spans() {
        assert!(s.start_s >= 0.0 && s.end_s <= b.iter_s + 1e-9, "span out of window: {s:?}");
        assert!(s.duration() >= 0.0);
    }
    // compute lanes must be busy a plausible fraction of the iteration
    let busy = trace.lane_busy();
    let gpu0_compute = busy
        .iter()
        .find(|(lane, _)| lane == "gpu0/compute")
        .map(|(_, b)| *b)
        .unwrap();
    assert!(gpu0_compute > 0.3 * b.iter_s, "GPU idle too much: {gpu0_compute} of {}", b.iter_s);
}

#[test]
fn oom_failure_paths_are_clean_errors() {
    // baseline OOM
    let topo = with_dram_capacity(config_a(), 8 * GIB);
    let cfg = RunConfig::new(qwen25_7b(), Workload::new(1, 1, 4096), Policy::DramOnly);
    let err = match MemoryPlan::build(&topo, &cfg) {
        Err(e) => e,
        Ok(_) => panic!("plan must not fit in 8 GiB"),
    };
    assert!(err.to_string().contains("cannot place"));
    // CXL policy OOM when even the AIC is too small
    let cfg2 = RunConfig::new(
        mistral_nemo_12b(),
        Workload::new(2, 32, 32768),
        Policy::CxlAware { striping: false },
    );
    let small = with_dram_capacity(config_a(), 64 * GIB);
    assert!(MemoryPlan::build(&small, &cfg2).is_err());
}

#[test]
#[should_panic(expected = "workload wants")]
fn too_many_gpus_is_rejected() {
    let topo = config_a(); // 2 GPUs
    let cfg = RunConfig::new(tiny_2m(), Workload::new(3, 1, 128), Policy::DramOnly);
    // plan succeeds (memory is memory) but simulation must reject
    let plan = MemoryPlan::build(&topo, &cfg).unwrap();
    let _ = simulate_iteration(&topo, &cfg, &plan);
}

#[test]
fn footprint_matches_allocator_accounting_exactly() {
    // Table-I totals and the allocator must agree byte-for-byte
    for (model, w) in [
        (qwen25_7b(), Workload::new(1, 8, 4096)),
        (mistral_nemo_12b(), Workload::new(2, 16, 8192)),
    ] {
        let topo = config_b();
        let f = Footprint::compute(&model, &w);
        let cfg = RunConfig::new(model, w, Policy::CxlAware { striping: true });
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        assert_eq!(plan.alloc.total_used(), f.total());
    }
}

#[test]
fn policy_relative_order_is_invariant_across_hardware() {
    // baseline ≥ cxl-aware ≥ naive on both CXL configurations
    for (mk_topo, striping) in [(config_a as fn() -> _, false), (config_b as fn() -> _, true)] {
        let base_topo = mk_topo();
        let cxl_topo = with_dram_capacity(mk_topo(), 128 * GIB);
        let w = Workload::new(2, 8, 8192);
        let run = |topo: &cxlfine::topology::SystemTopology, policy| {
            let cfg = RunConfig::new(qwen25_7b(), w, policy);
            let plan = MemoryPlan::build(topo, &cfg).unwrap();
            simulate_iteration(topo, &cfg, &plan).tokens_per_sec()
        };
        let base = run(&base_topo, Policy::DramOnly);
        let ours = run(&cxl_topo, Policy::CxlAware { striping });
        let naive = run(&cxl_topo, Policy::NaiveInterleave);
        assert!(base >= ours * 0.999, "baseline {base} vs ours {ours}");
        assert!(ours >= naive, "ours {ours} vs naive {naive}");
    }
}

#[test]
fn striping_beats_affinity_under_shared_aic_pressure() {
    // Config B, both GPUs: striped placement should never lose to affinity
    let topo = with_dram_capacity(config_b(), 128 * GIB);
    let w = Workload::new(2, 1, 8192);
    let run = |policy| {
        let cfg = RunConfig::new(qwen25_7b(), w, policy);
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        simulate_iteration(&topo, &cfg, &plan).tokens_per_sec()
    };
    let affinity = run(Policy::CxlAware { striping: false });
    let striped = run(Policy::CxlAware { striping: true });
    assert!(striped >= affinity * 0.999, "striped {striped} vs affinity {affinity}");
}

#[test]
fn manifest_failure_injection() {
    // missing directory
    assert!(Manifest::load("/nonexistent/path").is_err());
    // corrupt json
    let dir = std::env::temp_dir().join(format!("cxlfine_manifest_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    assert!(Manifest::load(&dir).is_err());
    // structurally valid but empty
    std::fs::write(dir.join("manifest.json"), r#"{"entries": {}}"#).unwrap();
    assert!(Manifest::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn allocator_survives_adversarial_sequences() {
    use cxlfine::mem::NumaAllocator;
    // fill, free everything, refill — capacity must be fully recovered
    let topo = dev_tiny();
    let mut a = NumaAllocator::new(&topo, Policy::CxlAware { striping: true });
    let mut ids = vec![];
    loop {
        match a.alloc(RegionRequest::new("x", TensorClass::Activations, GIB)) {
            Ok(id) => ids.push(id),
            Err(_) => break,
        }
    }
    let n_first = ids.len();
    assert!(n_first >= 15, "should fit ~16 GiB of activations, got {n_first}");
    for id in ids.drain(..) {
        assert!(a.release(id));
    }
    assert_eq!(a.total_used(), 0);
    // refill reaches the same count (no leaks, no fragmentation artifacts)
    let mut n_second = 0;
    while a
        .alloc(RegionRequest::new("y", TensorClass::Activations, GIB))
        .is_ok()
    {
        n_second += 1;
    }
    assert_eq!(n_first, n_second);
}

#[test]
fn naive_interleave_touches_every_node() {
    let topo = config_b();
    let cfg = RunConfig::new(
        qwen25_7b(),
        Workload::new(1, 4, 4096),
        Policy::NaiveInterleave,
    );
    let plan = MemoryPlan::build(&topo, &cfg).unwrap();
    for node in topo.all_nodes() {
        assert!(
            plan.alloc.used_on(node) > 0,
            "interleave must use node {node:?}"
        );
    }
    // whereas CXL-aware keeps node 0 for PGO only when DRAM suffices
    let cfg2 = RunConfig::new(
        qwen25_7b(),
        Workload::new(1, 4, 4096),
        Policy::CxlAware { striping: true },
    );
    let plan2 = MemoryPlan::build(&topo, &cfg2).unwrap();
    let f = plan2.footprint.latency_critical();
    assert_eq!(plan2.alloc.used_on(NodeId(0)), f, "only PGO in DRAM");
}
