//! Placement policies — the heart of the paper.
//!
//! * [`Policy::DramOnly`] — the baseline: everything in local DRAM.
//! * [`Policy::NaiveInterleave`] — the "Naive CXL" configuration: pages
//!   round-robin across the local-DRAM node and every CXL node
//!   (`numactl --interleave=all`), blind to data classes.
//! * [`Policy::CxlAware`] — §IV-A: latency-critical optimizer data (fp32
//!   P/G/O) pinned to local DRAM, latency-tolerant GPU-transfer data (bf16
//!   P/G, activation checkpoints) on CXL. With `striping` (§IV-B) the
//!   CXL-resident data of each GPU is striped across *all* AICs, and
//!   optimizer data that spills out of DRAM is partitioned across
//!   DRAM + AICs proportionally to sustained bandwidth (Fig. 8c).
//!   Without striping (single-AIC Config A) per-GPU data keeps an AIC
//!   affinity (GPU *i* → AIC *i mod n*).

use super::region::{Placement, RegionRequest};
use super::striping;
use crate::sim::memmodel::AccessMode;
use crate::topology::{NodeId, SystemTopology};

/// The three evaluated placement policies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    DramOnly,
    NaiveInterleave,
    CxlAware { striping: bool },
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::DramOnly => "baseline-dram",
            Policy::NaiveInterleave => "naive-cxl",
            Policy::CxlAware { striping: false } => "cxl-aware",
            Policy::CxlAware { striping: true } => "cxl-aware+striping",
        }
    }

    pub fn by_name(s: &str) -> Option<Policy> {
        match s {
            "baseline" | "dram" | "baseline-dram" => Some(Policy::DramOnly),
            "naive" | "naive-cxl" | "interleave" => Some(Policy::NaiveInterleave),
            "cxl-aware" | "ours" => Some(Policy::CxlAware { striping: false }),
            "cxl-aware+striping" | "ours+striping" | "striped" => {
                Some(Policy::CxlAware { striping: true })
            }
            _ => None,
        }
    }

    /// Compute the placement for `req` given per-node free bytes.
    /// Returns `Err(shortfall)` if the policy cannot place the region.
    pub fn place(
        self,
        topo: &SystemTopology,
        req: &RegionRequest,
        free: &[u64],
    ) -> Result<Placement, u64> {
        if req.bytes == 0 {
            return Ok(Placement {
                parts: vec![],
                mode: AccessMode::Partitioned,
            });
        }
        match self {
            Policy::DramOnly => {
                let dram = NodeId(0);
                if free[0] >= req.bytes {
                    Ok(Placement::single(dram, req.bytes))
                } else {
                    Err(req.bytes - free[0])
                }
            }
            Policy::NaiveInterleave => {
                // interleave across all nodes, capacity-aware
                let nodes = topo.all_nodes();
                let (parts, unplaced) = striping::equal_split(req.bytes, &nodes, free);
                if unplaced > 0 {
                    return Err(unplaced);
                }
                Ok(Placement {
                    parts,
                    mode: AccessMode::Interleaved,
                })
            }
            Policy::CxlAware { striping: stripe } => {
                self.place_cxl_aware(topo, req, free, stripe)
            }
        }
    }

    fn place_cxl_aware(
        self,
        topo: &SystemTopology,
        req: &RegionRequest,
        free: &[u64],
        stripe: bool,
    ) -> Result<Placement, u64> {
        let dram = NodeId(0);
        let cxl = topo.cxl_nodes();
        if req.class.latency_critical() {
            // DRAM first; spill per §IV-B (Fig. 8c).
            if free[0] >= req.bytes {
                return Ok(Placement::single(dram, req.bytes));
            }
            let dram_take = free[0];
            let rest = req.bytes - dram_take;
            let (mut parts, unplaced) = if stripe {
                // bandwidth-proportional partition of the spill across AICs
                let weights: Vec<f64> =
                    cxl.iter().map(|&n| topo.node(n).cpu_stream_bw).collect();
                striping::weighted_split(rest, &cxl, &weights, free)
            } else {
                striping::sequential_fill(rest, &cxl, free)
            };
            if unplaced > 0 {
                return Err(unplaced);
            }
            if dram_take > 0 {
                parts.insert(0, (dram, dram_take));
            }
            Ok(Placement {
                parts,
                mode: AccessMode::Partitioned,
            })
        } else {
            // Latency-tolerant → CXL capacity; overflow back to DRAM.
            let preferred: Vec<NodeId> = if cxl.is_empty() {
                vec![dram]
            } else if stripe {
                cxl.clone()
            } else {
                // AIC affinity: GPU i → AIC (i mod n); non-GPU data fills
                // sequentially.
                match req.gpu {
                    Some(g) => {
                        let mut order: Vec<NodeId> = Vec::with_capacity(cxl.len());
                        for k in 0..cxl.len() {
                            order.push(cxl[(g.0 + k) % cxl.len()]);
                        }
                        order
                    }
                    None => cxl.clone(),
                }
            };
            let (mut parts, unplaced) = if stripe && !cxl.is_empty() {
                striping::equal_split(req.bytes, &preferred, free)
            } else {
                striping::sequential_fill(req.bytes, &preferred, free)
            };
            let mut rest = unplaced;
            if rest > 0 && !cxl.is_empty() {
                // overflow to DRAM
                let take = rest.min(free[0]);
                if take > 0 {
                    parts.push((dram, take));
                    rest -= take;
                }
            }
            if rest > 0 {
                return Err(rest);
            }
            Ok(Placement {
                parts,
                mode: AccessMode::Partitioned,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::region::TensorClass;
    use crate::topology::presets::{config_a, config_b, with_dram_capacity};
    use crate::topology::GpuId;
    use crate::util::units::GIB;

    fn free_of(topo: &SystemTopology) -> Vec<u64> {
        topo.mem_nodes.iter().map(|n| n.capacity).collect()
    }

    #[test]
    fn dram_only_places_or_fails() {
        let topo = config_a();
        let mut free = free_of(&topo);
        let req = RegionRequest::new("p", TensorClass::MasterParams, 10 * GIB);
        let p = Policy::DramOnly.place(&topo, &req, &free).unwrap();
        assert_eq!(p.parts, vec![(NodeId(0), 10 * GIB)]);
        free[0] = GIB;
        let err = Policy::DramOnly.place(&topo, &req, &free).unwrap_err();
        assert_eq!(err, 9 * GIB);
    }

    #[test]
    fn naive_interleave_spreads_equally() {
        let topo = config_a(); // dram + 1 AIC
        let free = free_of(&topo);
        let req = RegionRequest::new("x", TensorClass::OptimizerStates, 100 * GIB);
        let p = Policy::NaiveInterleave.place(&topo, &req, &free).unwrap();
        assert_eq!(p.mode, AccessMode::Interleaved);
        assert_eq!(p.parts.len(), 2);
        assert_eq!(p.bytes_on(NodeId(0)), 50 * GIB);
        assert_eq!(p.bytes_on(NodeId(1)), 50 * GIB);
    }

    #[test]
    fn naive_interleave_ignores_latency_classes() {
        // the defining flaw: optimizer data lands on CXL even with DRAM free
        let topo = config_a();
        let free = free_of(&topo);
        let req = RegionRequest::new("o", TensorClass::OptimizerStates, 10 * GIB);
        let p = Policy::NaiveInterleave.place(&topo, &req, &free).unwrap();
        assert!(p.touches(NodeId(1)), "naive policy must hit CXL");
    }

    #[test]
    fn cxl_aware_pins_optimizer_data_to_dram() {
        let topo = config_a();
        let free = free_of(&topo);
        for class in [
            TensorClass::MasterParams,
            TensorClass::Gradients32,
            TensorClass::OptimizerStates,
        ] {
            let req = RegionRequest::new("c", class, 40 * GIB);
            let p = Policy::CxlAware { striping: false }
                .place(&topo, &req, &free)
                .unwrap();
            assert_eq!(p.parts, vec![(NodeId(0), 40 * GIB)], "{class:?}");
        }
    }

    #[test]
    fn cxl_aware_sends_transfer_data_to_cxl() {
        let topo = config_a();
        let free = free_of(&topo);
        for class in [
            TensorClass::Params16,
            TensorClass::Grads16,
            TensorClass::Activations,
        ] {
            let req = RegionRequest::new("t", class, 40 * GIB);
            let p = Policy::CxlAware { striping: false }
                .place(&topo, &req, &free)
                .unwrap();
            assert!(!p.touches(NodeId(0)), "{class:?} should avoid DRAM");
            assert!(p.touches(NodeId(1)));
        }
    }

    #[test]
    fn cxl_aware_spill_stripes_proportionally() {
        // Fig. 8c: optimizer state too big for DRAM → DRAM + AIC partition.
        let topo = with_dram_capacity(config_b(), 16 * GIB);
        let free = free_of(&topo);
        let req = RegionRequest::new("o", TensorClass::OptimizerStates, 48 * GIB);
        let p = Policy::CxlAware { striping: true }
            .place(&topo, &req, &free)
            .unwrap();
        assert_eq!(p.mode, AccessMode::Partitioned);
        assert_eq!(p.bytes_on(NodeId(0)), 16 * GIB, "DRAM filled first");
        // spill split across two AICs with equal cpu_stream_bw → equal halves
        assert_eq!(p.bytes_on(NodeId(1)), 16 * GIB);
        assert_eq!(p.bytes_on(NodeId(2)), 16 * GIB);
    }

    #[test]
    fn striping_spreads_activations_across_all_aics() {
        let topo = config_b();
        let free = free_of(&topo);
        let req =
            RegionRequest::new("a", TensorClass::Activations, 64 * GIB).for_gpu(GpuId(0));
        let p = Policy::CxlAware { striping: true }
            .place(&topo, &req, &free)
            .unwrap();
        assert_eq!(p.bytes_on(NodeId(1)), 32 * GIB);
        assert_eq!(p.bytes_on(NodeId(2)), 32 * GIB);
    }

    #[test]
    fn no_striping_gives_per_gpu_affinity() {
        let topo = config_b();
        let free = free_of(&topo);
        let p0 = Policy::CxlAware { striping: false }
            .place(
                &topo,
                &RegionRequest::new("a0", TensorClass::Activations, GIB).for_gpu(GpuId(0)),
                &free,
            )
            .unwrap();
        let p1 = Policy::CxlAware { striping: false }
            .place(
                &topo,
                &RegionRequest::new("a1", TensorClass::Activations, GIB).for_gpu(GpuId(1)),
                &free,
            )
            .unwrap();
        assert_eq!(p0.parts, vec![(NodeId(1), GIB)]);
        assert_eq!(p1.parts, vec![(NodeId(2), GIB)]);
    }

    #[test]
    fn transfer_data_overflows_to_dram_when_cxl_full() {
        let topo = config_a();
        let mut free = free_of(&topo);
        free[1] = GIB; // AIC almost full
        let req = RegionRequest::new("a", TensorClass::Activations, 3 * GIB);
        let p = Policy::CxlAware { striping: false }
            .place(&topo, &req, &free)
            .unwrap();
        assert_eq!(p.bytes_on(NodeId(1)), GIB);
        assert_eq!(p.bytes_on(NodeId(0)), 2 * GIB);
    }

    #[test]
    fn shortfall_reported_when_nothing_fits() {
        let topo = config_a();
        let free = vec![GIB, GIB];
        let req = RegionRequest::new("x", TensorClass::Activations, 10 * GIB);
        let err = Policy::CxlAware { striping: true }
            .place(&topo, &req, &free)
            .unwrap_err();
        assert_eq!(err, 8 * GIB);
    }

    #[test]
    fn striped_spill_engages_when_dram_is_full() {
        // DRAM exhausted entirely → the whole latency-critical region is a
        // spill, bandwidth-proportionally striped across both AICs.
        let topo = config_b();
        let mut free = free_of(&topo);
        free[0] = 0;
        let req = RegionRequest::new("o", TensorClass::OptimizerStates, 48 * GIB);
        let p = Policy::CxlAware { striping: true }
            .place(&topo, &req, &free)
            .unwrap();
        assert_eq!(p.mode, AccessMode::Partitioned);
        assert!(!p.touches(NodeId(0)), "no DRAM part when DRAM is full");
        // equal cpu_stream_bw on both AICs → equal halves
        assert_eq!(p.bytes_on(NodeId(1)), 24 * GIB);
        assert_eq!(p.bytes_on(NodeId(2)), 24 * GIB);
        assert_eq!(p.total_bytes(), 48 * GIB);
    }

    #[test]
    fn unstriped_spill_fills_aics_sequentially() {
        // Without striping the spill packs AIC-by-AIC (Config A's
        // single-card behaviour generalized): first card fills before the
        // second sees a byte.
        let topo = config_b();
        let mut free = free_of(&topo);
        free[0] = 4 * GIB;
        free[1] = 10 * GIB; // first AIC nearly full
        let req = RegionRequest::new("g", TensorClass::Gradients32, 30 * GIB);
        let p = Policy::CxlAware { striping: false }
            .place(&topo, &req, &free)
            .unwrap();
        assert_eq!(p.parts[0], (NodeId(0), 4 * GIB), "DRAM part leads");
        assert_eq!(p.bytes_on(NodeId(1)), 10 * GIB, "AIC0 filled to capacity");
        assert_eq!(p.bytes_on(NodeId(2)), 16 * GIB, "remainder on AIC1");
        assert_eq!(p.mode, AccessMode::Partitioned);
    }

    #[test]
    fn spill_shortfall_propagates_when_aics_are_full_too() {
        // DRAM and both AICs nearly full → Err carries the exact number of
        // bytes that found no home, for both striped and sequential spills.
        let topo = config_b();
        let free = vec![2 * GIB, GIB, GIB];
        let req = RegionRequest::new("m", TensorClass::MasterParams, 10 * GIB);
        for striping in [true, false] {
            let err = Policy::CxlAware { striping }
                .place(&topo, &req, &free)
                .unwrap_err();
            assert_eq!(err, 6 * GIB, "striping={striping}");
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for p in [
            Policy::DramOnly,
            Policy::NaiveInterleave,
            Policy::CxlAware { striping: false },
            Policy::CxlAware { striping: true },
        ] {
            assert_eq!(Policy::by_name(p.name()), Some(p));
        }
        assert_eq!(Policy::by_name("??"), None);
    }

    #[test]
    fn placement_conservation_property() {
        use crate::util::proptest_lite::*;
        let topo = config_b();
        let gen = PairOf(
            U64Range {
                lo: 1,
                hi: 300 * GIB,
            },
            UsizeRange { lo: 0, hi: 5 },
        );
        for policy in [
            Policy::DramOnly,
            Policy::NaiveInterleave,
            Policy::CxlAware { striping: false },
            Policy::CxlAware { striping: true },
        ] {
            forall("placement-conserves", 7, 200, &gen, |(bytes, class_idx)| {
                let class = TensorClass::all()[*class_idx % 6];
                let free = free_of(&topo);
                let req = RegionRequest::new("r", class, *bytes);
                match policy.place(&topo, &req, &free) {
                    Ok(p) => {
                        if p.total_bytes() != *bytes {
                            return Err(format!(
                                "{policy:?}: placed {} of {bytes}",
                                p.total_bytes()
                            ));
                        }
                        for (n, b) in &p.parts {
                            if *b > free[n.0] {
                                return Err(format!("{policy:?}: node {} over cap", n.0));
                            }
                        }
                        Ok(())
                    }
                    Err(short) => {
                        if short == 0 {
                            Err("zero shortfall error".into())
                        } else {
                            Ok(())
                        }
                    }
                }
            });
        }
    }
}
