//! Serving requests and arrival traces (the request-level analogue of
//! `fleet::job`).
//!
//! A [`RequestSpec`] names one inference request by configuration — model
//! preset × prompt length × output budget × latency SLO — plus its
//! arrival time. Models are stored as registry names (resolved through
//! `model::presets::by_name` at simulation time), so traces serialize to
//! plain JSON and replay bit-identically on any host.
//!
//! [`RequestGen`] is the seeded synthetic workload generator: Poisson-ish
//! arrivals via [`Xoshiro256pp::exp_mean`] and heavy-tailed lengths —
//! prompts are bounded-Pareto (most prompts short, a fat tail of
//! long-context ones), output budgets ride a Zipf rank over a geometric
//! ladder. One PRNG stream, one fixed sampling order per request
//! (inter-arrival, prompt, output, jitterless SLO), so the same seed
//! always yields a byte-identical trace, and [`RequestTrace::to_json`]
//! embeds a digest so a replayed file is self-certifying.

use crate::jobj;
use crate::util::digest::Fnv64;
use crate::util::json::Json;
use crate::util::prng::Xoshiro256pp;

/// One inference request.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestSpec {
    pub id: u64,
    /// Arrival time at the serving host, seconds from trace start.
    pub arrival_s: f64,
    /// Model preset name (`model::presets::by_name`).
    pub model: String,
    /// Prompt length in tokens (prefill work + initial KV footprint).
    pub prompt_tokens: usize,
    /// Output budget: the request decodes exactly this many tokens.
    pub max_output_tokens: usize,
    /// Time-to-first-token SLO in milliseconds.
    pub slo_ms: f64,
}

impl RequestSpec {
    /// Total KV-cache tokens the request holds when fully decoded.
    pub fn total_kv_tokens(&self) -> usize {
        self.prompt_tokens + self.max_output_tokens
    }

    /// Memoization key of the request's *configuration* — the identity
    /// fields that determine calibrated step costs (id/arrival/SLO
    /// excluded).
    pub fn config_key(&self) -> String {
        format!(
            "{}|{}|{}",
            self.model, self.prompt_tokens, self.max_output_tokens
        )
    }

    pub fn to_json(&self) -> Json {
        jobj! {
            "id" => self.id,
            "arrival_s" => self.arrival_s,
            "model" => self.model.as_str(),
            "prompt_tokens" => self.prompt_tokens,
            "max_output_tokens" => self.max_output_tokens,
            "slo_ms" => self.slo_ms,
        }
    }

    /// Parse one request. Shape errors (missing / mistyped fields,
    /// non-finite times) abort; *value* errors — non-positive token
    /// counts or SLO — do not, so the trace linter can report every P211
    /// instead of stopping at the first. Strict consumers
    /// ([`RequestTrace::from_json`]) reject on [`Self::validity_issues`].
    pub fn from_json(j: &Json) -> Result<RequestSpec, String> {
        let num = |key: &str| {
            j.path(&[key])
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("request missing numeric {key:?}"))
        };
        let spec = RequestSpec {
            id: num("id")?,
            arrival_s: j
                .path(&["arrival_s"])
                .and_then(Json::as_f64)
                .ok_or_else(|| "request missing arrival_s".to_string())?,
            model: j
                .path(&["model"])
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| "request missing string \"model\"".to_string())?,
            prompt_tokens: num("prompt_tokens")? as usize,
            max_output_tokens: num("max_output_tokens")? as usize,
            slo_ms: j
                .path(&["slo_ms"])
                .and_then(Json::as_f64)
                .ok_or_else(|| "request missing slo_ms".to_string())?,
        };
        if !(spec.arrival_s.is_finite() && spec.arrival_s >= 0.0) {
            return Err(format!(
                "request {}: arrival_s must be a non-negative finite time",
                spec.id
            ));
        }
        if !spec.slo_ms.is_finite() {
            return Err(format!("request {}: slo_ms must be finite", spec.id));
        }
        Ok(spec)
    }

    /// Value-level problems a parsed request may still carry: the
    /// non-positive token counts / SLO the P211 lint reports. Empty for a
    /// simulatable request.
    pub fn validity_issues(&self) -> Vec<String> {
        let mut out = Vec::new();
        if self.prompt_tokens == 0 {
            out.push("prompt_tokens must be positive".to_string());
        }
        if self.max_output_tokens == 0 {
            out.push("max_output_tokens must be positive".to_string());
        }
        if self.slo_ms <= 0.0 {
            out.push(format!("slo_ms {} must be positive", self.slo_ms));
        }
        out
    }

    /// Registry resolution: does the request's model preset exist? The
    /// static verifier reports each entry as a P204 diagnostic.
    pub fn registry_issues(&self) -> Vec<String> {
        let mut out = Vec::new();
        if crate::model::presets::by_name(&self.model).is_none() {
            out.push(format!("names unregistered model preset {:?}", self.model));
        }
        out
    }

    pub(crate) fn fold(&self, h: &mut Fnv64) {
        h.write_u64(self.id);
        h.write_f64(self.arrival_s);
        h.write_str(&self.model);
        h.write_u64(self.prompt_tokens as u64);
        h.write_u64(self.max_output_tokens as u64);
        h.write_f64(self.slo_ms);
    }
}

/// A replayable request-arrival trace: the generator seed (0 for
/// hand-built traces) plus every request in arrival order.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestTrace {
    pub seed: u64,
    pub requests: Vec<RequestSpec>,
}

impl RequestTrace {
    /// Bit-exact FNV-1a fingerprint of the whole trace (float fields by
    /// IEEE-754 pattern): two traces match iff they are byte-identical.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_u64(self.seed);
        h.write_u64(self.requests.len() as u64);
        for r in &self.requests {
            r.fold(&mut h);
        }
        h.finish()
    }

    /// Machine-readable trace (what `cxlfine serve --trace` writes and
    /// replays), digest-embedded so files are self-certifying. The seed
    /// rides a decimal *string* for the same reason `FleetTrace` does:
    /// JSON numbers are f64 here and would round seeds above 2^53.
    pub fn to_json(&self) -> Json {
        let requests: Vec<Json> = self.requests.iter().map(RequestSpec::to_json).collect();
        jobj! {
            "seed" => self.seed.to_string(),
            "digest" => format!("{:016x}", self.digest()),
            "requests" => Json::Arr(requests),
        }
    }

    /// Parse a trace, verifying the embedded digest when present and
    /// rejecting duplicate ids and value-invalid requests (the replay
    /// path is strict; only the linter tolerates them).
    pub fn from_json(j: &Json) -> Result<RequestTrace, String> {
        let seed_field = j
            .path(&["seed"])
            .ok_or_else(|| "trace missing seed".to_string())?;
        let seed = match seed_field {
            Json::Str(s) => s
                .parse::<u64>()
                .map_err(|e| format!("trace seed {s:?}: {e}"))?,
            other => other
                .as_u64()
                .ok_or_else(|| "trace seed must be a u64".to_string())?,
        };
        let raw = j
            .path(&["requests"])
            .and_then(Json::as_arr)
            .ok_or_else(|| "trace missing requests array".to_string())?;
        let requests = raw
            .iter()
            .map(RequestSpec::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let mut ids = std::collections::BTreeSet::new();
        for r in &requests {
            if !ids.insert(r.id) {
                return Err(format!("trace has duplicate request id {}", r.id));
            }
            if let Some(issue) = r.validity_issues().into_iter().next() {
                return Err(format!("request {}: {issue}", r.id));
            }
        }
        let trace = RequestTrace { seed, requests };
        if let Some(want) = j.path(&["digest"]).and_then(Json::as_str) {
            let got = format!("{:016x}", trace.digest());
            if want != got {
                return Err(format!(
                    "trace digest mismatch: file says {want}, contents hash to {got}"
                ));
            }
        }
        Ok(trace)
    }
}

/// Seeded synthetic request generator.
///
/// Arrivals are a Poisson process (inverse-CDF exponential inter-arrivals
/// on [`Xoshiro256pp`]); prompt lengths are bounded-Pareto in
/// `[prompt_lo, prompt_hi]` with tail index `prompt_alpha`; output budgets
/// are `out_unit · zipf(out_ranks, out_s)` (rank 1 = the shortest reply
/// dominates). Sampling order per request is fixed (inter-arrival,
/// prompt, output), so a seed pins the whole trace bitwise.
#[derive(Clone, Debug)]
pub struct RequestGen {
    pub seed: u64,
    pub n_requests: usize,
    pub mean_interarrival_s: f64,
    pub model: String,
    pub prompt_lo: f64,
    pub prompt_hi: f64,
    pub prompt_alpha: f64,
    /// Output budget = `out_unit × rank`, rank Zipf-distributed.
    pub out_unit: usize,
    pub out_ranks: u64,
    pub out_s: f64,
    pub slo_ms: f64,
}

impl RequestGen {
    /// The default chat-style mix on a given model: short-prompt-heavy
    /// with a long-context tail, short replies dominating.
    pub fn mixed(seed: u64, n_requests: usize, model: &str) -> Self {
        Self {
            seed,
            n_requests,
            mean_interarrival_s: 2.0,
            model: model.to_string(),
            prompt_lo: 256.0,
            prompt_hi: 16384.0,
            prompt_alpha: 1.1,
            out_unit: 32,
            out_ranks: 16,
            out_s: 1.1,
            slo_ms: 30_000.0,
        }
    }

    pub fn generate(&self) -> RequestTrace {
        assert!(self.n_requests > 0, "generator needs at least one request");
        assert!(self.out_unit >= 1 && self.out_ranks >= 1);
        let mut rng = Xoshiro256pp::seeded(self.seed);
        let mut t = 0.0;
        let mut requests = Vec::with_capacity(self.n_requests);
        for id in 0..self.n_requests {
            t += rng.exp_mean(self.mean_interarrival_s);
            let prompt = rng
                .bounded_pareto(self.prompt_lo, self.prompt_hi, self.prompt_alpha)
                .round() as usize;
            let out = self.out_unit * rng.zipf(self.out_ranks, self.out_s) as usize;
            requests.push(RequestSpec {
                id: id as u64,
                arrival_s: t,
                model: self.model.clone(),
                prompt_tokens: prompt.max(1),
                max_output_tokens: out,
                slo_ms: self.slo_ms,
            });
        }
        RequestTrace {
            seed: self.seed,
            requests,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_yields_byte_identical_traces() {
        let a = RequestGen::mixed(7, 50, "tiny-2m").generate();
        let b = RequestGen::mixed(7, 50, "tiny-2m").generate();
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty()
        );
        let c = RequestGen::mixed(8, 50, "tiny-2m").generate();
        assert_ne!(a.digest(), c.digest(), "a different seed must diverge");
    }

    #[test]
    fn arrivals_ascend_and_lengths_are_heavy_tailed() {
        let t = RequestGen::mixed(5, 400, "tiny-2m").generate();
        assert_eq!(t.requests.len(), 400);
        for w in t.requests.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s, "arrivals must ascend");
        }
        for r in &t.requests {
            assert!((256..=16384).contains(&r.prompt_tokens));
            assert!(r.max_output_tokens >= 32 && r.max_output_tokens <= 32 * 16);
            assert!(r.validity_issues().is_empty());
            assert!(r.registry_issues().is_empty());
        }
        // Heavy tail: short prompts dominate, but long ones exist.
        let short = t.requests.iter().filter(|r| r.prompt_tokens < 1024).count();
        let long = t.requests.iter().filter(|r| r.prompt_tokens > 8192).count();
        assert!(short > t.requests.len() / 2, "short {short}");
        assert!(long >= 1, "the Pareto tail must reach past 8k tokens");
    }

    #[test]
    fn trace_json_round_trips_and_verifies_digest() {
        let t = RequestGen::mixed(11, 17, "7b").generate();
        let text = t.to_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        let back = RequestTrace::from_json(&parsed).unwrap();
        assert_eq!(t, back, "round trip must preserve every field bitwise");
        // Tampering must be rejected by the digest check.
        let mut t2 = t.clone();
        t2.requests[0].prompt_tokens += 1;
        let mut tampered = t2.to_json();
        if let Json::Obj(o) = &mut tampered {
            o.set("digest", format!("{:016x}", t.digest()));
        }
        let err = RequestTrace::from_json(&tampered).unwrap_err();
        assert!(err.contains("digest mismatch"), "{err}");
    }

    #[test]
    fn strict_parse_rejects_bad_values_lenient_parse_reports_them() {
        // Zero output budget: parses (shape-valid) but is value-invalid.
        let j = Json::parse(
            r#"{"id": 3, "arrival_s": 1.0, "model": "7b",
                "prompt_tokens": 128, "max_output_tokens": 0, "slo_ms": 500.0}"#,
        )
        .unwrap();
        let spec = RequestSpec::from_json(&j).unwrap();
        assert_eq!(
            spec.validity_issues(),
            vec!["max_output_tokens must be positive".to_string()]
        );
        let trace = Json::parse(&format!(
            r#"{{"seed": 1, "requests": [{}]}}"#,
            j.to_string_pretty()
        ))
        .unwrap();
        let err = RequestTrace::from_json(&trace).unwrap_err();
        assert!(err.contains("max_output_tokens"), "{err}");
        // Duplicate ids are rejected even without a digest.
        let mut dup = RequestGen::mixed(1, 2, "7b").generate();
        dup.requests[1].id = dup.requests[0].id;
        let mut json = dup.to_json();
        if let Json::Obj(o) = &mut json {
            o.set("digest", Json::Null);
        }
        let err = RequestTrace::from_json(&json).unwrap_err();
        assert!(err.contains("duplicate request id"), "{err}");
        // Seeds above 2^53 survive the string round trip.
        let mut big = RequestGen::mixed(1, 3, "7b").generate();
        big.seed = (1u64 << 53) + 7;
        let back =
            RequestTrace::from_json(&Json::parse(&big.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(back.seed, (1u64 << 53) + 7);
    }
}
