//! Tiny declarative CLI argument parser (the offline vendor set has no
//! `clap`). Supports subcommands, `--flag`, `--opt value` / `--opt=value`,
//! repeated options, positionals, defaults and auto-generated `--help`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one option/flag.
#[derive(Clone, Debug)]
struct OptSpec {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
    repeated: bool,
}

/// A declarative command-line spec; build with the fluent API then `parse`.
#[derive(Clone, Debug, Default)]
pub struct CliSpec {
    name: &'static str,
    about: &'static str,
    opts: Vec<OptSpec>,
    positionals: Vec<(&'static str, &'static str, bool)>, // (name, help, required)
}

/// Parse result: option values + positionals.
#[derive(Clone, Debug, Default)]
pub struct CliArgs {
    values: BTreeMap<&'static str, Vec<String>>,
    flags: BTreeMap<&'static str, bool>,
    positionals: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    /// `--help` was requested; the payload is the rendered help text.
    Help(String),
    /// A genuine parse failure; payload is the message (help appended).
    Bad(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Help(h) => write!(f, "{h}"),
            CliError::Bad(m) => write!(f, "{m}"),
        }
    }
}
impl std::error::Error for CliError {}

impl CliSpec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            ..Default::default()
        }
    }

    /// A boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
            repeated: false,
        });
        self
    }

    /// A `--name <value>` option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: Some(default.to_string()),
            repeated: false,
        });
        self
    }

    /// A required `--name <value>` option.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: None,
            repeated: false,
        });
        self
    }

    /// A repeatable `--name <value>` option (collects all occurrences).
    pub fn multi(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: None,
            repeated: true,
        });
        self
    }

    /// A positional argument.
    pub fn positional(mut self, name: &'static str, help: &'static str, required: bool) -> Self {
        self.positionals.push((name, help, required));
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = writeln!(s, "\nUSAGE:\n  {} [OPTIONS]{}", self.name, {
            let mut p = String::new();
            for (name, _, required) in &self.positionals {
                if *required {
                    let _ = write!(p, " <{name}>");
                } else {
                    let _ = write!(p, " [{name}]");
                }
            }
            p
        });
        if !self.positionals.is_empty() {
            let _ = writeln!(s, "\nARGS:");
            for (name, help, _) in &self.positionals {
                let _ = writeln!(s, "  {name:<22} {help}");
            }
        }
        let _ = writeln!(s, "\nOPTIONS:");
        for o in &self.opts {
            let lhs = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let default = match &o.default {
                Some(d) if o.takes_value => format!(" [default: {d}]"),
                _ => String::new(),
            };
            let _ = writeln!(s, "  {lhs:<22} {}{}", o.help, default);
        }
        let _ = writeln!(s, "  {:<22} print this help", "--help");
        s
    }

    /// Parse a raw token list (without the program name).
    pub fn parse(&self, raw: &[String]) -> Result<CliArgs, CliError> {
        let mut out = CliArgs::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                out.values.insert(o.name, vec![d.clone()]);
            }
            if !o.takes_value {
                out.flags.insert(o.name, false);
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError::Help(self.help_text()));
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| self.bad(format!("unknown option --{name}")))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .cloned()
                                .ok_or_else(|| self.bad(format!("--{name} needs a value")))?
                        }
                    };
                    let slot = out.values.entry(spec.name).or_default();
                    if spec.repeated {
                        // first push replaces the (empty) default state
                        if !spec.repeated || slot.first().map(|s| s.as_str())
                            == spec.default.as_deref()
                        {
                            slot.clear();
                        }
                        slot.push(val);
                    } else {
                        *slot = vec![val];
                    }
                } else {
                    if inline_val.is_some() {
                        return Err(self.bad(format!("flag --{name} takes no value")));
                    }
                    out.flags.insert(spec.name, true);
                }
            } else {
                out.positionals.push(tok.clone());
            }
            i += 1;
        }
        // Required options and positionals.
        for o in &self.opts {
            if o.takes_value && o.default.is_none() && !o.repeated && !out.values.contains_key(o.name)
            {
                return Err(self.bad(format!("missing required option --{}", o.name)));
            }
        }
        let required_positionals = self.positionals.iter().filter(|(_, _, r)| *r).count();
        if out.positionals.len() < required_positionals {
            return Err(self.bad(format!(
                "expected at least {required_positionals} positional argument(s)"
            )));
        }
        Ok(out)
    }

    fn bad(&self, msg: String) -> CliError {
        CliError::Bad(format!("{msg}\n\n{}", self.help_text()))
    }
}

impl CliArgs {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> &[String] {
        self.values.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }

    pub fn parse_u64(&self, name: &str) -> Result<u64, CliError> {
        let s = self
            .get(name)
            .ok_or_else(|| CliError::Bad(format!("missing --{name}")))?;
        crate::util::units::parse_count(s).map_err(CliError::Bad)
    }

    pub fn parse_usize(&self, name: &str) -> Result<usize, CliError> {
        self.parse_u64(name).map(|v| v as usize)
    }

    pub fn parse_f64(&self, name: &str) -> Result<f64, CliError> {
        let s = self
            .get(name)
            .ok_or_else(|| CliError::Bad(format!("missing --{name}")))?;
        s.parse()
            .map_err(|e| CliError::Bad(format!("--{name}: bad float {s:?}: {e}")))
    }

    pub fn parse_bytes(&self, name: &str) -> Result<u64, CliError> {
        let s = self
            .get(name)
            .ok_or_else(|| CliError::Bad(format!("missing --{name}")))?;
        crate::util::units::parse_bytes(s).map_err(CliError::Bad)
    }

    /// Parse a comma-separated list of counts, e.g. `--batch 1,2,4,8`.
    pub fn parse_count_list(&self, name: &str) -> Result<Vec<u64>, CliError> {
        let s = self
            .get(name)
            .ok_or_else(|| CliError::Bad(format!("missing --{name}")))?;
        s.split(',')
            .map(|t| crate::util::units::parse_count(t.trim()).map_err(CliError::Bad))
            .collect()
    }
}

#[cfg(test)]
fn strings(toks: &[&str]) -> Vec<String> {
    toks.iter().map(|s| s.to_string()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CliSpec {
        CliSpec::new("demo", "test spec")
            .opt("model", "tiny", "model preset")
            .opt("batch", "8", "batch size")
            .flag("verbose", "chatty output")
            .multi("policy", "placement policy (repeatable)")
            .positional("input", "input file", false)
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(&[]).unwrap();
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.parse_u64("batch").unwrap(), 8);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn parse_forms() {
        let a = spec()
            .parse(&strings(&["--model=7b", "--batch", "32", "--verbose", "in.txt"]))
            .unwrap();
        assert_eq!(a.get("model"), Some("7b"));
        assert_eq!(a.parse_u64("batch").unwrap(), 32);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(0), Some("in.txt"));
    }

    #[test]
    fn repeated_options_collect() {
        let a = spec()
            .parse(&strings(&["--policy", "dram", "--policy", "cxl-aware"]))
            .unwrap();
        assert_eq!(a.get_all("policy"), &["dram", "cxl-aware"]);
    }

    #[test]
    fn unknown_option_errors() {
        match spec().parse(&strings(&["--nope"])) {
            Err(CliError::Bad(msg)) => assert!(msg.contains("unknown option")),
            other => panic!("expected Bad, got {other:?}"),
        }
    }

    #[test]
    fn help_requested() {
        match spec().parse(&strings(&["--help"])) {
            Err(CliError::Help(h)) => {
                assert!(h.contains("model preset"));
                assert!(h.contains("USAGE"));
            }
            other => panic!("expected Help, got {other:?}"),
        }
    }

    #[test]
    fn missing_value_errors() {
        assert!(matches!(
            spec().parse(&strings(&["--batch"])),
            Err(CliError::Bad(_))
        ));
    }

    #[test]
    fn count_suffixes() {
        let a = spec().parse(&strings(&["--batch", "32k"])).unwrap();
        assert_eq!(a.parse_u64("batch").unwrap(), 32_000);
    }

    #[test]
    fn count_list() {
        let s = CliSpec::new("x", "y").opt("sizes", "1,2", "sweep");
        let a = s.parse(&strings(&["--sizes", "4k, 32k ,1m"])).unwrap();
        assert_eq!(a.parse_count_list("sizes").unwrap(), vec![4000, 32_000, 1_000_000]);
    }

    #[test]
    fn required_option_enforced() {
        let s = CliSpec::new("x", "y").req("out", "output dir");
        assert!(matches!(s.parse(&[]), Err(CliError::Bad(_))));
        let a = s.parse(&strings(&["--out", "/tmp"])).unwrap();
        assert_eq!(a.get("out"), Some("/tmp"));
    }
}
