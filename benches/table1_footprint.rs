//! Table I: breakdown of system-memory components during CPU offloading.
//!
//! Regenerates the paper's table for both workload models at representative
//! workloads, and checks the formulas' structural properties (fixed 20·P
//! cost + context-linear activations).

use cxlfine::jobj;
use cxlfine::model::footprint::{Footprint, Workload};
use cxlfine::model::presets::{mistral_nemo_12b, qwen25_7b};
use cxlfine::trow;
use cxlfine::util::bench::BenchReport;
use cxlfine::util::table::Table;
use cxlfine::util::units::fmt_bytes;

fn main() {
    let mut report = BenchReport::new("table1_footprint");
    for model in [qwen25_7b(), mistral_nemo_12b()] {
        let w = Workload::new(2, 16, 4096);
        let f = Footprint::compute(&model, &w);
        let mut t = Table::new(&["component", "precision", "formula", "bytes"]).left(0).left(1).left(2);
        let p = model.params();
        t.row(trow!["model parameters", "bf16", "2*P", fmt_bytes(f.params_bf16)]);
        t.row(trow!["gradients", "bf16", "2*P", fmt_bytes(f.grads_bf16)]);
        t.row(trow![
            "checkpointed activations",
            "bf16",
            "2*(Ng*B*C*L*H)",
            fmt_bytes(f.activations_bf16)
        ]);
        t.row(trow!["model parameters", "fp32", "4*P", fmt_bytes(f.params_fp32)]);
        t.row(trow!["gradients", "fp32", "4*P", fmt_bytes(f.grads_fp32)]);
        t.row(trow!["optimizer states", "fp32", "8*P", fmt_bytes(f.optimizer_fp32)]);
        t.row(trow!["TOTAL", "", "20*P + act", fmt_bytes(f.total())]);
        // structural checks (the "formulas hold" assertion)
        assert_eq!(f.total() - f.activations_bf16, 20 * p);
        let raw = jobj! {
            "model" => model.name.as_str(),
            "params" => p,
            "total_bytes" => f.total(),
            "activations_bytes" => f.activations_bf16,
            "latency_critical_bytes" => f.latency_critical(),
        };
        report.section(&format!("{}", model.name), t, raw);
    }
    report.finish();
}
