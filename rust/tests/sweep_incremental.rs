//! Incremental sweep engine pins (the PR-9 contract): the cached path
//! (`sweep_grid_matrix` / `sweep_grid_matrix_with_ctx`) must be
//! bit-for-bit identical to the PR-8 uncached path
//! (`sweep_grid_matrix_nocache`) on a pinned engine × schedule ×
//! context grid, across thread counts {1, 4, 8}, cold and warm — plus
//! proptest memo-soundness: equal keys imply bitwise-equal values, and
//! perturbing any config dimension changes the key.

use cxlfine::mem::{EngineRef, Policy};
use cxlfine::model::footprint::Workload;
use cxlfine::model::presets::{qwen25_7b, tiny_2m};
use cxlfine::offload::evalcache::{cfg_key, topo_digest};
use cxlfine::offload::{
    schedules, sweep_grid_matrix, sweep_grid_matrix_nocache, sweep_grid_matrix_with_ctx, EvalCtx,
    RunConfig, ScheduleRef, SweepResult,
};
use cxlfine::topology::presets::{config_a, dev_tiny, with_dram_capacity};
use cxlfine::util::units::GIB;

/// The pinned grid: a DRAM-starved baseline host (so `baseline-dram`
/// OOMs, exercising the cached-error short-circuit), a CXL-rich policy
/// host, three engines, two schedules, short and long contexts.
struct PinnedGrid {
    base: cxlfine::topology::SystemTopology,
    cxl: cxlfine::topology::SystemTopology,
    policies: Vec<EngineRef>,
    scheds: Vec<ScheduleRef>,
    contexts: Vec<usize>,
    batches: Vec<usize>,
}

fn pinned_grid() -> PinnedGrid {
    PinnedGrid {
        base: with_dram_capacity(config_a(), 8 * GIB),
        cxl: with_dram_capacity(config_a(), 128 * GIB),
        policies: vec![
            EngineRef::from(Policy::DramOnly),
            EngineRef::from(Policy::NaiveInterleave),
            EngineRef::from(Policy::CxlAware { striping: false }),
        ],
        scheds: vec![
            schedules::by_name("zero-offload").unwrap(),
            schedules::by_name("lora").unwrap(),
        ],
        contexts: vec![4096, 16384],
        batches: vec![2, 8],
    }
}

fn run_nocache(g: &PinnedGrid, nthreads: usize) -> SweepResult {
    sweep_grid_matrix_nocache(
        &g.base,
        &g.cxl,
        &qwen25_7b(),
        1,
        &g.contexts,
        &g.batches,
        &g.policies,
        &g.scheds,
        nthreads,
    )
}

fn run_cached(g: &PinnedGrid, nthreads: usize) -> SweepResult {
    sweep_grid_matrix(
        &g.base,
        &g.cxl,
        &qwen25_7b(),
        1,
        &g.contexts,
        &g.batches,
        &g.policies,
        &g.scheds,
        nthreads,
    )
}

fn run_with_ctx(g: &PinnedGrid, ctx: &EvalCtx, nthreads: usize) -> SweepResult {
    sweep_grid_matrix_with_ctx(
        ctx,
        &g.base,
        &g.cxl,
        &qwen25_7b(),
        1,
        &g.contexts,
        &g.batches,
        &g.policies,
        &g.scheds,
        nthreads,
    )
}

/// Field-by-field bitwise comparison — stricter than `digest()` equality
/// in that a digest collision cannot mask a drift, and failures name the
/// exact cell and column.
fn assert_bits_equal(a: &SweepResult, b: &SweepResult, what: &str) {
    assert_eq!(a.digest(), b.digest(), "{what}: digests differ");
    assert_eq!(a.policies, b.policies, "{what}: column labels differ");
    assert_eq!(a.points.len(), b.points.len(), "{what}: grid size differs");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        let cell = format!("{what}: cell (C={}, B={})", pa.context, pa.batch);
        assert_eq!(pa.context, pb.context, "{cell}: context");
        assert_eq!(pa.batch, pb.batch, "{cell}: batch");
        assert_eq!(pa.oom, pb.oom, "{cell}: OOM reasons");
        assert_eq!(pa.runs.len(), pb.runs.len(), "{cell}: column count");
        for (i, (ra, rb)) in pa.runs.iter().zip(&pb.runs).enumerate() {
            match (ra, rb) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.fwd_s.to_bits(), y.fwd_s.to_bits(), "{cell} col {i}: fwd_s");
                    assert_eq!(x.bwd_s.to_bits(), y.bwd_s.to_bits(), "{cell} col {i}: bwd_s");
                    assert_eq!(x.step_s.to_bits(), y.step_s.to_bits(), "{cell} col {i}: step_s");
                    assert_eq!(x.iter_s.to_bits(), y.iter_s.to_bits(), "{cell} col {i}: iter_s");
                    assert_eq!(x.tokens, y.tokens, "{cell} col {i}: tokens");
                }
                _ => panic!("{cell} col {i}: ran on one path but not the other"),
            }
        }
    }
}

#[test]
fn cached_sweep_matches_the_uncached_path_across_thread_counts() {
    let g = pinned_grid();
    let oracle = run_nocache(&g, 1);

    // The pinned grid must actually exercise both branches: OOM cells
    // (starved baseline at long context) and completed DES runs.
    let n_oom: usize = oracle
        .points
        .iter()
        .flat_map(|p| &p.oom)
        .filter(|o| o.is_some())
        .count();
    let n_ran: usize = oracle
        .points
        .iter()
        .flat_map(|p| &p.runs)
        .filter(|r| r.is_some())
        .count();
    assert!(n_oom > 0, "pinned grid must contain OOM cells");
    assert!(n_ran > 0, "pinned grid must contain completed cells");

    for nthreads in [1usize, 4, 8] {
        let cached = run_cached(&g, nthreads);
        assert_bits_equal(&oracle, &cached, &format!("cold cache, {nthreads} threads"));
    }
}

#[test]
fn warm_resweeps_are_bitwise_identical_and_compute_nothing() {
    let g = pinned_grid();
    let ctx = EvalCtx::new();
    let cold = run_with_ctx(&g, &ctx, 4);
    let after_cold = ctx.stats();
    assert_eq!(after_cold.exec_hits, 0, "a cold context cannot hit");
    assert!(after_cold.exec_misses > 0, "cold sweep must run the DES");

    for nthreads in [1usize, 4, 8] {
        let warm = run_with_ctx(&g, &ctx, nthreads);
        assert_bits_equal(&cold, &warm, &format!("warm re-sweep, {nthreads} threads"));
    }
    let after_warm = ctx.stats();
    assert_eq!(
        after_warm.misses(),
        after_cold.misses(),
        "warm re-sweeps must be pure memo traffic: no new probe, plan, \
         schedule, or DES work"
    );
    assert!(after_warm.exec_hits > 0 && after_warm.plan_hits > 0);
}

#[test]
fn warm_resweep_matches_the_uncached_oracle_exactly() {
    // Transitivity check done explicitly: legacy == cold == warm, so a
    // stale cache entry can never leak into results.
    let g = pinned_grid();
    let oracle = run_nocache(&g, 4);
    let ctx = EvalCtx::new();
    let _cold = run_with_ctx(&g, &ctx, 4);
    let warm = run_with_ctx(&g, &ctx, 1);
    assert_bits_equal(&oracle, &warm, "warm vs uncached oracle");
}

/// Memo-soundness properties, randomized over config dimensions.
mod memo_soundness {
    use super::*;
    use cxlfine::util::memo::Memo;
    use cxlfine::util::proptest_lite::*;

    fn cfg_from(dims: &[u64; 5]) -> RunConfig {
        let mut model = tiny_2m();
        model.layers = 1 + (dims[0] as usize % 4);
        let w = Workload::new(
            1 + (dims[1] as usize % 2),
            1 + (dims[2] as usize % 4),
            256 * (1 + dims[3] as usize % 4),
        );
        let mut cfg = RunConfig::new(model, w, Policy::DramOnly);
        cfg.prefetch_depth = 1 + (dims[4] as usize % 3);
        cfg
    }

    fn dims_gen() -> VecOf<U64Range> {
        VecOf {
            inner: U64Range { lo: 0, hi: 1 << 32 },
            min_len: 5,
            max_len: 5,
        }
    }

    #[test]
    fn equal_dimensions_hash_equal_and_engines_are_excluded() {
        let gen = dims_gen();
        forall("cfg-key-equal", 0x5eed, 32, &gen, |dims| {
            let d: [u64; 5] = [dims[0], dims[1], dims[2], dims[3], dims[4]];
            let a = cfg_from(&d);
            // Same dimensions, different engine object: the key must not
            // see the engine (it keys the plan memo separately).
            let mut b = cfg_from(&d);
            b.engine = EngineRef::from(Policy::NaiveInterleave);
            if cfg_key(&a) != cfg_key(&b) {
                return Err("equal dimensions must produce equal keys".into());
            }
            Ok(())
        });
    }

    #[test]
    fn perturbing_any_dimension_changes_the_key() {
        let gen = PairOf(dims_gen(), UsizeRange { lo: 0, hi: 4 });
        forall("cfg-key-separates", 0xd1ff, 48, &gen, |(dims, which)| {
            let d: [u64; 5] = [dims[0], dims[1], dims[2], dims[3], dims[4]];
            let a = cfg_from(&d);
            let mut d2 = d;
            // Every dimension feeds cfg_from through `1 + d % m`, and
            // `(d + 1) % m != d % m` for every m >= 2, so a +1 bump is
            // guaranteed to change exactly that config dimension.
            d2[*which] += 1;
            let b = cfg_from(&d2);
            if cfg_key(&a) == cfg_key(&b) {
                return Err(format!("dimension {which} perturbed but key unchanged"));
            }
            Ok(())
        });
    }

    #[test]
    fn equal_keys_yield_bitwise_equal_values() {
        // Run the same cell through a fresh context and through a shared
        // (already warm) one, across random small workloads: equal memo
        // keys must reproduce the cold value bit-for-bit.
        let topo = dev_tiny();
        let topo_d = topo_digest(&topo);
        let scheds = vec![schedules::by_name("zero-offload").unwrap()];
        let engine = EngineRef::from(Policy::CxlAware { striping: false });
        let shared = EvalCtx::new();
        let gen = PairOf(UsizeRange { lo: 1, hi: 4 }, UsizeRange { lo: 1, hi: 4 });
        forall("memo-value-stable", 0xcafe, 8, &gen, |(batch, ctx_step)| {
            let w = Workload::new(1, *batch, 256 * *ctx_step);
            let model = tiny_2m();
            let fresh = EvalCtx::new();
            let (cold, cold_oom) =
                fresh.eval_engine_cell(&topo, topo_d, &model, w, &engine, &scheds);
            // First visit seeds the shared memo; later proptest cases
            // that collide on the key replay it and must match `cold`.
            let (warm, warm_oom) =
                shared.eval_engine_cell(&topo, topo_d, &model, w, &engine, &scheds);
            if cold_oom != warm_oom {
                return Err("OOM outcome must not depend on cache state".into());
            }
            for (a, b) in cold.iter().zip(&warm) {
                match (a, b) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        if x.iter_s.to_bits() != y.iter_s.to_bits()
                            || x.fwd_s.to_bits() != y.fwd_s.to_bits()
                            || x.bwd_s.to_bits() != y.bwd_s.to_bits()
                            || x.step_s.to_bits() != y.step_s.to_bits()
                            || x.tokens != y.tokens
                        {
                            return Err("memoized value drifted from cold value".into());
                        }
                    }
                    _ => return Err("ran on one path but not the other".into()),
                }
            }
            Ok(())
        });
        // Re-visiting a cell must replay it from the memo without any
        // recomputation (the random cases above may or may not collide).
        let w = Workload::new(1, 2, 512);
        let model = tiny_2m();
        shared.eval_engine_cell(&topo, topo_d, &model, w, &engine, &scheds);
        let before = shared.stats();
        shared.eval_engine_cell(&topo, topo_d, &model, w, &engine, &scheds);
        let after = shared.stats();
        assert!(after.exec_hits > before.exec_hits, "revisit must hit the exec memo");
        assert_eq!(after.misses(), before.misses(), "revisit must not compute");
    }

    #[test]
    fn memo_round_trips_are_bitwise_stable() {
        // The Memo container itself: get after insert returns the exact
        // bits that went in, and counters see through it.
        let gen = PairOf(
            U64Range { lo: 0, hi: u64::MAX - 1 },
            U64Range { lo: 1, hi: 1 << 62 },
        );
        forall("memo-roundtrip", 0xbeef, 64, &gen, |(k, v)| {
            let mut memo: Memo<u64, f64> = Memo::new();
            let val = f64::from_bits(*v);
            if memo.get(k).is_some() {
                return Err("empty memo must miss".into());
            }
            memo.insert(*k, val);
            match memo.get(k) {
                Some(got) if got.to_bits() == val.to_bits() => {}
                _ => return Err("round-trip lost bits".into()),
            }
            if memo.hits() != 1 || memo.misses() != 1 {
                return Err(format!("counters off: {}h {}m", memo.hits(), memo.misses()));
            }
            Ok(())
        });
    }
}
