//! Hardware topology model.
//!
//! Encodes the experimental platform of Table II (and variants) as data:
//! CPU, local-DRAM NUMA node, CXL Type-3 AICs (CPU-less NUMA nodes behind
//! PCIe Gen5 links), GPUs (each on its own PCIe link), and the calibration
//! constants of DESIGN.md §6. The simulator (`sim/`), allocator (`mem/`)
//! and workflow engine (`offload/`) all consume this description — nothing
//! downstream hard-codes hardware numbers.

pub mod presets;

use crate::util::units::{GB, GIB};

/// Identifier of a memory node (NUMA node). Node 0 is always local DRAM.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// Identifier of a PCIe link.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LinkId(pub usize);

/// Identifier of a GPU.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GpuId(pub usize);

/// Kind of memory node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemKind {
    /// CPU-attached DDR DIMMs (via the integrated memory controllers).
    LocalDram,
    /// CXL Type-3 add-in card: CPU-less NUMA node behind a PCIe link.
    CxlAic,
}

/// A PCIe link (one device's connection to the host root complex).
#[derive(Clone, Debug)]
pub struct LinkSpec {
    pub name: String,
    /// Theoretical per-direction bandwidth in bytes/s (Gen5 ×16 = 64 GB/s).
    pub per_dir_bw: f64,
    /// Fraction of theoretical achievable by a single DMA stream
    /// (protocol + packetization overhead). ~0.85 for Gen5.
    pub single_stream_eff: f64,
    /// Efficiency multiplier when `n ≥ 2` concurrent DMA streams share the
    /// link *through a CXL memory controller*. The paper measures the
    /// aggregate collapsing to ~25 GiB/s (Fig. 6b) — far below both the
    /// link rate and 2× the single-stream rate — because competing
    /// requests defeat the device-side prefetch/scheduling. 1.0 for plain
    /// GPU links (the root complex arbitrates cleanly).
    pub contended_eff: f64,
}

impl LinkSpec {
    /// Effective capacity of one direction given `n` concurrent flows.
    pub fn capacity(&self, n_flows: usize) -> f64 {
        if n_flows <= 1 {
            self.per_dir_bw * self.single_stream_eff
        } else {
            self.per_dir_bw * self.contended_eff
        }
    }

    pub fn pcie_gen5_x16(name: &str) -> Self {
        Self {
            name: name.to_string(),
            per_dir_bw: 64.0 * GB as f64,
            single_stream_eff: 0.85,
            // Plain PCIe links keep their efficiency under concurrency —
            // the root complex arbitrates streams cleanly.
            contended_eff: 0.85,
        }
    }
}

/// A memory node (local DRAM or one CXL AIC).
#[derive(Clone, Debug)]
pub struct MemNodeSpec {
    pub name: String,
    pub kind: MemKind,
    pub capacity: u64,
    /// Load-to-use latency in ns (Fig. 4: DRAM 80–140, CXL 170–250).
    pub latency_ns: f64,
    /// Peak sequential bandwidth of the medium itself, bytes/s.
    pub peak_bw: f64,
    /// Sustained bandwidth for CPU read-modify-write streams (the optimizer
    /// access class). Real CXL AICs deliver far less to CPU loads/stores
    /// than to DMA engines: the CXL.mem round trip limits per-core MLP.
    pub cpu_stream_bw: f64,
    /// PCIe link this node sits behind (None for local DRAM).
    pub link: Option<LinkId>,
}

/// GPU compute + connectivity description. Absolute speed only affects the
/// FWD/BWD : STEP ratio; the reproduction targets relative shapes.
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: String,
    /// Dense bf16 throughput, FLOP/s (H100 PCIe ≈ 756e12 with sparsity off).
    pub bf16_flops: f64,
    /// Model FLOPs utilization achieved during fine-tuning (≈ 0.35–0.45).
    pub mfu: f64,
    pub hbm_bytes: u64,
    pub link: LinkId,
}

impl GpuSpec {
    /// Effective training FLOP/s.
    pub fn effective_flops(&self) -> f64 {
        self.bf16_flops * self.mfu
    }
}

/// Host CPU description (optimizer-step compute model).
#[derive(Clone, Debug)]
pub struct CpuSpec {
    pub name: String,
    pub cores: usize,
    /// Last-level cache size in bytes (knee position of Fig. 5).
    pub llc_bytes: u64,
    /// Optimizer compute floor: ns per Adam element when the working set is
    /// cache-resident (vectorized fp32 update, all cores). Calibrated so
    /// small-N DRAM and CXL coincide (Fig. 5 left region).
    pub adam_compute_ns_per_elem: f64,
    /// Threads the offload engine uses for the optimizer step.
    pub optimizer_threads: usize,
}

/// The whole machine.
#[derive(Clone, Debug)]
pub struct SystemTopology {
    pub name: String,
    pub cpu: CpuSpec,
    pub mem_nodes: Vec<MemNodeSpec>,
    pub links: Vec<LinkSpec>,
    pub gpus: Vec<GpuSpec>,
}

impl SystemTopology {
    pub fn dram(&self) -> &MemNodeSpec {
        &self.mem_nodes[0]
    }

    pub fn node(&self, id: NodeId) -> &MemNodeSpec {
        &self.mem_nodes[id.0]
    }

    pub fn link(&self, id: LinkId) -> &LinkSpec {
        &self.links[id.0]
    }

    pub fn gpu(&self, id: GpuId) -> &GpuSpec {
        &self.gpus[id.0]
    }

    /// NodeIds of all CXL AICs.
    pub fn cxl_nodes(&self) -> Vec<NodeId> {
        self.mem_nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.kind == MemKind::CxlAic)
            .map(|(i, _)| NodeId(i))
            .collect()
    }

    /// All memory node ids (DRAM first).
    pub fn all_nodes(&self) -> Vec<NodeId> {
        (0..self.mem_nodes.len()).map(NodeId).collect()
    }

    /// Total system memory (DRAM + all AICs).
    pub fn total_mem(&self) -> u64 {
        self.mem_nodes.iter().map(|n| n.capacity).sum()
    }

    /// Aggregate bandwidth available for bulk page migration between DRAM
    /// and the CXL tier: the sum of the single-flow link capacities of
    /// every *online* AIC (offline nodes — capacity zeroed by a degraded
    /// view — contribute nothing), with the DRAM stream bandwidth as the
    /// floor when every AIC is gone. Shared by the fleet's fault-recovery
    /// evacuations and the serving KV pager's promotion/demotion costing,
    /// so both price traffic through the same degraded-topology views.
    pub fn migration_bandwidth(&self) -> f64 {
        let mut bw = 0.0;
        for n in self.cxl_nodes() {
            if self.node(n).capacity > 0 {
                if let Some(l) = self.node(n).link {
                    bw += self.link(l).capacity(1);
                }
            }
        }
        if bw > 0.0 {
            bw
        } else {
            self.dram().peak_bw
        }
    }

    /// Consistency checks; panics on violation (used by tests and presets).
    pub fn validate(&self) {
        assert!(!self.mem_nodes.is_empty(), "need at least local DRAM");
        assert_eq!(
            self.mem_nodes[0].kind,
            MemKind::LocalDram,
            "node 0 must be local DRAM"
        );
        for (i, n) in self.mem_nodes.iter().enumerate() {
            assert!(n.capacity > 0, "node {i} has zero capacity");
            assert!(n.latency_ns > 0.0 && n.peak_bw > 0.0 && n.cpu_stream_bw > 0.0);
            match n.kind {
                MemKind::LocalDram => assert!(n.link.is_none(), "DRAM has no PCIe link"),
                MemKind::CxlAic => {
                    let l = n.link.expect("CXL node must sit behind a link");
                    assert!(l.0 < self.links.len(), "dangling link id on node {i}");
                }
            }
        }
        for (i, g) in self.gpus.iter().enumerate() {
            assert!(g.link.0 < self.links.len(), "dangling link id on gpu {i}");
            assert!(g.bf16_flops > 0.0 && g.mfu > 0.0 && g.mfu <= 1.0);
        }
        // No two devices share a link in these topologies (each GPU/AIC has
        // its own ×16 slot, per Table II).
        let mut used = std::collections::HashSet::new();
        for n in &self.mem_nodes {
            if let Some(l) = n.link {
                assert!(used.insert(l.0), "link {} assigned twice", l.0);
            }
        }
        for g in &self.gpus {
            assert!(used.insert(g.link.0), "link {} assigned twice", g.link.0);
        }
        assert!(self.cpu.cores > 0 && self.cpu.optimizer_threads > 0);
        assert!(self.cpu.llc_bytes > 0);
    }

    /// Human-readable summary (used by `cxlfine topo`).
    pub fn describe(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(s, "topology: {}", self.name);
        let _ = writeln!(
            s,
            "  cpu: {} ({} cores, LLC {})",
            self.cpu.name,
            self.cpu.cores,
            crate::util::units::fmt_bytes(self.cpu.llc_bytes)
        );
        for (i, n) in self.mem_nodes.iter().enumerate() {
            let _ = writeln!(
                s,
                "  mem[{i}] {}: {:?} {} lat={}ns peak={:.0}GB/s cpu-stream={:.0}GB/s",
                n.name,
                n.kind,
                crate::util::units::fmt_bytes(n.capacity),
                n.latency_ns,
                n.peak_bw / GB as f64,
                n.cpu_stream_bw / GB as f64,
            );
        }
        for (i, g) in self.gpus.iter().enumerate() {
            let _ = writeln!(
                s,
                "  gpu[{i}] {}: {:.0} TFLOP/s bf16 × {:.2} MFU, HBM {}",
                g.name,
                g.bf16_flops / 1e12,
                g.mfu,
                crate::util::units::fmt_bytes(g.hbm_bytes)
            );
        }
        let _ = writeln!(s, "  total memory: {}", crate::util::units::fmt_bytes(self.total_mem()));
        s
    }
}

/// Calibration sanity range checks used by tests (Fig. 4 constants).
pub const DRAM_LATENCY_RANGE_NS: (f64, f64) = (80.0, 140.0);
pub const CXL_LATENCY_RANGE_NS: (f64, f64) = (170.0, 250.0);

#[allow(unused)]
fn _unit_refs() {
    let _ = GIB;
}

#[cfg(test)]
mod tests {
    use super::presets::*;
    use super::*;

    #[test]
    fn config_a_matches_table_ii() {
        let t = config_a();
        t.validate();
        assert_eq!(t.gpus.len(), 2);
        assert_eq!(t.cxl_nodes().len(), 1);
        assert_eq!(t.node(t.cxl_nodes()[0]).capacity, 512 * GIB);
        assert_eq!(t.dram().capacity, 512 * GIB);
    }

    #[test]
    fn config_b_has_two_aics() {
        let t = config_b();
        t.validate();
        let cxl = t.cxl_nodes();
        assert_eq!(cxl.len(), 2);
        for id in cxl {
            assert_eq!(t.node(id).capacity, 256 * GIB);
        }
    }

    #[test]
    fn latencies_within_fig4_ranges() {
        for t in [config_a(), config_b()] {
            let d = t.dram().latency_ns;
            assert!(
                (DRAM_LATENCY_RANGE_NS.0..=DRAM_LATENCY_RANGE_NS.1).contains(&d),
                "dram latency {d}"
            );
            for id in t.cxl_nodes() {
                let c = t.node(id).latency_ns;
                assert!(
                    (CXL_LATENCY_RANGE_NS.0..=CXL_LATENCY_RANGE_NS.1).contains(&c),
                    "cxl latency {c}"
                );
            }
        }
    }

    #[test]
    fn cxl_latency_exceeds_dram() {
        let t = config_a();
        for id in t.cxl_nodes() {
            assert!(t.node(id).latency_ns > t.dram().latency_ns);
        }
    }

    #[test]
    fn link_capacity_contention_shape() {
        let t = config_a();
        let aic_link = t.node(t.cxl_nodes()[0]).link.unwrap();
        let l = t.link(aic_link);
        // Single stream beats contended aggregate (the Fig. 6b anomaly).
        assert!(l.capacity(1) > l.capacity(2));
        // Contended aggregate lands near the paper's ~25 GiB/s.
        let gib = (1u64 << 30) as f64;
        let agg = l.capacity(2) / gib;
        assert!((20.0..32.0).contains(&agg), "contended aggregate {agg} GiB/s");
    }

    #[test]
    fn gpu_links_do_not_degrade_under_contention() {
        let t = config_a();
        let l = t.link(t.gpu(GpuId(0)).link);
        assert_eq!(l.capacity(1), l.capacity(2));
        assert_eq!(l.capacity(1), l.capacity(4));
    }

    #[test]
    fn describe_mentions_everything() {
        let d = config_a().describe();
        assert!(d.contains("mem[0]"));
        assert!(d.contains("gpu[1]"));
        assert!(d.contains("total memory"));
    }

    #[test]
    #[should_panic(expected = "node 0 must be local DRAM")]
    fn validate_rejects_cxl_first() {
        let mut t = config_a();
        t.mem_nodes.swap(0, 1);
        t.validate();
    }

    #[test]
    fn dual_gpu_dev_preset_validates() {
        let t = dev_tiny();
        t.validate();
        assert!(t.total_mem() > 0);
    }
}
