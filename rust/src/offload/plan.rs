//! Memory plan: allocate every Table-I region for a fine-tuning run under a
//! chosen placement policy. The plan is what the iteration simulator and
//! the functional trainer both consume — placement decisions are made once,
//! here, exactly like the real system pins its arenas at startup.
//!
//! Since the tensor-lifetime IR landed, the plan closes the loop between
//! the schedule and the memory subsystem: when the engine asks for
//! profiles (`uses_profiles`) or the caller wants timeline accounting
//! ([`MemoryPlan::build_lifetime_aware`]), the builder first *profiles*
//! the run — it builds the schedule against a throwaway unconstrained
//! all-DRAM probe plan (profiles are placement-independent, so the probe
//! is exact; pinned by tests below), walks it with
//! [`crate::mem::profile_schedule`], and then threads each region's
//! measured [`AccessProfile`] through
//! [`crate::mem::PlacementEngine::place_profiled`] and its liveness
//! window into the allocator's per-phase timeline.

use std::collections::BTreeMap;

use super::schedules::{self, ScheduleRef};
use crate::mem::{
    profile_schedule, AccessProfile, EngineRef, NumaAllocator, Policy, RegionId, RegionRequest,
    TensorClass,
};
use crate::model::footprint::{Footprint, Workload};
use crate::model::ModelConfig;
use crate::sim::memmodel::{AccessMode, OptLayout};
use crate::topology::presets::with_dram_capacity;
use crate::topology::{GpuId, NodeId, SystemTopology};

/// Everything needed to run (or simulate) one fine-tuning configuration.
/// Placement goes through a pluggable [`crate::mem::PlacementEngine`];
/// `RunConfig::new` accepts anything convertible (a legacy
/// [`crate::mem::Policy`], [`crate::mem::AdaptiveSpill`], or an existing
/// [`EngineRef`]). The iteration *schedule* is pluggable the same way: a
/// [`ScheduleRef`] resolved from the `offload::schedules` registry
/// (default: the paper's `zero-offload` workflow).
#[derive(Clone)]
pub struct RunConfig {
    pub model: ModelConfig,
    pub workload: Workload,
    pub engine: EngineRef,
    /// Blocks of parameters prefetched ahead of compute (ZeRO-Offload
    /// overlaps the next block's H2D copy with the current block's kernel).
    pub prefetch_depth: usize,
    /// The fine-tuning scenario simulated for this run.
    pub schedule: ScheduleRef,
}

impl RunConfig {
    pub fn new(model: ModelConfig, workload: Workload, engine: impl Into<EngineRef>) -> Self {
        Self {
            model,
            workload,
            engine: engine.into(),
            prefetch_depth: 2,
            schedule: schedules::zero_offload(),
        }
    }

    /// Builder-style schedule override.
    pub fn with_schedule(mut self, schedule: ScheduleRef) -> Self {
        self.schedule = schedule;
        self
    }
}

impl std::fmt::Debug for RunConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RunConfig")
            .field("model", &self.model.name)
            .field("workload", &self.workload)
            .field("engine", &self.engine.name())
            .field("prefetch_depth", &self.prefetch_depth)
            .field("schedule", &self.schedule.name())
            .finish()
    }
}

/// Measured access profiles of one run, keyed by region name (names are
/// stable across plans of the same config, region ids need not be).
#[derive(Clone, Debug, Default)]
pub struct RunProfiles {
    /// Schedule phase names — the index space of every profile lifetime.
    pub phases: Vec<String>,
    pub by_name: BTreeMap<String, AccessProfile>,
}

impl RunProfiles {
    pub fn n_phases(&self) -> usize {
        self.phases.len()
    }

    pub fn get(&self, name: &str) -> Option<&AccessProfile> {
        self.by_name.get(name)
    }
}

/// The committed regions of one run.
pub struct MemoryPlan<'t> {
    pub alloc: NumaAllocator<'t>,
    pub footprint: Footprint,
    pub master: RegionId,
    pub grads32: RegionId,
    pub optstates: RegionId,
    pub params16: RegionId,
    pub grads16: RegionId,
    /// One checkpointed-activation region per GPU.
    pub activations: Vec<RegionId>,
    /// The measured profiles placement was driven by, when the builder
    /// computed them (profile-consuming engine or lifetime accounting);
    /// `None` on the plain static path.
    pub profiles: Option<RunProfiles>,
}

/// Why a plan could not be built.
#[derive(Debug, Clone)]
pub struct PlanError {
    pub message: String,
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}
impl std::error::Error for PlanError {}

impl<'t> MemoryPlan<'t> {
    /// Allocate all regions under static (whole-run) capacity accounting.
    /// Latency-critical regions are requested first so the CXL-aware
    /// policy reserves DRAM for them before bulk data arrives (the real
    /// allocator pins arenas in the same order) — and, for the
    /// profile-aware engine, hot-first admission is the static analogue of
    /// evict-by-coldness: whenever DRAM is contended, the coldest bytes
    /// are the ones that end up on CXL.
    pub fn build(
        topo: &'t SystemTopology,
        cfg: &RunConfig,
    ) -> Result<MemoryPlan<'t>, PlanError> {
        Self::build_inner(topo, cfg, false)
    }

    /// [`MemoryPlan::build`] with lifetime-aware timeline accounting: each
    /// region is committed only over its measured liveness window, so the
    /// fit check is per-phase *peak* occupancy per node instead of the
    /// static sum — activations dead during the optimizer step no longer
    /// count against it, which fits cells that [`MemoryPlan::build`]
    /// rejects as OOM.
    pub fn build_lifetime_aware(
        topo: &'t SystemTopology,
        cfg: &RunConfig,
    ) -> Result<MemoryPlan<'t>, PlanError> {
        Self::build_inner(topo, cfg, true)
    }

    /// [`MemoryPlan::build`] / [`MemoryPlan::build_lifetime_aware`] with a
    /// pre-computed profile set, skipping the probe pass entirely.
    /// Profiles are placement-independent (pinned by
    /// `profiles_are_placement_independent`), so a cached set measured on
    /// any capacity variant of the same machine is exact here — this is
    /// what lets a long-lived fleet host admission-check hundreds of jobs
    /// against constrained topology *views* (whose zero-capacity nodes the
    /// probe clone could not even validate) at allocation cost only.
    pub fn build_with_profiles(
        topo: &'t SystemTopology,
        cfg: &RunConfig,
        lifetime_aware: bool,
        profiles: RunProfiles,
    ) -> Result<MemoryPlan<'t>, PlanError> {
        Self::build_inner_with(topo, cfg, lifetime_aware, Some(profiles))
    }

    fn build_inner(
        topo: &'t SystemTopology,
        cfg: &RunConfig,
        lifetime_aware: bool,
    ) -> Result<MemoryPlan<'t>, PlanError> {
        Self::build_inner_with(topo, cfg, lifetime_aware, None)
    }

    fn build_inner_with(
        topo: &'t SystemTopology,
        cfg: &RunConfig,
        lifetime_aware: bool,
        precomputed: Option<RunProfiles>,
    ) -> Result<MemoryPlan<'t>, PlanError> {
        let f = Footprint::compute(&cfg.model, &cfg.workload);
        // The profiling pass costs a probe plan + schedule walk; only pay
        // for it when something consumes the result and the caller did not
        // bring a cached set (this also keeps the legacy engines' static
        // path work-identical, not just byte-identical).
        let profiles = match precomputed {
            Some(p) => Some(p),
            None if lifetime_aware || cfg.engine.uses_profiles() => {
                Some(Self::profile_run(topo, cfg)?)
            }
            None => None,
        };
        let n_phases = profiles.as_ref().map(|p| p.n_phases()).unwrap_or(1);
        let mut alloc = if lifetime_aware {
            NumaAllocator::with_phases(topo, cfg.engine.clone(), n_phases)
        } else {
            NumaAllocator::new(topo, cfg.engine.clone())
        };
        let mut get = |req: RegionRequest| {
            let prof = profiles.as_ref().and_then(|p| p.get(&req.name));
            let req = match prof {
                Some(p) if lifetime_aware => req.with_lifetime(p.lifetime),
                _ => req,
            };
            alloc.alloc_profiled(req, prof).map_err(|e| PlanError {
                message: format!("{} (policy {})", e, cfg.engine.name()),
            })
        };
        let master = get(RegionRequest::new(
            "master-params",
            TensorClass::MasterParams,
            f.params_fp32,
        ))?;
        let grads32 = get(RegionRequest::new(
            "grads-fp32",
            TensorClass::Gradients32,
            f.grads_fp32,
        ))?;
        let optstates = get(RegionRequest::new(
            "optimizer-states",
            TensorClass::OptimizerStates,
            f.optimizer_fp32,
        ))?;
        let params16 = get(RegionRequest::new(
            "params-bf16",
            TensorClass::Params16,
            f.params_bf16,
        ))?;
        let grads16 = get(RegionRequest::new(
            "grads-bf16",
            TensorClass::Grads16,
            f.grads_bf16,
        ))?;
        let mut activations = Vec::with_capacity(cfg.workload.n_gpus);
        for g in 0..cfg.workload.n_gpus {
            activations.push(get(RegionRequest::new(
                format!("activations-gpu{g}"),
                TensorClass::Activations,
                f.activations_per_gpu(&cfg.workload),
            )
            .for_gpu(GpuId(g)))?);
        }
        drop(get);
        let plan = MemoryPlan {
            alloc,
            footprint: f,
            master,
            grads32,
            optstates,
            params16,
            grads16,
            activations,
            profiles,
        };
        // Post-build verification gate (DESIGN.md §12): placement
        // integrity and per-phase fit re-checked as diagnostics. An error
        // here means allocator accounting was corrupted — fail the build
        // with the diagnostic rendered rather than hand out a bad plan.
        let diags = crate::analysis::lint_plan(&plan);
        if let Some(d) = diags.first_error() {
            return Err(PlanError {
                message: format!("plan failed static lint: {}", d.render()),
            });
        }
        Ok(plan)
    }

    /// Compute the run's per-region [`AccessProfile`]s *before* placement.
    ///
    /// Chicken-and-egg: the schedule builder needs a plan (for byte counts
    /// and stripe fractions), but placement wants the profiles. The knot is
    /// cut by profiling against a **probe**: the same config planned with
    /// `baseline-dram` on an unconstrained-DRAM clone of the topology.
    /// Every profiled quantity (bytes, element counts, phase windows,
    /// touch counts) comes from op payloads that are placement-independent
    /// — only stripe fractions differ between probe and final schedule —
    /// so the probe profiles are exact (pinned by
    /// `profiles_are_placement_independent` below).
    pub fn profile_run(topo: &SystemTopology, cfg: &RunConfig) -> Result<RunProfiles, PlanError> {
        // Big enough that any Table-I footprint fits in DRAM alone; small
        // enough that node-capacity sums stay far from u64 overflow.
        const PROBE_DRAM: u64 = 1 << 61;
        let probe_topo = with_dram_capacity(topo.clone(), PROBE_DRAM);
        let probe_cfg = RunConfig {
            engine: Policy::DramOnly.into(),
            ..cfg.clone()
        };
        let probe_plan = MemoryPlan::build(&probe_topo, &probe_cfg)?;
        let sched = cfg.schedule.build(&probe_topo, &probe_cfg, &probe_plan);
        // Static verification gate: the probe plan gives the linter full
        // region context, so a builder with structural defects or dangling
        // touch annotations (P007) fails here with a rendered diagnostic
        // instead of panicking mid-profiling.
        let ctx = crate::analysis::ScheduleLintContext::from_plan(&probe_plan);
        let diags = crate::analysis::lint_schedule(&sched, &probe_topo, Some(&ctx));
        if let Some(d) = diags.first_error() {
            return Err(PlanError {
                message: format!(
                    "schedule '{}' failed static lint: {}",
                    cfg.schedule.name(),
                    d.render()
                ),
            });
        }
        let sp = profile_schedule(&sched);
        let mut by_name = BTreeMap::new();
        for (rid, prof) in sp.by_region {
            let name = probe_plan
                .alloc
                .region(rid)
                .expect("lint guarantees touches reference plan regions")
                .name
                .clone();
            by_name.insert(name, prof);
        }
        Ok(RunProfiles {
            phases: sp.phases,
            by_name,
        })
    }

    /// Does this configuration fit at all (used by capacity sweeps)?
    pub fn fits(topo: &SystemTopology, cfg: &RunConfig) -> bool {
        MemoryPlan::build(topo, cfg).is_ok()
    }

    /// [`MemoryPlan::fits`] under lifetime-aware timeline accounting.
    pub fn fits_lifetime_aware(topo: &SystemTopology, cfg: &RunConfig) -> bool {
        MemoryPlan::build_lifetime_aware(topo, cfg).is_ok()
    }

    /// Merged placement of the optimizer's working set (fp32 P, G, O) as an
    /// [`OptLayout`] for the STEP timing model.
    pub fn opt_layout(&self) -> OptLayout {
        let regions = [self.master, self.grads32, self.optstates];
        let mut per_node: std::collections::BTreeMap<usize, u64> = Default::default();
        let mut mode = AccessMode::Partitioned;
        for id in regions {
            let r = self.alloc.region(id).expect("plan region");
            if r.placement.mode == AccessMode::Interleaved {
                mode = AccessMode::Interleaved;
            }
            for (n, b) in &r.placement.parts {
                *per_node.entry(n.0).or_insert(0) += *b;
            }
        }
        let total: u64 = per_node.values().sum();
        OptLayout {
            parts: per_node
                .into_iter()
                .map(|(n, b)| (NodeId(n), b as f64 / total as f64))
                .collect(),
            mode,
        }
    }

    /// Generic stream layout of a single region (for cast/copy timing).
    pub fn region_layout(&self, id: RegionId) -> OptLayout {
        let r = self.alloc.region(id).expect("plan region");
        OptLayout {
            parts: r.placement.fractions(),
            mode: r.placement.mode,
        }
    }

    /// Host-side node fractions a GPU's parameter stream reads from.
    pub fn params16_fractions(&self) -> Vec<(NodeId, f64)> {
        self.alloc
            .region(self.params16)
            .unwrap()
            .placement
            .fractions()
    }

    /// Host-side node fractions a GPU's gradient offload writes to.
    pub fn grads16_fractions(&self) -> Vec<(NodeId, f64)> {
        self.alloc
            .region(self.grads16)
            .unwrap()
            .placement
            .fractions()
    }

    /// Host-side node fractions of one GPU's activation checkpoints.
    pub fn activation_fractions(&self, gpu: GpuId) -> Vec<(NodeId, f64)> {
        self.alloc
            .region(self.activations[gpu.0])
            .unwrap()
            .placement
            .fractions()
    }

    /// The plan's per-node byte demand (see [`PlanReservation`]): what a
    /// long-lived multi-job host debits for the job's whole residency.
    pub fn reservation(&self) -> PlanReservation {
        let parts = self
            .alloc
            .topo()
            .all_nodes()
            .into_iter()
            .filter_map(|n| {
                let used = self.alloc.used_on(n);
                (used > 0).then_some((n, used))
            })
            .collect();
        PlanReservation { parts }
    }
}

/// Per-node byte demand of a built plan, the plan → reservation handle the
/// fleet simulator commits against its long-lived host allocator. For
/// plans built with [`MemoryPlan::build`] this is the static per-node sum;
/// for [`MemoryPlan::build_lifetime_aware`] it is the per-phase *peak* per
/// node — strictly smaller whenever liveness windows do not all overlap,
/// which is exactly the capacity a lifetime-aware admission policy can
/// hand to additional tenants.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanReservation {
    /// `(node, bytes)` in ascending node order, zero-byte nodes omitted.
    pub parts: Vec<(NodeId, u64)>,
}

impl PlanReservation {
    pub fn total_bytes(&self) -> u64 {
        self.parts.iter().map(|(_, b)| *b).sum()
    }

    pub fn bytes_on(&self, node: NodeId) -> u64 {
        self.parts
            .iter()
            .find(|(n, _)| *n == node)
            .map(|(_, b)| *b)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Policy;
    use crate::model::presets::{mistral_nemo_12b, qwen25_7b, tiny_2m};
    use crate::topology::presets::{config_a, config_b, dev_tiny, with_dram_capacity};
    use crate::util::units::GIB;

    #[test]
    fn baseline_plan_all_in_dram() {
        let topo = config_a();
        let cfg = RunConfig::new(qwen25_7b(), Workload::new(1, 8, 4096), Policy::DramOnly);
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        assert_eq!(plan.alloc.used_on(NodeId(1)), 0);
        let layout = plan.opt_layout();
        assert_eq!(layout.parts, vec![(NodeId(0), 1.0)]);
    }

    #[test]
    fn paper_constrained_dram_forces_cxl_use() {
        // §V-B: 128 GiB DRAM + 512 GiB AIC. 7.6B model: fp32 PGO = 121.7 GiB
        // fits DRAM; bf16 P/G + activations land on CXL.
        let topo = with_dram_capacity(config_a(), 128 * GIB);
        let cfg = RunConfig::new(
            qwen25_7b(),
            Workload::new(1, 8, 4096),
            Policy::CxlAware { striping: false },
        );
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let layout = plan.opt_layout();
        assert_eq!(layout.parts, vec![(NodeId(0), 1.0)], "PGO stays in DRAM");
        for (_, frac) in plan.params16_fractions() {
            assert!(frac > 0.0);
        }
        let p16 = plan.params16_fractions();
        assert!(p16.iter().all(|(n, _)| n.0 != 0), "bf16 params on CXL");
    }

    #[test]
    fn naive_plan_puts_optimizer_data_on_cxl() {
        let topo = with_dram_capacity(config_a(), 128 * GIB);
        let cfg = RunConfig::new(
            qwen25_7b(),
            Workload::new(1, 8, 4096),
            Policy::NaiveInterleave,
        );
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let layout = plan.opt_layout();
        assert_eq!(layout.mode, AccessMode::Interleaved);
        assert!(
            layout.parts.iter().any(|(n, f)| n.0 == 1 && *f > 0.3),
            "naive interleave must put a large PGO share on CXL: {layout:?}"
        );
    }

    #[test]
    fn dram_only_larger_than_capacity_fails() {
        // 12B @ 32K context × 2 GPUs × batch 16 overflows 512 GB DRAM → the
        // motivation for CXL (Fig. 2/3).
        let topo = config_a();
        let cfg = RunConfig::new(
            mistral_nemo_12b(),
            Workload::new(2, 16, 32768),
            Policy::DramOnly,
        );
        assert!(!MemoryPlan::fits(&topo, &cfg));
        // ...but the CXL-aware plan fits using the AIC.
        let cfg2 = RunConfig {
            engine: Policy::CxlAware { striping: false }.into(),
            ..cfg
        };
        assert!(MemoryPlan::fits(&topo, &cfg2));
    }

    #[test]
    fn striping_spreads_activations_over_both_aics() {
        let topo = config_b();
        let cfg = RunConfig::new(
            mistral_nemo_12b(),
            Workload::new(2, 16, 4096),
            Policy::CxlAware { striping: true },
        );
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        for g in 0..2 {
            let fr = plan.activation_fractions(GpuId(g));
            assert_eq!(fr.len(), 2, "gpu{g} activations should stripe: {fr:?}");
            for (_, f) in fr {
                assert!((f - 0.5).abs() < 0.01);
            }
        }
    }

    #[test]
    fn affinity_mode_separates_gpus() {
        let topo = config_b();
        let cfg = RunConfig::new(
            qwen25_7b(),
            Workload::new(2, 8, 4096),
            Policy::CxlAware { striping: false },
        );
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let f0 = plan.activation_fractions(GpuId(0));
        let f1 = plan.activation_fractions(GpuId(1));
        assert_ne!(f0[0].0, f1[0].0, "per-GPU AIC affinity expected");
    }

    #[test]
    fn spilled_optimizer_layout_is_partitioned() {
        // dev_tiny has 8 GiB DRAM; a 2M model with huge batch won't spill,
        // so shrink DRAM instead: 12B fp32 PGO = 195 GiB > 128 GiB DRAM.
        let topo = with_dram_capacity(config_b(), 128 * GIB);
        let cfg = RunConfig::new(
            mistral_nemo_12b(),
            Workload::new(1, 1, 512),
            Policy::CxlAware { striping: true },
        );
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let layout = plan.opt_layout();
        assert_eq!(layout.mode, AccessMode::Partitioned);
        assert!(layout.parts.len() >= 2, "spill expected: {layout:?}");
        let dram_frac = layout
            .parts
            .iter()
            .find(|(n, _)| n.0 == 0)
            .map(|(_, f)| *f)
            .unwrap_or(0.0);
        assert!(dram_frac > 0.5, "most PGO still in DRAM: {dram_frac}");
    }

    #[test]
    fn tiny_plan_on_dev_machine() {
        let topo = dev_tiny();
        for policy in [
            Policy::DramOnly,
            Policy::NaiveInterleave,
            Policy::CxlAware { striping: false },
            Policy::CxlAware { striping: true },
        ] {
            let cfg = RunConfig::new(tiny_2m(), Workload::new(2, 4, 512), policy);
            let plan = MemoryPlan::build(&topo, &cfg).unwrap();
            assert_eq!(plan.activations.len(), 2);
            let total_expected = plan.footprint.total();
            assert_eq!(plan.alloc.total_used(), total_expected);
            assert!(plan.profiles.is_none(), "static legacy path must not profile");
        }
    }

    // ------------------------------------------------------------------
    // The tensor-lifetime IR: profiles, timeline accounting, and the
    // profile-aware engine through the whole plan stack.
    // ------------------------------------------------------------------

    use crate::mem::{engine, Lifetime, ProfileAware};
    use crate::offload::simulate_iteration;

    #[test]
    fn profiles_are_placement_independent() {
        // The probe plan is all-DRAM; the real plan stripes over CXL. The
        // profiles extracted from either schedule must be identical — that
        // is the contract `profile_run` rests on.
        let topo = with_dram_capacity(config_b(), 128 * GIB);
        let cfg = RunConfig::new(
            qwen25_7b(),
            Workload::new(2, 8, 4096),
            Policy::CxlAware { striping: true },
        );
        let via_probe = MemoryPlan::profile_run(&topo, &cfg).unwrap();

        let real_plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let real_sched = cfg.schedule.build(&topo, &cfg, &real_plan);
        let sp = crate::mem::profile_schedule(&real_sched);
        let mut via_real = std::collections::BTreeMap::new();
        for (rid, prof) in sp.by_region {
            let name = real_plan.alloc.region(rid).unwrap().name.clone();
            via_real.insert(name, prof);
        }
        assert_eq!(via_probe.phases, sp.phases);
        assert_eq!(via_probe.by_name, via_real);
    }

    #[test]
    fn zero_offload_profiles_match_taxonomy_and_windows() {
        let topo = config_a();
        let cfg = RunConfig::new(qwen25_7b(), Workload::new(1, 8, 4096), Policy::DramOnly);
        let prof = MemoryPlan::profile_run(&topo, &cfg).unwrap();
        let f = Footprint::compute(&cfg.model, &cfg.workload);
        assert_eq!(prof.phases, vec!["fwd", "bwd", "step"]);
        assert_eq!(prof.by_name.len(), 6, "all Table-I regions touched");

        let close = |a: f64, b: f64| (a - b).abs() <= 1e-6 * b.abs().max(1.0);

        // Optimizer working set: RMW-hot, live only during the step.
        for name in ["master-params", "grads-fp32", "optimizer-states"] {
            let p = prof.get(name).unwrap();
            assert!(p.latency_critical(), "{name} must be RMW-hot");
            assert_eq!(p.lifetime, Lifetime::spanning(2, 2), "{name}");
            assert_eq!(p.cpu_rmw_elements, cfg.model.params(), "{name}");
        }
        // bf16 params stream in twice (fwd load + bwd reload), cast once.
        let p16 = prof.get("params-bf16").unwrap();
        assert!(!p16.latency_critical());
        assert_eq!(p16.lifetime, Lifetime::spanning(0, 2));
        assert!(close(p16.h2d_bytes, 2.0 * f.params_bf16 as f64), "{}", p16.h2d_bytes);
        assert!(close(p16.cpu_stream_bytes, f.params_bf16 as f64));
        // bf16 grads offload during bwd and are kept alive through the step.
        let g16 = prof.get("grads-bf16").unwrap();
        assert_eq!(g16.lifetime, Lifetime::spanning(1, 2));
        assert!(close(g16.d2h_bytes, f.grads_bf16 as f64));
        assert_eq!(g16.h2d_bytes, 0.0);
        // Activations round-trip and die before the step — the capacity win.
        let acts = prof.get("activations-gpu0").unwrap();
        assert_eq!(acts.lifetime, Lifetime::spanning(0, 1));
        assert!(close(acts.d2h_bytes, f.activations_bf16 as f64));
        assert!(close(acts.h2d_bytes, acts.d2h_bytes));
        assert!(!acts.latency_critical());
        // The master stream (read) shows up as CPU stream traffic.
        let master = prof.get("master-params").unwrap();
        assert!(close(master.cpu_stream_bytes, f.params_fp32 as f64));
    }

    #[test]
    fn lora_profiles_shrink_the_rmw_working_set() {
        let topo = config_a();
        let cfg = RunConfig::new(qwen25_7b(), Workload::new(1, 8, 4096), Policy::DramOnly)
            .with_schedule(crate::offload::schedules::by_name("lora:16").unwrap());
        let prof = MemoryPlan::profile_run(&topo, &cfg).unwrap();
        let opt = prof.get("optimizer-states").unwrap();
        assert!(opt.latency_critical());
        assert!(
            opt.cpu_rmw_elements < cfg.model.params() / 1000,
            "adapter-only RMW must be orders of magnitude below full FT: {}",
            opt.cpu_rmw_elements
        );
    }

    #[test]
    fn executor_ledger_validates_profiles() {
        // The loop closed, for EVERY registered schedule: a schedule that
        // lints clean must have executor ledger == static profile — the
        // runtime cross-check of the registration-time verifier
        // (DESIGN.md §12).
        let topo = with_dram_capacity(config_a(), 128 * GIB);
        for sref in crate::offload::schedules::registered() {
            let sched_name = sref.name().to_string();
            let cfg = RunConfig::new(
                qwen25_7b(),
                Workload::new(1, 4, 4096),
                Policy::CxlAware { striping: false },
            )
            .with_schedule(sref);
            let prof = MemoryPlan::profile_run(&topo, &cfg).unwrap();
            let plan = MemoryPlan::build(&topo, &cfg).unwrap();
            let sched = cfg.schedule.build(&topo, &cfg, &plan);
            // Registered builders must lint clean against their own plan
            // — zero errors AND zero warnings (honest annotations).
            let ctx = crate::analysis::ScheduleLintContext::from_plan(&plan);
            let diags = crate::analysis::lint_schedule(&sched, &topo, Some(&ctx));
            assert!(
                !diags.has_errors() && !diags.has_warnings(),
                "{sched_name}: registered schedule must lint clean:\n{}",
                diags.render()
            );
            let ex = crate::offload::execute(&topo, &sched);
            let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * b.abs().max(1.0);
            let mut dma_regions = 0;
            for r in plan.alloc.regions() {
                let p = match prof.get(&r.name) {
                    Some(p) => p,
                    None => {
                        // Never-touched regions (no-act-offload keeps
                        // activations in HBM) have no profile — and must
                        // move no traffic.
                        assert!(
                            ex.region_traffic.get(&r.id).is_none(),
                            "{sched_name}/{}: unprofiled region moved traffic",
                            r.name
                        );
                        continue;
                    }
                };
                match ex.region_traffic.get(&r.id) {
                    Some(t) => {
                        dma_regions += 1;
                        assert!(
                            close(t.h2d_bytes, p.h2d_bytes) && close(t.d2h_bytes, p.d2h_bytes),
                            "{sched_name}/{}: executor moved ({}, {}) but profile says ({}, {})",
                            r.name,
                            t.h2d_bytes,
                            t.d2h_bytes,
                            p.h2d_bytes,
                            p.d2h_bytes
                        );
                        let dma_touches = p.touches
                            - u32::from(p.cpu_rmw_elements > 0)
                            - u32::from(p.cpu_stream_bytes > 0.0);
                        assert_eq!(t.touches, dma_touches, "{sched_name}/{}", r.name);
                    }
                    None => assert_eq!(
                        p.dma_bytes(),
                        0.0,
                        "{sched_name}/{}: profiled DMA but no ledger entry",
                        r.name
                    ),
                }
            }
            // no-act-offload moves only the param/grad streams; every
            // other scenario also DMAs activation checkpoints.
            let min_dma = if sched_name == "no-act-offload" { 2 } else { 3 };
            assert!(
                dma_regions >= min_dma,
                "{sched_name}: expected >= {min_dma} DMA-touched regions, got {dma_regions}"
            );
        }
    }

    /// The acceptance regression: lifetime accounting fits a (model,
    /// context, capacity) cell that static accounting rejects as OOM.
    #[test]
    fn lifetime_accounting_fits_cell_static_rejects() {
        let model = qwen25_7b();
        let w = Workload::new(1, 8, 4096);
        let f = Footprint::compute(&model, &w);
        // Per-phase peaks of the zero-offload liveness windows (DRAM-only
        // placement): activations die before the step, the fp32 working
        // set is dead until it.
        let peak_bwd = f.params_bf16 + f.grads_bf16 + f.activations_bf16;
        let peak_step =
            f.params_fp32 + f.grads_fp32 + f.optimizer_fp32 + f.params_bf16 + f.grads_bf16;
        let peak = peak_bwd.max(peak_step);
        let total = f.total();
        assert!(peak < total, "windows must actually overlap-free some bytes");
        // A DRAM budget strictly between the peak and the static sum.
        let cap = peak + (total - peak) / 2;
        let topo = with_dram_capacity(config_a(), cap);
        let cfg = RunConfig::new(model, w, Policy::DramOnly);
        assert!(
            !MemoryPlan::fits(&topo, &cfg),
            "static accounting must reject the cell"
        );
        assert!(
            MemoryPlan::fits_lifetime_aware(&topo, &cfg),
            "per-phase peak accounting must fit it"
        );
        // And the lifetime plan's committed windows are the profiled ones.
        let plan = MemoryPlan::build_lifetime_aware(&topo, &cfg).unwrap();
        assert_eq!(plan.alloc.n_phases(), 3);
        let acts = plan.alloc.region(plan.activations[0]).unwrap();
        assert_eq!(acts.lifetime, Some(Lifetime::spanning(0, 1)));
        let opt = plan.alloc.region(plan.optstates).unwrap();
        assert_eq!(opt.lifetime, Some(Lifetime::spanning(2, 2)));
    }

    #[test]
    fn lifetime_build_matches_static_placements_on_ample_capacity() {
        // With no capacity pressure the timeline never changes a placement
        // decision — only the accounting differs.
        let topo = config_b();
        let cfg = RunConfig::new(
            qwen25_7b(),
            Workload::new(2, 8, 4096),
            Policy::CxlAware { striping: true },
        );
        let a = MemoryPlan::build(&topo, &cfg).unwrap();
        let b = MemoryPlan::build_lifetime_aware(&topo, &cfg).unwrap();
        let pa: Vec<_> = a.alloc.regions().map(|r| (r.name.clone(), r.placement.clone())).collect();
        let pb: Vec<_> = b.alloc.regions().map(|r| (r.name.clone(), r.placement.clone())).collect();
        assert_eq!(pa, pb);
    }

    #[test]
    fn profile_aware_plan_pins_hot_in_dram_and_strides_cold_on_cxl() {
        // 12B under the §V-B DRAM budget: the fp32 working set overflows
        // 128 GiB, so profile-aware pins what fits and spills the rest to
        // CXL partitioned; every DMA-only region stays off DRAM entirely.
        let topo = with_dram_capacity(config_a(), 128 * GIB);
        let cfg = RunConfig::new(
            mistral_nemo_12b(),
            Workload::new(1, 16, 4096),
            ProfileAware,
        );
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        assert!(plan.profiles.is_some(), "profile engine must trigger the pass");
        let master = plan.alloc.region(plan.master).unwrap();
        assert_eq!(
            master.placement.parts,
            vec![(NodeId(0), plan.footprint.params_fp32)],
            "hottest region fills DRAM first"
        );
        let opt = plan.alloc.region(plan.optstates).unwrap();
        assert!(opt.placement.touches(NodeId(1)), "overflow spills to the AIC");
        for id in [plan.params16, plan.grads16, plan.activations[0]] {
            let r = plan.alloc.region(id).unwrap();
            assert!(
                !r.placement.touches(NodeId(0)),
                "{}: DMA-bound data must stay off DRAM",
                r.name
            );
        }
    }

    #[test]
    fn build_with_profiles_matches_the_self_profiling_paths() {
        // Handing the builder a cached profile set must reproduce both the
        // static and the lifetime-aware plans byte-for-byte — the contract
        // the fleet admission path (hundreds of cached-profile builds per
        // sim) rests on.
        let topo = with_dram_capacity(config_a(), 128 * GIB);
        let cfg = RunConfig::new(
            qwen25_7b(),
            Workload::new(1, 8, 4096),
            Policy::CxlAware { striping: true },
        );
        let cached = MemoryPlan::profile_run(&topo, &cfg).unwrap();
        let snapshot = |p: &MemoryPlan<'_>| {
            p.alloc
                .regions()
                .map(|r| (r.name.clone(), r.placement.clone(), r.lifetime))
                .collect::<Vec<_>>()
        };
        for lifetime in [false, true] {
            let direct = if lifetime {
                MemoryPlan::build_lifetime_aware(&topo, &cfg).unwrap()
            } else {
                MemoryPlan::build(&topo, &cfg).unwrap()
            };
            let via_cache =
                MemoryPlan::build_with_profiles(&topo, &cfg, lifetime, cached.clone()).unwrap();
            assert_eq!(snapshot(&direct), snapshot(&via_cache), "lifetime={lifetime}");
            assert_eq!(direct.reservation(), via_cache.reservation());
        }
    }

    #[test]
    fn reservation_sums_static_and_peaks_lifetime() {
        let topo = with_dram_capacity(config_a(), 128 * GIB);
        let cfg = RunConfig::new(
            qwen25_7b(),
            Workload::new(1, 8, 4096),
            Policy::CxlAware { striping: true },
        );
        // Static: the reservation is exactly the per-node placement sums.
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let res = plan.reservation();
        for n in topo.all_nodes() {
            let sum: u64 = plan
                .alloc
                .regions()
                .map(|r| r.placement.bytes_on(n))
                .sum();
            assert_eq!(res.bytes_on(n), sum, "node {}", n.0);
        }
        assert_eq!(res.total_bytes(), plan.footprint.total());
        // Lifetime-aware on a single node: the reservation is the phase
        // peak, strictly below the static sum (activations die before the
        // step, the fp32 set is dead until it).
        let ample = config_a();
        let dcfg = RunConfig::new(qwen25_7b(), Workload::new(1, 8, 4096), Policy::DramOnly);
        let dstatic = MemoryPlan::build(&ample, &dcfg).unwrap().reservation();
        let life = MemoryPlan::build_lifetime_aware(&ample, &dcfg).unwrap();
        let lres = life.reservation();
        assert!(
            lres.total_bytes() < dstatic.total_bytes(),
            "per-phase peak {} must undercut static sum {}",
            lres.total_bytes(),
            dstatic.total_bytes()
        );
        // Ascending node order, no zero shards.
        for w in lres.parts.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
        assert!(lres.parts.iter().all(|(_, b)| *b > 0));
    }

    #[test]
    fn profile_aware_not_slower_than_naive_on_fig7_cells() {
        // Acceptance gate: on the Fig. 7 grid the profile-aware engine
        // never loses to naive interleave.
        let cxl_topo = with_dram_capacity(config_a(), 128 * GIB);
        let naive = engine::by_name("naive-cxl").unwrap();
        let ours = engine::by_name("profile-aware").unwrap();
        for (gpus, batch) in [(1usize, 16usize), (2, 1)] {
            let w = Workload::new(gpus, batch, 4096);
            let run = |e: &crate::mem::EngineRef| {
                let cfg = RunConfig::new(mistral_nemo_12b(), w, e.clone());
                let plan = MemoryPlan::build(&cxl_topo, &cfg).unwrap();
                simulate_iteration(&cxl_topo, &cfg, &plan).tokens_per_sec()
            };
            let tn = run(&naive);
            let tp = run(&ours);
            assert!(
                tp >= tn * (1.0 - 1e-9),
                "fig7 {gpus}x{batch}: profile-aware {tp:.1} tok/s lost to naive {tn:.1}"
            );
        }
    }
}
